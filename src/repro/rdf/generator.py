"""Synthetic RDF dataset generators (LUBM-style + stress ontologies).

The paper evaluates on LUBM1K/LUBM10K (133M / 1.3B triples) plus DBPedia and
Wikidata dumps.  We reproduce the *generator* side: a vectorized LUBM-like
ABox builder whose per-university triple count (~130K) and type/property/
literal mix match the benchmark, and random deep/wide ontology generators
that stand in for DBPedia (depth > 6 branches) and Wikidata (>200K concepts,
deep enough to need wide ids).

Terms are produced directly as structural 63-bit fingerprints (mix64 of
small int tuples) so that building millions of triples never materializes
millions of Python strings; renderable IRI strings are kept optionally
(``keep_strings``) for locate/extract round-trip tests — the same
driver/executor split as the paper's Spark pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tbox import RDF_TYPE, Ontology
from repro.rdf.vocab import lubm_ontology
from repro.utils.hashing import fingerprint_string, mix64

# entity kinds (structural fingerprint name-spaces)
(K_UNIV, K_DEPT, K_RG, K_FP, K_AP, K_ASP, K_LECT, K_UG, K_GR, K_CRS, K_GCRS,
 K_PUB, K_RES) = range(1, 14)
K_LIT = 20  # literal namespace: mix64(K_LIT, field, owner_fp)

FACULTY_CONCEPT = {
    K_FP: "FullProfessor",
    K_AP: "AssociateProfessor",
    K_ASP: "AssistantProfessor",
    K_LECT: "Lecturer",
}
_KIND_LABEL = {
    K_UNIV: "University", K_DEPT: "Department", K_RG: "ResearchGroup",
    K_FP: "FullProfessor", K_AP: "AssociateProfessor",
    K_ASP: "AssistantProfessor", K_LECT: "Lecturer",
    K_UG: "UndergraduateStudent", K_GR: "GraduateStudent",
    K_CRS: "Course", K_GCRS: "GraduateCourse", K_PUB: "Publication",
    K_RES: "Research",
}
_LIT_FIELDS = {1: "emailAddress", 2: "name", 3: "telephone", 4: "researchInterest"}


@dataclass
class RawDataset:
    """Unencoded triples as parallel fingerprint columns (the 'string' KB)."""

    s: np.ndarray  # int64[N]
    p: np.ndarray  # int64[N]
    o: np.ndarray  # int64[N]
    onto: Ontology
    term_strings: dict | None = None  # fp -> IRI/literal string
    meta: dict | None = None

    @property
    def n_triples(self) -> int:
        return int(self.s.shape[0])

    def triples(self) -> np.ndarray:
        return np.stack([self.s, self.p, self.o], axis=1)


class _TripleSink:
    def __init__(self):
        self.s, self.p, self.o = [], [], []

    def add(self, s, p, o):
        s, p, o = np.broadcast_arrays(
            np.asarray(s, dtype=np.int64),
            np.asarray(p, dtype=np.int64),
            np.asarray(o, dtype=np.int64),
        )
        self.s.append(s.ravel())
        self.p.append(p.ravel())
        self.o.append(o.ravel())

    def arrays(self):
        return (
            np.concatenate(self.s) if self.s else np.zeros(0, np.int64),
            np.concatenate(self.p) if self.p else np.zeros(0, np.int64),
            np.concatenate(self.o) if self.o else np.zeros(0, np.int64),
        )


def _ent(kind, u, d, i):
    return mix64(np.int64(kind), np.int64(u), np.int64(d), np.int64(i))


def _lit(field, owner_fp):
    return mix64(np.int64(K_LIT), np.int64(field), np.asarray(owner_fp, dtype=np.int64))


def generate_lubm(
    n_universities: int = 1,
    seed: int = 0,
    keep_strings: bool = False,
    literals: bool = True,
    univ_offset: int = 0,
) -> RawDataset:
    """LUBM-like ABox: ~130K triples per university (cf. paper Table III).

    ``univ_offset`` shifts the university index space: universities are
    numbered ``[univ_offset, univ_offset + n_universities)``, so a dataset
    generated at a disjoint offset is a pure-growth *delta* over a base KB
    (every entity term is new) — the shape incremental-update benchmarks
    and tests feed to ``KnowledgeBase.insert``.
    """
    onto = lubm_ontology()
    rng = np.random.default_rng(seed)
    sink = _TripleSink()

    cfp = {c: fingerprint_string(c) for c in onto.concepts}
    pfp = {p: fingerprint_string(p) for p in onto.properties + [RDF_TYPE]}
    TYPE = pfp[RDF_TYPE]

    univs = _ent(K_UNIV, univ_offset + np.arange(n_universities), 0, 0)
    sink.add(univs, TYPE, cfp["University"])

    for u in range(univ_offset, univ_offset + n_universities):
        n_dept = int(rng.integers(15, 26))
        for d in range(n_dept):
            dept = _ent(K_DEPT, u, d, 0)
            sink.add(dept, TYPE, cfp["Department"])
            sink.add(dept, pfp["subOrganizationOf"], univs[u - univ_offset])

            n_rg = int(rng.integers(10, 21))
            rgs = _ent(K_RG, u, d, np.arange(n_rg))
            sink.add(rgs, TYPE, cfp["ResearchGroup"])
            sink.add(rgs, pfp["subOrganizationOf"], dept)
            res = _ent(K_RES, u, d, np.arange(n_rg))
            sink.add(res, TYPE, cfp["Research"])
            sink.add(rgs, pfp["researchProject"], res)

            # ---- faculty -------------------------------------------------
            fac_kind_counts = {
                K_FP: int(rng.integers(7, 11)),
                K_AP: int(rng.integers(10, 15)),
                K_ASP: int(rng.integers(8, 12)),
                K_LECT: int(rng.integers(5, 8)),
            }
            fac_fps, prof_fps = [], []
            for kind, cnt in fac_kind_counts.items():
                f = _ent(kind, u, d, np.arange(cnt))
                fac_fps.append(f)
                if kind in (K_FP, K_AP, K_ASP):
                    prof_fps.append(f)
                sink.add(f, TYPE, cfp[FACULTY_CONCEPT[kind]])
            faculty = np.concatenate(fac_fps)
            professors = np.concatenate(prof_fps)
            nf = faculty.shape[0]
            sink.add(faculty, pfp["worksFor"], dept)
            # the chair heads the department — NO explicit Chair type: the
            # paper's Q4 relies on it being derivable from domain(headOf).
            sink.add(faculty[:1], pfp["headOf"], dept)
            for prop in ("undergraduateDegreeFrom", "mastersDegreeFrom", "doctoralDegreeFrom"):
                sink.add(faculty, pfp[prop], univs[rng.integers(0, n_universities, nf)])

            # ---- courses -------------------------------------------------
            n_crs = nf * 2
            n_gcrs = max(nf, 1)
            courses = _ent(K_CRS, u, d, np.arange(n_crs))
            gcourses = _ent(K_GCRS, u, d, np.arange(n_gcrs))
            sink.add(courses, TYPE, cfp["Course"])
            sink.add(gcourses, TYPE, cfp["GraduateCourse"])
            sink.add(faculty, pfp["teacherOf"], courses[rng.permutation(n_crs)[:nf]])
            sink.add(faculty, pfp["teacherOf"], gcourses[rng.integers(0, n_gcrs, nf)])

            # ---- publications --------------------------------------------
            pubs_per = rng.integers(5, 16, nf)
            n_pub = int(pubs_per.sum())
            pubs = _ent(K_PUB, u, d, np.arange(n_pub))
            pub_cls = rng.choice(
                [cfp["JournalArticle"], cfp["ConferencePaper"], cfp["TechnicalReport"], cfp["Book"]],
                size=n_pub,
            )
            sink.add(pubs, TYPE, pub_cls)
            sink.add(pubs, pfp["publicationAuthor"], np.repeat(faculty, pubs_per))

            # ---- students ------------------------------------------------
            n_ug = nf * int(rng.integers(8, 15))
            n_gr = nf * int(rng.integers(3, 5))
            ug = _ent(K_UG, u, d, np.arange(n_ug))
            gr = _ent(K_GR, u, d, np.arange(n_gr))
            sink.add(ug, TYPE, cfp["UndergraduateStudent"])
            sink.add(gr, TYPE, cfp["GraduateStudent"])
            sink.add(ug, pfp["memberOf"], dept)
            sink.add(gr, pfp["memberOf"], dept)
            # course loads
            for _ in range(3):
                sink.add(ug, pfp["takesCourse"], courses[rng.integers(0, n_crs, n_ug)])
            for _ in range(2):
                sink.add(gr, pfp["takesCourse"], gcourses[rng.integers(0, n_gcrs, n_gr)])
            # advisors: all grads, 1/5 of undergrads
            sink.add(gr, pfp["advisor"], professors[rng.integers(0, professors.shape[0], n_gr)])
            ug_adv = ug[rng.random(n_ug) < 0.2]
            sink.add(ug_adv, pfp["advisor"], professors[rng.integers(0, professors.shape[0], ug_adv.shape[0])])
            sink.add(gr, pfp["undergraduateDegreeFrom"], univs[rng.integers(0, n_universities, n_gr)])
            # 1/5 of grads TA a course (type TeachingAssistant is *derived*)
            tas = gr[rng.random(n_gr) < 0.2]
            sink.add(tas, pfp["teachingAssistantOf"], courses[rng.integers(0, n_crs, tas.shape[0])])

            # ---- literals ------------------------------------------------
            if literals:
                people = np.concatenate([faculty, ug, gr])
                for field, prop in ((1, "emailAddress"), (2, "name"), (3, "telephone")):
                    sink.add(people, pfp[prop], _lit(field, people))
                sink.add(faculty, pfp["researchInterest"], _lit(4, faculty))

    s, p, o = sink.arrays()
    term_strings = (
        _build_strings(onto, s, p, o, n_universities, univ_offset)
        if keep_strings else None)
    return RawDataset(
        s=s, p=p, o=o, onto=onto, term_strings=term_strings,
        meta=dict(kind="lubm", n_universities=n_universities, seed=seed,
                  univ_offset=univ_offset),
    )


def _build_strings(onto, s, p, o, n_univ, univ_offset: int = 0) -> dict:
    """fp -> string map (only for keep_strings scales)."""
    out = {}
    for c in onto.concepts:
        out[fingerprint_string(c)] = f"ub:{c}"
    for pr in onto.properties + [RDF_TYPE]:
        out[fingerprint_string(pr)] = f"ub:{pr}"
    # regenerate structural names by brute-force enumeration of the id space
    # actually observed in the dataset
    seen = set(np.concatenate([s, p, o]).tolist())
    for kind, label in _KIND_LABEL.items():
        for u in range(univ_offset, univ_offset + n_univ):
            for d in range(64):
                fps = _ent(kind, u, d, np.arange(4096))
                hit = [i for i, f in enumerate(fps.tolist()) if f in seen]
                for i in hit:
                    out[int(fps[i])] = (
                        f"http://www.Department{d}.University{u}.edu/{label}{i}"
                        if kind not in (K_UNIV,)
                        else f"http://www.University{u}.edu"
                    )
                if not hit and d > 0:
                    break
    # literals
    for field, prop in _LIT_FIELDS.items():
        owners = np.array([f for f in seen], dtype=np.int64)
        lits = _lit(field, owners)
        for owner, lf in zip(owners.tolist(), lits.tolist()):
            if lf in seen:
                out[lf] = f'"{prop}_of_{owner & 0xffff:x}"'
    return out


# ---------------------------------------------------------------------------
# Stress ontologies (DBPedia-like depth, Wikidata-like width)
# ---------------------------------------------------------------------------


def generate_deep_ontology(
    n_concepts: int = 800,
    n_properties: int = 60,
    max_children: int = 9,
    depth_bias: float = 0.6,
    n_subprop: int = 25,
    n_domain: int = 30,
    n_range: int = 28,
    seed: int = 0,
    max_depth: int | None = None,
) -> Ontology:
    """Random ontology with deep branches (DBPedia/Wikidata stand-in).

    ``depth_bias`` > 0.5 prefers attaching to recently created (deep)
    concepts, producing branches of depth > 6 like DBPedia's (the regime
    where the paper's full-materialization baseline blows up by 13–58%).
    """
    rng = np.random.default_rng(seed)
    concepts = [f"C{i}" for i in range(n_concepts)]
    child_count = np.zeros(n_concepts, dtype=np.int64)
    depth = np.zeros(n_concepts, dtype=np.int64)
    subclass = []
    for i in range(1, n_concepts):
        for _ in range(64):
            if rng.random() < depth_bias:
                lo = max(0, i - max(1, i // 4))
                parent = int(rng.integers(lo, i))
            else:
                parent = int(rng.integers(0, i))
            ok_depth = max_depth is None or depth[parent] + 1 < max_depth
            if child_count[parent] < max_children and ok_depth:
                break
        else:
            parent = 0
        child_count[parent] += 1
        depth[i] = depth[parent] + 1
        subclass.append((concepts[i], concepts[parent]))

    props = [f"p{i}" for i in range(n_properties)]
    subprop = []
    for i in range(1, min(n_subprop + 1, n_properties)):
        subprop.append((props[i], props[int(rng.integers(0, i))]))
    domain = {
        props[int(i)]: [concepts[int(rng.integers(0, n_concepts))]]
        for i in rng.permutation(n_properties)[:n_domain]
    }
    range_ = {
        props[int(i)]: [concepts[int(rng.integers(0, n_concepts))]]
        for i in rng.permutation(n_properties)[:n_range]
    }
    return Ontology(
        concepts=concepts, properties=props, subclass=subclass,
        subprop=subprop, domain=domain, range_=range_,
    )


def generate_random_abox(
    onto: Ontology,
    n_instances: int = 10_000,
    n_type_triples: int = 8_000,
    n_prop_triples: int = 30_000,
    seed: int = 0,
    instance_offset: int = 0,
) -> RawDataset:
    """Uniform random ABox over an arbitrary ontology (property tests).

    ``instance_offset`` shifts the instance fingerprint space (the random
    analogue of ``generate_lubm``'s ``univ_offset``): a dataset generated at
    a disjoint offset is a pure-growth delta over a base KB — every
    instance term is new, so update benchmarks/tests can pin O(delta)
    behavior without the delta aliasing base instances.
    """
    rng = np.random.default_rng(seed)
    cfps = np.array([fingerprint_string(c) for c in onto.concepts], dtype=np.int64)
    pfps = np.array([fingerprint_string(p) for p in onto.properties], dtype=np.int64)
    TYPE = fingerprint_string(RDF_TYPE)
    inst = mix64(np.int64(99), np.arange(n_instances) + instance_offset, 0, 0)

    sink = _TripleSink()
    sink.add(
        inst[rng.integers(0, n_instances, n_type_triples)],
        TYPE,
        cfps[rng.integers(0, len(cfps), n_type_triples)],
    )
    sink.add(
        inst[rng.integers(0, n_instances, n_prop_triples)],
        pfps[rng.integers(0, len(pfps), n_prop_triples)],
        inst[rng.integers(0, n_instances, n_prop_triples)],
    )
    s, p, o = sink.arrays()
    return RawDataset(s=s, p=p, o=o, onto=onto, meta=dict(kind="random", seed=seed))
