"""The LUBM-style university ontology used throughout the evaluation.

Mirrors the Lehigh University Benchmark ontology [Guo, Pan, Heflin 2005] at
the RDFS level: 43 concepts, 32 properties, 21 domain and 18 range axioms —
the same shape as the paper's Table II row for LUBM.  OWL restrictions that
RDFS cannot express are approximated the way the paper's experiments imply:
e.g. LUBM defines Chair as "Person ⊓ ∃headOf.Department"; we set
``domain(headOf) = Chair`` so that lite materialization derives Chair types
from headOf assertions (which is why, like the paper notes for their Q4, the
raw dataset contains no explicit Chair triples).
"""
from __future__ import annotations

from repro.core.tbox import Ontology

CONCEPTS = [
    # organizations
    "University", "College", "Department", "Institute", "Program",
    "ResearchGroup", "Organization",
    # works & publications
    "Work", "Course", "GraduateCourse", "Research", "Publication", "Article",
    "Book", "ConferencePaper", "JournalArticle", "Manual", "Software",
    "Specification", "TechnicalReport", "UnofficialPublication",
    # people
    "Person", "Employee", "AdministrativeStaff", "ClericalStaff",
    "SystemsStaff", "Faculty", "Lecturer", "PostDoc", "Professor",
    "AssistantProfessor", "AssociateProfessor", "Chair", "Dean",
    "FullProfessor", "VisitingProfessor", "Director", "Student",
    "GraduateStudent", "UndergraduateStudent", "ResearchAssistant",
    "TeachingAssistant",
    # misc
    "Schedule",
]

SUBCLASS = [
    ("University", "Organization"), ("College", "Organization"),
    ("Department", "Organization"), ("Institute", "Organization"),
    ("Program", "Organization"), ("ResearchGroup", "Organization"),
    ("Course", "Work"), ("GraduateCourse", "Course"), ("Research", "Work"),
    ("Article", "Publication"), ("Book", "Publication"),
    ("ConferencePaper", "Article"), ("JournalArticle", "Article"),
    ("TechnicalReport", "Article"), ("Manual", "Publication"),
    ("Software", "Publication"), ("Specification", "Publication"),
    ("UnofficialPublication", "Publication"),
    ("Employee", "Person"), ("AdministrativeStaff", "Employee"),
    ("ClericalStaff", "AdministrativeStaff"),
    ("SystemsStaff", "AdministrativeStaff"), ("Faculty", "Employee"),
    ("Lecturer", "Faculty"), ("PostDoc", "Faculty"),
    ("Professor", "Faculty"), ("AssistantProfessor", "Professor"),
    ("AssociateProfessor", "Professor"), ("Chair", "Professor"),
    ("Dean", "Professor"), ("FullProfessor", "Professor"),
    ("VisitingProfessor", "Professor"), ("Director", "Person"),
    ("Student", "Person"), ("GraduateStudent", "Student"),
    ("UndergraduateStudent", "Student"), ("ResearchAssistant", "Person"),
    ("TeachingAssistant", "Person"),
]

OBJECT_PROPERTIES = [
    "advisor", "affiliatedOrganizationOf", "affiliateOf", "degreeFrom",
    "doctoralDegreeFrom", "mastersDegreeFrom", "undergraduateDegreeFrom",
    "headOf", "worksFor", "memberOf", "member", "orgPublication",
    "publicationAuthor", "publicationResearch", "researchProject",
    "softwareDocumentation", "subOrganizationOf", "takesCourse",
    "teacherOf", "teachingAssistantOf", "hasAlumnus", "listedCourse",
    "publicationDate", "softwareVersion", "tenured",
]
DATATYPE_PROPERTIES = [
    "age", "emailAddress", "name", "officeNumber", "researchInterest",
    "telephone", "title",
]
PROPERTIES = OBJECT_PROPERTIES + DATATYPE_PROPERTIES

SUBPROP = [
    ("doctoralDegreeFrom", "degreeFrom"),
    ("mastersDegreeFrom", "degreeFrom"),
    ("undergraduateDegreeFrom", "degreeFrom"),
    ("headOf", "worksFor"),
    ("worksFor", "memberOf"),
]

DOMAIN = {  # 21 domain axioms
    "advisor": ["Person"],
    "degreeFrom": ["Person"],
    "doctoralDegreeFrom": ["Person"],
    "mastersDegreeFrom": ["Person"],
    "undergraduateDegreeFrom": ["Person"],
    "headOf": ["Chair"],  # RDFS reading of LUBM's Chair restriction
    "worksFor": ["Employee"],
    "memberOf": ["Person"],
    "member": ["Organization"],
    "orgPublication": ["Organization"],
    "publicationAuthor": ["Publication"],
    "publicationResearch": ["Publication"],
    "researchProject": ["ResearchGroup"],
    "softwareDocumentation": ["Software"],
    "subOrganizationOf": ["Organization"],
    "takesCourse": ["Student"],
    "teacherOf": ["Faculty"],
    "teachingAssistantOf": ["TeachingAssistant"],
    "hasAlumnus": ["University"],
    "tenured": ["Professor"],
    "emailAddress": ["Person"],
}

RANGE = {  # 18 range axioms
    "advisor": ["Professor"],
    "degreeFrom": ["University"],
    "doctoralDegreeFrom": ["University"],
    "mastersDegreeFrom": ["University"],
    "undergraduateDegreeFrom": ["University"],
    "headOf": ["Department"],
    "worksFor": ["Organization"],
    "memberOf": ["Organization"],
    "member": ["Person"],
    "orgPublication": ["Publication"],
    "publicationAuthor": ["Person"],
    "publicationResearch": ["Research"],
    "researchProject": ["Research"],
    "softwareDocumentation": ["Publication"],
    "subOrganizationOf": ["Organization"],
    "takesCourse": ["Course"],
    "teacherOf": ["Course"],
    "teachingAssistantOf": ["Course"],
}


def lubm_ontology() -> Ontology:
    return Ontology(
        concepts=list(CONCEPTS),
        properties=list(PROPERTIES),
        subclass=list(SUBCLASS),
        subprop=list(SUBPROP),
        domain={k: list(v) for k, v in DOMAIN.items()},
        range_={k: list(v) for k, v in RANGE.items()},
    )
