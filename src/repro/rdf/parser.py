"""Minimal N-Triples reader/writer (host-side string world).

Covers the N-Triples subset needed to ingest real dumps: IRIs, blank nodes,
plain/typed/lang-tagged literals, comments.  Ontology axioms
(rdfs:subClassOf / subPropertyOf / domain / range) found in the stream are
split out into an ``Ontology`` — the TBox/ABox separation the paper performs
before encoding.
"""
from __future__ import annotations

import re

import numpy as np

from repro.core.tbox import RDF_TYPE, Ontology
from repro.rdf.generator import RawDataset
from repro.utils.hashing import fingerprint_string

RDF_TYPE_IRI = "http://www.w3.org/1999/02/22-rdf-syntax-ns#type"
RDFS = "http://www.w3.org/2000/01/rdf-schema#"
SUBCLASS_IRI = RDFS + "subClassOf"
SUBPROP_IRI = RDFS + "subPropertyOf"
DOMAIN_IRI = RDFS + "domain"
RANGE_IRI = RDFS + "range"

_TERM = re.compile(
    r"""\s*(?:
        <(?P<iri>[^>]*)> |
        (?P<bnode>_:[A-Za-z0-9]+) |
        (?P<lit>"(?:[^"\\]|\\.)*"(?:\^\^<[^>]*>|@[A-Za-z0-9\-]+)?)
    )""",
    re.X,
)


def _parse_line(line: str):
    terms = []
    pos = 0
    for _ in range(3):
        m = _TERM.match(line, pos)
        if not m:
            return None
        terms.append(m.group("iri") or m.group("bnode") or m.group("lit"))
        if m.group("iri") is not None:
            terms[-1] = "<" + terms[-1] + ">"
        pos = m.end()
    if line[pos:].strip() != ".":
        return None
    return tuple(terms)


def parse_ntriples(text: str, extract_ontology: bool = True):
    """Parse N-Triples text -> (RawDataset, Ontology).

    Schema triples (subClassOf/subPropertyOf/domain/range) go to the
    Ontology; everything else becomes fingerprinted ABox columns.
    """
    subclass, subprop = [], []
    domain, range_ = {}, {}
    concepts, properties = set(), set()
    s_col, p_col, o_col = [], [], []
    strings: dict = {}

    def fp(term: str) -> int:
        f = fingerprint_string(term)
        strings[f] = term
        return f

    # rdf:type is normalized to its canonical alias so the TBox term map
    # (which registers "rdf:type") always resolves it
    type_fp = fp(RDF_TYPE)
    strings[fingerprint_string("<" + RDF_TYPE_IRI + ">")] = RDF_TYPE

    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parsed = _parse_line(line)
        if parsed is None:
            raise ValueError(f"unparsable N-Triples line: {raw!r}")
        s, p, o = parsed
        bare_p = p.strip("<>")
        if extract_ontology and bare_p in (SUBCLASS_IRI, SUBPROP_IRI, DOMAIN_IRI, RANGE_IRI):
            if bare_p == SUBCLASS_IRI:
                subclass.append((s, o))
                concepts.update((s, o))
            elif bare_p == SUBPROP_IRI:
                subprop.append((s, o))
                properties.update((s, o))
            elif bare_p == DOMAIN_IRI:
                domain.setdefault(s, []).append(o)
                properties.add(s)
                concepts.add(o)
            else:
                range_.setdefault(s, []).append(o)
                properties.add(s)
                concepts.add(o)
            continue
        pf = type_fp if bare_p == RDF_TYPE_IRI else fp(p)
        s_col.append(fp(s))
        p_col.append(pf)
        o_col.append(fp(o))
        if bare_p == RDF_TYPE_IRI:
            concepts.add(o)
        else:
            properties.add(p)

    onto = Ontology(
        concepts=sorted(concepts),
        properties=sorted(properties),
        subclass=subclass,
        subprop=subprop,
        domain=domain,
        range_=range_,
    )
    ds = RawDataset(
        s=np.array(s_col, dtype=np.int64),
        p=np.array(p_col, dtype=np.int64),
        o=np.array(o_col, dtype=np.int64),
        onto=onto,
        term_strings=strings,
        meta=dict(kind="ntriples"),
    )
    return ds, onto


def write_ntriples(ds: RawDataset) -> str:
    """RawDataset (with term_strings) -> N-Triples text."""
    if ds.term_strings is None:
        raise ValueError("dataset has no term strings to render")
    ts = ds.term_strings
    type_fp = fingerprint_string(RDF_TYPE)

    def render(f: int) -> str:
        if int(f) == type_fp:
            return "<" + RDF_TYPE_IRI + ">"
        t = ts.get(int(f), f"<urn:fp:{int(f):x}>")
        if t.startswith(("<", '"', "_:")):
            return t
        return f"<urn:repro:{t}>"

    lines = []
    for s, p, o in zip(ds.s.tolist(), ds.p.tolist(), ds.o.tolist()):
        lines.append(f"{render(s)} {render(p)} {render(o)} .")
    return "\n".join(lines) + "\n"
