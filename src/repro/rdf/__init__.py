from repro.rdf.vocab import lubm_ontology
from repro.rdf.generator import generate_lubm, generate_deep_ontology, RawDataset
