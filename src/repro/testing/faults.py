"""Deterministic fault-injection harness for the concurrent read/write path.

The serving runtime's robustness claims — a mid-flush crash never corrupts a
published snapshot, a slow shard turns into a deadline miss instead of a
hang, a pinned reader survives insert/delete/compact, snapshot retirement
never races a pin — are only testable if the failures themselves are
*reproducible*.  This module supplies hook-driven injection with no wall
clock and no randomness in the trigger logic:

  * Production code marks **sites** with ``faults.fire("site.name", **ctx)``.
    With no injector installed this is one global read and an ``is None``
    branch — free to ship in hot paths.
  * Tests install a :class:`FaultInjector` (via the :func:`inject` context
    manager) and **arm** faults against sites: raise an exception class,
    sleep a fixed delay, or both, starting at the Nth hit and firing a
    bounded number of times.  Trigger decisions depend only on per-site hit
    counters, so a failing schedule replays exactly.
  * Every hit and every firing is recorded (site, hit index, context) so
    tests can assert the fault actually happened — a matrix leg that
    silently stopped injecting is itself a test failure.

Instrumented sites (grep for ``faults.fire``):

  ``engine.flush_mat``        per derived batch inside KnowledgeBase._flush_mat
  ``shard.flush_mat``         per derived batch inside ShardedKB._flush
  ``shard.shard_map``         before a stacked shard_map group executes
  ``shard.query_shard``       per shard inside the dispatch loop (slow shard)
  ``shard.ingest_encode``     per part inside ShardedKB.ingest's encode step
  ``snapshot.publish``        inside SnapshotRegistry publish (holding locks)
  ``snapshot.retire``         between victim selection and removal (race window)
  ``serving.execute``         per attempt inside the runtime worker

:class:`FaultError` is the *transient* marker: retry loops (serving runtime,
ingest) treat it as recoverable; anything else propagates.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


class FaultError(RuntimeError):
    """Injected transient failure — retryable by design."""


class FaultCrash(RuntimeError):
    """Injected hard failure — NOT retryable; models a crashed writer."""


@dataclass
class Fault:
    """One armed failure: fires on hits ``after < hit_index <= after+times``."""

    site: str
    exc: type | None = None  # exception class to raise (None: delay only)
    delay_s: float = 0.0  # sleep before (possibly) raising — "slow shard"
    after: int = 0  # skip this many hits before the first firing
    times: int = 1  # how many consecutive hits fire (<=0: every hit)
    message: str = ""
    fired: int = 0

    def should_fire(self, hit_index: int) -> bool:
        if hit_index <= self.after:
            return False
        return self.times <= 0 or hit_index <= self.after + self.times


@dataclass
class FaultInjector:
    """Armed fault set + per-site hit accounting (thread-safe)."""

    faults: dict = field(default_factory=dict)  # site -> list[Fault]
    hits: dict = field(default_factory=dict)  # site -> total hit count
    log: list = field(default_factory=list)  # (site, hit, kind, ctx) tuples
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def arm(self, site: str, exc: type | None = FaultError,
            delay_s: float = 0.0, after: int = 0, times: int = 1,
            message: str = "") -> Fault:
        f = Fault(site=site, exc=exc, delay_s=delay_s, after=after,
                  times=times, message=message or f"injected fault at {site}")
        with self._lock:
            self.faults.setdefault(site, []).append(f)
        return f

    def fire(self, site: str, **ctx) -> None:
        """Record a hit at ``site``; sleep/raise if an armed fault matches."""
        with self._lock:
            hit = self.hits.get(site, 0) + 1
            self.hits[site] = hit
            armed = [f for f in self.faults.get(site, ())
                     if f.should_fire(hit)]
            for f in armed:
                f.fired += 1
            self.log.append((site, hit, "fired" if armed else "hit", ctx))
        for f in armed:  # sleep/raise OUTSIDE the lock: sites overlap
            if f.delay_s:
                time.sleep(f.delay_s)
            if f.exc is not None:
                raise f.exc(f"{f.message} (site={site} hit={hit} ctx={ctx})")

    def fired(self, site: str) -> int:
        with self._lock:
            return sum(f.fired for f in self.faults.get(site, ()))

    def hit_count(self, site: str) -> int:
        with self._lock:
            return self.hits.get(site, 0)


_ACTIVE: FaultInjector | None = None
_INSTALL_LOCK = threading.Lock()


def fire(site: str, **ctx) -> None:
    """Production-side hook: no-op unless a test installed an injector."""
    inj = _ACTIVE
    if inj is not None:
        inj.fire(site, **ctx)


def install(injector: FaultInjector | None = None) -> FaultInjector:
    global _ACTIVE
    with _INSTALL_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultInjector is already installed")
        _ACTIVE = injector or FaultInjector()
        return _ACTIVE


def uninstall() -> None:
    global _ACTIVE
    with _INSTALL_LOCK:
        _ACTIVE = None


class inject:
    """``with faults.inject() as inj: inj.arm(...)`` — scoped installation."""

    def __init__(self, injector: FaultInjector | None = None):
        self._injector = injector

    def __enter__(self) -> FaultInjector:
        self._injector = install(self._injector)
        return self._injector

    def __exit__(self, *exc) -> None:
        uninstall()


__all__ = ["Fault", "FaultInjector", "FaultError", "FaultCrash", "fire",
           "install", "uninstall", "inject"]
