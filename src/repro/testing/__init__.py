"""Test-support runtime pieces importable from production code paths.

The only module here with production call sites is :mod:`repro.testing.faults`
— the deterministic fault-injection harness.  Its instrumented sites compile
down to one global read + one ``is None`` branch when no injector is armed,
so shipping them inside the serving/update paths costs nothing.
"""
