"""Fault-tolerant training loop: checkpoint/restart, preemption, metrics.

The loop is deliberately boring — that is the point of the fault-tolerance
contract:

  * state = (params, opt_state, step); data is a pure function of step
    (data/tokens.py), so restore(step) resumes bit-exactly;
  * SIGTERM/SIGINT set a preemption flag -> synchronous checkpoint -> clean
    exit (tested by killing and resuming a live run);
  * checkpoints every ``ckpt_every`` steps via the atomic CheckpointManager;
  * a step-time watchdog logs straggling steps (> ``straggler_factor`` x
    median) — on real fleets this feeds the reschedule signal.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import numpy as np

import jax

from repro.distributed.checkpoint import CheckpointManager


@dataclass
class TrainLoop:
    step_fn: object  # (params, opt_state, batch) -> (params, opt_state, metrics)
    batch_at: object  # step -> batch dict
    ckpt: CheckpointManager
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    log_every: int = 10
    _preempted: bool = field(default=False, init=False)

    def install_signal_handlers(self):
        def handler(signum, frame):  # noqa: ARG001
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)
        try:
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def run(self, params, opt_state, n_steps: int, start_step: int | None = None):
        """Returns (params, opt_state, last_step, history). Resumes if a
        checkpoint exists and start_step is None."""
        step = 0
        if start_step is not None:
            step = start_step
        else:
            latest = self.ckpt.latest_step()
            if latest is not None:
                (params, opt_state), manifest = self.ckpt.restore(
                    (params, opt_state)
                )
                step = int(manifest["extra"].get("next_step", latest))

        history = []
        times = []
        while step < n_steps:
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v) for k, v in self.batch_at(step).items()}
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            if len(times) > 5 and dt > self.straggler_factor * float(np.median(times)):
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(median {np.median(times):.2f}s)")
            history.append(loss)
            step += 1
            if step % self.log_every == 0:
                print(f"step {step}: loss={loss:.4f} ({dt*1000:.0f} ms)")
            if self._preempted or step % self.ckpt_every == 0 or step == n_steps:
                self.ckpt.save(step, (params, opt_state), extra={"next_step": step})
                if self._preempted:
                    print(f"[preempted] checkpointed at step {step}; exiting")
                    break
        return params, opt_state, step, history
