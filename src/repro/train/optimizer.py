"""AdamW + global-norm clipping, pure-pytree (no optax dependency)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = cfg.lr * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, opt_state["nu"], grads)

    def upd(p, m, v):
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, gnorm
