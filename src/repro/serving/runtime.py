"""Request runtime: deadlines, admission control, retries, degradation.

This is the layer between clients and the MVCC substrate
(core/snapshot.py).  Every read executes against a **pinned snapshot** —
writers (``insert`` / ``delete`` / ``compact`` on the runtime) mutate the
live store under its write lock and publish the new version when done — so
a burst of concurrent readers racing a background update stream each see
one consistent version end to end.

Request lifecycle (the degradation ladder, best outcome first):

  1. **ok** — admitted, pinned, answered before its deadline.  The outcome
     carries ``version`` (what the answer is consistent with) and
     ``stale=True`` when the pin was degraded (a writer held the flush
     lock past the pin timeout, so the *last published* version served).
  2. **retry** — a transient failure (:class:`~repro.testing.faults.FaultError`
     — injected churn, a device hiccup) inside the attempt is retried with
     jittered exponential backoff while the deadline allows; the sharded
     engine additionally degrades from the stacked shard_map executable to
     the per-shard dispatch loop on device failure (core/shard.py).
  3. **deadline** — admitted but out of time (before or during execution).
  4. **error** — a non-transient failure; reported, never raised into the
     worker loop.
  5. **shed** — the bounded admission queue is full; the request is
     rejected *at submit time* (backpressure), before consuming any
     execution resources.

Observability: every counter/histogram lands in a per-runtime
:class:`~repro.obs.metrics.MetricsRegistry` (``rt.metrics``) — ``stats``
is now a read-only dict view over it, keeping the PR-6 key set.  Pass a
:class:`~repro.obs.trace.Tracer` to record one span tree per request
(queue wait, per-attempt pin / execute / backoff, stale-degradation
events); ``Outcome.trace_id`` links the result back to its trace.
``Outcome.latency_s`` splits into ``queue_s`` (admission-queue wait) +
``exec_s`` (service time); the two always sum to ``latency_s``.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.snapshot import SnapshotRegistry
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.testing import faults
from repro.testing.faults import FaultError

_STOP = object()  # worker-loop sentinel


@dataclass
class Outcome:
    """What the runtime resolves a request's Future to (never an exception)."""

    status: str  # "ok" | "shed" | "deadline" | "error"
    answers: set | None = None
    version: int | None = None  # store version the answer is consistent with
    stale: bool = False  # True: degraded pin served the last published version
    retries: int = 0
    latency_s: float = 0.0  # == queue_s + exec_s
    queue_s: float = 0.0  # admission-queue wait (submit -> worker dequeue)
    exec_s: float = 0.0  # service time (dequeue -> resolution)
    error: str | None = None
    trace_id: str | None = None  # set when the runtime has a Tracer

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclass
class _Request:
    patterns: list
    select: object
    mode: str | None
    deadline_t: float | None  # absolute monotonic deadline (None: unbounded)
    submitted_t: float
    future: Future = field(default_factory=Future)
    dequeue_t: float | None = None
    trace: object = None  # obs_trace.Trace when the runtime traces
    root: object = None  # the "request" root span
    queue_span: object = None


class ServingRuntime:
    """Thread-pooled snapshot-isolated serving over one (Sharded)KnowledgeBase.

    >>> rt = ServingRuntime(K, modes=("litemat", "rewrite"))
    >>> with rt:
    ...     out = rt.serve(PAPER_QUERIES["Q3"])          # sync
    ...     fut = rt.submit(PAPER_QUERIES["Q1"])          # async
    ...     rt.insert(more_triples)                       # publishes new version
    ...     assert fut.result().ok
    """

    def __init__(self, kb, modes=("litemat",), use_index: bool = True,
                 n_workers: int = 2, max_queue: int = 64,
                 default_deadline_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.005,
                 retry_backoff_cap_s: float = 0.1,
                 pin_lock_timeout_s: float = 0.05, seed: int = 0,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.kb = kb
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.registry = SnapshotRegistry(
            kb, modes=modes, use_index=use_index,
            lock_timeout_s=pin_lock_timeout_s, metrics=self.metrics)
        self.n_workers = n_workers
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._workers: list = []
        self._started = False
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._latencies: list = []  # (status, latency_s) per finished request

    @property
    def stats(self) -> dict:
        """PR-6-shaped counter dict, now a read-only registry view."""
        m = self.metrics
        return {
            "submitted": m.counter_value("serving/submitted"),
            "ok": m.counter_value("serving/outcomes", status="ok"),
            "shed": m.counter_value("serving/outcomes", status="shed"),
            "deadline": m.counter_value("serving/outcomes",
                                        status="deadline"),
            "errors": m.counter_value("serving/outcomes", status="error"),
            "retries": m.counter_value("serving/retries"),
            "stale_served": m.counter_value("serving/stale_served"),
            "updates": m.counter_value("serving/updates"),
            "publish_failures": m.counter_value("serving/publish_failures"),
        }

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingRuntime":
        if not self._started:
            self._started = True
            self.registry.publish()
            for i in range(self.n_workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"serve-worker-{i}", daemon=True)
                t.start()
                self._workers.append(t)
        return self

    def stop(self) -> None:
        if self._started:
            for _ in self._workers:
                self._queue.put(_STOP)
            for t in self._workers:
                t.join()
            self._workers.clear()
            self._started = False

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- read path -----------------------------------------------------------
    def submit(self, patterns, select=None, mode: str | None = None,
               deadline_s: float | None = None) -> Future:
        """Admit a query (or shed it) and return a Future[Outcome].

        The Future always resolves to an :class:`Outcome` — shed and
        failed requests report through ``status``, they never raise.
        """
        if not self._started:
            self.start()
        now = time.monotonic()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        req = _Request(
            patterns=list(patterns), select=select, mode=mode,
            deadline_t=None if deadline_s is None else now + deadline_s,
            submitted_t=now)
        self.metrics.counter("serving/submitted").inc()
        if self.tracer is not None:
            req.trace = self.tracer.new_trace()
            req.root = self.tracer.start_root(
                req.trace, "request", n_patterns=len(req.patterns),
                mode=req.mode or "default")
            req.queue_span = req.trace.new_span("queue", req.root.span_id, {})
        try:
            self._queue.put_nowait(req)
            self.metrics.gauge("serving/queue_depth").set(
                self._queue.qsize())
        except queue.Full:
            # backpressure: reject at admission, before any execution cost
            lat = time.monotonic() - now
            out = Outcome(status="shed", latency_s=lat, queue_s=lat)
            self._finish(req, out)
        return req.future

    def serve(self, patterns, select=None, mode: str | None = None,
              deadline_s: float | None = None) -> Outcome:
        """Synchronous submit: blocks for this request's Outcome."""
        return self.submit(patterns, select=select, mode=mode,
                           deadline_s=deadline_s).result()

    # -- write path ----------------------------------------------------------
    def _write(self, op, *a, **kw) -> dict:
        with self.kb.write_lock:
            stats = op(*a, **kw)
            try:
                self.registry.publish()
            except Exception:  # noqa: BLE001 — degrade, don't fail the write
                # capture crashed (e.g. mid-flush): the mutation is
                # committed but unpublished — readers keep degrading to the
                # last published snapshot (stale tag) until a later pin or
                # publish captures this version successfully
                self.metrics.counter("serving/publish_failures").inc()
        self.metrics.counter("serving/updates").inc()
        return stats

    def insert(self, raw, **kw) -> dict:
        return self._write(self.kb.insert, raw, **kw)

    def delete(self, raw, **kw) -> dict:
        return self._write(self.kb.delete, raw, **kw)

    def compact(self, **kw) -> dict:
        return self._write(self.kb.compact, **kw)

    # -- worker internals ----------------------------------------------------
    def _finish(self, req: _Request, out: Outcome) -> None:
        m = self.metrics
        m.counter("serving/outcomes", status=out.status).inc()
        if out.stale and out.ok:
            m.counter("serving/stale_served").inc()
        m.histogram("serving/latency_s", status=out.status).observe(
            out.latency_s)
        if out.status != "shed":
            m.histogram("serving/queue_s").observe(out.queue_s)
            m.histogram("serving/exec_s").observe(out.exec_s)
        with self._lock:
            self._latencies.append((out.status, out.latency_s))
        if req.trace is not None:
            out.trace_id = req.trace.trace_id
            req.root.set_attr(status=out.status, retries=out.retries,
                              stale=out.stale, version=out.version)
            self.tracer.finish_trace(req.trace)
        req.future.set_result(out)

    def _jitter(self, attempt: int) -> float:
        base = min(self.retry_backoff_cap_s,
                   self.retry_backoff_s * (2 ** attempt))
        with self._lock:
            u = float(self._rng.random())
        return base * (0.5 + 0.5 * u)

    def _worker_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is _STOP:
                return
            req.dequeue_t = time.monotonic()
            self.metrics.gauge("serving/queue_depth").set(
                self._queue.qsize())
            if req.queue_span is not None:
                req.queue_span.finish()
            with obs_trace.activate(req.root):
                try:
                    out = self._execute(req)
                except Exception as e:  # noqa: BLE001 — workers must survive
                    out = self._outcome(req, "error",
                                        error=f"{type(e).__name__}: {e}")
            self._finish(req, out)

    def _time_left(self, req: _Request) -> float:
        if req.deadline_t is None:
            return float("inf")
        return req.deadline_t - time.monotonic()

    def _outcome(self, req: _Request, status: str, **kw) -> Outcome:
        """Resolve timing fields so queue_s + exec_s == latency_s exactly."""
        lat = time.monotonic() - req.submitted_t
        q = ((req.dequeue_t - req.submitted_t)
             if req.dequeue_t is not None else lat)
        return Outcome(status=status, latency_s=lat, queue_s=q,
                       exec_s=lat - q, **kw)

    def _execute(self, req: _Request) -> Outcome:
        retries = 0
        last_err: Exception | None = None
        while True:
            if self._time_left(req) <= 0:
                obs_trace.event("deadline_preempt", attempt=retries)
                return self._outcome(
                    req, "deadline", retries=retries,
                    error=None if last_err is None else
                    f"{type(last_err).__name__}: {last_err}")
            with obs_trace.span("attempt", attempt=retries) as att:
                with obs_trace.span("pin") as pin_sp:
                    pin = self.registry.pin()
                    pin_sp.set_attr(version=pin.version, stale=pin.stale)
                try:
                    faults.fire("serving.execute", attempt=retries)
                    if pin.stale:
                        obs_trace.event("stale_degraded",
                                        version=pin.version)
                    with obs_trace.span("execute"):
                        answers = pin.answers(req.patterns,
                                              select=req.select,
                                              mode=req.mode)
                    if self._time_left(req) < 0:
                        # finished late (e.g. a slow shard): the answer is
                        # useless to a deadlined caller — report the miss
                        obs_trace.event("deadline_after_execute")
                        return self._outcome(req, "deadline",
                                             retries=retries)
                    return self._outcome(
                        req, "ok", answers=answers, version=pin.version,
                        stale=pin.stale, retries=retries)
                except FaultError as e:
                    # transient churn: back off with jitter and retry while
                    # the deadline and the retry budget allow
                    last_err = e
                    att.set_attr(fault=f"{type(e).__name__}: {e}")
                    if retries >= self.max_retries:
                        return self._outcome(
                            req, "error", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    delay = self._jitter(retries)
                    retries += 1
                    self.metrics.counter("serving/retries").inc()
                    if self._time_left(req) <= delay:
                        return self._outcome(
                            req, "deadline", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    with obs_trace.span("backoff",
                                        delay_s=round(delay, 6)):
                        time.sleep(delay)
                finally:
                    pin.release()

    # -- reporting -----------------------------------------------------------
    def latency_stats(self, status: str = "ok") -> dict:
        with self._lock:
            lat = sorted(l for s, l in self._latencies if s == status)
        if not lat:
            return dict(n=0)
        arr = np.asarray(lat)
        return dict(
            n=len(lat),
            p50_ms=float(np.percentile(arr, 50) * 1e3),
            p99_ms=float(np.percentile(arr, 99) * 1e3),
            mean_ms=float(arr.mean() * 1e3),
        )


__all__ = ["ServingRuntime", "Outcome"]
