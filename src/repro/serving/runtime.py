"""Request runtime: deadlines, admission control, retries, degradation,
micro-batching, pagination.

This is the layer between clients and the MVCC substrate
(core/snapshot.py).  Every read executes against a **pinned snapshot** —
writers (``insert`` / ``delete`` / ``compact`` on the runtime) mutate the
live store under its write lock and publish the new version when done — so
a burst of concurrent readers racing a background update stream each see
one consistent version end to end.

Request lifecycle (the degradation ladder, best outcome first):

  1. **ok** — admitted, pinned, answered before its deadline.  The outcome
     carries ``version`` (what the answer is consistent with) and
     ``stale=True`` when the pin was degraded (a writer held the flush
     lock past the pin timeout, so the *last published* version served).
  2. **retry** — a transient failure (:class:`~repro.testing.faults.FaultError`
     — injected churn, a device hiccup) inside the attempt is retried with
     jittered exponential backoff while the deadline allows; the sharded
     engine additionally degrades from the stacked shard_map executable to
     the per-shard dispatch loop on device failure (core/shard.py).
  3. **deadline** — admitted but out of time (before or during execution).
  4. **error** — a non-transient failure; reported, never raised into the
     worker loop.
  5. **shed** — the bounded admission queue is full; the request is
     rejected *at submit time* (backpressure), before consuming any
     execution resources.

Micro-batching (ROADMAP item 1): a worker that dequeues a request keeps
draining the admission queue — up to ``max_batch`` requests or for
``batch_window_s`` — and executes same-kind requests as ONE batched
dispatch: pattern queries ride the engine's vmapped
:meth:`~repro.core.query.QueryEngine.run_batch` (requests whose patterns
lower to the same signature tuple share a single XLA call, capacities
sized from ``observed_selectivity``), and ``class_members`` /
``class_prop_join`` requests concatenate into the
:class:`~repro.serving.engine.QueryServer` /
:class:`~repro.serving.engine.ShardedQueryServer` batched plans.  The
default window is 0 (drain-only): sparse traffic pays zero added latency
and batches only form under concurrent load.  Every member of a batch
carries its OWN Outcome — deadline checks, fault injection
(``serving.execute``), version/stale tags and trace spans stay
per-request, and a member that faults is retried alone without poisoning
its batchmates (a whole-batch failure degrades every member to the solo
retry ladder).

Pagination: ``submit(..., page_size=N)`` answers with the first N rows of
a STABLE total order (sorted result tuples at the pinned version) plus an
opaque :class:`Cursor`; submitting with ``cursor=`` re-pins that exact
version so page K+1 continues where page K stopped.  When the version has
been retired between pages the runtime degrades to a fresh pin and tags
the outcome ``stale=True`` instead of erroring.  Paginated outcomes carry
``answers`` as an ORDERED list of rows plus ``total``.

Observability: every counter/histogram lands in a per-runtime
:class:`~repro.obs.metrics.MetricsRegistry` (``rt.metrics``) — ``stats``
is now a read-only dict view over it, keeping the PR-6 key set, and
``latency_stats`` is derived from the bounded ``serving/latency_s``
histogram sketch (nothing in the runtime grows per-request anymore).
Pass a :class:`~repro.obs.trace.Tracer` to record one span tree per
request (queue wait, per-attempt pin / execute / backoff,
stale-degradation events; batched members get ``batched=True`` +
``batch_size`` attrs); ``Outcome.trace_id`` links the result back to its
trace.  ``Outcome.latency_s`` splits into ``queue_s`` (admission-queue
wait) + ``exec_s`` (service time); the two always sum to ``latency_s``.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.snapshot import SnapshotRegistry
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.testing import faults
from repro.testing.faults import FaultError

_STOP = object()  # worker-loop sentinel


@dataclass(frozen=True)
class Cursor:
    """Opaque continuation token for paginated reads.

    ``version`` names the pinned snapshot the total order was computed
    against; ``offset`` is where the next page starts in that order.  The
    token is immutable and printable — clients hold it between pages, the
    runtime re-pins ``version`` on continuation.
    """

    version: int
    offset: int
    page_size: int


@dataclass
class Outcome:
    """What the runtime resolves a request's Future to (never an exception)."""

    status: str  # "ok" | "shed" | "deadline" | "error"
    answers: object = None  # set of rows; ordered list when paginated;
    #                         (counts, members) arrays for server kinds
    version: int | None = None  # store version the answer is consistent with
    stale: bool = False  # True: degraded pin served the last published version
    retries: int = 0
    latency_s: float = 0.0  # == queue_s + exec_s
    queue_s: float = 0.0  # admission-queue wait (submit -> worker dequeue)
    exec_s: float = 0.0  # service time (dequeue -> resolution)
    error: str | None = None
    trace_id: str | None = None  # set when the runtime has a Tracer
    cursor: Cursor | None = None  # continuation for the NEXT page (paginated)
    total: int | None = None  # full result count at the pinned version

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclass
class _Request:
    patterns: list
    select: object
    mode: str | None
    deadline_t: float | None  # absolute monotonic deadline (None: unbounded)
    submitted_t: float
    kind: str = "query"  # "query" | "members" | "prop_join"
    args: tuple = ()  # server-kind request payload (name lists)
    page_size: int | None = None  # first-page request when set
    cursor: Cursor | None = None  # continuation request when set
    future: Future = field(default_factory=Future)
    dequeue_t: float | None = None
    trace: object = None  # obs_trace.Trace when the runtime traces
    root: object = None  # the "request" root span
    queue_span: object = None


class ServingRuntime:
    """Thread-pooled snapshot-isolated serving over one (Sharded)KnowledgeBase.

    >>> rt = ServingRuntime(K, modes=("litemat", "rewrite"))
    >>> with rt:
    ...     out = rt.serve(PAPER_QUERIES["Q3"])          # sync
    ...     fut = rt.submit(PAPER_QUERIES["Q1"])          # async
    ...     page = rt.serve(PAPER_QUERIES["Q1"], page_size=10)  # paginated
    ...     rest = rt.serve(PAPER_QUERIES["Q1"], cursor=page.cursor)
    ...     rt.insert(more_triples)                       # publishes new version
    ...     assert fut.result().ok
    """

    def __init__(self, kb, modes=("litemat",), use_index: bool = True,
                 n_workers: int = 2, max_queue: int = 64,
                 default_deadline_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.005,
                 retry_backoff_cap_s: float = 0.1,
                 pin_lock_timeout_s: float = 0.05, seed: int = 0,
                 batch_window_s: float = 0.0, max_batch: int = 16,
                 server_topk: int = 32,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.kb = kb
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.registry = SnapshotRegistry(
            kb, modes=modes, use_index=use_index,
            lock_timeout_s=pin_lock_timeout_s, metrics=self.metrics)
        self.n_workers = n_workers
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        # micro-batching: a dequeuing worker drains up to max_batch peers,
        # waiting at most batch_window_s for stragglers (0 = drain-only:
        # coalesce what is already queued, never delay a lone request)
        self.batch_window_s = batch_window_s
        self.max_batch = max_batch
        self.server_topk = server_topk
        self.max_queue = max_queue
        # SLO-driven soft admission bound: the queue's hard capacity never
        # changes, but the burn-rate monitor can lower this to shed
        # earlier under sustained budget burn (enable_slo_control)
        self.admission_bound = max_queue
        self._batch_window_s0 = batch_window_s
        self._slo_state = "ok"
        self._slo_monitor = None
        self._slo_rollup = None
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._workers: list = []
        self._started = False
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        # QueryServer/ShardedQueryServer are NOT safe under concurrent
        # callers (atomic view resync + jit fan caches); all server-kind
        # execution serializes here
        self._server_lock = threading.Lock()
        self._server = None

    @property
    def stats(self) -> dict:
        """PR-6-shaped counter dict, now a read-only registry view."""
        m = self.metrics
        return {
            "submitted": m.counter_value("serving/submitted"),
            "ok": m.counter_value("serving/outcomes", status="ok"),
            "shed": m.counter_value("serving/outcomes", status="shed"),
            "deadline": m.counter_value("serving/outcomes",
                                        status="deadline"),
            "errors": m.counter_value("serving/outcomes", status="error"),
            "retries": m.counter_value("serving/retries"),
            "stale_served": m.counter_value("serving/stale_served"),
            "updates": m.counter_value("serving/updates"),
            "publish_failures": m.counter_value("serving/publish_failures"),
            "batched": m.counter_value("serving/batched"),
        }

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingRuntime":
        # check-and-set under the lock: two concurrent first submits used
        # to both see _started == False and each spawn a worker pool
        with self._lock:
            if self._started:
                return self
            self._started = True
            self.registry.publish()
            for i in range(self.n_workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"serve-worker-{i}", daemon=True)
                t.start()
                self._workers.append(t)
        if self._slo_rollup is not None:
            self._slo_rollup.start()
        return self

    def stop(self) -> None:
        with self._lock:
            if not self._started:
                return
            workers, self._workers = self._workers, []
            self._started = False
        if self._slo_rollup is not None:
            self._slo_rollup.stop()
        for _ in workers:
            self._queue.put(_STOP)
        for t in workers:
            t.join()

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- SLO control plane ---------------------------------------------------
    def enable_slo_control(self, slos=None, interval_s: float = 0.25,
                           fast_window: int = 3, slow_window: int = 12,
                           warn_burn: float = 1.0, page_burn: float = 2.0,
                           min_events: int = 8, track_ledger: bool = True):
        """Close the telemetry loop: rollup thread + burn-rate monitor
        driving this runtime's admission bound and batch window.

        Builds a :class:`~repro.obs.slo.TelemetryRollup` over
        ``self.metrics`` (sampling the global resource ledger each tick)
        and an :class:`~repro.obs.slo.SLOMonitor` whose overall-state
        transitions call :meth:`_apply_slo_state`:

          * ``warn`` — admission bound halves, batch window >= 1 ms
            (bigger batches amortize dispatches under pressure);
          * ``page`` — admission bound quarters (floor 4): sustained
            budget burn sheds load at submit time, before execution cost;
          * ``ok`` — both knobs restore to their constructor values.

        The rollup thread starts/stops with the runtime; returns the
        monitor (``monitor.detail`` carries per-SLO burn rates).  Call
        ``self._slo_rollup.tick()`` to drive the loop synchronously
        (tests, benches).
        """
        if self._slo_monitor is not None:
            return self._slo_monitor
        from repro.obs.ledger import LEDGER
        from repro.obs.slo import (SLOMonitor, TelemetryRollup,
                                   default_serving_slos)

        ledger = None
        if track_ledger:
            if hasattr(self.kb, "track_ledger"):
                self.kb.track_ledger()
            if getattr(self.registry, "_ledger_handle", None) is None:
                self.registry._ledger_handle = LEDGER.track(
                    "snapshots", self.registry)
            ledger = LEDGER
        monitor = SLOMonitor(
            slos if slos is not None else default_serving_slos(),
            fast_window=fast_window, slow_window=slow_window,
            warn_burn=warn_burn, page_burn=page_burn,
            min_events=min_events, registry=self.metrics)
        monitor.on_transition(self._apply_slo_state)
        self._slo_monitor = monitor
        self._slo_rollup = TelemetryRollup(
            self.metrics, interval_s=interval_s, ledger=ledger,
            monitor=monitor)
        if self._started:
            self._slo_rollup.start()
        return monitor

    def _apply_slo_state(self, state: str, detail=None) -> None:
        """Monitor-transition callback: retune admission + batching knobs.

        Runs on the rollup thread.  The ``slo.apply`` fault site lets the
        harness fail the CONTROL plane: a faulted apply keeps the previous
        knobs (the data plane keeps serving) and the next transition
        retries.  Every applied transition lands as a counter, gauge
        updates, and — when the runtime traces — a single-span
        ``slo_transition`` trace, so the timeline of the control loop is
        reconstructable from the trace export alone.
        """
        prev = self._slo_state
        try:
            faults.fire("slo.apply", state=state)
        except FaultError as e:
            self.metrics.counter("slo/apply_faults").inc()
            obs_trace.event("slo_apply_fault", state=state,
                            error=f"{type(e).__name__}: {e}")
            return
        if state == "page":
            self.admission_bound = max(4, self.max_queue // 4)
            self.batch_window_s = max(self._batch_window_s0, 0.002)
        elif state == "warn":
            self.admission_bound = max(8, self.max_queue // 2)
            self.batch_window_s = max(self._batch_window_s0, 0.001)
        else:
            self.admission_bound = self.max_queue
            self.batch_window_s = self._batch_window_s0
        self._slo_state = state
        self.metrics.counter("slo/applied", frm=prev, to=state).inc()
        self.metrics.gauge("serving/admission_bound").set(
            self.admission_bound)
        self.metrics.gauge("serving/batch_window_s").set(
            self.batch_window_s)
        if self.tracer is not None:
            tr = self.tracer.new_trace()
            root = self.tracer.start_root(
                tr, "slo_transition", frm=prev, to=state,
                admission_bound=self.admission_bound,
                batch_window_s=self.batch_window_s)
            root.finish()
            self.tracer.finish_trace(tr)

    # -- read path -----------------------------------------------------------
    def submit(self, patterns, select=None, mode: str | None = None,
               deadline_s: float | None = None,
               page_size: int | None = None,
               cursor: Cursor | None = None) -> Future:
        """Admit a query (or shed it) and return a Future[Outcome].

        The Future always resolves to an :class:`Outcome` — shed and
        failed requests report through ``status``, they never raise.
        ``page_size`` asks for the first page of a stable-order result
        (the outcome carries ``cursor`` for the next one); ``cursor``
        continues a previous page at its pinned version.
        """
        req = _Request(
            patterns=list(patterns), select=select, mode=mode,
            deadline_t=None, submitted_t=0.0,
            page_size=page_size if cursor is None else None, cursor=cursor)
        return self._admit(req, deadline_s)

    def serve(self, patterns, select=None, mode: str | None = None,
              deadline_s: float | None = None,
              page_size: int | None = None,
              cursor: Cursor | None = None) -> Outcome:
        """Synchronous submit: blocks for this request's Outcome."""
        return self.submit(patterns, select=select, mode=mode,
                           deadline_s=deadline_s, page_size=page_size,
                           cursor=cursor).result()

    def submit_class_members(self, class_names,
                             deadline_s: float | None = None) -> Future:
        """Admit a batched Q1-style server request: per-class distinct
        member counts + smallest-topk member ids.  The outcome's
        ``answers`` is ``(counts, members)`` aligned with ``class_names``.
        """
        req = _Request(patterns=[], select=None, mode=None, deadline_t=None,
                       submitted_t=0.0, kind="members",
                       args=(list(class_names),))
        return self._admit(req, deadline_s)

    def class_members(self, class_names,
                      deadline_s: float | None = None) -> Outcome:
        return self.submit_class_members(class_names,
                                         deadline_s=deadline_s).result()

    def submit_class_prop_join(self, class_names, prop_names,
                               deadline_s: float | None = None) -> Future:
        """Admit a batched Q3-style server request (x:C ⋈ (x p y))."""
        req = _Request(patterns=[], select=None, mode=None, deadline_t=None,
                       submitted_t=0.0, kind="prop_join",
                       args=(list(class_names), list(prop_names)))
        return self._admit(req, deadline_s)

    def class_prop_join(self, class_names, prop_names,
                        deadline_s: float | None = None) -> Outcome:
        return self.submit_class_prop_join(
            class_names, prop_names, deadline_s=deadline_s).result()

    def _admit(self, req: _Request, deadline_s: float | None) -> Future:
        if not self._started:
            self.start()
        now = time.monotonic()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        req.submitted_t = now
        req.deadline_t = None if deadline_s is None else now + deadline_s
        self.metrics.counter("serving/submitted").inc()
        if self.tracer is not None:
            req.trace = self.tracer.new_trace()
            req.root = self.tracer.start_root(
                req.trace, "request", n_patterns=len(req.patterns),
                mode=req.mode or "default", kind=req.kind)
            req.queue_span = req.trace.new_span("queue", req.root.span_id, {})
        try:
            if self._queue.qsize() >= self.admission_bound:
                raise queue.Full  # SLO-tightened soft bound: shed early
            self._queue.put_nowait(req)
            self.metrics.gauge("serving/queue_depth").set(
                self._queue.qsize())
        except queue.Full:
            # backpressure: reject at admission, before any execution cost
            lat = time.monotonic() - now
            if req.queue_span is not None:
                # the request dies in the queue, but its queue span must
                # still close — an open span in a finished trace is a leak
                # the validator now rejects
                req.queue_span.finish()
            out = Outcome(status="shed", latency_s=lat, queue_s=lat)
            self._finish(req, out)
        return req.future

    # -- write path ----------------------------------------------------------
    def _write(self, op, *a, **kw) -> dict:
        with self.kb.write_lock:
            stats = op(*a, **kw)
            try:
                self.registry.publish()
            except Exception:  # noqa: BLE001 — degrade, don't fail the write
                # capture crashed (e.g. mid-flush): the mutation is
                # committed but unpublished — readers keep degrading to the
                # last published snapshot (stale tag) until a later pin or
                # publish captures this version successfully
                self.metrics.counter("serving/publish_failures").inc()
        self.metrics.counter("serving/updates").inc()
        return stats

    def insert(self, raw, **kw) -> dict:
        return self._write(self.kb.insert, raw, **kw)

    def delete(self, raw, **kw) -> dict:
        return self._write(self.kb.delete, raw, **kw)

    def compact(self, **kw) -> dict:
        return self._write(self.kb.compact, **kw)

    # -- worker internals ----------------------------------------------------
    def _finish(self, req: _Request, out: Outcome) -> None:
        m = self.metrics
        m.counter("serving/outcomes", status=out.status).inc()
        if out.stale and out.ok:
            m.counter("serving/stale_served").inc()
        m.histogram("serving/latency_s", status=out.status).observe(
            out.latency_s)
        if out.status != "shed":
            m.histogram("serving/queue_s").observe(out.queue_s)
            m.histogram("serving/exec_s").observe(out.exec_s)
        if req.trace is not None:
            out.trace_id = req.trace.trace_id
            req.root.set_attr(status=out.status, retries=out.retries,
                              stale=out.stale, version=out.version)
            req.root.finish()
            self.tracer.finish_trace(req.trace)
        req.future.set_result(out)

    def _jitter(self, attempt: int) -> float:
        base = min(self.retry_backoff_cap_s,
                   self.retry_backoff_s * (2 ** attempt))
        with self._lock:
            u = float(self._rng.random())
        return base * (0.5 + 0.5 * u)

    @staticmethod
    def _batchable(req: _Request) -> bool:
        """Paginated reads pin specific versions / slice their own pages —
        they take the solo path; everything else can coalesce."""
        if req.kind != "query":
            return True
        return req.cursor is None and req.page_size is None

    def _drain_batch(self, first: _Request):
        """Coalesce queued peers behind ``first``: up to ``max_batch``
        requests, waiting at most ``batch_window_s`` for stragglers.
        Returns (batch, saw_stop); a drained _STOP retires THIS worker
        after the batch resolves (stop() enqueues one sentinel per
        worker, and each worker consumes exactly one).
        """
        first.dequeue_t = time.monotonic()
        if first.queue_span is not None:
            first.queue_span.finish()
        batch = [first]
        if self.max_batch <= 1:
            return batch, False
        deadline = first.dequeue_t + self.batch_window_s
        while len(batch) < self.max_batch:
            wait = deadline - time.monotonic()
            try:
                nxt = (self._queue.get(timeout=wait) if wait > 0
                       else self._queue.get_nowait())
            except queue.Empty:
                break
            if nxt is _STOP:
                return batch, True
            nxt.dequeue_t = time.monotonic()
            if nxt.queue_span is not None:
                nxt.queue_span.finish()
            batch.append(nxt)
        return batch, False

    def _worker_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is _STOP:
                return
            batch, saw_stop = self._drain_batch(req)
            self.metrics.gauge("serving/queue_depth").set(
                self._queue.qsize())
            self._handle_batch(batch)
            if saw_stop:
                return

    def _handle_batch(self, batch) -> None:
        """Partition one drained batch into coalescable groups + solos."""
        groups: dict = {}
        for r in batch:
            if self._batchable(r):
                groups.setdefault((r.kind, r.mode), []).append(r)
            else:
                self._run_one(r)
        for (kind, mode), grp in groups.items():
            self.metrics.histogram("serving/batch_size",
                                   kind=kind).observe(len(grp))
            if len(grp) == 1:
                self._run_one(grp[0])
            elif kind == "query":
                self._execute_query_batch(grp, mode)
            else:
                self._execute_server_batch(grp, kind)

    def _run_one(self, req: _Request) -> None:
        """The solo path: full retry ladder, exact per-request spans."""
        with obs_trace.activate(req.root):
            try:
                out = self._execute(req)
            except Exception as e:  # noqa: BLE001 — workers must survive
                out = self._outcome(req, "error",
                                    error=f"{type(e).__name__}: {e}")
        self._finish(req, out)

    def _gate_members(self, reqs, batch_size: int):
        """Per-member admission to a shared dispatch: deadline check +
        fault-injection gate.  A member that faults here retries ALONE
        through the solo ladder — its batchmates proceed untouched."""
        ready = []
        for r in reqs:
            if self._time_left(r) <= 0:
                self._finish(r, self._outcome(r, "deadline"))
                continue
            try:
                faults.fire("serving.execute", attempt=0,
                            batch=batch_size)
            except FaultError:
                self.metrics.counter("serving/batch_fallback",
                                     reason="member_fault").inc()
                self._run_one(r)
                continue
            ready.append(r)
        return ready

    def _member_spans(self, reqs, batch_size: int, version, stale):
        """Open attempt/execute spans for every traced batch member."""
        spans = {}
        for r in reqs:
            if r.trace is None:
                continue
            att = r.trace.new_span(
                "attempt", r.root.span_id,
                {"attempt": 0, "batched": True, "batch_size": batch_size})
            ex = r.trace.new_span(
                "execute", att.span_id, {"version": version, "stale": stale})
            spans[id(r)] = (att, ex)
        return spans

    @staticmethod
    def _close_member_spans(spans, **attrs) -> None:
        for att, ex in spans.values():
            if attrs:
                ex.set_attr(**attrs)
            ex.finish()
            att.finish()

    def _execute_query_batch(self, reqs, mode) -> None:
        """ONE pin + ONE engine-batched dispatch for same-mode queries.

        Members keep individual outcomes: deadline misses resolve before
        and after the dispatch, fault injection fires per member, and a
        whole-batch failure degrades every member to the solo retry
        ladder (nobody inherits a batchmate's error).
        """
        ready = self._gate_members(reqs, len(reqs))
        if not ready:
            return
        if len(ready) == 1:
            self._run_one(ready[0])
            return
        try:
            pin = self.registry.pin()
        except Exception as e:  # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            for r in ready:
                self._finish(r, self._outcome(r, "error", error=err))
            return
        spans = self._member_spans(ready, len(ready), pin.version, pin.stale)
        try:
            try:
                results = pin.query_batch(
                    [(r.patterns, r.select) for r in ready], mode=mode)
            except Exception:  # noqa: BLE001 — degrade, don't poison
                self._close_member_spans(spans, fallback=True)
                self.metrics.counter("serving/batch_fallback",
                                     reason="batch_error").inc()
                for r in ready:
                    self._run_one(r)
                return
            self._close_member_spans(spans)
            self.metrics.counter("serving/batched").inc(len(ready))
            # the engine fans ONE rows array to structurally identical
            # requests — build each unique answer set once and share it
            # (duplicate-heavy bursts would otherwise pay the Python set
            # construction per member, which dwarfs the dispatch itself)
            memo: dict = {}
            for r, (rows, _) in zip(ready, results):
                if self._time_left(r) < 0:
                    self._finish(r, self._outcome(r, "deadline"))
                    continue
                answers = memo.get(id(rows))
                if answers is None:
                    answers = {tuple(t) for t in rows.tolist()}
                    memo[id(rows)] = answers
                self._finish(r, self._outcome(
                    r, "ok", answers=answers, version=pin.version,
                    stale=pin.stale))
        finally:
            pin.release()

    def _server_inst(self):
        """Lazily build the (Sharded)QueryServer facade (server_lock held)."""
        if self._server is None:
            from repro.serving.engine import (QueryServer,
                                              ShardedQueryServer)

            cls = (ShardedQueryServer if hasattr(self.kb, "shards")
                   else QueryServer)
            self._server = cls(self.kb, topk=self.server_topk)
        return self._server

    def _server_call(self, kind: str, args: tuple):
        """One serialized server dispatch; returns (counts, members, version).

        The server resyncs its views to the live store version on entry
        (its own atomic ``_sync``), so the answer's version tag is the
        version the views were rebuilt at.
        """
        with self._server_lock:
            server = self._server_inst()
            if kind == "members":
                counts, members = server.class_members(args[0])
            else:
                counts, members = server.class_prop_join(args[0], args[1])
            return counts, members, server.served_version

    def _execute_server_batch(self, reqs, kind: str) -> None:
        """Concatenate same-kind server requests into ONE fan-out dispatch.

        ``class_members([A]), class_members([B, C])`` queued together
        execute as ``class_members([A, B, C])`` — one index-range
        resolution, one (shard_mapped) vmapped plan — then the count /
        member planes split back per request.
        """
        ready = self._gate_members(reqs, len(reqs))
        if not ready:
            return
        if len(ready) == 1:
            self._run_one(ready[0])
            return
        offsets = np.cumsum([0] + [len(r.args[0]) for r in ready])
        cat = tuple([n for r in ready for n in r.args[i]]
                    for i in range(len(ready[0].args)))
        spans = self._member_spans(ready, len(ready), None, False)
        try:
            counts, members, version = self._server_call(kind, cat)
        except Exception:  # noqa: BLE001 — degrade, don't poison
            self._close_member_spans(spans, fallback=True)
            self.metrics.counter("serving/batch_fallback",
                                 reason="batch_error").inc()
            for r in ready:
                self._run_one(r)
            return
        self._close_member_spans(spans, version=version)
        self.metrics.counter("serving/batched").inc(len(ready))
        for i, r in enumerate(ready):
            if self._time_left(r) < 0:
                self._finish(r, self._outcome(r, "deadline"))
                continue
            lo, hi = int(offsets[i]), int(offsets[i + 1])
            self._finish(r, self._outcome(
                r, "ok", answers=(counts[lo:hi], members[lo:hi]),
                version=version))

    def _time_left(self, req: _Request) -> float:
        if req.deadline_t is None:
            return float("inf")
        return req.deadline_t - time.monotonic()

    def _outcome(self, req: _Request, status: str, **kw) -> Outcome:
        """Resolve timing fields so queue_s + exec_s == latency_s exactly."""
        lat = time.monotonic() - req.submitted_t
        q = ((req.dequeue_t - req.submitted_t)
             if req.dequeue_t is not None else lat)
        return Outcome(status=status, latency_s=lat, queue_s=q,
                       exec_s=lat - q, **kw)

    def _pin_for(self, req: _Request):
        """Pin for one attempt: cursor continuations re-pin their exact
        version, degrading to a fresh pin (stale tag) when it is gone."""
        if req.cursor is None:
            return self.registry.pin(), False
        pin = self.registry.pin_version(req.cursor.version)
        if pin is not None:
            return pin, False
        # the cursor's version was retired between pages — serve the
        # current one and tell the client their iteration order broke
        obs_trace.event("cursor_version_retired",
                        version=req.cursor.version)
        return self.registry.pin(), True

    def _page(self, req: _Request, pin):
        """One stable-order page at the pinned version.

        The total order is the sorted result-tuple order — a pure
        function of the pinned version's answer set, so any worker
        computing page K+1 at the same version sees the same order page
        K was cut from.
        """
        rows, _ = pin.query(req.patterns, select=req.select, mode=req.mode)
        ordered = sorted(map(tuple, rows.tolist()))
        ps = (req.page_size if req.page_size is not None
              else req.cursor.page_size)
        off = req.cursor.offset if req.cursor is not None else 0
        page = ordered[off:off + ps]
        nxt = (Cursor(version=pin.version, offset=off + ps, page_size=ps)
               if off + ps < len(ordered) else None)
        return page, nxt, len(ordered)

    def _execute(self, req: _Request) -> Outcome:
        if req.kind != "query":
            return self._execute_server(req)
        retries = 0
        last_err: Exception | None = None
        while True:
            if self._time_left(req) <= 0:
                obs_trace.event("deadline_preempt", attempt=retries)
                return self._outcome(
                    req, "deadline", retries=retries,
                    error=None if last_err is None else
                    f"{type(last_err).__name__}: {last_err}")
            with obs_trace.span("attempt", attempt=retries) as att:
                with obs_trace.span("pin") as pin_sp:
                    pin, cursor_stale = self._pin_for(req)
                    stale = pin.stale or cursor_stale
                    pin_sp.set_attr(version=pin.version, stale=stale)
                try:
                    faults.fire("serving.execute", attempt=retries)
                    if stale:
                        obs_trace.event("stale_degraded",
                                        version=pin.version)
                    paged = (req.page_size is not None
                             or req.cursor is not None)
                    with obs_trace.span("execute", paginated=paged):
                        nxt = total = None
                        if paged:
                            answers, nxt, total = self._page(req, pin)
                        else:
                            answers = pin.answers(req.patterns,
                                                  select=req.select,
                                                  mode=req.mode)
                    if self._time_left(req) < 0:
                        # finished late (e.g. a slow shard): the answer is
                        # useless to a deadlined caller — report the miss
                        obs_trace.event("deadline_after_execute")
                        return self._outcome(req, "deadline",
                                             retries=retries)
                    return self._outcome(
                        req, "ok", answers=answers, version=pin.version,
                        stale=stale, retries=retries, cursor=nxt,
                        total=total)
                except FaultError as e:
                    # transient churn: back off with jitter and retry while
                    # the deadline and the retry budget allow
                    last_err = e
                    att.set_attr(fault=f"{type(e).__name__}: {e}")
                    if retries >= self.max_retries:
                        return self._outcome(
                            req, "error", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    delay = self._jitter(retries)
                    retries += 1
                    self.metrics.counter("serving/retries").inc()
                    if self._time_left(req) <= delay:
                        return self._outcome(
                            req, "deadline", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    with obs_trace.span("backoff",
                                        delay_s=round(delay, 6)):
                        time.sleep(delay)
                finally:
                    pin.release()

    def _execute_server(self, req: _Request) -> Outcome:
        """Solo retry ladder for class_members / class_prop_join requests —
        the same degradation contract as the pattern-query path."""
        retries = 0
        last_err: Exception | None = None
        while True:
            if self._time_left(req) <= 0:
                obs_trace.event("deadline_preempt", attempt=retries)
                return self._outcome(
                    req, "deadline", retries=retries,
                    error=None if last_err is None else
                    f"{type(last_err).__name__}: {last_err}")
            with obs_trace.span("attempt", attempt=retries,
                                kind=req.kind) as att:
                try:
                    faults.fire("serving.execute", attempt=retries)
                    with obs_trace.span("execute", kind=req.kind) as ex:
                        counts, members, version = self._server_call(
                            req.kind, req.args)
                        ex.set_attr(version=version)
                    if self._time_left(req) < 0:
                        obs_trace.event("deadline_after_execute")
                        return self._outcome(req, "deadline",
                                             retries=retries)
                    return self._outcome(
                        req, "ok", answers=(counts, members),
                        version=version, retries=retries)
                except FaultError as e:
                    last_err = e
                    att.set_attr(fault=f"{type(e).__name__}: {e}")
                    if retries >= self.max_retries:
                        return self._outcome(
                            req, "error", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    delay = self._jitter(retries)
                    retries += 1
                    self.metrics.counter("serving/retries").inc()
                    if self._time_left(req) <= delay:
                        return self._outcome(
                            req, "deadline", retries=retries,
                            error=f"{type(e).__name__}: {e}")
                    with obs_trace.span("backoff",
                                        delay_s=round(delay, 6)):
                        time.sleep(delay)

    # -- reporting -----------------------------------------------------------
    def latency_stats(self, status: str = "ok") -> dict:
        """p50/p99/mean latency (ms) by status, derived from the bounded
        registry histogram — the runtime no longer keeps a per-request
        list, so long-running deployments hold O(1) reporting state.
        Percentiles are the log-bucket sketch's (~4.5% resolution)."""
        s = self.metrics.histogram("serving/latency_s",
                                   status=status).summary()
        if s.get("n", 0) == 0:
            return dict(n=0)
        return dict(
            n=s["n"],
            p50_ms=float(s["p50"] * 1e3),
            p99_ms=float(s["p99"] * 1e3),
            mean_ms=float(s["mean"] * 1e3),
        )


__all__ = ["ServingRuntime", "Outcome", "Cursor"]
