"""Request runtime: deadlines, admission control, retries, degradation.

This is the layer between clients and the MVCC substrate
(core/snapshot.py).  Every read executes against a **pinned snapshot** —
writers (``insert`` / ``delete`` / ``compact`` on the runtime) mutate the
live store under its write lock and publish the new version when done — so
a burst of concurrent readers racing a background update stream each see
one consistent version end to end.

Request lifecycle (the degradation ladder, best outcome first):

  1. **ok** — admitted, pinned, answered before its deadline.  The outcome
     carries ``version`` (what the answer is consistent with) and
     ``stale=True`` when the pin was degraded (a writer held the flush
     lock past the pin timeout, so the *last published* version served).
  2. **retry** — a transient failure (:class:`~repro.testing.faults.FaultError`
     — injected churn, a device hiccup) inside the attempt is retried with
     jittered exponential backoff while the deadline allows; the sharded
     engine additionally degrades from the stacked shard_map executable to
     the per-shard dispatch loop on device failure (core/shard.py).
  3. **deadline** — admitted but out of time (before or during execution).
  4. **error** — a non-transient failure; reported, never raised into the
     worker loop.
  5. **shed** — the bounded admission queue is full; the request is
     rejected *at submit time* (backpressure), before consuming any
     execution resources.

All knobs are constructor arguments; ``stats`` / ``latency_stats()``
expose counts and p50/p99 for benchmarks (benchmarks/bench_serving.py).
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.snapshot import SnapshotRegistry
from repro.testing import faults
from repro.testing.faults import FaultError

_STOP = object()  # worker-loop sentinel


@dataclass
class Outcome:
    """What the runtime resolves a request's Future to (never an exception)."""

    status: str  # "ok" | "shed" | "deadline" | "error"
    answers: set | None = None
    version: int | None = None  # store version the answer is consistent with
    stale: bool = False  # True: degraded pin served the last published version
    retries: int = 0
    latency_s: float = 0.0
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclass
class _Request:
    patterns: list
    select: object
    mode: str | None
    deadline_t: float | None  # absolute monotonic deadline (None: unbounded)
    submitted_t: float
    future: Future = field(default_factory=Future)


class ServingRuntime:
    """Thread-pooled snapshot-isolated serving over one (Sharded)KnowledgeBase.

    >>> rt = ServingRuntime(K, modes=("litemat", "rewrite"))
    >>> with rt:
    ...     out = rt.serve(PAPER_QUERIES["Q3"])          # sync
    ...     fut = rt.submit(PAPER_QUERIES["Q1"])          # async
    ...     rt.insert(more_triples)                       # publishes new version
    ...     assert fut.result().ok
    """

    def __init__(self, kb, modes=("litemat",), use_index: bool = True,
                 n_workers: int = 2, max_queue: int = 64,
                 default_deadline_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.005,
                 retry_backoff_cap_s: float = 0.1,
                 pin_lock_timeout_s: float = 0.05, seed: int = 0):
        self.kb = kb
        self.registry = SnapshotRegistry(
            kb, modes=modes, use_index=use_index,
            lock_timeout_s=pin_lock_timeout_s)
        self.n_workers = n_workers
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self._queue: queue.Queue = queue.Queue(maxsize=max_queue)
        self._workers: list = []
        self._started = False
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._latencies: list = []  # (status, latency_s) per finished request
        self.stats = {
            "submitted": 0, "ok": 0, "shed": 0, "deadline": 0, "errors": 0,
            "retries": 0, "stale_served": 0, "updates": 0,
            "publish_failures": 0,
        }

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingRuntime":
        if not self._started:
            self._started = True
            self.registry.publish()
            for i in range(self.n_workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"serve-worker-{i}", daemon=True)
                t.start()
                self._workers.append(t)
        return self

    def stop(self) -> None:
        if self._started:
            for _ in self._workers:
                self._queue.put(_STOP)
            for t in self._workers:
                t.join()
            self._workers.clear()
            self._started = False

    def __enter__(self) -> "ServingRuntime":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- read path -----------------------------------------------------------
    def submit(self, patterns, select=None, mode: str | None = None,
               deadline_s: float | None = None) -> Future:
        """Admit a query (or shed it) and return a Future[Outcome].

        The Future always resolves to an :class:`Outcome` — shed and
        failed requests report through ``status``, they never raise.
        """
        if not self._started:
            self.start()
        now = time.monotonic()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        req = _Request(
            patterns=list(patterns), select=select, mode=mode,
            deadline_t=None if deadline_s is None else now + deadline_s,
            submitted_t=now)
        with self._lock:
            self.stats["submitted"] += 1
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            # backpressure: reject at admission, before any execution cost
            out = Outcome(status="shed", latency_s=time.monotonic() - now)
            self._finish(req, out)
        return req.future

    def serve(self, patterns, select=None, mode: str | None = None,
              deadline_s: float | None = None) -> Outcome:
        """Synchronous submit: blocks for this request's Outcome."""
        return self.submit(patterns, select=select, mode=mode,
                           deadline_s=deadline_s).result()

    # -- write path ----------------------------------------------------------
    def _write(self, op, *a, **kw) -> dict:
        with self.kb.write_lock:
            stats = op(*a, **kw)
            try:
                self.registry.publish()
            except Exception:  # noqa: BLE001 — degrade, don't fail the write
                # capture crashed (e.g. mid-flush): the mutation is
                # committed but unpublished — readers keep degrading to the
                # last published snapshot (stale tag) until a later pin or
                # publish captures this version successfully
                with self._lock:
                    self.stats["publish_failures"] += 1
        with self._lock:
            self.stats["updates"] += 1
        return stats

    def insert(self, raw, **kw) -> dict:
        return self._write(self.kb.insert, raw, **kw)

    def delete(self, raw, **kw) -> dict:
        return self._write(self.kb.delete, raw, **kw)

    def compact(self, **kw) -> dict:
        return self._write(self.kb.compact, **kw)

    # -- worker internals ----------------------------------------------------
    def _finish(self, req: _Request, out: Outcome) -> None:
        with self._lock:
            self.stats[out.status if out.status != "error" else "errors"] \
                += 1
            if out.stale and out.ok:
                self.stats["stale_served"] += 1
            self._latencies.append((out.status, out.latency_s))
        req.future.set_result(out)

    def _jitter(self, attempt: int) -> float:
        base = min(self.retry_backoff_cap_s,
                   self.retry_backoff_s * (2 ** attempt))
        with self._lock:
            u = float(self._rng.random())
        return base * (0.5 + 0.5 * u)

    def _worker_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is _STOP:
                return
            try:
                out = self._execute(req)
            except Exception as e:  # noqa: BLE001 — workers must survive
                out = Outcome(status="error",
                              latency_s=time.monotonic() - req.submitted_t,
                              error=f"{type(e).__name__}: {e}")
            self._finish(req, out)

    def _time_left(self, req: _Request) -> float:
        if req.deadline_t is None:
            return float("inf")
        return req.deadline_t - time.monotonic()

    def _execute(self, req: _Request) -> Outcome:
        retries = 0
        last_err: Exception | None = None
        while True:
            if self._time_left(req) <= 0:
                return Outcome(
                    status="deadline", retries=retries,
                    latency_s=time.monotonic() - req.submitted_t,
                    error=None if last_err is None else
                    f"{type(last_err).__name__}: {last_err}")
            pin = self.registry.pin()
            try:
                faults.fire("serving.execute", attempt=retries)
                answers = pin.answers(req.patterns, select=req.select,
                                      mode=req.mode)
                if self._time_left(req) < 0:
                    # finished late (e.g. a slow shard): the answer is
                    # useless to a deadlined caller — report the miss
                    return Outcome(
                        status="deadline", retries=retries,
                        latency_s=time.monotonic() - req.submitted_t)
                return Outcome(
                    status="ok", answers=answers, version=pin.version,
                    stale=pin.stale, retries=retries,
                    latency_s=time.monotonic() - req.submitted_t)
            except FaultError as e:
                # transient churn: back off with jitter and retry while
                # the deadline and the retry budget allow
                last_err = e
                if retries >= self.max_retries:
                    return Outcome(
                        status="error", retries=retries,
                        latency_s=time.monotonic() - req.submitted_t,
                        error=f"{type(e).__name__}: {e}")
                delay = self._jitter(retries)
                retries += 1
                with self._lock:
                    self.stats["retries"] += 1
                if self._time_left(req) <= delay:
                    return Outcome(
                        status="deadline", retries=retries,
                        latency_s=time.monotonic() - req.submitted_t,
                        error=f"{type(e).__name__}: {e}")
                time.sleep(delay)
            finally:
                pin.release()

    # -- reporting -----------------------------------------------------------
    def latency_stats(self, status: str = "ok") -> dict:
        with self._lock:
            lat = sorted(l for s, l in self._latencies if s == status)
        if not lat:
            return dict(n=0)
        arr = np.asarray(lat)
        return dict(
            n=len(lat),
            p50_ms=float(np.percentile(arr, 50) * 1e3),
            p99_ms=float(np.percentile(arr, 99) * 1e3),
            mean_ms=float(arr.mean() * 1e3),
        )


__all__ = ["ServingRuntime", "Outcome"]
