"""Batched query serving — LiteMat as an online inference service.

The paper's query processor evaluates one SPARQL query per Spark job.  A
serving deployment instead sees *streams* of parameterized queries ("all
members of class C", "all x with x:C and (x p y)") that share a plan and
differ only in constants.  Because LiteMat turns inference into interval
compares, a parameterized plan is a pure tensor function of (lo, hi) pairs —
a whole batch executes as ONE vmapped XLA call over the store.

Request resolution rides the (object, subject)-sorted type index
(core/index.py): a class interval [lo, hi) is two host binary searches +
one contiguous device slice, so per-request work is bounded by the *largest
class in the batch* (bucketed to a power of two), not the type view.
Answer semantics are DISTINCT subjects (SPARQL set semantics, matching the
QueryEngine oracle): an instance can legitimately carry several MSC types
inside the queried interval (e.g. Chair + FullProfessor under Professor),
so each request still deduplicates its own slice — a sort over the slice,
never over the view.

View freshness is automatic: every serving call compares the monotonic
``KnowledgeBase.version`` counter against the version its views were built
at and rebuilds them when the store has changed — ``insert`` / ``delete`` /
``compact`` need no manual invalidation.  ``invalidate()`` remains for the
one case the counter cannot see: direct (out-of-API) mutation of a store
field.

:class:`ShardedQueryServer` is the multi-device deployment of the same
plans over a :class:`~repro.core.shard.ShardedKB`: every shard keeps its
own type index and property view (class-membership subjects are co-hashed
— derived ``(x rdf:type C)`` rows live on ``shard(x)`` — so per-shard
distinct sets are DISJOINT), a batch fans out through ``shard_map`` (vmap
with fewer devices than shards), and the per-shard answers merge by
summing distinct counts and merge-sorting the per-shard member lists.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial

from jax.sharding import PartitionSpec as P

from repro.core.engine import KnowledgeBase
from repro.core.index import TypeIndex
from repro.kernels import ops
from repro.obs.metrics import REGISTRY
from repro.utils.jaxcompat import make_mesh, shard_map

INVALID = jnp.int32(np.iinfo(np.int32).max)


def _distinct_count_topk(hits, topk: int):
    """Sorted-dedup count + first-k distinct values of INVALID-padded hits."""
    h = jnp.sort(hits)
    first = jnp.concatenate([jnp.ones((1,), bool), h[1:] != h[:-1]])
    uniq = first & (h != INVALID)
    count = uniq.astype(jnp.int32).sum()
    vals = jnp.where(uniq, h, INVALID)
    top = -jax.lax.top_k(-vals, topk)[0]
    return count, jnp.where(top == INVALID, -1, top)


def _slice_hits(subj_os, start_row, len_row, cap: int):
    """Gather one request's type-index segments (primary + spill intervals)."""
    src, ok, _, _ = ops.segment_positions(start_row, len_row, cap)
    return jnp.where(ok, subj_os[jnp.clip(src, 0, subj_os.shape[0] - 1)],
                     INVALID)


def _members_shard(subj_os, starts, lens, cap: int, topk: int):
    """One store's batched Q1 plan (vmapped over the request axis)."""

    def one(start_row, len_row):
        return _distinct_count_topk(
            _slice_hits(subj_os, start_row, len_row, cap), topk)

    return jax.vmap(one)(starts, lens)


def _prop_join_shard(subj_os, ps_sorted, p_sorted, starts, lens,
                     plo, phi, cap: int, topk: int, kp: int):
    """One store's batched Q3 plan: x:C ⋈ (x p y) semi-join per request.

    The type side is an index slice; ``ps_sorted`` are property-triple
    subjects pre-sorted by (s, p) once per store, so each sliced subject
    semi-joins with one binary search per property interval (kp of them:
    primary + spills, usually 1).
    """

    from repro.utils import pair64

    def one(start_row, len_row, plo_row, phi_row):
        hits = _slice_hits(subj_os, start_row, len_row, cap)
        # rows are sorted by the (subject, predicate) composite, so the first
        # row >= (s, plo) decides the semi-join: it matches iff its subject
        # is s and its predicate is still < phi (contiguous interval run).
        hit = jnp.zeros(hits.shape, bool)
        for i in range(kp):
            X = pair64.searchsorted_pair(
                ps_sorted, p_sorted, hits,
                jnp.full(hits.shape, plo_row[i], jnp.int32), side="left",
            )
            Xc = jnp.clip(X, 0, ps_sorted.shape[0] - 1)
            hit = hit | ((ps_sorted[Xc] == hits) & (p_sorted[Xc] < phi_row[i]))
        return _distinct_count_topk(jnp.where(hit, hits, INVALID), topk)

    return jax.vmap(one)(starts, lens, plo, phi)


@partial(jax.jit, static_argnames=("cap", "topk"))
def _serve_class_members(subj_os, starts, lens, cap: int, topk: int):
    """vmapped Q1 plan over index slices: (B, k) ranges -> counts + members."""
    return _members_shard(subj_os, starts, lens, cap, topk)


@partial(jax.jit, static_argnames=("cap", "topk", "kp"))
def _serve_class_prop_join(subj_os, ps_sorted, p_sorted, starts, lens,
                           plo, phi, cap: int, topk: int, kp: int):
    """vmapped Q3 plan: x:C ⋈ (x p y) -> distinct-x counts + bindings."""
    return _prop_join_shard(subj_os, ps_sorted, p_sorted, starts, lens,
                            plo, phi, cap, topk, kp)


@dataclass
class QueryServer:
    """Compile-once, serve-batches facade over a KnowledgeBase."""

    K: KnowledgeBase
    topk: int = 32
    _views: dict = field(default_factory=dict)
    _seen_version: int | None = field(default=None)

    @property
    def served_version(self) -> int | None:
        """Store version the current views were (re)built at — what an
        answer returned right now is consistent with."""
        return self._seen_version

    def invalidate(self):
        """Drop derived views/indexes after an out-of-API store mutation.

        ``insert`` / ``delete`` / ``compact`` bump ``K.version`` and are
        picked up automatically; this only matters when a store field was
        swapped directly (tests, manual surgery).
        """
        self._views.clear()
        self._seen_version = self.K.version

    def _sync(self):
        """Rebuild every derived view atomically against ONE store version.

        The old pattern — compare the version, clear, let views rebuild
        lazily on first use — raced the writer: the type index could build
        at version v and the property view at v+1, silently mixing two
        stores in one batch.  Now a detected change rebuilds ALL views
        eagerly under the store's write lock (writers are excluded, so the
        version provably cannot move between the capture and the builds);
        the version-equality fast path stays lock-free.
        """
        if self._seen_version == self.K.version:
            return
        with self.K.write_lock:
            v = self.K.version
            self._views.clear()
            self._build_views()
            self._seen_version = v

    def _build_views(self):
        """Eagerly materialize every derived view (write lock held)."""
        self._type_index()
        self._prop_view()

    def _store(self):
        """The live lite store (base ∪ delta, tombstones dropped)."""
        return self.K.store_rows("litemat")

    def _type_index(self) -> TypeIndex:
        if "type_os" not in self._views:
            self._views["type_os"] = TypeIndex.build(
                self._store(), int(self.K.dtb.rdf_type_id))
        return self._views["type_os"]

    def _prop_view(self):
        """Property triples sorted by (subject, predicate)."""
        if "prop" not in self._views:
            spo = np.asarray(self._store())
            m = spo[:, 1] != self.K.dtb.rdf_type_id
            s, p = spo[m, 0], spo[m, 1]
            order = np.lexsort((p, s))
            self._views["prop"] = (jnp.asarray(s[order]), jnp.asarray(p[order]))
        return self._views["prop"]

    def _intervals(self, names, enc):
        """Per name: primary + spill [lo, hi) intervals, 0-padded to (B, k).

        Spill intervals carry the secondary-edge subsumees under multiple
        inheritance; dropping them would silently undercount (the
        QueryEngine oracle honors them, so the server must too).
        """
        per = []
        for n in names:
            (lo, hi), spills = enc.interval_of(n)
            per.append([(int(lo), int(hi))] + [(int(a), int(b))
                                               for a, b in spills])
        k = max(len(p) for p in per) if per else 1
        lo = np.zeros((len(names), k), np.int32)
        hi = np.zeros((len(names), k), np.int32)
        for i, p in enumerate(per):
            for j, (a, b) in enumerate(p):
                lo[i, j], hi[i, j] = a, b
        return lo, hi

    def _ranges(self, class_names):
        """Host-side index lookups: (starts, lens (B, k), capacity bucket)."""
        ti = self._type_index()
        clo, chi = self._intervals(class_names, self.K.kb.tbox.concepts)
        starts = np.zeros(clo.shape, np.int32)
        lens = np.zeros(clo.shape, np.int32)
        for i in range(clo.shape[0]):
            for j in range(clo.shape[1]):
                starts[i, j], lens[i, j] = ti.range_of(int(clo[i, j]),
                                                       int(chi[i, j]))
        from repro.core.query import _pow2

        longest = max(int(lens.sum(axis=1).max()) if lens.size else 1,
                      self.topk, 1)
        cap = _pow2(longest, floor=1)
        return ti, jnp.asarray(starts), jnp.asarray(lens), cap

    def class_members(self, class_names):
        """Batch of Q1-style requests -> (distinct counts, member ids)."""
        self._sync()
        REGISTRY.histogram("server/batch_size",
                           kind="members").observe(len(class_names))
        ti, starts, lens, cap = self._ranges(class_names)
        counts, members = _serve_class_members(ti.subj, starts, lens, cap,
                                               self.topk)
        return np.asarray(counts), np.asarray(members)

    def class_prop_join(self, class_names, prop_names):
        """Batch of Q3-style requests -> (distinct-x counts, x bindings)."""
        self._sync()
        REGISTRY.histogram("server/batch_size",
                           kind="prop_join").observe(len(class_names))
        ti, starts, lens, cap = self._ranges(class_names)
        ps, pp = self._prop_view()
        plo, phi = self._intervals(prop_names, self.K.kb.tbox.properties)
        counts, subs = _serve_class_prop_join(
            ti.subj, ps, pp, starts, lens, jnp.asarray(plo), jnp.asarray(phi),
            cap, self.topk, kp=int(plo.shape[1]),
        )
        return np.asarray(counts), np.asarray(subs)


# ---------------------------------------------------------------------------
# Sharded serving: per-shard fan-out + distinct-count merge
# ---------------------------------------------------------------------------


def _merge_members(members, topk: int):
    """Merge per-shard ascending member lists into the global smallest-topk.

    Subjects are co-hashed, so the per-shard distinct sets are disjoint and
    a merge-sort of the per-shard topk lists IS the global topk.  ``-1``
    padding maps through INVALID so it sorts last.
    """
    S, B, _ = members.shape
    m = jnp.where(members < 0, INVALID, members)
    m = jnp.transpose(m, (1, 0, 2)).reshape(B, -1)
    m = jnp.sort(m, axis=1)[:, :topk]
    return jnp.where(m == INVALID, -1, m)


def _pad_plane(arrs: list, fill) -> np.ndarray:
    """Stack 1-D arrays of unequal length into [S, max] with a fill tail."""
    cap = max(a.shape[0] for a in arrs)
    out = np.full((len(arrs), cap), fill, arrs[0].dtype)
    for i, a in enumerate(arrs):
        out[i, :a.shape[0]] = a
    return out


@dataclass
class ShardedQueryServer:
    """Compile-once, serve-batches facade over a ShardedKB.

    Identical request/answer contract to :class:`QueryServer` — counts and
    member lists are pinned equal in tests — but the device work fans out
    per shard: the batch's index ranges resolve against every shard's own
    type index, the stacked plans execute through ``shard_map`` when a
    device per shard exists (vmap otherwise — same math, one device), and
    the per-shard answers merge by summing counts (disjoint distinct sets)
    and merge-sorting member lists.
    """

    K: object  # ShardedKB
    topk: int = 32
    use_shard_map: bool | None = None
    _views: dict = field(default_factory=dict)
    _fans: dict = field(default_factory=dict, repr=False)
    _seen_version: int | None = field(default=None)

    @property
    def served_version(self) -> int | None:
        """Store version the current views were (re)built at."""
        return self._seen_version

    def invalidate(self):
        self._views.clear()
        self._seen_version = self.K.version

    def _sync(self):
        """Atomic resync — same contract as :meth:`QueryServer._sync`."""
        if self._seen_version == self.K.version:
            return
        with self.K.write_lock:
            v = self.K.version
            self._views.clear()
            self._build_views()
            self._seen_version = v

    def _build_views(self):
        """Eagerly materialize every derived view (write lock held)."""
        tis = self._type_indexes()
        self._prop_views()
        if "subj" not in self._views:
            self._views["subj"] = jnp.asarray(_pad_plane(
                [np.asarray(ti.subj) for ti in tis],
                np.int32(np.iinfo(np.int32).max)))

    def _sm(self) -> bool:
        if self.use_shard_map is not None:
            return self.use_shard_map
        return jax.local_device_count() >= self.K.n_shards > 1

    def _type_indexes(self):
        if "type_os" not in self._views:
            self.K._flush("litemat")
            tid = int(self.K.dtb.rdf_type_id)
            self._views["type_os"] = [
                TypeIndex.build(np.asarray(K.store_rows("litemat")), tid)
                for K in self.K.shards]
        return self._views["type_os"]

    def _prop_views(self):
        if "prop" not in self._views:
            self.K._flush("litemat")
            tid = self.K.dtb.rdf_type_id
            ps, pp = [], []
            for K in self.K.shards:
                spo = np.asarray(K.store_rows("litemat"))
                m = spo[:, 1] != tid
                s, p = spo[m, 0], spo[m, 1]
                order = np.lexsort((p, s))
                ps.append(s[order])
                pp.append(p[order])
            self._views["prop"] = (
                jnp.asarray(_pad_plane(ps, np.int32(np.iinfo(np.int32).max))),
                jnp.asarray(_pad_plane(pp, np.int32(np.iinfo(np.int32).max))))
        return self._views["prop"]

    _intervals = QueryServer._intervals  # same host-side interval resolution

    def _ranges(self, class_names):
        """Per-shard index lookups -> stacked (subj, starts, lens, cap)."""
        tis = self._type_indexes()
        clo, chi = self._intervals(class_names, self.K.kb.tbox.concepts)
        S, B, k = len(tis), clo.shape[0], clo.shape[1]
        starts = np.zeros((S, B, k), np.int32)
        lens = np.zeros((S, B, k), np.int32)
        for si, ti in enumerate(tis):
            for i in range(B):
                for j in range(k):
                    starts[si, i, j], lens[si, i, j] = ti.range_of(
                        int(clo[i, j]), int(chi[i, j]))
        from repro.core.query import _pow2

        longest = max(
            int(lens.sum(axis=2).max()) if lens.size else 1, self.topk, 1)
        cap = _pow2(longest, floor=1)
        if "subj" not in self._views:
            self._views["subj"] = jnp.asarray(_pad_plane(
                [np.asarray(ti.subj) for ti in tis],
                np.int32(np.iinfo(np.int32).max)))
        return (self._views["subj"], jnp.asarray(starts), jnp.asarray(lens),
                cap)

    def _fan_members(self, subj, starts, lens, cap: int):
        """Stacked per-shard Q1 execution: shard_map or vmap fan-out."""
        key = ("members", cap, self.topk, self._sm())
        fn = self._fans.get(key)
        if fn is None:
            if self._sm():
                mesh = make_mesh((self.K.n_shards,), ("shard",))

                def body(su, st, ln):
                    c, m = _members_shard(su[0], st[0], ln[0], cap, self.topk)
                    return c[None], m[None]

                fn = jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(P("shard"),) * 3,
                    out_specs=(P("shard"),) * 2, check_vma=False))
            else:
                fn = jax.jit(jax.vmap(
                    lambda su, st, ln: _members_shard(
                        su, st, ln, cap, self.topk)))
            self._fans[key] = fn
        return fn(subj, starts, lens)

    def _fan_prop_join(self, subj, ps, pp, starts, lens, plo, phi,
                       cap: int, kp: int):
        key = ("propjoin", cap, self.topk, kp, self._sm())
        fn = self._fans.get(key)
        if fn is None:
            if self._sm():
                mesh = make_mesh((self.K.n_shards,), ("shard",))

                def body(su, s_, p_, st, ln, lo, hi):
                    c, m = _prop_join_shard(
                        su[0], s_[0], p_[0], st[0], ln[0], lo[0], hi[0],
                        cap, self.topk, kp)
                    return c[None], m[None]

                fn = jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(P("shard"),) * 7,
                    out_specs=(P("shard"),) * 2, check_vma=False))
            else:
                fn = jax.jit(jax.vmap(
                    lambda su, s_, p_, st, ln, lo, hi: _prop_join_shard(
                        su, s_, p_, st, ln, lo, hi, cap, self.topk, kp)))
            self._fans[key] = fn
        return fn(subj, ps, pp, starts, lens, plo, phi)

    def class_members(self, class_names):
        """Batched Q1: fan out per shard, sum counts, merge member lists."""
        self._sync()
        REGISTRY.histogram("server/batch_size",
                           kind="members").observe(len(class_names))
        subj, starts, lens, cap = self._ranges(class_names)
        counts, members = self._fan_members(subj, starts, lens, cap)
        return (np.asarray(counts.sum(axis=0)),
                np.asarray(_merge_members(members, self.topk)))

    def class_prop_join(self, class_names, prop_names):
        """Batched Q3: the semi-join is fully shard-local (co-hashed x)."""
        self._sync()
        REGISTRY.histogram("server/batch_size",
                           kind="prop_join").observe(len(class_names))
        subj, starts, lens, cap = self._ranges(class_names)
        ps, pp = self._prop_views()
        plo, phi = self._intervals(prop_names, self.K.kb.tbox.properties)
        S = self.K.n_shards
        plo_s = jnp.broadcast_to(jnp.asarray(plo), (S, *plo.shape))
        phi_s = jnp.broadcast_to(jnp.asarray(phi), (S, *phi.shape))
        counts, subs = self._fan_prop_join(
            subj, ps, pp, starts, lens, plo_s, phi_s, cap,
            kp=int(plo.shape[1]))
        return (np.asarray(counts.sum(axis=0)),
                np.asarray(_merge_members(subs, self.topk)))
