"""Batched query serving — LiteMat as an online inference service.

The paper's query processor evaluates one SPARQL query per Spark job.  A
serving deployment instead sees *streams* of parameterized queries ("all
members of class C", "all x with x:C and (x p y)") that share a plan and
differ only in constants.  Because LiteMat turns inference into interval
compares, a parameterized plan is a pure tensor function of (lo, hi) pairs —
a whole batch executes as ONE vmapped XLA call over the store.

Answer semantics are DISTINCT subjects (SPARQL set semantics, matching the
QueryEngine oracle): an instance can legitimately carry several MSC types
inside the queried interval (e.g. Chair + FullProfessor under Professor), so
each request deduplicates its hits.  The type-triple subset is pre-extracted
once so the per-request sort runs over ~#type-rows, not the whole store.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial

from repro.core.engine import KnowledgeBase

INVALID = jnp.int32(np.iinfo(np.int32).max)


def _distinct_count_topk(hits, topk: int):
    """Sorted-dedup count + first-k distinct values of INVALID-padded hits."""
    h = jnp.sort(hits)
    first = jnp.concatenate([jnp.ones((1,), bool), h[1:] != h[:-1]])
    uniq = first & (h != INVALID)
    count = uniq.astype(jnp.int32).sum()
    vals = jnp.where(uniq, h, INVALID)
    top = -jax.lax.top_k(-vals, topk)[0]
    return count, jnp.where(top == INVALID, -1, top)


@partial(jax.jit, static_argnames=("topk",))
def _serve_class_members(ty_s, ty_o, clo, chi, topk: int):
    """vmapped Q1 plan: (B,) class intervals -> distinct counts + members."""

    def one(lo, hi):
        mask = (ty_o >= lo) & (ty_o < hi)
        return _distinct_count_topk(jnp.where(mask, ty_s, INVALID), topk)

    return jax.vmap(one)(clo, chi)


@partial(jax.jit, static_argnames=("topk",))
def _serve_class_prop_join(ty_s, ty_o, ps_sorted, p_sorted, clo, chi, plo, phi, topk: int):
    """vmapped Q3 plan: x:C ⋈ (x p y) -> distinct-x counts + bindings.

    ``ps_sorted`` are property-triple subjects pre-sorted by (p, s) once per
    store, so each request semi-joins with two binary searches per type row.
    """

    from repro.utils import pair64

    def one(lo, hi, plo_, phi_):
        tmask = (ty_o >= lo) & (ty_o < hi)
        # rows are sorted by the (subject, predicate) composite, so the first
        # row >= (s, plo) decides the semi-join: it matches iff its subject
        # is s and its predicate is still < phi (contiguous interval run).
        X = pair64.searchsorted_pair(
            ps_sorted, p_sorted, ty_s, jnp.full(ty_s.shape, plo_, jnp.int32), side="left"
        )
        Xc = jnp.clip(X, 0, ps_sorted.shape[0] - 1)
        hit = (ps_sorted[Xc] == ty_s) & (p_sorted[Xc] < phi_)
        semi = tmask & hit
        return _distinct_count_topk(jnp.where(semi, ty_s, INVALID), topk)

    return jax.vmap(one)(clo, chi, plo, phi)


@dataclass
class QueryServer:
    """Compile-once, serve-batches facade over a KnowledgeBase."""

    K: KnowledgeBase
    topk: int = 32
    _views: dict = field(default_factory=dict)

    def _type_view(self):
        if "type" not in self._views:
            spo = self.K.lite_spo
            m = np.asarray(spo[:, 1] == self.K.dtb.rdf_type_id)
            self._views["type"] = (
                jnp.asarray(np.asarray(spo[:, 0])[m]),
                jnp.asarray(np.asarray(spo[:, 2])[m]),
            )
        return self._views["type"]

    def _prop_view(self):
        """Property triples sorted by (subject, predicate)."""
        if "prop" not in self._views:
            spo = np.asarray(self.K.lite_spo)
            m = spo[:, 1] != self.K.dtb.rdf_type_id
            s, p = spo[m, 0], spo[m, 1]
            order = np.lexsort((p, s))
            self._views["prop"] = (jnp.asarray(s[order]), jnp.asarray(p[order]))
        return self._views["prop"]

    def _intervals(self, names, enc):
        lo = np.empty(len(names), np.int32)
        hi = np.empty(len(names), np.int32)
        for i, n in enumerate(names):
            (l, h), _ = enc.interval_of(n)
            lo[i], hi[i] = l, h
        return jnp.asarray(lo), jnp.asarray(hi)

    def class_members(self, class_names):
        """Batch of Q1-style requests -> (distinct counts, member ids)."""
        ty_s, ty_o = self._type_view()
        clo, chi = self._intervals(class_names, self.K.kb.tbox.concepts)
        counts, members = _serve_class_members(ty_s, ty_o, clo, chi, self.topk)
        return np.asarray(counts), np.asarray(members)

    def class_prop_join(self, class_names, prop_names):
        """Batch of Q3-style requests -> (distinct-x counts, x bindings)."""
        ty_s, ty_o = self._type_view()
        ps, pp = self._prop_view()
        clo, chi = self._intervals(class_names, self.K.kb.tbox.concepts)
        plo, phi = self._intervals(prop_names, self.K.kb.tbox.properties)
        counts, subs = _serve_class_prop_join(
            ty_s, ty_o, ps, pp, clo, chi, plo, phi, self.topk
        )
        return np.asarray(counts), np.asarray(subs)
