"""Batched query serving — LiteMat as an online inference service.

The paper's query processor evaluates one SPARQL query per Spark job.  A
serving deployment instead sees *streams* of parameterized queries ("all
members of class C", "all x with x:C and (x p y)") that share a plan and
differ only in constants.  Because LiteMat turns inference into interval
compares, a parameterized plan is a pure tensor function of (lo, hi) pairs —
a whole batch executes as ONE vmapped XLA call over the store.

Request resolution rides the (object, subject)-sorted type index
(core/index.py): a class interval [lo, hi) is two host binary searches +
one contiguous device slice, so per-request work is bounded by the *largest
class in the batch* (bucketed to a power of two), not the type view.
Answer semantics are DISTINCT subjects (SPARQL set semantics, matching the
QueryEngine oracle): an instance can legitimately carry several MSC types
inside the queried interval (e.g. Chair + FullProfessor under Professor),
so each request still deduplicates its own slice — a sort over the slice,
never over the view.

View freshness is automatic: every serving call compares the monotonic
``KnowledgeBase.version`` counter against the version its views were built
at and rebuilds them when the store has changed — ``insert`` / ``delete`` /
``compact`` need no manual invalidation.  ``invalidate()`` remains for the
one case the counter cannot see: direct (out-of-API) mutation of a store
field.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial

from repro.core.engine import KnowledgeBase
from repro.core.index import TypeIndex
from repro.kernels import ops

INVALID = jnp.int32(np.iinfo(np.int32).max)


def _distinct_count_topk(hits, topk: int):
    """Sorted-dedup count + first-k distinct values of INVALID-padded hits."""
    h = jnp.sort(hits)
    first = jnp.concatenate([jnp.ones((1,), bool), h[1:] != h[:-1]])
    uniq = first & (h != INVALID)
    count = uniq.astype(jnp.int32).sum()
    vals = jnp.where(uniq, h, INVALID)
    top = -jax.lax.top_k(-vals, topk)[0]
    return count, jnp.where(top == INVALID, -1, top)


def _slice_hits(subj_os, start_row, len_row, cap: int):
    """Gather one request's type-index segments (primary + spill intervals)."""
    src, ok, _, _ = ops.segment_positions(start_row, len_row, cap)
    return jnp.where(ok, subj_os[jnp.clip(src, 0, subj_os.shape[0] - 1)],
                     INVALID)


@partial(jax.jit, static_argnames=("cap", "topk"))
def _serve_class_members(subj_os, starts, lens, cap: int, topk: int):
    """vmapped Q1 plan over index slices: (B, k) ranges -> counts + members."""

    def one(start_row, len_row):
        return _distinct_count_topk(
            _slice_hits(subj_os, start_row, len_row, cap), topk)

    return jax.vmap(one)(starts, lens)


@partial(jax.jit, static_argnames=("cap", "topk", "kp"))
def _serve_class_prop_join(subj_os, ps_sorted, p_sorted, starts, lens,
                           plo, phi, cap: int, topk: int, kp: int):
    """vmapped Q3 plan: x:C ⋈ (x p y) -> distinct-x counts + bindings.

    The type side is an index slice; ``ps_sorted`` are property-triple
    subjects pre-sorted by (s, p) once per store, so each sliced subject
    semi-joins with one binary search per property interval (kp of them:
    primary + spills, usually 1).
    """

    from repro.utils import pair64

    def one(start_row, len_row, plo_row, phi_row):
        hits = _slice_hits(subj_os, start_row, len_row, cap)
        # rows are sorted by the (subject, predicate) composite, so the first
        # row >= (s, plo) decides the semi-join: it matches iff its subject
        # is s and its predicate is still < phi (contiguous interval run).
        hit = jnp.zeros(hits.shape, bool)
        for i in range(kp):
            X = pair64.searchsorted_pair(
                ps_sorted, p_sorted, hits,
                jnp.full(hits.shape, plo_row[i], jnp.int32), side="left",
            )
            Xc = jnp.clip(X, 0, ps_sorted.shape[0] - 1)
            hit = hit | ((ps_sorted[Xc] == hits) & (p_sorted[Xc] < phi_row[i]))
        return _distinct_count_topk(jnp.where(hit, hits, INVALID), topk)

    return jax.vmap(one)(starts, lens, plo, phi)


@dataclass
class QueryServer:
    """Compile-once, serve-batches facade over a KnowledgeBase."""

    K: KnowledgeBase
    topk: int = 32
    _views: dict = field(default_factory=dict)
    _seen_version: int | None = field(default=None)

    def invalidate(self):
        """Drop derived views/indexes after an out-of-API store mutation.

        ``insert`` / ``delete`` / ``compact`` bump ``K.version`` and are
        picked up automatically; this only matters when a store field was
        swapped directly (tests, manual surgery).
        """
        self._views.clear()
        self._seen_version = self.K.version

    def _sync(self):
        """Auto-invalidate when the KnowledgeBase has moved past our views."""
        if self._seen_version != self.K.version:
            self._views.clear()
            self._seen_version = self.K.version

    def _store(self):
        """The live lite store (base ∪ delta, tombstones dropped)."""
        return self.K.store_rows("litemat")

    def _type_index(self) -> TypeIndex:
        if "type_os" not in self._views:
            self._views["type_os"] = TypeIndex.build(
                self._store(), int(self.K.dtb.rdf_type_id))
        return self._views["type_os"]

    def _prop_view(self):
        """Property triples sorted by (subject, predicate)."""
        if "prop" not in self._views:
            spo = np.asarray(self._store())
            m = spo[:, 1] != self.K.dtb.rdf_type_id
            s, p = spo[m, 0], spo[m, 1]
            order = np.lexsort((p, s))
            self._views["prop"] = (jnp.asarray(s[order]), jnp.asarray(p[order]))
        return self._views["prop"]

    def _intervals(self, names, enc):
        """Per name: primary + spill [lo, hi) intervals, 0-padded to (B, k).

        Spill intervals carry the secondary-edge subsumees under multiple
        inheritance; dropping them would silently undercount (the
        QueryEngine oracle honors them, so the server must too).
        """
        per = []
        for n in names:
            (lo, hi), spills = enc.interval_of(n)
            per.append([(int(lo), int(hi))] + [(int(a), int(b))
                                               for a, b in spills])
        k = max(len(p) for p in per) if per else 1
        lo = np.zeros((len(names), k), np.int32)
        hi = np.zeros((len(names), k), np.int32)
        for i, p in enumerate(per):
            for j, (a, b) in enumerate(p):
                lo[i, j], hi[i, j] = a, b
        return lo, hi

    def _ranges(self, class_names):
        """Host-side index lookups: (starts, lens (B, k), capacity bucket)."""
        ti = self._type_index()
        clo, chi = self._intervals(class_names, self.K.kb.tbox.concepts)
        starts = np.zeros(clo.shape, np.int32)
        lens = np.zeros(clo.shape, np.int32)
        for i in range(clo.shape[0]):
            for j in range(clo.shape[1]):
                starts[i, j], lens[i, j] = ti.range_of(int(clo[i, j]),
                                                       int(chi[i, j]))
        from repro.core.query import _pow2

        longest = max(int(lens.sum(axis=1).max()) if lens.size else 1,
                      self.topk, 1)
        cap = _pow2(longest, floor=1)
        return ti, jnp.asarray(starts), jnp.asarray(lens), cap

    def class_members(self, class_names):
        """Batch of Q1-style requests -> (distinct counts, member ids)."""
        self._sync()
        ti, starts, lens, cap = self._ranges(class_names)
        counts, members = _serve_class_members(ti.subj, starts, lens, cap,
                                               self.topk)
        return np.asarray(counts), np.asarray(members)

    def class_prop_join(self, class_names, prop_names):
        """Batch of Q3-style requests -> (distinct-x counts, x bindings)."""
        self._sync()
        ti, starts, lens, cap = self._ranges(class_names)
        ps, pp = self._prop_view()
        plo, phi = self._intervals(prop_names, self.K.kb.tbox.properties)
        counts, subs = _serve_class_prop_join(
            ti.subj, ps, pp, starts, lens, jnp.asarray(plo), jnp.asarray(phi),
            cap, self.topk, kp=int(plo.shape[1]),
        )
        return np.asarray(counts), np.asarray(subs)
