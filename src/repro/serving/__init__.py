from repro.serving.engine import QueryServer
from repro.serving.runtime import Outcome, ServingRuntime
