from repro.serving.engine import QueryServer
