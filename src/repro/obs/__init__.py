"""repro.obs — end-to-end tracing and metrics for the serving stack.

Three small modules, imported lazily by the layers they instrument:

  * :mod:`repro.obs.metrics` — counters / gauges / log-bucketed histogram
    sketches in a :class:`~repro.obs.metrics.MetricsRegistry`; the
    module-level ``REGISTRY`` is the process-wide default.
  * :mod:`repro.obs.trace` — per-request span trees propagated via
    contextvars; ``span(...)`` is a cheap no-op when no trace is active.
  * :mod:`repro.obs.export` — JSON dumps and the trace schema validator
    that CI runs over every exported trace.
"""
from repro.obs.metrics import REGISTRY, MetricsRegistry  # noqa: F401
from repro.obs.trace import Tracer, activate, event, span  # noqa: F401

__all__ = ["REGISTRY", "MetricsRegistry", "Tracer", "activate", "event",
           "span"]
