"""repro.obs — fleet telemetry for the serving stack.

Six small modules, imported lazily by the layers they instrument:

  * :mod:`repro.obs.metrics` — counters / gauges / log-bucketed histogram
    sketches in a :class:`~repro.obs.metrics.MetricsRegistry`; the
    module-level ``REGISTRY`` is the process-wide default.  Histogram
    states are mergeable (bucket-wise), and ``mergeable_snapshot()``
    emits the cross-process wire form.
  * :mod:`repro.obs.trace` — per-request span trees propagated via
    contextvars; ``span(...)`` is a cheap no-op when no trace is active.
  * :mod:`repro.obs.export` — JSON dumps plus the trace AND metrics
    snapshot schema validators CI runs over every exported artifact.
  * :mod:`repro.obs.aggregate` — combines per-process mergeable snapshots
    into ONE fleet snapshot (counters sum, histograms merge bucket-wise,
    gauges keep per-process labels).
  * :mod:`repro.obs.ledger` — pull-based device-memory accounting:
    ``hbm_bytes{shard,component}`` and ``store/bytes_per_triple`` gauges
    from weakly-tracked buffer owners.
  * :mod:`repro.obs.slo` — windowed rollups (rates as first-class
    series) and error-budget burn-rate monitoring that drives the
    serving runtime's admission control.
"""
from repro.obs.metrics import REGISTRY, MetricsRegistry  # noqa: F401
from repro.obs.trace import Tracer, activate, event, span  # noqa: F401

__all__ = ["REGISTRY", "MetricsRegistry", "Tracer", "activate", "event",
           "span"]
