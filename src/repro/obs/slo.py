"""Windowed telemetry rollups + SLO burn-rate monitoring (control plane).

Raw counters and histogram sketches only say "what happened since the
process started"; operating a serving fleet needs *rates* ("how many
requests are we shedding per second, right now?") and *error budgets*
("at this miss rate, how fast are we burning the SLO?").  This module
closes that gap — and closes the loop:

:class:`TelemetryRollup`
    A sampler (optionally a daemon thread) that ticks a
    :class:`~repro.obs.metrics.MetricsRegistry` into a bounded timeline of
    points.  Each tick diffs monitored counters into per-second rates
    (published back as ``rate/<name>`` gauges, so arrival / shed /
    deadline-miss rates are first-class series in every snapshot), windows
    monitored histograms through
    :func:`~repro.obs.metrics.window_summary` (published as
    ``rollup/<name>/p50|p99|n`` gauges), samples the
    :class:`~repro.obs.ledger.ResourceLedger` for device-byte gauges, and
    feeds the :class:`SLOMonitor`.

:class:`SLO` / :class:`SLOMonitor`
    An SLO declares an error budget: "at most ``objective`` of events may
    be bad".  Badness is either a counter ratio (deadline misses /
    submissions; stale serves / oks) or a latency-threshold exceedance
    read from the histogram sketch's bucket diff (fraction of requests
    slower than ``threshold_s``).  The monitor computes **burn rates** —
    observed bad fraction / objective — over a fast and a slow window of
    rollup ticks; sustained burn over both windows escalates
    ``ok -> warn -> page``, and the fast window's recovery de-escalates.
    State lives in ``slo/state{slo=}`` gauges and every overall transition
    invokes registered callbacks.

The serving runtime (:meth:`repro.serving.runtime.ServingRuntime.
enable_slo_control`) registers a callback that tightens its admission
bound and widens its batch window on ``warn``/``page`` and restores them
on recovery — load shedding driven by the error budget itself rather than
by a static queue size.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

from repro.obs.metrics import REGISTRY, _bucket_value, window_summary

_STATES = ("ok", "warn", "page")
_RANK = {s: i for i, s in enumerate(_STATES)}


def _spec(name: str, **labels) -> tuple:
    """Hashable (name, ((k, v), ...)) instrument spec."""
    return (name, tuple(sorted(labels.items())))


#: Counters every rollup rates by default (the serving arrival/outcome set).
DEFAULT_RATE_COUNTERS = (
    _spec("serving/submitted"),
    _spec("serving/outcomes", status="ok"),
    _spec("serving/outcomes", status="shed"),
    _spec("serving/outcomes", status="deadline"),
    _spec("serving/retries"),
    _spec("serving/stale_served"),
)

#: Histograms every rollup windows by default.
DEFAULT_WINDOW_HISTS = (
    _spec("serving/latency_s", status="ok"),
)


@dataclass(frozen=True)
class SLO:
    """One service-level objective: "at most ``objective`` of events bad".

    ``kind="ratio"``: bad fraction = Δ``num`` / Δ``den`` counter diffs.
    ``kind="latency"``: bad fraction = share of Δ``hist`` observations
    whose bucket value exceeds ``threshold_s``.
    """

    name: str
    objective: float  # allowed bad fraction of events (error budget)
    kind: str = "ratio"  # "ratio" | "latency"
    num: tuple = ()  # counter spec (ratio kind)
    den: tuple = ()  # counter spec (ratio kind)
    hist: tuple = ()  # histogram spec (latency kind)
    threshold_s: float = 0.0  # latency threshold (latency kind)


def default_serving_slos(latency_threshold_s: float = 0.1,
                         latency_objective: float = 0.05,
                         miss_objective: float = 0.02,
                         stale_objective: float = 0.10) -> tuple:
    """The serving runtime's stock SLO set: p-latency, deadline-miss
    rate, staleness — the three the ISSUE's control loop acts on."""
    return (
        SLO(name="latency", kind="latency", objective=latency_objective,
            hist=_spec("serving/latency_s", status="ok"),
            threshold_s=latency_threshold_s),
        SLO(name="deadline_miss", objective=miss_objective,
            num=_spec("serving/outcomes", status="deadline"),
            den=_spec("serving/submitted")),
        SLO(name="staleness", objective=stale_objective,
            num=_spec("serving/stale_served"),
            den=_spec("serving/outcomes", status="ok")),
    )


class SLOMonitor:
    """Multi-window error-budget burn rates + ok/warn/page state machine.

    Reads points from a :class:`TelemetryRollup` timeline (it never
    touches the registry's instruments directly, so one collection pass
    serves both rates and burn rates).  Per SLO and per window::

        burn = (bad events / total events) / objective

    ``burn == 1.0`` means "bad at exactly the budgeted rate"; sustained
    ``burn >= page_burn`` over BOTH the fast and the slow window pages.
    Using ``min(fast, slow)`` makes escalation require a sustained burn
    (a single hiccup moves only the fast window) and de-escalation track
    the fast window (recovery is visible immediately).
    """

    def __init__(self, slos, fast_window: int = 3, slow_window: int = 12,
                 warn_burn: float = 1.0, page_burn: float = 2.0,
                 min_events: int = 8, registry=None):
        self.slos = tuple(slos)
        self.fast_window = int(fast_window)
        self.slow_window = int(slow_window)
        self.warn_burn = float(warn_burn)
        self.page_burn = float(page_burn)
        self.min_events = int(min_events)
        self.registry = registry if registry is not None else REGISTRY
        self.state = "ok"
        self.detail: dict = {}
        self._cbs: list = []

    def on_transition(self, cb) -> None:
        """``cb(new_state, detail)`` fires on every OVERALL state change."""
        self._cbs.append(cb)

    def counter_specs(self) -> tuple:
        return tuple(s for slo in self.slos for s in (slo.num, slo.den) if s)

    def hist_specs(self) -> tuple:
        return tuple(slo.hist for slo in self.slos if slo.hist)

    # -- burn math -----------------------------------------------------------
    def _bad_fraction(self, slo: SLO, a: dict, b: dict):
        """Bad fraction of events between timeline points a -> b, or None
        when the window holds too few events to mean anything."""
        if slo.kind == "ratio":
            den = b["counters"].get(slo.den, 0) - a["counters"].get(slo.den, 0)
            if den < self.min_events:
                return None
            num = b["counters"].get(slo.num, 0) - a["counters"].get(slo.num, 0)
            return num / den
        sa = a["hists"].get(slo.hist, {"buckets": {}, "count": 0})
        sb = b["hists"].get(slo.hist, {"buckets": {}, "count": 0})
        total = sb["count"] - sa["count"]
        if total < self.min_events:
            return None
        over = sum(
            c - sa["buckets"].get(bk, 0)
            for bk, c in sb["buckets"].items()
            if c > sa["buckets"].get(bk, 0)
            and _bucket_value(bk) > slo.threshold_s)
        return over / total

    def _window_burn(self, slo: SLO, timeline, n: int):
        if len(timeline) < 2:
            return None
        a = timeline[max(0, len(timeline) - 1 - n)]
        frac = self._bad_fraction(slo, a, timeline[-1])
        if frac is None:
            return None
        return frac / max(slo.objective, 1e-12)

    def observe(self, timeline) -> str:
        """One evaluation pass over the rollup timeline; returns state."""
        detail = {}
        worst = "ok"
        for slo in self.slos:
            fast = self._window_burn(slo, timeline, self.fast_window)
            slow = self._window_burn(slo, timeline, self.slow_window)
            sustained = min(fast or 0.0, slow or 0.0)
            if sustained >= self.page_burn:
                state = "page"
            elif sustained >= self.warn_burn:
                state = "warn"
            else:
                state = "ok"
            detail[slo.name] = {"fast": fast, "slow": slow, "state": state}
            self.registry.gauge("slo/burn_rate", slo=slo.name,
                                window="fast").set(fast or 0.0)
            self.registry.gauge("slo/burn_rate", slo=slo.name,
                                window="slow").set(slow or 0.0)
            self.registry.gauge("slo/state", slo=slo.name).set(_RANK[state])
            if _RANK[state] > _RANK[worst]:
                worst = state
        self.detail = detail
        self.registry.gauge("slo/state_overall").set(_RANK[worst])
        if worst != self.state:
            prev, self.state = self.state, worst
            self.registry.counter("slo/transitions", frm=prev,
                                  to=worst).inc()
            for cb in self._cbs:
                cb(worst, detail)
        return self.state


class TelemetryRollup:
    """Bounded-timeline sampler: counters -> rates, histograms -> windows.

    ``tick()`` is safe to call directly (tests and benches drive the loop
    synchronously); ``start()`` runs it on a daemon thread every
    ``interval_s``.  The timeline is a deque of points::

        {"t": monotonic, "counters": {spec: value},
         "hists": {spec: state}, "rates": {spec: per_second}}

    bounded at ``maxlen`` — long-running deployments hold O(maxlen)
    reporting state no matter the request volume.
    """

    def __init__(self, registry=None, interval_s: float = 0.25,
                 maxlen: int = 240, ledger=None, monitor: SLOMonitor = None,
                 rate_counters=DEFAULT_RATE_COUNTERS,
                 window_hists=DEFAULT_WINDOW_HISTS):
        self.registry = registry if registry is not None else REGISTRY
        self.interval_s = float(interval_s)
        self.ledger = ledger
        self.monitor = monitor
        rate_counters = tuple(rate_counters)
        window_hists = tuple(window_hists)
        if monitor is not None:  # one collection pass serves the monitor too
            rate_counters = tuple(dict.fromkeys(
                rate_counters + monitor.counter_specs()))
            window_hists = tuple(dict.fromkeys(
                window_hists + monitor.hist_specs()))
        self.rate_counters = rate_counters
        self.window_hists = window_hists
        self.timeline: deque = deque(maxlen=maxlen)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def tick(self) -> dict:
        """One sample: collect, publish rate/rollup gauges, feed monitor."""
        reg = self.registry
        point = {
            "t": time.monotonic(),
            "counters": {s: reg.counter_value(s[0], **dict(s[1]))
                         for s in self.rate_counters},
            "hists": {s: reg.histogram(s[0], **dict(s[1])).state()
                      for s in self.window_hists},
            "rates": {},
        }
        if self.timeline:
            prev = self.timeline[-1]
            dt = max(point["t"] - prev["t"], 1e-9)
            for s in self.rate_counters:
                rate = (point["counters"][s]
                        - prev["counters"].get(s, 0)) / dt
                point["rates"][s] = rate
                reg.gauge("rate/" + s[0], **dict(s[1])).set(rate)
            for s in self.window_hists:
                w = window_summary(reg.histogram(s[0], **dict(s[1])),
                                   prev["hists"].get(
                                       s, {"buckets": {}, "count": 0,
                                           "sum": 0.0}))
                labels = dict(s[1])
                reg.gauge(f"rollup/{s[0]}/n", **labels).set(w.get("n", 0))
                if w.get("n"):
                    reg.gauge(f"rollup/{s[0]}/p50",
                              **labels).set(w["p50"])
                    reg.gauge(f"rollup/{s[0]}/p99",
                              **labels).set(w["p99"])
        self.timeline.append(point)
        if self.ledger is not None:
            self.ledger.sample()
        if self.monitor is not None:
            self.monitor.observe(self.timeline)
        return point

    def rate_series(self, name: str, **labels) -> list:
        """First-class rate series: [(t, per_second), ...] for one counter."""
        s = _spec(name, **labels)
        return [(p["t"], p["rates"][s]) for p in self.timeline
                if s in p["rates"]]

    # -- thread --------------------------------------------------------------
    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.tick()
            except Exception:  # noqa: BLE001 — telemetry must not crash
                self.registry.counter("rollup/tick_errors").inc()

    def start(self) -> "TelemetryRollup":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="telemetry-rollup", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None


__all__ = ["SLO", "SLOMonitor", "TelemetryRollup", "default_serving_slos",
           "DEFAULT_RATE_COUNTERS", "DEFAULT_WINDOW_HISTS"]
