"""Span-based request tracing with contextvar propagation.

One :class:`Trace` per served request, identified by a ``trace_id`` minted
at ``ServingRuntime.submit`` and carried on the request object into the
worker thread.  Inside the worker, :class:`activate` roots the trace in a
``contextvars.ContextVar`` so every layer below — snapshot pin, plan-cache
lookup, executable dispatch, shard_map fallback — can open child spans
with plain ``with span("pin"):`` blocks and land under the right parent
without plumbing ids through call signatures.

The design mirrors ``repro.testing.faults``: instrumentation is a
module-level context slot that is empty by default, and :func:`span` is a
shared no-op context manager when nothing is active.  Instrumented code
pays one contextvar read + one ``is None`` test per call site when
tracing is off — that is what keeps the <3% overhead gate honest.

Span shape (see obs/export.py for the JSON schema):

    name        e.g. "request", "queue", "attempt", "pin", "execute"
    span_id / parent_id   ids local to the trace; exactly one root (-1)
    t0 / t1     perf_counter seconds relative to the tracer epoch
    attrs       set at open or via set_attr() (e.g. stale=True, path=...)
    events      point-in-time markers appended with event()

Finished traces land in a bounded ring on the :class:`Tracer` (oldest
dropped, drop count kept) so long benches can't grow memory unbounded.
"""
from __future__ import annotations

import contextvars
import threading
import time
from dataclasses import dataclass, field

# Current span for this thread/context; None = tracing off here.
_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_span", default=None)


@dataclass
class Span:
    trace: "Trace"
    span_id: int
    parent_id: int  # -1 for the root
    name: str
    t0: float
    t1: float = -1.0
    attrs: dict = field(default_factory=dict)
    events: list = field(default_factory=list)

    def set_attr(self, **kv) -> None:
        self.attrs.update(kv)

    def add_event(self, name: str, **attrs) -> None:
        ev = {"name": name, "t": self.trace.tracer.now()}
        if attrs:
            ev["attrs"] = attrs
        self.events.append(ev)

    def finish(self) -> None:
        if self.t1 < 0:
            self.t1 = self.trace.tracer.now()

    @property
    def duration_s(self) -> float:
        return max((self.t1 if self.t1 >= 0 else self.t0) - self.t0, 0.0)

    def to_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1 if self.t1 >= 0 else self.t0,
            "attrs": dict(self.attrs),
            "events": list(self.events),
        }


class Trace:
    """All spans of one request; the root span is spans[0]."""

    __slots__ = ("tracer", "trace_id", "spans", "_lock", "_next_id")

    def __init__(self, tracer: "Tracer", trace_id: str):
        self.tracer = tracer
        self.trace_id = trace_id
        self.spans: list = []
        self._lock = threading.Lock()
        self._next_id = 0

    def new_span(self, name: str, parent_id: int, attrs: dict) -> Span:
        t0 = self.tracer.now()
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            sp = Span(self, sid, parent_id, name, t0, attrs=dict(attrs))
            self.spans.append(sp)
        return sp

    @property
    def root(self) -> Span:
        return self.spans[0]

    def find(self, name: str) -> list:
        return [s for s in self.spans if s.name == name]

    def to_dict(self) -> dict:
        with self._lock:
            spans = [s.to_dict() for s in self.spans]
        return {"trace_id": self.trace_id, "spans": spans}


class Tracer:
    """Mints traces; collects finished ones in a bounded ring."""

    def __init__(self, max_traces: int = 4096):
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._seq = 0
        self.max_traces = max_traces
        self._finished: list = []
        self.dropped = 0

    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def new_trace(self, kind: str = "req") -> Trace:
        with self._lock:
            self._seq += 1
            seq = self._seq
        return Trace(self, f"{kind}-{seq:06d}")

    def start_root(self, trace: Trace, name: str, **attrs) -> Span:
        return trace.new_span(name, -1, attrs)

    def finish_trace(self, trace: Trace) -> None:
        for sp in list(trace.spans):
            if sp.t1 < 0 and sp.parent_id != -1:
                # a non-root span nobody closed — a lifecycle leak in the
                # instrumented code.  Closing it here keeps the export
                # parseable, but the leak is MARKED so validate_trace can
                # reject the trace instead of silently papering over it.
                sp.set_attr(dangling=True)
            sp.finish()
        with self._lock:
            self._finished.append(trace)
            if len(self._finished) > self.max_traces:
                drop = len(self._finished) - self.max_traces
                del self._finished[:drop]
                self.dropped += drop

    def finished_traces(self) -> list:
        with self._lock:
            return list(self._finished)

    def to_dicts(self) -> list:
        return [t.to_dict() for t in self.finished_traces()]


class _ActiveSpan:
    """Opens a child span as the contextvar current; restores on exit."""

    __slots__ = ("span", "_token")

    def __init__(self, span: Span):
        self.span = span
        self._token = None

    def __enter__(self) -> Span:
        self._token = _CURRENT.set(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.span.set_attr(error=f"{exc_type.__name__}: {exc}")
        self.span.finish()
        _CURRENT.reset(self._token)


class _NoopSpan:
    """Shared do-nothing span: the off-path cost of instrumentation."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def set_attr(self, **kv):
        pass

    def add_event(self, name, **attrs):
        pass


_NOOP = _NoopSpan()


def span(name: str, **attrs):
    """Open a child span under the current one; no-op when tracing is off.

    Usage at every instrumented call site::

        with obs_trace.span("pin", version=v) as sp:
            ...
            sp.set_attr(stale=True)
    """
    cur = _CURRENT.get()
    if cur is None:
        return _NOOP
    return _ActiveSpan(cur.trace.new_span(name, cur.span_id, attrs))


def event(name: str, **attrs) -> None:
    """Append a point-in-time event to the current span (no-op when off)."""
    cur = _CURRENT.get()
    if cur is not None:
        cur.add_event(name, **attrs)


def current_span():
    """The active Span, or None when tracing is off in this context."""
    return _CURRENT.get()


class activate:
    """Root a span in this thread/context: ``with activate(root): ...``.

    The serving worker uses this to re-home the request's trace (minted
    on the submitting thread) into its own context so spans opened
    anywhere down-stack parent correctly.  Passing ``None`` is a no-op
    activation (tracing stays off inside the block).
    """

    __slots__ = ("_root", "_token")

    def __init__(self, root):
        self._root = root
        self._token = None

    def __enter__(self):
        if self._root is not None:
            self._token = _CURRENT.set(self._root)
        return self._root

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._token is not None:
            _CURRENT.reset(self._token)


__all__ = ["Span", "Trace", "Tracer", "span", "event", "current_span",
           "activate"]
