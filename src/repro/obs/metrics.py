"""Process-wide metrics registry: counters, gauges, histogram sketches.

Before this module the repo's operational signals were scattered one-off
dicts — ``ServingRuntime.stats``, ``SnapshotRegistry.stats``,
``DeviceStoreCache.stats``, ``ops.pass_counters`` — each with its own
locking story (or none) and no percentile support.  The registry gives
every layer the same three instruments:

  * :class:`Counter` — monotonic, lock-guarded increments (safe under the
    threaded serving workers; a GIL'd ``dict[k] += 1`` is NOT atomic across
    its read/add/store bytecodes).
  * :class:`Gauge` — last-write-wins point-in-time values (queue depth,
    live snapshot versions, observed selectivities).
  * :class:`Histogram` — a log-bucketed sketch: observations land in
    geometric buckets ``GROWTH**i`` (GROWTH = 2^(1/8), ~9% wide), so p50 /
    p99 / mean come from O(#buckets) memory at <= ~4.5% relative value
    error, never from an unbounded sample list.  Exact count / sum / min /
    max ride along.

Instruments are keyed by ``(name, sorted(labels))`` and created on first
use::

    REGISTRY.counter("serving/outcomes", status="ok").inc()
    REGISTRY.histogram("serving/latency_s", status="ok").observe(dt)
    REGISTRY.gauge("serving/queue_depth").set(q.qsize())

``MetricsRegistry`` instances are cheap; per-object scopes (one per
:class:`~repro.serving.runtime.ServingRuntime`, one per
:class:`~repro.core.snapshot.SnapshotRegistry`) keep test assertions
isolated, while the module-level :data:`REGISTRY` is the process-wide
default that engine flushes, device-transfer accounting, kernel pass
counts, and planner selectivities report into.  ``snapshot()`` renders
everything to one JSON-ready dict (obs/export.py writes it to disk; the
serving bench derives its BENCH rows from it).
"""
from __future__ import annotations

import math
import threading

# Geometric bucket growth: 2^(1/8) per bucket => any observation is
# reported within +-(GROWTH-1)/2 ~ 4.5% of its true value.
_GROWTH_LOG = math.log(2.0) / 8.0

#: Wire-format version of :meth:`MetricsRegistry.mergeable_snapshot`.
#: Bumped whenever the snapshot layout OR bucket geometry changes —
#: the aggregator refuses to merge across versions.
SNAPSHOT_SCHEMA_VERSION = "repro.metrics.snapshot/1"


def _bucket_of(v: float) -> int:
    return int(math.floor(math.log(v) / _GROWTH_LOG)) if v > 0 else -(1 << 30)


def _bucket_value(i: int) -> float:
    # geometric midpoint of [GROWTH**i, GROWTH**(i+1))
    return math.exp((i + 0.5) * _GROWTH_LOG)


class Counter:
    """Monotonic counter; ``inc`` is atomic under its own lock."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> int:
        with self._lock:
            self._value += n
            return self._value

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def add(self, dv: float) -> float:
        with self._lock:
            self._value += dv
            return self._value

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Log-bucketed duration/size sketch with exact count/sum/min/max."""

    __slots__ = ("_lock", "buckets", "count", "sum", "min", "max")

    def __init__(self):
        self._lock = threading.Lock()
        self.buckets: dict = {}  # bucket index -> count
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        b = _bucket_of(v)
        with self._lock:
            self.buckets[b] = self.buckets.get(b, 0) + 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]); 0.0 when empty."""
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = q / 100.0 * (self.count - 1)
            seen = 0
            for b in sorted(self.buckets):
                seen += self.buckets[b]
                if seen > rank:
                    # clamp the sketch to the exact observed envelope
                    return min(max(_bucket_value(b), self.min), self.max)
            return self.max

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def summary(self) -> dict:
        with self._lock:
            if self.count == 0:
                return dict(n=0)
            count, vmin, vmax, total = (self.count, self.min, self.max,
                                        self.sum)
        return dict(n=count, sum=total, mean=total / count, min=vmin,
                    max=vmax, p50=self.percentile(50),
                    p99=self.percentile(99))

    def state(self) -> dict:
        """Copy of the accumulator — pair with :func:`window_summary` to
        report only the observations that landed after this point (the
        serving bench excludes its warmup epoch this way).  The raw bucket
        map is also the MERGEABLE wire form: two states from different
        processes combine bucket-wise (same geometric bucket boundaries by
        construction) — see :mod:`repro.obs.aggregate`."""
        with self._lock:
            return dict(buckets=dict(self.buckets), count=self.count,
                        sum=self.sum, min=self.min, max=self.max)


def merge_states(*states: dict) -> dict:
    """Merge raw :meth:`Histogram.state` dicts bucket-wise.

    The log-bucket boundaries are fixed by ``_GROWTH_LOG`` (a process
    constant), so sketches from different processes share bucket indexes
    and merging is a per-index count sum — associative and commutative by
    construction.  Count / sum add exactly; the min/max envelope is the
    elementwise extreme.  Accepts states with or without min/max (older
    window states) — absent extremes fall back to the bucket envelope.
    """
    buckets: dict = {}
    count, total = 0, 0.0
    vmin, vmax = math.inf, -math.inf
    for s in states:
        for b, c in s.get("buckets", {}).items():
            b = int(b)  # JSON round-trips bucket indexes as strings
            buckets[b] = buckets.get(b, 0) + int(c)
        count += int(s.get("count", 0))
        total += float(s.get("sum", 0.0))
        if s.get("count", 0):
            vmin = min(vmin, float(s.get("min", math.inf)))
            vmax = max(vmax, float(s.get("max", -math.inf)))
    if count and not math.isfinite(vmin):  # no envelope in any input
        idx = sorted(buckets)
        vmin, vmax = _bucket_value(idx[0]), _bucket_value(idx[-1])
    return dict(buckets=buckets, count=count, sum=total, min=vmin, max=vmax)


def summarize_state(state: dict) -> dict:
    """Render a raw (possibly merged) histogram state like ``summary()``."""
    count = int(state.get("count", 0))
    if count == 0:
        return dict(n=0)
    buckets = {int(b): int(c) for b, c in state.get("buckets", {}).items()}
    idx = sorted(buckets)
    vmin = float(state.get("min", _bucket_value(idx[0])))
    vmax = float(state.get("max", _bucket_value(idx[-1])))

    def pct(q: float) -> float:
        rank = q / 100.0 * (count - 1)
        seen = 0
        for b in idx:
            seen += buckets[b]
            if seen > rank:
                return min(max(_bucket_value(b), vmin), vmax)
        return vmax

    total = float(state.get("sum", 0.0))
    return dict(n=count, sum=total, mean=total / count, min=vmin, max=vmax,
                p50=pct(50), p99=pct(99))


def window_summary(hist: Histogram, before: dict) -> dict:
    """Summary of the observations landed since ``before = hist.state()``.

    Count / sum / mean are exact differences; percentiles come from the
    bucket-count diff, and the min/max envelope is the sketch's own bucket
    resolution (~4.5%) because the windowed extremes are not tracked.
    """
    after = hist.state()
    count = after["count"] - before["count"]
    if count <= 0:
        return dict(n=0)
    total = after["sum"] - before["sum"]
    buckets = {b: after["buckets"].get(b, 0) - before["buckets"].get(b, 0)
               for b in after["buckets"]}
    buckets = {b: c for b, c in buckets.items() if c > 0}
    idx = sorted(buckets)

    def pct(q: float) -> float:
        rank = q / 100.0 * (count - 1)
        seen = 0
        for b in idx:
            seen += buckets[b]
            if seen > rank:
                return _bucket_value(b)
        return _bucket_value(idx[-1])

    return dict(n=count, sum=total, mean=total / count,
                min=_bucket_value(idx[0]), max=_bucket_value(idx[-1]),
                p50=pct(50), p99=pct(99))


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _label_str(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """Create-on-first-use instrument store, thread-safe, JSON-exportable."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    def _get(self, store: dict, cls, name: str, labels: dict):
        key = _key(name, labels)
        inst = store.get(key)
        if inst is None:
            with self._lock:
                inst = store.setdefault(key, cls())
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(self._histograms, Histogram, name, labels)

    # -- reading back ---------------------------------------------------------
    def counter_value(self, name: str, **labels) -> int:
        """Current count, 0 if the counter was never touched (no create)."""
        inst = self._counters.get(_key(name, labels))
        return inst.value if inst is not None else 0

    def gauge_value(self, name: str, default: float = 0.0, **labels) -> float:
        inst = self._gauges.get(_key(name, labels))
        return inst.value if inst is not None else default

    def values(self, name: str) -> dict:
        """All label-variants of one counter name -> {labels tuple: value}."""
        with self._lock:
            keys = [k for k in self._counters if k[0] == name]
        return {k[1]: self._counters[k].value for k in keys}

    def gauges_with_prefix(self, prefix: str) -> dict:
        """Gauge readbacks by name prefix — e.g. observed selectivities."""
        with self._lock:
            keys = [k for k in self._gauges if k[0].startswith(prefix)]
        return {_label_str(k): self._gauges[k].value for k in keys}

    def snapshot(self) -> dict:
        """One JSON-ready dict of every instrument (the export surface)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {_label_str(k): c.value for k, c in
                         sorted(counters.items())},
            "gauges": {_label_str(k): g.value for k, g in
                       sorted(gauges.items())},
            "histograms": {_label_str(k): h.summary() for k, h in
                           sorted(histograms.items())},
        }

    def mergeable_snapshot(self, process: str = "0") -> dict:
        """One process's share of a FLEET snapshot, in mergeable form.

        Unlike :meth:`snapshot` (human-oriented: flattened label strings,
        lossy histogram summaries), this keeps labels structured and
        histograms as raw log-bucket states so :mod:`repro.obs.aggregate`
        can combine any number of processes losslessly: counters sum,
        gauges get a ``process`` label, histogram sketches merge
        bucket-wise.  ``growth_log`` stamps the bucket geometry — the
        aggregator refuses to merge snapshots whose sketches use different
        bucket boundaries (or a different schema version).
        """
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)

        def entry(key, **rest):
            name, labels = key
            return dict(name=name, labels={k: str(v) for k, v in labels},
                        **rest)

        hists = []
        for k, h in sorted(histograms.items()):
            st = h.state()
            hists.append(entry(
                k, buckets={str(b): c for b, c in sorted(st["buckets"].items())},
                count=st["count"], sum=st["sum"],
                min=st["min"] if st["count"] else None,
                max=st["max"] if st["count"] else None))
        return {
            "schema": SNAPSHOT_SCHEMA_VERSION,
            "growth_log": _GROWTH_LOG,
            "process": str(process),
            "counters": [entry(k, value=c.value)
                         for k, c in sorted(counters.items())],
            "gauges": [entry(k, value=g.value)
                       for k, g in sorted(gauges.items())],
            "histograms": hists,
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: Process-wide default registry: engine/shard flush timings, device
#: transfer accounting, kernel pass counts, planner selectivities.
REGISTRY = MetricsRegistry()

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "SNAPSHOT_SCHEMA_VERSION", "merge_states", "summarize_state",
           "window_summary"]
