"""Cross-process metrics aggregation — N process snapshots, ONE fleet view.

PR 7's :class:`~repro.obs.metrics.MetricsRegistry` is strictly
per-process: the 2-process ``jax.distributed`` job and the forced-8-device
matrix each produce their own snapshot, and nothing could answer a
fleet-level question ("what is the p99 across BOTH processes?", "how many
HBM bytes does the whole store hold?") without hand-eyeballing files.

This module combines any number of
:meth:`~repro.obs.metrics.MetricsRegistry.mergeable_snapshot` documents
into one fleet snapshot with no information loss:

  * **counters sum** — monotonic event counts are additive across
    processes;
  * **histograms merge bucket-wise** — the log-bucket sketches share
    their geometric bucket boundaries (a process constant stamped into
    every snapshot as ``growth_log``), so merging is a per-index count
    sum: associative, commutative, and exactly the sketch the pooled
    observation stream would have produced;
  * **gauges label by process** — a point-in-time value (queue depth,
    HBM bytes) is NOT additive in general, so each gauge keeps its
    identity under an added ``process`` label; sums are the *reader's*
    choice (``scripts/fleet_report.py`` sums ``hbm_bytes`` because bytes
    on different shards genuinely add).

Mixed-schema inputs are rejected up front with a clear error: snapshots
from different code versions (schema string) or different bucket
geometries (``growth_log``) cannot be merged meaningfully, and a silent
best-effort merge would corrupt every percentile downstream.

CLI (the ``distributed`` CI job runs this to publish ONE artifact)::

    python -m repro.obs.aggregate --out fleet.json snap0.json snap1.json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.metrics import (SNAPSHOT_SCHEMA_VERSION, merge_states,
                               summarize_state)

#: Wire-format version of an aggregated fleet snapshot.
FLEET_SCHEMA_VERSION = "repro.metrics.fleet/1"


class AggregationError(ValueError):
    """Incompatible snapshots — schema/bucket-geometry mismatch."""


def _entry_key(e: dict) -> tuple:
    return (e["name"], tuple(sorted(e.get("labels", {}).items())))


def check_compatible(snapshots: list) -> None:
    """Raise :class:`AggregationError` unless every snapshot merges.

    Checks: schema version, bucket geometry (``growth_log``), and
    process-name uniqueness (two snapshots claiming the same process would
    silently collide on every gauge label).
    """
    if not snapshots:
        raise AggregationError("no snapshots to aggregate")
    seen_procs: dict = {}
    for i, s in enumerate(snapshots):
        schema = s.get("schema")
        if schema != SNAPSHOT_SCHEMA_VERSION:
            raise AggregationError(
                f"snapshot[{i}] has schema {schema!r}, expected "
                f"{SNAPSHOT_SCHEMA_VERSION!r} — refusing to merge "
                "mixed-schema snapshots (re-export with matching code)")
        g0 = snapshots[0].get("growth_log")
        if s.get("growth_log") != g0:
            raise AggregationError(
                f"snapshot[{i}] bucket geometry growth_log="
                f"{s.get('growth_log')!r} != {g0!r} — sketches with "
                "different bucket boundaries cannot merge bucket-wise")
        proc = str(s.get("process", i))
        if proc in seen_procs:
            raise AggregationError(
                f"snapshot[{i}] and snapshot[{seen_procs[proc]}] both "
                f"claim process {proc!r} — every process must export "
                "under a unique name or gauges would collide")
        seen_procs[proc] = i


def aggregate(snapshots: list) -> dict:
    """Merge mergeable process snapshots into one fleet snapshot."""
    check_compatible(snapshots)

    counters: dict = {}
    gauges: list = []
    hists: dict = {}
    processes = []
    for s in snapshots:
        proc = str(s.get("process", len(processes)))
        processes.append(proc)
        for e in s.get("counters", ()):
            k = _entry_key(e)
            counters[k] = counters.get(k, 0) + int(e["value"])
        for e in s.get("gauges", ()):
            labels = dict(e.get("labels", {}))
            labels["process"] = proc
            gauges.append(dict(name=e["name"], labels=labels,
                               value=e["value"]))
        for e in s.get("histograms", ()):
            k = _entry_key(e)
            st = dict(buckets=e.get("buckets", {}), count=e.get("count", 0),
                      sum=e.get("sum", 0.0))
            if e.get("min") is not None:
                st["min"], st["max"] = e["min"], e["max"]
            prev = hists.get(k)
            hists[k] = merge_states(prev, st) if prev else merge_states(st)

    def hist_entry(k, st):
        name, labels = k
        cnt = st["count"]
        return dict(name=name, labels=dict(labels),
                    buckets={str(b): c
                             for b, c in sorted(st["buckets"].items())},
                    count=cnt, sum=st["sum"],
                    min=st["min"] if cnt else None,
                    max=st["max"] if cnt else None,
                    summary=summarize_state(st))

    return {
        "schema": FLEET_SCHEMA_VERSION,
        "growth_log": snapshots[0].get("growth_log"),
        "processes": processes,
        "counters": [dict(name=k[0], labels=dict(k[1]), value=v)
                     for k, v in sorted(counters.items())],
        "gauges": sorted(gauges, key=_entry_key),
        "histograms": [hist_entry(k, st)
                       for k, st in sorted(hists.items())],
    }


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Merge per-process metrics snapshots into ONE fleet "
                    "snapshot (counters sum, histograms merge bucket-wise, "
                    "gauges label by process).")
    ap.add_argument("snapshots", nargs="+",
                    help="per-process mergeable snapshot JSON files")
    ap.add_argument("--out", required=True, help="fleet snapshot output path")
    args = ap.parse_args(argv)

    from repro.obs.export import validate_metrics_snapshot

    snaps = []
    for path in args.snapshots:
        snap = load_snapshot(path)
        errors = validate_metrics_snapshot(snap)
        if errors:
            for e in errors:
                print(f"{path}: {e}", file=sys.stderr)
            return 1
        snaps.append(snap)
    try:
        fleet = aggregate(snaps)
    except AggregationError as e:
        print(f"aggregate: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(fleet, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out}: {len(snaps)} processes, "
          f"{len(fleet['counters'])} counters, {len(fleet['gauges'])} "
          f"gauges, {len(fleet['histograms'])} histograms")
    return 0


__all__ = ["FLEET_SCHEMA_VERSION", "AggregationError", "aggregate",
           "check_compatible", "load_snapshot", "main"]

if __name__ == "__main__":
    sys.exit(main())
