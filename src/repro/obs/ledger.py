"""Device resource accounting: who holds how many HBM bytes, in what.

Before this module nothing could answer "how many device bytes does
shard 3 hold, and in what?" — the store's buffers are scattered across
base arrays, lazily-materialized permutations, pow2 delta buckets,
liveness masks, and pinned snapshots leasing superseded bases.  The
:class:`ResourceLedger` makes the answer a gauge read:

  * components (``core/delta.py``, ``core/engine.py``,
    ``core/snapshot.py``, ``core/shard.py``) expose a side-effect-free
    ``device_buffers()`` walk of the device arrays they currently
    reference, each as ``(component, buffer id, nbytes)``;
  * owners register with :meth:`ResourceLedger.track` under a shard
    name; the ledger holds only a **weakref** (a dropped store
    unregisters itself — telemetry must never extend object lifetimes);
  * :meth:`ResourceLedger.sample` walks every tracked owner, **dedupes
    buffers globally by id** (a snapshot pinning the live base, or two
    views sharing one permutation, counts ONCE — attribution goes to the
    first owner in registration order), and publishes the result as
    gauges:

      ``hbm_bytes{shard=S,component=C}``   resident bytes per component
      ``store/live_triples{shard=S}``      live triples per shard
      ``store/bytes_per_triple``           fleet total bytes / total live
                                           triples — THE number ROADMAP
                                           item 4's compression work is
                                           gated on

Sampling is pull-based: nothing in the mutation/query hot path pays for
accounting; the :class:`~repro.obs.slo.TelemetryRollup` thread (or a
test, or a bench) calls ``sample()`` at its own cadence.
"""
from __future__ import annotations

import threading
import weakref

from repro.obs.metrics import REGISTRY


class ResourceLedger:
    """Weakref registry of device-buffer owners + gauge publication."""

    def __init__(self, registry=None):
        self._lock = threading.Lock()
        self._owners: list = []  # (handle, shard, weakref) in track order
        self._next_handle = 1
        self._published: set = set()  # gauge keys we set last sample
        self.registry = registry if registry is not None else REGISTRY

    def track(self, shard, owner) -> int:
        """Track ``owner`` (anything with ``device_buffers()``) under a
        shard name; returns a handle for :meth:`untrack`.  Only a weak
        reference is kept — garbage-collected owners drop out of the next
        sample automatically."""
        with self._lock:
            h = self._next_handle
            self._next_handle += 1
            self._owners.append((h, str(shard), weakref.ref(owner)))
            return h

    def untrack(self, handle: int) -> None:
        with self._lock:
            self._owners = [o for o in self._owners if o[0] != handle]

    def clear(self) -> None:
        """Drop every tracked owner (test isolation)."""
        with self._lock:
            self._owners = []
            self._published = set()

    def sample(self) -> dict:
        """Walk owners, dedupe buffers by id, publish gauges.

        Returns ``{"shards": {S: {"components": {C: bytes}, "triples": n,
        "total": bytes}}, "total_bytes": b, "total_triples": n,
        "bytes_per_triple": b/n}`` — the same numbers the gauges carry,
        for direct (test/report) consumption.
        """
        with self._lock:
            owners = list(self._owners)
        shards: dict = {}
        seen_ids: set = set()
        dead = []
        for handle, shard, ref in owners:
            obj = ref()
            if obj is None:
                dead.append(handle)
                continue
            rec = shards.setdefault(
                shard, {"components": {}, "triples": 0, "total": 0})
            for component, buf_id, nbytes in obj.device_buffers():
                if buf_id is not None:
                    if buf_id in seen_ids:
                        continue  # shared buffer: first owner keeps it
                    seen_ids.add(buf_id)
                nbytes = int(nbytes)
                comps = rec["components"]
                comps[component] = comps.get(component, 0) + nbytes
                rec["total"] += nbytes
            n_live = getattr(obj, "n_live_triples", None)
            if callable(n_live):
                rec["triples"] += int(n_live())
        if dead:
            with self._lock:
                self._owners = [o for o in self._owners if o[0] not in dead]

        published = set()
        for shard, rec in shards.items():
            for component, nbytes in rec["components"].items():
                key = ("hbm_bytes", shard, component)
                published.add(key)
                self.registry.gauge("hbm_bytes", shard=shard,
                                    component=component).set(nbytes)
            key = ("store/live_triples", shard, None)
            published.add(key)
            self.registry.gauge("store/live_triples",
                                shard=shard).set(rec["triples"])
        # zero gauges that existed last sample but vanished (a dropped
        # store must not leave a stale byte count behind)
        for key in self._published - published:
            name, shard, component = key
            if component is None:
                self.registry.gauge(name, shard=shard).set(0)
            else:
                self.registry.gauge(name, shard=shard,
                                    component=component).set(0)
        self._published = published

        total_bytes = sum(r["total"] for r in shards.values())
        total_triples = sum(r["triples"] for r in shards.values())
        bpt = total_bytes / total_triples if total_triples else 0.0
        self.registry.gauge("store/hbm_bytes_total").set(total_bytes)
        self.registry.gauge("store/bytes_per_triple").set(bpt)
        return {"shards": shards, "total_bytes": total_bytes,
                "total_triples": total_triples, "bytes_per_triple": bpt}


#: Process-wide default ledger: KnowledgeBase / ShardedKB /
#: SnapshotRegistry register themselves here (weakly).
LEDGER = ResourceLedger()

__all__ = ["ResourceLedger", "LEDGER"]
