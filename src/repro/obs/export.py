"""JSON exporters + trace schema validation for the obs subsystem.

Two export surfaces:

  * :func:`export_traces` / :func:`export_metrics` — dump a Tracer's
    finished traces / a MetricsRegistry snapshot to JSON files.  The
    serving bench honours ``REPRO_TRACE_EXPORT`` / ``REPRO_METRICS_EXPORT``
    env knobs and the CI obs smoke leg uploads the results.
  * :data:`TRACE_SCHEMA` + :func:`validate_trace` — the contract CI holds
    every exported trace to (``scripts/check_traces.py``).  The validator
    is a small hand-rolled subset of JSON Schema (type / properties /
    required / items / enum) because the container has no ``jsonschema``
    package; on top of the schema walk it checks structural invariants a
    JSON schema can't express: exactly one root span, every parent_id
    resolves, every span's [t0, t1] is well ordered.
"""
from __future__ import annotations

import json

#: Schema one exported trace object must satisfy (subset of JSON Schema).
TRACE_SCHEMA = {
    "type": "object",
    "required": ["trace_id", "spans"],
    "properties": {
        "trace_id": {"type": "string"},
        "spans": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["span_id", "parent_id", "name", "t0", "t1"],
                "properties": {
                    "span_id": {"type": "integer"},
                    "parent_id": {"type": "integer"},
                    "name": {"type": "string"},
                    "t0": {"type": "number"},
                    "t1": {"type": "number"},
                    "attrs": {"type": "object"},
                    "events": {
                        "type": "array",
                        "items": {
                            "type": "object",
                            "required": ["name", "t"],
                            "properties": {
                                "name": {"type": "string"},
                                "t": {"type": "number"},
                            },
                        },
                    },
                },
            },
        },
    },
}

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "integer": int,
    "number": (int, float),
    "boolean": bool,
}


def validate(obj, schema, path: str = "$") -> list:
    """Walk ``obj`` against a JSON-Schema subset; return error strings."""
    errors = []
    typ = schema.get("type")
    if typ is not None:
        pytype = _TYPES[typ]
        ok = isinstance(obj, pytype)
        if typ in ("integer", "number") and isinstance(obj, bool):
            ok = False  # bool is an int subclass; schemas mean real numbers
        if not ok:
            errors.append(f"{path}: expected {typ}, got "
                          f"{type(obj).__name__}")
            return errors
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in {schema['enum']}")
    if typ == "object":
        for key in schema.get("required", ()):
            if key not in obj:
                errors.append(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in obj:
                errors.extend(validate(obj[key], sub, f"{path}.{key}"))
    elif typ == "array" and "items" in schema:
        for i, item in enumerate(obj):
            errors.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errors


def validate_trace(trace: dict) -> list:
    """Schema check + structural invariants; returns error strings."""
    errors = validate(trace, TRACE_SCHEMA)
    if errors:
        return errors
    spans = trace["spans"]
    tid = trace["trace_id"]
    if not spans:
        return [f"{tid}: trace has no spans"]
    ids = {s["span_id"] for s in spans}
    if len(ids) != len(spans):
        errors.append(f"{tid}: duplicate span_ids")
    roots = [s for s in spans if s["parent_id"] == -1]
    if len(roots) != 1:
        errors.append(f"{tid}: expected exactly one root span, "
                      f"got {len(roots)}")
    for s in spans:
        if s["parent_id"] != -1 and s["parent_id"] not in ids:
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) has "
                          f"dangling parent_id {s['parent_id']}")
        if s["t1"] < s["t0"]:
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) has "
                          f"t1 < t0")
        if s["parent_id"] != -1 and s.get("attrs", {}).get("dangling"):
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) was "
                          f"still open at trace finish (leaked span)")
    return errors


def export_traces(tracer, path: str) -> int:
    """Write {"traces": [...]} to ``path``; returns the trace count."""
    traces = tracer.to_dicts()
    with open(path, "w") as f:
        json.dump({"traces": traces, "dropped": tracer.dropped}, f, indent=1)
    return len(traces)


def export_metrics(registry, path: str) -> None:
    """Write a MetricsRegistry snapshot to ``path``."""
    with open(path, "w") as f:
        json.dump(registry.snapshot(), f, indent=1, sort_keys=True)


__all__ = ["TRACE_SCHEMA", "validate", "validate_trace", "export_traces",
           "export_metrics"]
