"""JSON exporters + trace/metrics schema validation for the obs subsystem.

Three export surfaces:

  * :func:`export_traces` / :func:`export_metrics` — dump a Tracer's
    finished traces / a MetricsRegistry snapshot to JSON files.  The
    serving bench honours ``REPRO_TRACE_EXPORT`` / ``REPRO_METRICS_EXPORT``
    env knobs and the CI obs smoke leg uploads the results.
  * :func:`export_mergeable_metrics` — one process's share of a FLEET
    snapshot (structured labels, raw histogram buckets); any number of
    these combine through :mod:`repro.obs.aggregate`.
  * :data:`TRACE_SCHEMA` + :func:`validate_trace`, and
    :data:`METRICS_SNAPSHOT_SCHEMA` / :data:`FLEET_SNAPSHOT_SCHEMA` +
    :func:`validate_metrics_snapshot` — the contracts CI holds every
    exported trace AND metrics snapshot to (``scripts/check_traces.py``).
    The validator is a small hand-rolled subset of JSON Schema (type /
    properties / required / items / enum) because the container has no
    ``jsonschema`` package; on top of the schema walk it checks structural
    invariants a JSON schema can't express: exactly one root span, every
    parent_id resolves, every span's [t0, t1] is well ordered — and for
    metrics: every histogram's bucket counts reconcile with its total
    count, bucket indexes parse as integers, min <= max.
"""
from __future__ import annotations

import json

from repro.obs.metrics import SNAPSHOT_SCHEMA_VERSION

#: Schema one exported trace object must satisfy (subset of JSON Schema).
TRACE_SCHEMA = {
    "type": "object",
    "required": ["trace_id", "spans"],
    "properties": {
        "trace_id": {"type": "string"},
        "spans": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["span_id", "parent_id", "name", "t0", "t1"],
                "properties": {
                    "span_id": {"type": "integer"},
                    "parent_id": {"type": "integer"},
                    "name": {"type": "string"},
                    "t0": {"type": "number"},
                    "t1": {"type": "number"},
                    "attrs": {"type": "object"},
                    "events": {
                        "type": "array",
                        "items": {
                            "type": "object",
                            "required": ["name", "t"],
                            "properties": {
                                "name": {"type": "string"},
                                "t": {"type": "number"},
                            },
                        },
                    },
                },
            },
        },
    },
}

_VALUE_ENTRY = {
    "type": "object",
    "required": ["name", "labels", "value"],
    "properties": {
        "name": {"type": "string"},
        "labels": {"type": "object"},
        "value": {"type": "number"},
    },
}

_HIST_ENTRY = {
    "type": "object",
    "required": ["name", "labels", "buckets", "count", "sum"],
    "properties": {
        "name": {"type": "string"},
        "labels": {"type": "object"},
        "buckets": {"type": "object"},
        "count": {"type": "integer"},
        "sum": {"type": "number"},
    },
}

#: Schema one per-process mergeable metrics snapshot must satisfy.
METRICS_SNAPSHOT_SCHEMA = {
    "type": "object",
    "required": ["schema", "growth_log", "process", "counters", "gauges",
                 "histograms"],
    "properties": {
        "schema": {"type": "string"},
        "growth_log": {"type": "number"},
        "process": {"type": "string"},
        "counters": {"type": "array", "items": _VALUE_ENTRY},
        "gauges": {"type": "array", "items": _VALUE_ENTRY},
        "histograms": {"type": "array", "items": _HIST_ENTRY},
    },
}

#: Schema an aggregated fleet snapshot must satisfy.
FLEET_SNAPSHOT_SCHEMA = {
    "type": "object",
    "required": ["schema", "growth_log", "processes", "counters", "gauges",
                 "histograms"],
    "properties": {
        "schema": {"type": "string"},
        "growth_log": {"type": "number"},
        "processes": {"type": "array", "items": {"type": "string"}},
        "counters": {"type": "array", "items": _VALUE_ENTRY},
        "gauges": {"type": "array", "items": _VALUE_ENTRY},
        "histograms": {"type": "array", "items": _HIST_ENTRY},
    },
}

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "integer": int,
    "number": (int, float),
    "boolean": bool,
}


def validate(obj, schema, path: str = "$") -> list:
    """Walk ``obj`` against a JSON-Schema subset; return error strings."""
    errors = []
    typ = schema.get("type")
    if typ is not None:
        pytype = _TYPES[typ]
        ok = isinstance(obj, pytype)
        if typ in ("integer", "number") and isinstance(obj, bool):
            ok = False  # bool is an int subclass; schemas mean real numbers
        if not ok:
            errors.append(f"{path}: expected {typ}, got "
                          f"{type(obj).__name__}")
            return errors
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in {schema['enum']}")
    if typ == "object":
        for key in schema.get("required", ()):
            if key not in obj:
                errors.append(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in obj:
                errors.extend(validate(obj[key], sub, f"{path}.{key}"))
    elif typ == "array" and "items" in schema:
        for i, item in enumerate(obj):
            errors.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errors


def validate_trace(trace: dict) -> list:
    """Schema check + structural invariants; returns error strings."""
    errors = validate(trace, TRACE_SCHEMA)
    if errors:
        return errors
    spans = trace["spans"]
    tid = trace["trace_id"]
    if not spans:
        return [f"{tid}: trace has no spans"]
    ids = {s["span_id"] for s in spans}
    if len(ids) != len(spans):
        errors.append(f"{tid}: duplicate span_ids")
    roots = [s for s in spans if s["parent_id"] == -1]
    if len(roots) != 1:
        errors.append(f"{tid}: expected exactly one root span, "
                      f"got {len(roots)}")
    for s in spans:
        if s["parent_id"] != -1 and s["parent_id"] not in ids:
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) has "
                          f"dangling parent_id {s['parent_id']}")
        if s["t1"] < s["t0"]:
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) has "
                          f"t1 < t0")
        if s["parent_id"] != -1 and s.get("attrs", {}).get("dangling"):
            errors.append(f"{tid}: span {s['span_id']} ({s['name']}) was "
                          f"still open at trace finish (leaked span)")
    return errors


def validate_metrics_snapshot(snap: dict) -> list:
    """Schema check + structural invariants for a metrics snapshot.

    Accepts both wire forms — a per-process mergeable snapshot and an
    aggregated fleet snapshot (dispatched on the ``schema`` field) — and
    returns error strings.  Beyond the schema walk it verifies what a
    JSON schema can't: bucket indexes parse as integers, per-histogram
    bucket counts are positive and sum exactly to ``count``, and the
    min/max envelope is ordered.  ``check_traces.py`` runs this over CI
    exports; :mod:`repro.obs.aggregate` runs it before merging so a
    corrupt snapshot fails loudly instead of skewing fleet percentiles.
    """
    from repro.obs.aggregate import FLEET_SCHEMA_VERSION

    if not isinstance(snap, dict):
        return [f"$: expected object, got {type(snap).__name__}"]
    schema_id = snap.get("schema")
    if schema_id == SNAPSHOT_SCHEMA_VERSION:
        errors = validate(snap, METRICS_SNAPSHOT_SCHEMA)
    elif schema_id == FLEET_SCHEMA_VERSION:
        errors = validate(snap, FLEET_SNAPSHOT_SCHEMA)
    else:
        return [f"$.schema: unknown metrics snapshot schema {schema_id!r} "
                f"(expected {SNAPSHOT_SCHEMA_VERSION!r} or "
                f"{FLEET_SCHEMA_VERSION!r})"]
    if errors:
        return errors
    for i, e in enumerate(snap["histograms"]):
        where = f"$.histograms[{i}] ({e['name']})"
        total = 0
        for b, c in e["buckets"].items():
            try:
                int(b)
            except ValueError:
                errors.append(f"{where}: bucket index {b!r} is not an "
                              "integer")
                continue
            if not isinstance(c, int) or isinstance(c, bool) or c <= 0:
                errors.append(f"{where}: bucket {b} count {c!r} must be a "
                              "positive integer")
                continue
            total += c
        if total != e["count"]:
            errors.append(f"{where}: bucket counts sum to {total} but "
                          f"count={e['count']}")
        if e["count"] > 0:
            vmin, vmax = e.get("min"), e.get("max")
            if vmin is None or vmax is None:
                errors.append(f"{where}: non-empty histogram missing "
                              "min/max envelope")
            elif vmin > vmax:
                errors.append(f"{where}: min {vmin} > max {vmax}")
    return errors


def export_traces(tracer, path: str) -> int:
    """Write {"traces": [...]} to ``path``; returns the trace count."""
    traces = tracer.to_dicts()
    with open(path, "w") as f:
        json.dump({"traces": traces, "dropped": tracer.dropped}, f, indent=1)
    return len(traces)


def export_metrics(registry, path: str) -> None:
    """Write a MetricsRegistry snapshot to ``path``."""
    with open(path, "w") as f:
        json.dump(registry.snapshot(), f, indent=1, sort_keys=True)


def export_mergeable_metrics(registry, path: str,
                             process: str = "0") -> dict:
    """Write one process's mergeable (fleet-combinable) snapshot.

    The document is validated before it hits disk — an unserializable or
    self-inconsistent snapshot fails at export time in the process that
    produced it, not later inside the aggregator with N files to bisect.
    """
    snap = registry.mergeable_snapshot(process=process)
    errors = validate_metrics_snapshot(snap)
    if errors:
        raise ValueError("refusing to export invalid metrics snapshot:\n"
                         + "\n".join(errors))
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
    return snap


__all__ = ["TRACE_SCHEMA", "METRICS_SNAPSHOT_SCHEMA",
           "FLEET_SNAPSHOT_SCHEMA", "validate", "validate_trace",
           "validate_metrics_snapshot", "export_traces", "export_metrics",
           "export_mergeable_metrics"]
