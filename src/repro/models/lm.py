"""Decoder-only LM family: olmo / gemma / gemma3 / olmoe / deepseek-v2.

One configurable module covers all five assigned LM architectures:

  * attention: MHA/GQA/MQA (``attn='gqa'``) or DeepSeek-V2 MLA (``'mla'``)
  * FFN: SwiGLU/GeGLU dense or shared+routed top-k MoE
  * layer pattern: uniform, N-local:1-global sliding window (gemma3),
    leading dense layers (deepseek-v2 layer 0)
  * non-parametric LayerNorm (olmo) or RMSNorm

Layers are stacked with ``lax.scan`` (+ optional remat) so the HLO stays
O(1) in depth — a 60-layer 236B config lowers in seconds and the dry-run's
memory analysis reflects per-layer activation reuse.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.layers import (
    apply_norm, cross_entropy_chunked, mlp_apply, mlp_init,
)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"
    norm: str = "rmsnorm"
    attn: str = "gqa"  # gqa | mla
    q_lora: int = 0
    kv_lora: int = 0
    rope_dim: int = 64
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_dff: int = 0
    capacity_factor: float = 1.25
    dense_layers: int = 0  # leading dense layers before the MoE stack
    dense_dff: int = 0
    window: int = 0  # sliding-window size; 0 = full attention
    local_ratio: int = 0  # N local : 1 global interleave (gemma3: 5)
    remat: bool = True
    dtype: str = "bfloat16"
    loss_chunks: int = 8
    aux_weight: float = 0.01
    attn_impl: str = "naive"  # naive | blockwise (flash-style, beyond-paper)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_is_global(self) -> np.ndarray:
        """bool[L_scan] — which scanned layers use full (global) attention."""
        L = self.n_layers - self.dense_layers
        if self.local_ratio <= 0 or self.window <= 0:
            return np.ones((L,), dtype=bool)
        r = self.local_ratio + 1
        return np.array([(i % r) == (r - 1) for i in range(L)])

    def param_count(self) -> int:
        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.key(0))
        return int(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)))

    def model_flops_per_token(self) -> float:
        """6·N (dense) or 6·N_active (MoE) — embedding excluded."""
        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.key(0))
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if "embed" in keys:
                continue
            n = int(np.prod(leaf.shape))
            if any(k in ("wi", "wg", "wo", "router") for k in keys) and self.moe and any(
                "layers" in str(k) for k in keys
            ) and leaf.ndim == 4:
                n = n * self.top_k // max(self.n_experts, 1)  # active fraction
            total += n
        return 6.0 * total


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: LMConfig, dense_ffn: bool):
    dt = cfg.jdtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {}
    p["attn"] = attn_lib.mla_init(k1, cfg, dt) if cfg.attn == "mla" else attn_lib.gqa_init(k1, cfg, dt)
    if cfg.moe and not dense_ffn:
        p["ffn"] = moe_lib.moe_init(k2, cfg, dt)
    else:
        ff = cfg.dense_dff if (dense_ffn and cfg.dense_dff) else cfg.d_ff
        p["ffn"] = mlp_init(k2, cfg.d_model, ff, cfg.act, dt)
    if cfg.norm == "rmsnorm":
        p["ln1"] = jnp.zeros((cfg.d_model,), dt)
        p["ln2"] = jnp.zeros((cfg.d_model,), dt)
    return p


def init_params(key, cfg: LMConfig):
    ke, kd, kl, kf = jax.random.split(key, 4)
    L = cfg.n_layers - cfg.dense_layers
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) / np.sqrt(cfg.d_model)).astype(cfg.jdtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg, dense_ffn=False))(
            jax.random.split(kl, L)
        ),
    }
    if cfg.dense_layers > 0:
        params["dense"] = [
            _layer_init(k, cfg, dense_ffn=True)
            for k in jax.random.split(kd, cfg.dense_layers)
        ]
    if cfg.norm == "rmsnorm":
        params["ln_f"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block(params_l, x, positions, cfg: LMConfig, is_global, dense_ffn: bool):
    h = apply_norm(cfg.norm, x, params_l.get("ln1"))
    a, kv = attn_lib.mla_forward(params_l["attn"], h, positions, cfg) if cfg.attn == "mla" \
        else attn_lib.gqa_forward_flagged(
            params_l["attn"], h, positions, cfg.window, is_global, cfg.attn_impl)
    x = x + a
    h = apply_norm(cfg.norm, x, params_l.get("ln2"))
    if cfg.moe and not dense_ffn:
        f, aux = moe_lib.moe_apply(params_l["ffn"], h, cfg)
    else:
        ff_act = cfg.act
        f, aux = mlp_apply(params_l["ffn"], h, ff_act), jnp.float32(0)
    return x + f, aux, kv


def forward(params, tokens, cfg: LMConfig, collect_cache: bool = False):
    """tokens (B, S) -> final hidden (B, S, d) [, stacked KV cache]."""
    B, S = tokens.shape
    x = params["embed"][tokens] * np.sqrt(cfg.d_model)
    x = x.astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    aux_total = jnp.float32(0)
    caches = []
    for pl_ in params.get("dense", []):
        x, aux, kv = _block(pl_, x, positions, cfg, jnp.bool_(True), dense_ffn=True)
        aux_total += aux
        caches.append(kv)

    flags = jnp.asarray(cfg.layer_is_global())

    def body(carry, layer):
        xc, aux_acc = carry
        pl_, flag = layer
        xn, aux, kv = _block(pl_, xc, positions, cfg, flag, dense_ffn=False)
        return (xn, aux_acc + aux), kv if collect_cache else None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux_total), kv_stack = jax.lax.scan(body_fn, (x, aux_total), (params["layers"], flags))
    x = apply_norm(cfg.norm, x, params.get("ln_f"))
    if collect_cache:
        return x, aux_total, (caches, kv_stack)
    return x, aux_total


def logits_fn(x, embed):
    return jnp.einsum("bsd,vd->bsv", x, embed) / np.sqrt(x.shape[-1])


def loss_fn(params, batch, cfg: LMConfig):
    x, aux = forward(params, batch["tokens"], cfg)
    ce = cross_entropy_chunked(
        logits_fn, x, params["embed"], batch["targets"], batch["mask"],
        n_chunks=cfg.loss_chunks,
    )
    return ce + cfg.aux_weight * aux, ce


def make_train_step(cfg: LMConfig, opt_cfg: AdamWConfig = AdamWConfig()):
    def train_step(params, opt_state, batch):
        (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV caches
# ---------------------------------------------------------------------------


def _pad_seq(arr, max_seq: int, axis: int):
    pad = max_seq - arr.shape[axis]
    if pad <= 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)


def make_prefill_step(cfg: LMConfig, max_seq: int | None = None):
    """(params, tokens (B,S)) -> (last-position logits, decode-ready cache)."""

    def prefill(params, tokens):
        x, _, (dense_caches, kv_stack) = forward(params, tokens, cfg, collect_cache=True)
        logits = logits_fn(x[:, -1:], params["embed"])
        if cfg.attn == "mla":
            cache = {"c": kv_stack[0], "kr": kv_stack[1]}
            if dense_caches:
                cache["dense_c"] = jnp.stack([c for c, _ in dense_caches])
                cache["dense_kr"] = jnp.stack([kr for _, kr in dense_caches])
        else:
            cache = {"k": kv_stack[0], "v": kv_stack[1]}
        if max_seq is not None:
            cache = {k: _pad_seq(v, max_seq, axis=2) for k, v in cache.items()}
        return logits, cache

    return prefill


def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    """Uniform (baseline) cache layout: every layer holds max_seq slots."""
    dt = dtype or cfg.jdtype
    L = cfg.n_layers - cfg.dense_layers
    if cfg.attn == "mla":
        cache = {
            "c": jnp.zeros((L, batch, max_seq, cfg.kv_lora), dt),
            "kr": jnp.zeros((L, batch, max_seq, cfg.rope_dim), dt),
        }
        if cfg.dense_layers > 0:
            cache["dense_c"] = jnp.zeros((cfg.dense_layers, batch, max_seq, cfg.kv_lora), dt)
            cache["dense_kr"] = jnp.zeros((cfg.dense_layers, batch, max_seq, cfg.rope_dim), dt)
        return cache
    return {
        "k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dt),
    }


def make_decode_step(cfg: LMConfig):
    """(params, cache, token (B,1), pos scalar) -> (logits, cache)."""
    flags = jnp.asarray(cfg.layer_is_global())

    def decode(params, cache, token, pos):
        B = token.shape[0]
        x = params["embed"][token] * np.sqrt(cfg.d_model)
        x = x.astype(cfg.jdtype)

        # leading dense layers (deepseek-v2 layer 0) run outside the scan
        new_dense_c, new_dense_kr = [], []
        for i, pl_ in enumerate(params.get("dense", [])):
            h = apply_norm(cfg.norm, x, pl_.get("ln1"))
            a, (c2, kr2) = attn_lib.mla_decode(
                pl_["attn"], h, cache["dense_c"][i], cache["dense_kr"][i], pos, cfg
            )
            new_dense_c.append(c2)
            new_dense_kr.append(kr2)
            x = x + a
            h = apply_norm(cfg.norm, x, pl_.get("ln2"))
            x = x + mlp_apply(pl_["ffn"], h, cfg.act)

        def body(xc, layer):
            if cfg.attn == "mla":
                pl_, c, kr = layer
                h = apply_norm(cfg.norm, xc, pl_.get("ln1"))
                a, (c2, kr2) = attn_lib.mla_decode(pl_["attn"], h, c, kr, pos, cfg)
                new_cache = (c2, kr2)
            else:
                pl_, k, v, flag = layer
                h = apply_norm(cfg.norm, xc, pl_.get("ln1"))
                a, (k2, v2) = attn_lib.gqa_decode_flagged(
                    pl_["attn"], h, k, v, pos, cfg.window, flag
                )
                new_cache = (k2, v2)
            xc = xc + a
            h = apply_norm(cfg.norm, xc, pl_.get("ln2"))
            if cfg.moe:
                f, _ = moe_lib.moe_apply(pl_["ffn"], h, cfg)
            else:
                f = mlp_apply(pl_["ffn"], h, cfg.act)
            return xc + f, new_cache

        if cfg.attn == "mla":
            xs = (params["layers"], cache["c"], cache["kr"])
        else:
            xs = (params["layers"], cache["k"], cache["v"], flags)
        x, new_caches = jax.lax.scan(body, x, xs)
        x = apply_norm(cfg.norm, x, params.get("ln_f"))
        logits = logits_fn(x, params["embed"])
        if cfg.attn == "mla":
            cache = {"c": new_caches[0], "kr": new_caches[1]}
            if new_dense_c:
                cache["dense_c"] = jnp.stack(new_dense_c)
                cache["dense_kr"] = jnp.stack(new_dense_kr)
        else:
            cache = {"k": new_caches[0], "v": new_caches[1]}
        return logits, cache

    return decode
