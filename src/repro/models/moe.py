"""Mixture-of-Experts FFN: shared + routed experts, top-k, EP-shardable.

Dispatch uses the sort/gather formulation rather than GShard one-hot
einsums: at the assigned scales (1M tokens x 160 experts) a (T, E, C)
dispatch tensor is infeasible, while (E, C) gather indices are tiny.

  1. router logits -> top-k (expert, weight) per token,
  2. flatten (T*k) assignments, rank each within its expert via the
     sort-free cumsum-of-one-hot... no — via argsort by expert id (XLA sort,
     near-roofline) + segment ranks,
  3. scatter token ids into an (E, C) slot table (capacity-dropped),
  4. gather tokens -> (E, C, d), per-expert einsum (E-sharded = expert
     parallelism over 'model'), weighted scatter-add back.

Capacity factor guards the static shapes; dropped tokens fall back to the
shared experts (dsv2) or identity (pure-MoE), matching standard practice.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_apply, mlp_init


def moe_init(key, cfg, dtype):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_dff
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, ff)) * s_in).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, d, ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, ff, d)) * s_out).astype(dtype),
    }
    if cfg.n_shared > 0:
        p["shared"] = mlp_init(ks[4], d, cfg.moe_dff * cfg.n_shared, "swiglu", dtype)
    return p


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (B, S, d). Routed top-k + optional shared experts."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = int(np.ceil(T * k / E * cfg.capacity_factor))

    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)  # renormalize

    # rank each (token, slot) within its expert
    flat_e = tope.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < C
    slot = jnp.clip(flat_e, 0, E - 1) * C + jnp.clip(rank, 0, C - 1)
    tok_of_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    # (E*C,) token id feeding each expert slot; T = empty sentinel.  Dropped
    # assignments scatter to index E*C which mode="drop" discards.
    slot_tok = jnp.full((E * C,), T, jnp.int32).at[
        jnp.where(keep, slot, E * C)
    ].set(tok_of_flat, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = xt_pad[slot_tok].reshape(E, C, d)  # gather (EP-sharded on E)
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["wg"])
    h = h * jax.nn.silu(g)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["wo"])  # (E, C, d)

    # combine: weighted scatter-add back to tokens
    w_flat = topw.reshape(-1)
    slot_w = jnp.zeros((E * C,), jnp.float32).at[
        jnp.where(keep, slot, E * C)
    ].set(w_flat, mode="drop")
    contrib = expert_out.reshape(E * C, d) * slot_w[:, None].astype(expert_out.dtype)
    out = jnp.zeros((T + 1, d), x.dtype).at[slot_tok].add(contrib)[:T]

    if "shared" in params:
        out = out + mlp_apply(params["shared"], xt, "swiglu")
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0 / (T * k))
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux
