"""SO(3) machinery for the eSCN / EquiformerV2 family, TPU-adapted.

GPU implementations (the official eSCN/EquiformerV2 repos) precompute one
Wigner-D matrix per edge on the host and gather them in the kernel — at
61M-edge scale that is ~100s of GB of matrix traffic.  The TPU-native
formulation used here avoids per-edge matrices entirely via the classic
Z-Y-Z factorization in the *real* spherical-harmonic basis:

    D(alpha, beta, 0) = Zr(alpha) · J · Zr(beta) · J

where ``J = d(pi/2)`` is a CONSTANT block-diagonal matrix (VMEM-resident,
computed once on the host from the complex Wigner small-d + the
complex->real unitary) and ``Zr(theta)`` is a per-edge *diagonal/2x2-block*
phase — O((2l+1)) elementwise work.  Rotating features therefore costs two
constant-matrix einsums (MXU work against a fixed operand) plus two cheap
phase multiplies, with zero per-edge matrix storage.

Feature layout: x[(l,m)] flattened to a single axis of size (l_max+1)^2 in
the order (l=0,m=0), (l=1,m=-1..1), ... — matching e3nn conventions.
"""
from __future__ import annotations

from functools import lru_cache
from math import factorial, sqrt

import numpy as np

import jax.numpy as jnp


def _small_d_entry(l: int, mp: int, m: int, beta: float) -> float:
    """Complex-basis Wigner small-d d^l_{mp,m}(beta) (Wikipedia convention)."""
    pref = sqrt(
        factorial(l + mp) * factorial(l - mp) * factorial(l + m) * factorial(l - m)
    )
    smin = max(0, m - mp)
    smax = min(l + m, l - mp)
    tot = 0.0
    for s in range(smin, smax + 1):
        num = (-1.0) ** (mp - m + s)
        den = (
            factorial(l + m - s) * factorial(s)
            * factorial(mp - m + s) * factorial(l - mp - s)
        )
        c = np.cos(beta / 2.0) ** (2 * l + m - mp - 2 * s)
        sn = np.sin(beta / 2.0) ** (mp - m + 2 * s)
        tot += num / den * c * sn
    return pref * tot


def _complex_to_real_U(l: int) -> np.ndarray:
    """U s.t. Y_real = U @ Y_complex, rows ordered m = -l..l (Condon-Shortley)."""
    n = 2 * l + 1
    U = np.zeros((n, n), dtype=np.complex128)
    for m in range(-l, l + 1):
        i = m + l
        if m < 0:
            U[i, m + l] = 1j / sqrt(2)
            U[i, -m + l] = -1j * (-1) ** m / sqrt(2)
        elif m == 0:
            U[i, l] = 1.0
        else:
            U[i, -m + l] = 1 / sqrt(2)
            U[i, m + l] = (-1) ** m / sqrt(2)
    return U


@lru_cache(maxsize=None)
def J_matrix(l: int) -> np.ndarray:
    """Real-basis J_l = D^l(g), g = rotation by pi about (y+z)/sqrt(2).

    g is an involution that conjugates Rz into Ry, so
    ``J Zr(beta) J = Ry(beta)`` and the ZYZ factorization
    ``D = Zr(alpha) J Zr(beta) J Zr(gamma)`` holds with a CONSTANT J.
    In ZYZ Euler form g = Rz(pi/2) Ry(pi/2) Rz(pi/2); we build its complex
    Wigner-D (z-phases e^{+i m theta} — the convention validated against the
    l=1 target) and conjugate into the real SH basis.
    """
    ms = np.arange(-l, l + 1)
    d = np.array(
        [[_small_d_entry(l, mp, m, np.pi / 2) for m in range(-l, l + 1)]
         for mp in range(-l, l + 1)]
    )
    Zc = np.diag(np.exp(1j * ms * (np.pi / 2)))
    Dg = Zc @ d.astype(np.complex128) @ Zc
    U = _complex_to_real_U(l)
    J = U @ Dg @ U.conj().T
    assert np.abs(J.imag).max() < 1e-9, "J must be real in the real SH basis"
    return J.real


@lru_cache(maxsize=None)
def J_block(l_max: int) -> np.ndarray:
    """Block-diagonal J over all l <= l_max: ((l_max+1)^2, (l_max+1)^2)."""
    n = (l_max + 1) ** 2
    out = np.zeros((n, n))
    off = 0
    for l in range(l_max + 1):
        k = 2 * l + 1
        out[off:off + k, off:off + k] = J_matrix(l)
        off += k
    return out


@lru_cache(maxsize=None)
def m_indices(l_max: int):
    """Per-coefficient (l, m) and the index of the (l, -m) partner."""
    ls, ms, partner = [], [], []
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            ls.append(l)
            ms.append(m)
            partner.append(off + (-m + l))
        off += 2 * l + 1
    return np.array(ls), np.array(ms), np.array(partner)


def z_rotate(x, theta, l_max: int):
    """Real-basis rotation about z by per-edge angle theta.

    x: (E, (l_max+1)^2, C); theta: (E,).  Real-basis z-rotation mixes the
    (l, m) and (l, -m) pair:  y_m = cos(m t) x_m - sin(m t) x_{-m}.
    """
    ls, ms, partner = m_indices(l_max)
    m = jnp.asarray(ms, jnp.float32)
    part = jnp.asarray(partner, jnp.int32)
    ang = theta[:, None] * m[None, :]
    c = jnp.cos(ang)[..., None].astype(x.dtype)
    s = jnp.sin(ang)[..., None].astype(x.dtype)
    return c * x - s * x[:, part, :]


def euler_from_edges(edge_vec):
    """(alpha, beta) s.t. Rz(-alpha) then Ry(-beta) maps the edge onto +z.

    Returns per-edge angles; degenerate (zero-length) edges get zeros.
    """
    n = edge_vec / jnp.maximum(jnp.linalg.norm(edge_vec, axis=-1, keepdims=True), 1e-9)
    beta = jnp.arccos(jnp.clip(n[:, 2], -1.0, 1.0))
    alpha = jnp.arctan2(n[:, 1], n[:, 0])
    return alpha, beta


def rotate_to_frame(x, alpha, beta, l_max: int, Jb):
    """Apply D(0, -beta, -alpha): world frame -> edge-aligned frame."""
    x = z_rotate(x, -alpha, l_max)
    x = jnp.einsum("ij,ejc->eic", Jb, x)
    x = z_rotate(x, -beta, l_max)
    x = jnp.einsum("ij,ejc->eic", Jb, x)
    return x


def rotate_from_frame(x, alpha, beta, l_max: int, Jb):
    """Inverse of rotate_to_frame (J is symmetric-orthogonal: J^{-1}=J^T)."""
    x = jnp.einsum("ji,ejc->eic", Jb, x)
    x = z_rotate(x, beta, l_max)
    x = jnp.einsum("ji,ejc->eic", Jb, x)
    x = z_rotate(x, alpha, l_max)
    return x


def rotation_matrix_l1(alpha, beta):
    """The l=1 real-SH-basis (y,z,x) rotation D(0,-beta,-alpha) as (E,3,3)
    matrices — used by equivariance tests to compare against plain 3D
    rotation of vectors."""
    E = alpha.shape[0]
    basis = jnp.zeros((3, E, 4, 1), jnp.float32).at[
        jnp.arange(3), :, jnp.arange(1, 4), 0
    ].set(1.0)
    Jb = jnp.asarray(J_block(1), jnp.float32)
    cols = [rotate_to_frame(basis[i], alpha, beta, 1, Jb)[:, 1:, 0] for i in range(3)]
    return jnp.stack(cols, axis=-1)  # (E, 3, 3) columns = images of y,z,x
