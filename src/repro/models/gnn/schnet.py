"""SchNet [arXiv:1706.08566] — continuous-filter convolutions on molecules.

3 interaction blocks, d=64, 300 Gaussian RBFs, 10 Å cutoff.  Energy readout
(sum over atom-wise MLP outputs); trained with MSE on energies.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.gnn.common import dense_init, edge_endpoints, seg_sum


@dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    n_out: int = 1  # 1 = energy regression; >1 = per-node classification
    dtype: str = "float32"


def init_params(key, cfg: SchNetConfig):
    d, r = cfg.d_hidden, cfg.n_rbf
    ks = jax.random.split(key, cfg.n_interactions * 4 + 4)
    blocks = []
    for i in range(cfg.n_interactions):
        k = ks[4 * i:4 * i + 4]
        blocks.append(
            {
                "filter1": dense_init(k[0], r, d),
                "filter2": dense_init(k[1], d, d),
                "in2f": dense_init(k[2], d, d),
                "f2out": dense_init(k[3], d, d),
            }
        )
    return {
        "embed": (jax.random.normal(ks[-3], (cfg.n_species, d)) * 0.3).astype(jnp.float32),
        "out1": dense_init(ks[-2], d, d // 2),
        "out2": dense_init(ks[-1], d // 2, cfg.n_out),
        "blocks": blocks,
    }


def _shifted_softplus(x):
    return jax.nn.softplus(x) - np.log(2.0)


def rbf_expand(dist, cfg: SchNetConfig):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0 / cfg.cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def forward(params, graph, cfg: SchNetConfig):
    """graph: species int32[N], pos f32[N,3], edges int32[E,2], batch_seg."""
    src, dst, valid = edge_endpoints(graph["edges"])
    pos = graph["pos"]
    n = pos.shape[0]
    h = params["embed"][graph["species"]]

    d_ij = jnp.linalg.norm(pos[src] - pos[dst] + 1e-12, axis=-1)
    rbf = rbf_expand(d_ij, cfg)
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d_ij / cfg.cutoff, 0, 1)) + 1.0)
    env = jnp.where(valid, env, 0.0)

    for blk in params["blocks"]:
        W = _shifted_softplus(rbf @ blk["filter1"]) @ blk["filter2"]  # (E, d)
        W = W * env[:, None]
        m = (h @ blk["in2f"])[src] * W
        agg = seg_sum(m, dst, n)
        h = h + _shifted_softplus(agg @ blk["f2out"])

    atom_out = _shifted_softplus(h @ params["out1"]) @ params["out2"]  # (N, n_out)
    if cfg.n_out > 1:
        return atom_out  # per-node logits (classification shapes)
    seg = graph.get("batch_seg")
    if seg is None:
        return atom_out.sum()
    return seg_sum(atom_out[:, 0], seg, graph["energy"].shape[0])


def loss_fn(params, graph, cfg: SchNetConfig):
    pred = forward(params, graph, cfg)
    return jnp.mean((pred - graph["energy"]) ** 2)
