"""EquiformerV2-style equivariant graph attention via eSCN [arXiv:2306.12059].

Node features are real-SH irrep tensors x: (N, (l_max+1)^2, C) with l_max=6.
Per edge, the eSCN trick [arXiv:2302.03655]: rotate both endpoint features
into the edge-aligned frame (so3.py — constant-J factorization, no per-edge
Wigner matrices), where the SO(3) tensor product collapses to a *block-
diagonal SO(2) linear map over |m| <= m_max* (m_max=2), i.e. the O(L^6)
Clebsch-Gordan contraction becomes O(L^3) dense matmuls — MXU food.
Messages are attention-weighted (invariant logits from the m=0 block),
rotated back, and scatter-summed.

Memory discipline: edge tensors ((E, 49, C)) are processed in ``edge_chunks``
scan slices so the peak footprint is bounded regardless of |E| — the 61M-edge
cells stream edges through a (E/chunks, 49, C) working set.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.gnn import so3
from repro.models.gnn.common import dense_init, edge_endpoints, seg_softmax, seg_sum


@dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    channels: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_species: int = 100
    edge_chunks: int = 1
    n_out: int = 1  # 1 = energy regression; >1 = node classification
    dtype: str = "float32"
    # beyond-paper perf knobs (EXPERIMENTS.md §Perf): compute only the
    # |m| <= m_max rows of the edge-frame rotation (exact — the SO(2) conv
    # never reads the rest), and stream edge tensors in bf16.
    rotate_restrict: bool = False
    edge_dtype: str = "float32"

    @property
    def n_coeff(self) -> int:
        return (self.l_max + 1) ** 2


def _m_groups(l_max: int, m_max: int):
    """Coefficient indices per |m| group: m=0 -> list, m>0 -> (pos, neg)."""
    groups = {}
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            idx = off + m + l
            key = abs(m)
            if key <= m_max:
                sign = "0" if m == 0 else ("+" if m > 0 else "-")
                groups.setdefault(key, {}).setdefault(sign, []).append(idx)
        off += 2 * l + 1
    return groups


def init_params(key, cfg: EquiformerConfig):
    C = cfg.channels
    groups = _m_groups(cfg.l_max, cfg.m_max)
    ks = iter(jax.random.split(key, cfg.n_layers * 16 + 8))
    layers = []
    for _ in range(cfg.n_layers):
        p = {"so2": {}}
        for m, g in groups.items():
            n_l = len(g["0" if m == 0 else "+"])
            dim = n_l * C
            if m == 0:
                p["so2"]["m0"] = dense_init(next(ks), 2 * dim, dim)  # src||dst
            else:
                p["so2"][f"m{m}r"] = dense_init(next(ks), 2 * dim, dim)
                p["so2"][f"m{m}i"] = dense_init(next(ks), 2 * dim, dim)
        n_l0 = len(groups[0]["0"])
        p["attn_w"] = dense_init(next(ks), 2 * n_l0 * C, C)
        p["attn_a"] = dense_init(next(ks), C, cfg.n_heads)
        # equivariant FFN: per-l channel mixing (shared across m) + scalar gate
        p["ffn_w"] = (jax.random.normal(next(ks), (cfg.l_max + 1, C, C))
                      / np.sqrt(C)).astype(jnp.float32)
        p["gate_w"] = dense_init(next(ks), C, (cfg.l_max + 1) * C)
        layers.append(p)
    return {
        "embed": (jax.random.normal(next(ks), (cfg.n_species, C)) * 0.3).astype(jnp.float32),
        "head": dense_init(next(ks), C, cfg.n_out),
        "layers": layers,
    }


def _equiv_norm(x, l_max: int, eps=1e-5):
    """Per-l RMS norm over (m, channel) — rotation invariant."""
    outs = []
    off = 0
    for l in range(l_max + 1):
        k = 2 * l + 1
        blk = x[:, off:off + k, :]
        rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2), keepdims=True) + eps)
        outs.append(blk / rms)
        off += k
    return jnp.concatenate(outs, axis=1)


def _so2_conv(p, z_src, z_dst, groups, C: int, n_rows: int | None = None):
    """SO(2)-restricted linear map in the edge frame (the eSCN core)."""
    E = z_src.shape[0]
    out = jnp.zeros((E, n_rows or z_src.shape[1], C), z_src.dtype)
    for m, g in groups.items():
        if m == 0:
            idx = jnp.asarray(g["0"], jnp.int32)
            xin = jnp.concatenate(
                [z_src[:, idx, :], z_dst[:, idx, :]], axis=1
            ).reshape(E, -1)
            y = xin @ p["so2"]["m0"].astype(xin.dtype)
            out = out.at[:, idx, :].set(y.reshape(E, len(g["0"]), C))
        else:
            ip = jnp.asarray(g["+"], jnp.int32)
            im = jnp.asarray(g["-"], jnp.int32)
            xp = jnp.concatenate([z_src[:, ip, :], z_dst[:, ip, :]], axis=1).reshape(E, -1)
            xm = jnp.concatenate([z_src[:, im, :], z_dst[:, im, :]], axis=1).reshape(E, -1)
            Wr = p["so2"][f"m{m}r"].astype(xp.dtype)
            Wi = p["so2"][f"m{m}i"].astype(xp.dtype)
            yp = xp @ Wr - xm @ Wi
            ym = xm @ Wr + xp @ Wi
            out = out.at[:, ip, :].set(yp.reshape(E, len(g["+"]), C))
            out = out.at[:, im, :].set(ym.reshape(E, len(g["-"]), C))
    return out  # coefficients with |m| > m_max stay zero (eSCN truncation)


def _sel_layout(groups, n_coeff):
    """Row subset with |m| <= m_max + groups remapped into that layout."""
    sel = sorted({i for g in groups.values() for lst in g.values() for i in lst})
    pos = {orig: k for k, orig in enumerate(sel)}
    rgroups = {
        m: {s: [pos[i] for i in lst] for s, lst in g.items()}
        for m, g in groups.items()
    }
    return sel, rgroups


def forward(params, graph, cfg: EquiformerConfig):
    """graph: species i32[N], pos f32[N,3], edges i32[E,2] -> (N, n_out)."""
    C = cfg.channels
    L = cfg.l_max
    n = graph["pos"].shape[0]
    groups = _m_groups(L, cfg.m_max)
    edt = jnp.dtype(cfg.edge_dtype)
    Jb = jnp.asarray(so3.J_block(L), edt)
    if cfg.rotate_restrict:
        sel_rows, conv_groups = _sel_layout(groups, cfg.n_coeff)
        sel = jnp.asarray(sel_rows, jnp.int32)
        Jb_sel = Jb[sel, :]
        # z-rotation phases for the selected rows only
        ls, ms, partner = so3.m_indices(L)
        pos_of = {orig: k for k, orig in enumerate(sel_rows)}
        m_sel = jnp.asarray(ms[sel_rows], jnp.float32)
        part_sel = jnp.asarray([pos_of[int(partner[i])] for i in sel_rows], jnp.int32)
        n_rows = len(sel_rows)
    else:
        conv_groups = groups
        n_rows = cfg.n_coeff

    x = jnp.zeros((n, cfg.n_coeff, C), jnp.float32)
    x = x.at[:, 0, :].set(params["embed"][graph["species"]])

    edges = graph["edges"]
    E = edges.shape[0]
    chunks = max(1, cfg.edge_chunks)
    pad = (-E) % chunks
    if pad:
        edges = jnp.concatenate([edges, jnp.full((pad, 2), -1, edges.dtype)])
    edges_c = edges.reshape(chunks, -1, 2)

    for p in params["layers"]:
        xn = _equiv_norm(x, L).astype(edt)  # cast BEFORE the edge gathers:
        # the (Ec, 49, C) gather outputs dominate HBM traffic at 61M edges

        def chunk_body(acc, ech):
            agg, wsum = acc
            src, dst, valid = edge_endpoints(ech)
            vec = graph["pos"][dst] - graph["pos"][src]
            # zero-length edges (self-loops) have no well-defined frame and
            # would silently break equivariance — mask them out.
            valid = valid & (jnp.sum(vec * vec, axis=-1) > 1e-12)
            alpha_e, beta_e = so3.euler_from_edges(vec)
            if cfg.rotate_restrict:
                # exact: the SO(2) conv only reads |m| <= m_max rows, so the
                # final J matmul emits just those rows (49 -> n_rows) and the
                # back-rotation starts from them.
                def to_frame(xg):
                    x1 = so3.z_rotate(xg, -alpha_e, L)
                    x1 = jnp.einsum("ij,ejc->eic", Jb, x1)
                    x1 = so3.z_rotate(x1, -beta_e, L)
                    return jnp.einsum("ij,ejc->eic", Jb_sel, x1)

                def from_frame(msg_sel):
                    x1 = jnp.einsum("ji,ejc->eic", Jb_sel, msg_sel)
                    x1 = so3.z_rotate(x1, beta_e, L)
                    x1 = jnp.einsum("ji,ejc->eic", Jb, x1)
                    return so3.z_rotate(x1, alpha_e, L)
            else:
                def to_frame(xg):
                    return so3.rotate_to_frame(xg, alpha_e, beta_e, L, Jb)

                def from_frame(m_):
                    return so3.rotate_from_frame(m_, alpha_e, beta_e, L, Jb)

            z_src = to_frame(xn[src])
            z_dst = to_frame(xn[dst])
            msg_f = _so2_conv(p, z_src, z_dst, conv_groups, C, n_rows)
            # invariant attention logits from the m=0 block
            idx0 = jnp.asarray(conv_groups[0]["0"], jnp.int32)
            inv = jnp.concatenate(
                [z_src[:, idx0, :], z_dst[:, idx0, :]], axis=1
            ).reshape(z_src.shape[0], -1).astype(jnp.float32)
            logits = jax.nn.silu(inv @ p["attn_w"]) @ p["attn_a"]  # (Ec, H)
            logits = 20.0 * jnp.tanh(logits / 20.0)  # soft-cap: chunk-streaming
            # softmax accumulates exp-weights across scan steps, so logits
            # must be bounded instead of max-subtracted.
            logits = jnp.where(valid[:, None], logits, -1e30)
            msg = from_frame(msg_f).astype(jnp.float32)
            msg = jnp.where(valid[:, None, None], msg, 0.0)
            # accumulate unnormalized attention (exp-logit weights, head-split)
            w = jnp.exp(jnp.where(logits > -1e29, logits - 20.0, -jnp.inf))
            H = cfg.n_heads
            msg_h = msg.reshape(msg.shape[0], cfg.n_coeff, H, C // H)
            wm = msg_h * w[:, None, :, None]
            agg = agg + seg_sum(wm.reshape(msg.shape[0], cfg.n_coeff, C), dst, n)
            wsum = wsum + seg_sum(
                jnp.repeat(w, C // H, axis=-1), dst, n
            )
            return (agg, wsum), None

        init = (
            jnp.zeros((n, cfg.n_coeff, C), jnp.float32),
            jnp.zeros((n, C), jnp.float32),
        )
        (agg, wsum), _ = jax.lax.scan(chunk_body, init, edges_c)
        attn_out = agg / jnp.maximum(wsum[:, None, :], 1e-9)
        x = x + attn_out

        # equivariant FFN: scalar-gated per-l channel mixing
        xn2 = _equiv_norm(x, L)
        gates = jax.nn.silu(xn2[:, 0, :] @ p["gate_w"]).reshape(n, L + 1, C)
        outs = []
        off = 0
        for l in range(L + 1):
            k = 2 * l + 1
            blk = jnp.einsum("nmc,cd->nmd", xn2[:, off:off + k, :], p["ffn_w"][l])
            outs.append(blk * gates[:, l:l + 1, :])
            off += k
        x = x + jnp.concatenate(outs, axis=1)

    inv_out = _equiv_norm(x, L)[:, 0, :]  # invariant readout
    return inv_out @ params["head"]


def loss_fn(params, graph, cfg: EquiformerConfig):
    out = forward(params, graph, cfg)
    if cfg.n_out == 1:
        seg = graph.get("batch_seg")
        if seg is not None:
            e = seg_sum(out[:, 0], seg, graph["energy"].shape[0])
            return jnp.mean((e - graph["energy"]) ** 2)
        return jnp.mean((out.sum() - graph["energy"]) ** 2)
    from repro.models.gnn.common import cross_entropy_nodes

    return cross_entropy_nodes(out, graph["labels"], graph["train_mask"])
