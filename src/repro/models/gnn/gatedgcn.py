"""GatedGCN [arXiv:1711.07553 / benchmarking-gnns arXiv:2003.00982].

16 layers, d=70, explicit edge features with gated aggregation:

    e'_ij = A h_i + B h_j + C e_ij
    h'_i  = U h_i + sum_j sigma(e'_ij) / (sum_j sigma(e'_ij) + eps) ⊙ V h_j

LayerNorm replaces the paper's BatchNorm (jit/shard-friendlier; noted in
DESIGN.md) + residuals, as in the benchmarking-gnns reference code.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    cross_entropy_nodes, dense_init, edge_endpoints, seg_sum,
)


@dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 1
    n_classes: int = 7
    dtype: str = "float32"


def init_params(key, cfg: GatedGCNConfig):
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers * 5 + 3)
    layers = []
    for i in range(cfg.n_layers):
        k = ks[5 * i:5 * i + 5]
        layers.append(
            {
                "A": dense_init(k[0], d, d), "B": dense_init(k[1], d, d),
                "C": dense_init(k[2], d, d), "U": dense_init(k[3], d, d),
                "V": dense_init(k[4], d, d),
            }
        )
    return {
        "embed_h": dense_init(ks[-3], cfg.d_in, d),
        "embed_e": dense_init(ks[-2], cfg.d_edge_in, d),
        "head": dense_init(ks[-1], d, cfg.n_classes),
        "layers": layers,
    }


def _ln(x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def forward(params, graph, cfg: GatedGCNConfig):
    src, dst, valid = edge_endpoints(graph["edges"])
    n = graph["nodes"].shape[0]
    h = graph["nodes"] @ params["embed_h"]
    e = graph.get("edge_feat")
    if e is None:
        e = jnp.ones((graph["edges"].shape[0], cfg.d_edge_in), h.dtype)
    e = e @ params["embed_e"]

    for p in params["layers"]:
        e_new = h[src] @ p["A"] + h[dst] @ p["B"] + e @ p["C"]
        gate = jax.nn.sigmoid(e_new)
        gate = jnp.where(valid[:, None], gate, 0.0)
        msg = gate * (h[src] @ p["V"])
        num = seg_sum(msg, dst, n)
        den = seg_sum(gate, dst, n)
        h_new = h @ p["U"] + num / (den + 1e-6)
        h = h + jax.nn.relu(_ln(h_new))  # residual
        e = e + jax.nn.relu(_ln(e_new))
    return h @ params["head"]


def loss_fn(params, graph, cfg: GatedGCNConfig):
    logits = forward(params, graph, cfg)
    return cross_entropy_nodes(logits, graph["labels"], graph["train_mask"])
