"""Shared GNN machinery: edge-index message passing via segment ops.

JAX sparse is BCOO-only, so message passing is implemented the idiomatic
way: gather source features by edge index, transform, ``segment_sum`` /
``segment_max`` into destinations.  All ops take ``num_nodes`` statically so
they jit/shard cleanly (edges row-sharded, nodes replicated or psum-reduced;
see launch/shardings.py).

Graphs are plain dicts:
  nodes: f32[N, F]   edges: int32[E, 2] (src, dst)   plus optional fields
  (edge_feat, pos, labels, train_mask, -1-padded edges allowed).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def seg_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def seg_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def seg_softmax(scores, segment_ids, num_segments: int, valid=None):
    """Numerically-stable softmax over edges grouped by destination."""
    if valid is not None:
        scores = jnp.where(valid, scores, -1e30)
    mx = seg_max(scores, segment_ids, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - mx[segment_ids])
    if valid is not None:
        ex = jnp.where(valid, ex, 0.0)
    den = seg_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(den[segment_ids], 1e-16)


def edge_endpoints(edges):
    """(src, dst, valid) with -1 padding mapped to node 0 + invalid mask."""
    src, dst = edges[:, 0], edges[:, 1]
    valid = (src >= 0) & (dst >= 0)
    return jnp.maximum(src, 0), jnp.maximum(dst, 0), valid


def degree(edges, num_nodes: int):
    src, dst, valid = edge_endpoints(edges)
    return seg_sum(valid.astype(jnp.float32), dst, num_nodes)


def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def cross_entropy_nodes(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return -(gold * m).sum() / jnp.maximum(m.sum(), 1.0)
