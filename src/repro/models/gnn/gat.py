"""GAT [arXiv:1710.10903] — graph attention via SDDMM + edge softmax + SpMM.

Cora config: 2 layers, 8 hidden per head, 8 heads (concat) -> 1 head out.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    cross_entropy_nodes, dense_init, edge_endpoints, seg_softmax, seg_sum,
)


@dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    dropout: float = 0.0  # inference/dry-run default
    dtype: str = "float32"


def init_params(key, cfg: GATConfig):
    ks = jax.random.split(key, cfg.n_layers * 3 + 1)
    params = {"layers": []}
    d_in = cfg.d_in
    dt = jnp.dtype(cfg.dtype)
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        params["layers"].append(
            {
                "w": dense_init(ks[3 * i], d_in, heads * d_out, dt),
                "a_src": (jax.random.normal(ks[3 * i + 1], (heads, d_out)) * 0.1).astype(dt),
                "a_dst": (jax.random.normal(ks[3 * i + 2], (heads, d_out)) * 0.1).astype(dt),
            }
        )
        d_in = heads * d_out if not last else d_out
    return params


def layer_apply(p, x, edges, num_nodes: int, heads: int, d_out: int, concat: bool):
    src, dst, valid = edge_endpoints(edges)
    h = (x @ p["w"]).reshape(-1, heads, d_out)  # (N, H, F)
    e_src = (h * p["a_src"][None]).sum(-1)  # (N, H)
    e_dst = (h * p["a_dst"][None]).sum(-1)
    scores = jax.nn.leaky_relu(e_src[src] + e_dst[dst], 0.2)  # (E, H)
    alpha = seg_softmax(scores, dst, num_nodes, valid[:, None])
    msg = h[src] * alpha[..., None]  # (E, H, F)
    out = seg_sum(jnp.where(valid[:, None, None], msg, 0), dst, num_nodes)
    return out.reshape(-1, heads * d_out) if concat else out.mean(axis=1)


def forward(params, graph, cfg: GATConfig):
    x = graph["nodes"]
    n = x.shape[0]
    for i, p in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        x = layer_apply(p, x, graph["edges"], n, heads, d_out, concat=not last)
        if not last:
            x = jax.nn.elu(x)
    return x  # (N, n_classes) logits


def loss_fn(params, graph, cfg: GATConfig):
    logits = forward(params, graph, cfg)
    return cross_entropy_nodes(logits, graph["labels"], graph["train_mask"])
