"""MIND [arXiv:1904.08030] — Multi-Interest Network with Dynamic routing.

User behavior sequences are routed into ``n_interests`` capsules (B2I
dynamic routing, 3 iterations); training uses label-aware attention over the
interests + in-batch sampled softmax; retrieval scores a candidate set with
a max over interests.

The embedding table is the hot path (10^6+ rows x 64, row-sharded across
the mesh).  LiteMat tie-in: items carry a LiteMat-encoded category id, so
retrieval supports *category-subtree filtering* — one interval compare per
candidate (``clo <= cat < chi``) instead of a set-membership probe against
the whole taxonomy (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 8_388_608  # 2^23 rows
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    dtype: str = "float32"
    serve_impl: str = "gather"  # gather | sharded_topk (beyond-paper)


def init_params(key, cfg: MINDConfig):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": (jax.random.normal(k1, (cfg.n_items, cfg.embed_dim)) * 0.05).astype(dt),
        # S: shared bilinear routing map (B2I capsules)
        "S": (jax.random.normal(k2, (cfg.embed_dim, cfg.embed_dim))
              / np.sqrt(cfg.embed_dim)).astype(dt),
    }


def _squash(v, axis=-1, eps=1e-9):
    n2 = jnp.sum(jnp.square(v), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + eps)


def user_interests(params, hist, cfg: MINDConfig):
    """hist: int32[B, L] (-1 padded) -> interests f32[B, K, D]."""
    B, L = hist.shape
    K, D = cfg.n_interests, cfg.embed_dim
    valid = (hist >= 0)[..., None]  # (B, L, 1)
    e = params["embed"][jnp.clip(hist, 0, cfg.n_items - 1)]  # (B, L, D)
    e = jnp.where(valid, e, 0.0)
    eS = e @ params["S"]  # behavior -> interest space

    # fixed (deterministic) logit init, as in the paper's B2I variant
    b = jnp.broadcast_to(
        jnp.linspace(-1.0, 1.0, K, dtype=e.dtype)[None, None, :], (B, L, K)
    )
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=-1) * valid  # (B, L, K)
        z = jnp.einsum("blk,bld->bkd", w, eS)
        v = _squash(z)  # (B, K, D)
        b = b + jnp.einsum("bkd,bld->blk", v, eS)
    return v


def label_aware_user(interests, target_e, pow_: float = 2.0):
    """MIND's label-aware attention: sharpened softmax over interests."""
    logits = jnp.einsum("bkd,bd->bk", interests, target_e)
    w = jax.nn.softmax(pow_ * logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def loss_fn(params, batch, cfg: MINDConfig):
    """In-batch sampled softmax with label-aware attention."""
    interests = user_interests(params, batch["hist"], cfg)
    tgt = params["embed"][jnp.clip(batch["target"], 0, cfg.n_items - 1)]  # (B, D)
    u = label_aware_user(interests, tgt)
    logits = (u @ tgt.T).astype(jnp.float32) / np.sqrt(cfg.embed_dim)
    labels = jnp.arange(logits.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def score_candidates(params, hist, cand_ids, cfg: MINDConfig,
                     cand_cat=None, cat_interval=None):
    """Retrieval scoring: max-over-interests dot product.

    hist: (B, L); cand_ids: (C,) -> scores (B, C).  Optional LiteMat
    category filter: cand_cat (C,) int32 + cat_interval (lo, hi) masks
    candidates outside the queried category subtree with -inf.
    """
    interests = user_interests(params, hist, cfg)  # (B, K, D)
    ce = params["embed"][jnp.clip(cand_ids, 0, cfg.n_items - 1)]  # (C, D)
    scores = jnp.einsum("bkd,cd->bkc", interests, ce).max(axis=1)  # (B, C)
    if cand_cat is not None and cat_interval is not None:
        lo, hi = cat_interval
        ok = (cand_cat >= lo) & (cand_cat < hi)
        scores = jnp.where(ok[None, :], scores, -jnp.inf)
    return scores


def make_train_step(cfg: MINDConfig, lr: float = 1e-3):
    """SGD on the sampled-softmax loss (embedding-heavy: sparse-ish grads)."""

    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return params, loss

    return step


def make_serve_step(cfg: MINDConfig, topk: int = 64):
    def serve(params, hist, cand_ids, cand_cat, cat_lo, cat_hi):
        scores = score_candidates(
            params, hist, cand_ids, cfg, cand_cat, (cat_lo, cat_hi)
        )
        vals, idx = jax.lax.top_k(scores, topk)
        return vals, cand_ids[idx]

    return serve


def make_serve_step_sharded(cfg: MINDConfig, mesh, topk: int = 64,
                            slack: float = 1.5):
    """Two-stage sharded retrieval (beyond-paper; see EXPERIMENTS.md §Perf).

    The naive plan gathers candidate rows from the row-sharded table, which
    GSPMD lowers to an all-reduce of the full (C, D) matrix (256 MB/chip at
    1M candidates).  Here candidate IDS (4 bytes each) are all_to_all-routed
    to the shard that owns their embedding row; each shard scores locally
    and only per-shard top-k (KB) is exchanged.  Collective volume drops
    from O(C·D) to O(C + shards·topk).

    B is expected tiny (retrieval_cand has B=1); interests are computed
    outside and replicated.
    """
    from repro.utils.jaxcompat import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    nd = int(mesh.devices.size)
    V_loc = cfg.n_items // nd

    def body(table_loc, interests, cand_loc, cat_loc, lo, hi):
        # --- route candidate ids to their owner shard -----------------------
        C_loc = cand_loc.shape[0]
        cap = int(np.ceil(C_loc / nd * slack)) + 8
        owner = jnp.clip(cand_loc // V_loc, 0, nd - 1)
        one_hot = (owner[:, None] == jnp.arange(nd)[None, :]).astype(jnp.int32)
        slot = (jnp.cumsum(one_hot, axis=0) - one_hot)
        slot = (slot * one_hot).sum(axis=1)
        keep = slot < cap
        flat = jnp.where(keep, owner * cap + slot, nd * cap)
        bins_id = jnp.full((nd * cap,), -1, jnp.int32).at[flat].set(
            cand_loc, mode="drop").reshape(nd, cap)
        bins_cat = jnp.full((nd * cap,), -1, jnp.int32).at[flat].set(
            cat_loc, mode="drop").reshape(nd, cap)
        recv_id = jax.lax.all_to_all(bins_id, axes, 0, 0, tiled=False)
        recv_cat = jax.lax.all_to_all(bins_cat, axes, 0, 0, tiled=False)
        rid = recv_id.reshape(-1)
        rcat = recv_cat.reshape(-1)

        # --- local gather + score + LiteMat category interval ---------------
        shard = jax.lax.axis_index(axes)
        local_row = rid - shard * V_loc
        valid = (rid >= 0) & (local_row >= 0) & (local_row < V_loc)
        rows = table_loc[jnp.clip(local_row, 0, V_loc - 1)]  # (nd*cap, D)
        s = jnp.einsum("bkd,cd->bkc", interests, rows).max(axis=1)  # (B, nd*cap)
        ok = valid & (rcat >= lo) & (rcat < hi)
        s = jnp.where(ok[None, :], s, -jnp.inf)

        # --- local top-k, then tiny global exchange -------------------------
        lv, li = jax.lax.top_k(s, topk)  # (B, topk)
        lids = rid[li]
        gv = jax.lax.all_gather(lv, axes)  # (nd, B, topk)
        gi = jax.lax.all_gather(lids, axes)
        B = lv.shape[0]
        gv = jnp.moveaxis(gv, 0, 1).reshape(B, -1)
        gi = jnp.moveaxis(gi, 0, 1).reshape(B, -1)
        fv, fi = jax.lax.top_k(gv, topk)
        return fv, jnp.take_along_axis(gi, fi, axis=1)

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(), P(axes), P(axes), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def serve(params, hist, cand_ids, cand_cat, cat_lo, cat_hi):
        interests = user_interests(params, hist, cfg)
        return smapped(params["embed"], interests, cand_ids, cand_cat,
                       cat_lo, cat_hi)

    return serve
