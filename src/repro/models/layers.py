"""Shared neural layers: norms, rotary embedding, MLPs, chunked loss."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def rms_norm(x, scale=None, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layer_norm_nonparam(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale, no bias)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x, scale=None):
    if kind == "rmsnorm":
        return rms_norm(x, scale)
    if kind == "layernorm_nonparam":
        return layer_norm_nonparam(x)
    raise ValueError(kind)


def rope_freqs(head_dim: int, max_pos: int, theta: float = 10_000.0):
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    t = np.arange(max_pos)
    f = np.outer(t, inv)
    return jnp.asarray(np.cos(f), jnp.float32), jnp.asarray(np.sin(f), jnp.float32)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D) with D even; positions: broadcastable (..., S)."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def mlp_apply(params, x, act: str):
    """Gated (SwiGLU/GeGLU) or plain MLP; params: wi/(wg)/wo."""
    h = x @ params["wi"]
    if act in ("swiglu", "geglu"):
        g = x @ params["wg"]
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = h * gate
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return h @ params["wo"]


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    p = {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["wg"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def cross_entropy_chunked(logits_fn, x_final, embed, targets, mask, n_chunks: int = 8):
    """Next-token CE with the vocab projection chunked over the time axis.

    Avoids materializing (B, S, V) logits at once — at 256K vocabs and 1M
    tokens that array alone would be hundreds of GB.  ``logits_fn`` maps a
    (B, C, d) slice to (B, C, V) (usually x @ embed.T).
    """
    B, S, _ = x_final.shape
    C = S // n_chunks
    assert C * n_chunks == S, "sequence must divide the chunk count"

    def body(carry, idx):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x_final, idx * C, C, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, idx * C, C, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * C, C, axis=1)
        logits = logits_fn(xs, embed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (tot + nll.sum(), cnt + ms.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), jnp.arange(n_chunks)
    )
    return tot / jnp.maximum(cnt, 1.0)
