"""Attention variants: GQA/MQA (+sliding window) and DeepSeek-V2 MLA.

All functions are shape-explicit and shard-friendly: head dims are the
tensor-parallel axis, batch the data axis, and decode paths take
sequence-shardable KV caches (the long-context cells shard S over mesh
axes).  Softmax runs in f32 regardless of activation dtype.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope

NEG_INF = -1e30


def _causal_mask(Sq, Skv, offset=0):
    # query position i (global offset+i) attends kv position j <= offset+i
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Skv)[None, :]
    return kj <= qi


def _window_mask(Sq, Skv, window, offset=0):
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Skv)[None, :]
    return (kj <= qi) & (kj > qi - window)


def gqa_init(key, cfg, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, KV, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, KV, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * (1.0 / np.sqrt(H * hd))).astype(dtype),
    }


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Skv,KV,hd); grouped heads; f32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_blockwise(q, k, v, positions, window: int, is_global,
                    q_chunk: int = 512, kv_chunk: int = 1024):
    """FlashAttention-style blockwise SDPA (beyond-paper optimization).

    Never materializes (Sq, Skv) scores or boolean masks: scans KV chunks
    with a running (max, sum, accumulator) online softmax, computing the
    causal/sliding-window predicate from indices inside each tile.  Peak
    attention memory drops from O(B·H·Sq·Skv) f32 to
    O(B·H·q_chunk·kv_chunk), which converts the LM cells from
    score-traffic-bound to parameter/activation-bound.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq = max(1, Sq // q_chunk)
    nk = max(1, Skv // kv_chunk)
    qc = Sq // nq
    kc = Skv // nk
    qr = q.reshape(B, nq, qc, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)

    def q_step(qi):
        q_i = qr[:, qi]  # (B, qc, KV, G, hd)
        # train/prefill positions are always 0..S-1 (batch-uniform)
        q_pos = qi * qc + jnp.arange(qc)  # (qc,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
            v_j = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j).astype(jnp.float32)
            s = s * scale
            kv_pos = ki * kc + jnp.arange(kc)
            ok = kv_pos[None, :] <= q_pos[:, None]
            if window > 0:
                ok = ok & (is_global | (kv_pos[None, :] > q_pos[:, None] - window))
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        # checkpoint the tile body: the backward pass recomputes the (qc, kc)
        # score tile instead of stacking nk copies of it as scan residuals —
        # this IS FlashAttention's backward, expressed in XLA.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, hd)

    outs = jax.lax.map(q_step, jnp.arange(nq))  # (nq, B, qc, KV, G, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def gqa_forward(params, x, positions, window: int = 0):
    """Training/prefill attention; window>0 => sliding-window causal."""
    return gqa_forward_flagged(params, x, positions, window, jnp.bool_(window <= 0))


def gqa_forward_flagged(params, x, positions, window: int, is_global,
                        impl: str = "naive"):
    """Like gqa_forward but the local/global choice is a *traced* flag so a
    single scanned layer stack can interleave window patterns (gemma3)."""
    S = x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions)
    k = apply_rope(k, positions)
    if impl == "blockwise":
        out = _sdpa_blockwise(q, k, v, positions, window, is_global)
    elif impl == "stub":
        # measurement surrogate: one pass over v with the attention output's
        # shape/sharding — used to isolate attention-tile HBM traffic in the
        # dry-run (EXPERIMENTS.md §Perf methodology), NOT a real model.
        G = q.shape[2] // k.shape[2]
        out = jnp.repeat(v, G, axis=2) + 0.0 * q
    else:
        mask = _causal_mask(S, S)
        if window > 0:
            qi = jnp.arange(S)[:, None]
            kj = jnp.arange(S)[None, :]
            mask = mask & (is_global | (kj > qi - window))
        out = _sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def gqa_decode(params, x, cache_k, cache_v, pos, window: int = 0):
    """One-token decode: x (B,1,d); cache (B,Smax,KV,hd); pos scalar."""
    return gqa_decode_flagged(
        params, x, cache_k, cache_v, pos, window, jnp.bool_(window <= 0)
    )


def gqa_decode_flagged(params, x, cache_k, cache_v, pos, window: int, is_global):
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posv = jnp.full((B, 1), pos)
    q = apply_rope(q, posv)
    k = apply_rope(k, posv)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    Smax = cache_k.shape[1]
    kj = jnp.arange(Smax)
    mask = kj <= pos
    if window > 0:
        mask = mask & (is_global | (kj > pos - window))
    out = _sdpa(q, cache_k, cache_v, mask[None, :])
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd, rd = cfg.head_dim, cfg.rope_dim
    ql, kvl = cfg.q_lora, cfg.kv_lora
    ks = jax.random.split(key, 8)

    def mat(k, shape, fan_in):
        return (jax.random.normal(k, shape) * (1.0 / np.sqrt(fan_in))).astype(dtype)

    return {
        "wdq": mat(ks[0], (d, ql), d),  # q down-projection
        "wuq": mat(ks[1], (ql, H, hd + rd), ql),  # q up (nope + rope parts)
        "wdkv": mat(ks[2], (d, kvl), d),  # shared latent KV down-projection
        "wkr": mat(ks[3], (d, rd), d),  # decoupled rope key (shared)
        "wuk": mat(ks[4], (kvl, H, hd), kvl),  # k up (nope)
        "wuv": mat(ks[5], (kvl, H, hd), kvl),  # v up
        "wo": mat(ks[6], (H, hd, d), H * hd),
    }


def mla_forward(params, x, positions, cfg):
    """Training/prefill MLA; returns compressed cache (c_kv, k_rope)."""
    hd, rd = cfg.head_dim, cfg.rope_dim
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, params["wdq"])
    q = jnp.einsum("bsq,qhk->bshk", q, params["wuq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions)

    c_kv = jnp.einsum("bsd,dc->bsc", x, params["wdkv"])  # (B,S,kv_lora)
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["wkr"])[:, :, None, :], positions
    )[:, :, 0]  # (B,S,rd) shared across heads
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, params["wuk"])
    v = jnp.einsum("bsc,chk->bshk", c_kv, params["wuv"])

    scale = 1.0 / np.sqrt(hd + rd)
    scores = (
        jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    mask = _causal_mask(S, S)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", w, v)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (c_kv, k_rope)


def mla_decode(params, x, cache_c, cache_kr, pos, cfg):
    """One-token decode against the compressed (c_kv, k_rope) cache."""
    hd, rd = cfg.head_dim, cfg.rope_dim
    B = x.shape[0]
    posv = jnp.full((B, 1), pos)
    q = jnp.einsum("bsd,dq->bsq", x, params["wdq"])
    q = jnp.einsum("bsq,qhk->bshk", q, params["wuq"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, posv)

    c_new = jnp.einsum("bsd,dc->bsc", x, params["wdkv"])
    kr_new = apply_rope(jnp.einsum("bsd,dr->bsr", x, params["wkr"])[:, :, None, :], posv)[:, :, 0]
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, c_new, pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, kr_new, pos, axis=1)

    # absorb wuk into q (the MLA trick): score = (q_nope @ wuk^T) . c_kv
    q_lat = jnp.einsum("bqhk,chk->bqhc", q_nope, params["wuk"])  # (B,1,H,kvl)
    scores = (
        jnp.einsum("bqhc,bsc->bhqs", q_lat, cache_c)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_kr)
    ).astype(jnp.float32) / np.sqrt(hd + rd)
    Smax = cache_c.shape[1]
    mask = jnp.arange(Smax)[None, :] <= pos
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhqs,bsc->bqhc", w, cache_c)  # attend in latent space
    out = jnp.einsum("bqhc,chk->bqhk", out_lat, params["wuv"])  # then up-project
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (cache_c, cache_kr)
