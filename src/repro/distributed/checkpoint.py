"""Fault-tolerant checkpointing with elastic (mesh-independent) restore.

Design (DESIGN.md §6): snapshots store HOST arrays + logical metadata, never
device layouts, so a job restarted on a different mesh shape (256 -> 512
chips, or a degraded 255-chip slice re-sliced to 128) reshards on load by
re-applying its PartitionSpec rules to the new mesh.  Writes are atomic
(tmp + rename), content-hashed, and keep-K garbage collected — a partially
written checkpoint can never be restored.

Format: one ``.npz`` per snapshot with flattened tree paths as keys, plus a
JSON manifest (step, tree structure, hashes).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path

import numpy as np

import jax


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, tree, extra: dict | None = None) -> Path:
        flat = _flatten(tree)
        digest = hashlib.sha256()
        for k in sorted(flat):
            digest.update(k.encode())
            digest.update(np.ascontiguousarray(flat[k]).tobytes())
        manifest = dict(
            step=step,
            keys=sorted(flat.keys()),
            sha256=digest.hexdigest(),
            extra=extra or {},
        )
        final = self._step_dir(step)
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            d = self._step_dir(s)
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    def all_steps(self):
        out = []
        for d in self.dir.iterdir():
            if d.name.startswith("step_") and (d / "manifest.json").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template_tree, step: int | None = None,
                shardings=None, verify: bool = True):
        """Restore onto the template's structure; optional resharding.

        ``shardings`` may be a pytree of NamedSharding for a *different* mesh
        than the one that saved — this is the elastic-restart path.
        Returns (tree, manifest).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        if verify:
            digest = hashlib.sha256()
            for k in sorted(data.files):
                digest.update(k.encode())
                digest.update(np.ascontiguousarray(data[k]).tobytes())
            if digest.hexdigest() != manifest["sha256"]:
                raise IOError(f"checkpoint {d} failed integrity check")

        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template_tree)
        shard_leaves = (
            jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
            if shardings is not None
            else [None] * len(leaves_p)
        )
        out = []
        for (path, leaf), shard in zip(leaves_p, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = data[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.device_put(arr.astype(leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), manifest
