"""Gradient compression: int8 quantized all-reduce with error feedback.

At 1000+ nodes the DP-axis all-reduce dominates step time for small models;
int8 quantization cuts that traffic 4x.  Error feedback (Seide et al. 2014 /
EF-SGD arXiv:1901.09847) accumulates the quantization residual locally and
re-injects it next step, preserving convergence (the compressed estimator
stays unbiased in the EF sense — property-tested in tests/).

``compressed_psum`` is shard_map-friendly: quantize -> psum(int32) ->
dequantize; the scale itself needs one tiny f32 psum (max-abs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x, scale=None):
    """x -> (int8 codes, scale). scale = max|x|/127 (per tensor)."""
    if scale is None:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_state):
    """(grads + carried error) -> (quantized tree, scales, new error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return q, s, target - deq

    flat = jax.tree.map(one, grads, error_state)
    q = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    e = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return q, s, e


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(axis_name: str):
    """Returns f(grads, error) -> (mean grads, new error) for shard_map.

    int8 codes are summed in int32 across the axis (no overflow: <= 2^24
    shards), then dequantized with the max participating scale.
    """

    def psum_one(g, e):
        target = g.astype(jnp.float32) + e
        scale = lax.pmax(jnp.max(jnp.abs(target)) / 127.0 + 1e-12, axis_name)
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_e = target - q.astype(jnp.float32) * scale
        total = lax.psum(q.astype(jnp.int32), axis_name)
        n = lax.psum(jnp.int32(1), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    def f(grads, error):
        pairs = jax.tree.map(psum_one, grads, error)
        mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
        new_e = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
        return mean, new_e

    return f
