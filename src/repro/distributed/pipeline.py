"""Pipeline parallelism over the 'pod' axis: GPipe schedule via shard_map.

For multi-pod training, an alternative to pure FSDP across pods: each pod
holds a contiguous slice of layers; microbatches flow pod -> pod through
``ppermute``.  The schedule below is classic GPipe (fill M microbatches,
drain), expressed as a lax.scan over M + (P-1) ticks inside shard_map —
deterministic, compiles to point-to-point collectives only on the 'pod'
axis, and composes with the in-pod ('data','model') shardings.

This module is deliberately model-agnostic: it pipelines any per-stage
``apply(stage_params, x) -> x``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.utils.jaxcompat import shard_map


def gpipe_forward(apply_fn, axis_name: str, n_stages: int, n_micro: int):
    """Builds f(stage_params, x_micro) for use INSIDE shard_map.

    stage_params: this pod's layer slice.  x_micro: (M, mb, ...) microbatches
    (only stage 0's content is used; other stages receive via ppermute).
    Returns (M, mb, ...) outputs valid on the LAST stage.
    """

    def f(stage_params, x_micro):
        stage = lax.axis_index(axis_name)
        M = x_micro.shape[0]
        ticks = M + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf = carry  # (mb, ...): value arriving at this stage this tick
            # stage s processes microbatch (t - s) when 0 <= t-s < M
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            x_in = jnp.where(
                stage == 0,
                x_micro[jnp.clip(mb_idx, 0, M - 1)],
                buf,
            )
            y = apply_fn(stage_params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            nxt = lax.ppermute(y, axis_name, perm)
            return nxt, y

        _, ys = lax.scan(tick, jnp.zeros_like(x_micro[0]), jnp.arange(ticks))
        # last stage's outputs for microbatch m appear at tick m + S - 1;
        # broadcast them to every stage so the result is pod-replicated.
        idx = jnp.arange(M) + n_stages - 1
        out = ys[idx]
        out = lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis_name
        )
        return out

    return f


def make_pipelined_step(apply_fn, mesh, n_micro: int):
    """shard_map-wrapped pipeline forward over the 'pod' axis."""
    n_stages = mesh.shape["pod"]
    inner = gpipe_forward(apply_fn, "pod", n_stages, n_micro)
    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(P("pod"), P(None, ("data",))),
        out_specs=P(None, ("data",)),
        check_vma=False,
    )
