"""Graph data pipeline: synthetic datasets + a real neighbor sampler.

Generators mirror the assigned shape grid:
  * ``make_cora_like``      — full_graph_sm   (2708 nodes / 10556 edges / 1433 feats)
  * ``make_product_graph``  — ogb_products-like power-law graphs
  * ``make_reddit_like``    — minibatch_lg source graph (sampled training)
  * ``make_molecules``      — batched small geometric graphs

``NeighborSampler`` implements real fanout-based k-hop sampling over a CSR
adjacency (numpy, host side — this is the data pipeline, exactly where
GraphSAGE-style systems put it), emitting padded, relabeled subgraphs whose
static shapes match the dry-run's input_specs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _sym(edges, n):
    e = np.concatenate([edges, edges[:, ::-1]], axis=0)
    e = np.unique(e, axis=0)
    return e


def make_cora_like(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7, seed=0,
                   with_pos: bool = False):
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish edges
    src = rng.integers(0, n_nodes, n_edges)
    dst = (src + rng.zipf(2.0, n_edges)) % n_nodes
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    feats = (rng.random((n_nodes, d_feat)) < 0.012).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    # make labels weakly learnable: add label-correlated feature block
    feats[np.arange(n_nodes), labels % d_feat] += 3.0
    mask = np.zeros(n_nodes, bool)
    mask[rng.permutation(n_nodes)[: max(140, n_nodes // 20)]] = True
    g = dict(
        nodes=feats, edges=edges, labels=labels,
        train_mask=mask.astype(np.float32),
    )
    if with_pos:
        g["pos"] = rng.normal(size=(n_nodes, 3)).astype(np.float32)
        g["species"] = labels % 64
    return g


def make_product_graph(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                       n_classes=47, seed=0, with_pos: bool = False):
    return make_cora_like(n_nodes, n_edges, d_feat, n_classes, seed, with_pos)


def make_reddit_like(n_nodes=232_965, n_edges=114_615_892, d_feat=602, seed=0):
    return make_cora_like(n_nodes, n_edges, d_feat, 41, seed)


def make_molecules(n_graphs=128, nodes_per=30, edges_per=64, n_species=16, seed=0):
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per
    pos = rng.normal(size=(N, 3)).astype(np.float32) * 2.0
    species = rng.integers(1, n_species, N).astype(np.int32)
    edges = []
    for g in range(n_graphs):
        base = g * nodes_per
        s = rng.integers(0, nodes_per, edges_per)
        d = (s + rng.integers(1, nodes_per, edges_per)) % nodes_per  # no loops
        edges.append(np.stack([s + base, d + base], axis=1))
    edges = np.concatenate(edges).astype(np.int32)
    batch_seg = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    energy = rng.normal(size=(n_graphs,)).astype(np.float32)
    return dict(
        pos=pos, species=species, edges=edges, batch_seg=batch_seg,
        n_graphs=n_graphs, energy=energy,
        nodes=np.eye(n_species, dtype=np.float32)[species],
    )


# ---------------------------------------------------------------------------
# Neighbor sampler (GraphSAGE-style fanout sampling, host side)
# ---------------------------------------------------------------------------


@dataclass
class CSRGraph:
    indptr: np.ndarray  # int64[N+1]
    indices: np.ndarray  # int32[E]

    @classmethod
    def from_edges(cls, edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        dst = edges[:, 1].astype(np.int64)
        order = np.argsort(dst, kind="stable")
        sorted_dst = dst[order]
        indptr = np.searchsorted(sorted_dst, np.arange(n_nodes + 1))
        return cls(indptr=indptr, indices=edges[order, 0].astype(np.int32))


class NeighborSampler:
    """Uniform fanout sampling producing padded, relabeled subgraphs."""

    def __init__(self, edges: np.ndarray, n_nodes: int, seed: int = 0):
        self.csr = CSRGraph.from_edges(edges, n_nodes)
        self.rng = np.random.default_rng(seed)
        self.n_nodes = n_nodes

    def sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """(B,) -> (B, fanout) sampled in-neighbors, -1 padded."""
        out = np.full((len(nodes), fanout), -1, dtype=np.int32)
        for i, v in enumerate(nodes):
            lo, hi = self.csr.indptr[v], self.csr.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = self.rng.integers(lo, hi, fanout) if deg > fanout else \
                np.concatenate([np.arange(lo, hi), self.rng.integers(lo, hi, fanout - deg)])
            out[i] = self.csr.indices[take[:fanout]]
        return out

    def sample_block(self, seeds: np.ndarray, fanouts):
        """k-hop sampled subgraph: returns (node_ids, edges_local, layers).

        node_ids: all touched global node ids (seeds first), edges_local:
        (E', 2) in local indices padded to the static budget implied by
        ``fanouts``, layers: per-hop frontier sizes (static).
        """
        frontier = np.asarray(seeds, dtype=np.int32)
        all_nodes = [frontier]
        all_edges = []
        id_of = {int(v): i for i, v in enumerate(frontier)}
        for fanout in fanouts:
            nbrs = self.sample_neighbors(frontier, fanout)  # (B, fanout)
            srcs, dsts = [], []
            next_frontier = []
            for i, v in enumerate(frontier):
                for u in nbrs[i]:
                    if u < 0:
                        srcs.append(-1)
                        dsts.append(-1)
                        continue
                    if int(u) not in id_of:
                        id_of[int(u)] = len(id_of)
                        next_frontier.append(u)
                    srcs.append(id_of[int(u)])
                    dsts.append(id_of[int(v)])
            all_edges.append(np.stack([np.array(srcs), np.array(dsts)], axis=1))
            frontier = np.array(next_frontier, dtype=np.int32) if next_frontier else frontier[:0]
            all_nodes.append(frontier)
        node_ids = np.concatenate(all_nodes) if len(all_nodes) else seeds
        edges_local = np.concatenate(all_edges).astype(np.int32)
        return np.array([id_for for id_for in id_of.keys()], dtype=np.int32), edges_local

    def padded_block(self, seeds: np.ndarray, fanouts, node_budget: int, edge_budget: int,
                     features: np.ndarray, labels: np.ndarray | None = None):
        """Fixed-shape training block for the minibatch_lg cell."""
        node_ids, edges_local = self.sample_block(seeds, fanouts)
        node_ids = node_ids[:node_budget]
        nodes = np.zeros((node_budget, features.shape[1]), np.float32)
        nodes[: len(node_ids)] = features[node_ids]
        e = np.full((edge_budget, 2), -1, np.int32)
        keep = edges_local[(edges_local[:, 0] < node_budget) & (edges_local[:, 1] < node_budget)
                           & (edges_local[:, 0] >= 0)]
        e[: min(len(keep), edge_budget)] = keep[:edge_budget]
        block = dict(nodes=nodes, edges=e)
        if labels is not None:
            lb = np.zeros((node_budget,), np.int32)
            lb[: len(node_ids)] = labels[node_ids]
            mask = np.zeros((node_budget,), np.float32)
            mask[: len(seeds)] = 1.0  # loss on seed nodes only
            block["labels"] = lb
            block["train_mask"] = mask
        return block


def block_shape_for(batch_nodes: int, fanouts) -> tuple:
    """Static (node_budget, edge_budget) implied by a fanout schedule."""
    nodes = batch_nodes
    total_nodes = batch_nodes
    edges = 0
    for f in fanouts:
        edges += nodes * f
        nodes = nodes * f
        total_nodes += nodes
    return total_nodes, edges
