"""Synthetic LM data pipeline: deterministic, seekable token streams.

Determinism matters for fault tolerance: batch(step) is a pure function of
(seed, step), so a restarted job resumes mid-stream bit-exactly — no
shuffle-buffer state to snapshot.  The stream is a mixture of Zipf-ish
unigram noise and copied spans so reduced models have something learnable.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S, V = self.batch, self.seq_len, self.vocab
        # zipf-ish marginal over the vocab
        u = rng.random((B, S + 1))
        toks = ((V - 1) * u ** 3).astype(np.int32) + 1
        # inject copy spans: second half repeats the first (learnable signal)
        half = (S + 1) // 2
        toks[:, half: 2 * half] = toks[:, :half]
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((B, S), np.float32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
