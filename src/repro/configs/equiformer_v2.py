"""equiformer-v2 [arXiv:2306.12059]: 12 blocks, C=128, l_max=6, m_max=2,
8 heads, SO(2) eSCN convolutions."""
from repro.models.gnn.equiformer import EquiformerConfig

ARCH_ID = "equiformer-v2"
FAMILY = "gnn"
MODEL = "equiformer"


def full_config(d_feat=16, n_classes=1, edge_chunks=1) -> EquiformerConfig:
    return EquiformerConfig(
        name=ARCH_ID, n_layers=12, channels=128, l_max=6, m_max=2, n_heads=8,
        n_out=n_classes, edge_chunks=edge_chunks,
    )


def reduced_config(d_feat=16, n_classes=1) -> EquiformerConfig:
    return EquiformerConfig(
        name=ARCH_ID + "-reduced", n_layers=2, channels=16, l_max=3, m_max=2,
        n_heads=4, n_out=n_classes, edge_chunks=2,
    )
