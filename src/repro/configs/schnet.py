"""schnet [arXiv:1706.08566]: 3 interactions, d=64, 300 RBFs, cutoff 10."""
from repro.models.gnn.schnet import SchNetConfig

ARCH_ID = "schnet"
FAMILY = "gnn"
MODEL = "schnet"


def full_config(d_feat=16, n_classes=1, edge_chunks=1) -> SchNetConfig:
    return SchNetConfig(name=ARCH_ID, n_interactions=3, d_hidden=64,
                        n_rbf=300, cutoff=10.0, n_out=n_classes)


def reduced_config(d_feat=16, n_classes=1) -> SchNetConfig:
    return SchNetConfig(name=ARCH_ID + "-reduced", n_interactions=2,
                        d_hidden=16, n_rbf=32, cutoff=5.0, n_out=n_classes)
