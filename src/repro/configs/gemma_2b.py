"""gemma-2b [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) hd=256 GeGLU
ff=16384 v=256000."""
from repro.models.lm import LMConfig

ARCH_ID = "gemma-2b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        head_dim=256, d_ff=16384, vocab=256000, act="geglu", dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=32, d_ff=256, vocab=512, act="geglu",
        dtype="float32", loss_chunks=4, remat=False,
    )
