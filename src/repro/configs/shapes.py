"""The assigned input-shape grids, one per architecture family."""
from __future__ import annotations

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="train", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7,
        task="cls", shard_nodes=False, edge_chunks=1,
    ),
    "minibatch_lg": dict(
        kind="train", batch_nodes=1024, fanouts=(15, 10), d_feat=602,
        n_classes=41, task="cls", shard_nodes=True, edge_chunks=8,
        src_nodes=232_965, src_edges=114_615_892,
    ),
    "ogb_products": dict(
        kind="train", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
        n_classes=47, task="cls", shard_nodes=True, edge_chunks=64,
    ),
    "molecule": dict(
        kind="train", n_nodes=30, n_edges=64, batch=128, d_feat=16,
        n_classes=1, task="reg", shard_nodes=False, edge_chunks=1,
    ),
}

