"""olmo-1b [arXiv:2402.00838]: 16L d=2048 16H (kv=16) ff=8192 v=50304,
non-parametric LayerNorm."""
from repro.models.lm import LMConfig

ARCH_ID = "olmo-1b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=8192, vocab=50304, act="swiglu",
        norm="layernorm_nonparam", dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=256, vocab=512, act="swiglu",
        norm="layernorm_nonparam", dtype="float32", loss_chunks=4, remat=False,
    )
