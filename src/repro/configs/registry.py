"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

from repro.configs import (
    deepseek_v2_236b, equiformer_v2, gat_cora, gatedgcn, gemma3_12b,
    gemma_2b, olmo_1b, olmoe_1b_7b, schnet,
)
from repro.configs.shapes import GNN_SHAPES, LM_SHAPES

_MODULES = [
    olmo_1b, gemma_2b, gemma3_12b, olmoe_1b_7b, deepseek_v2_236b,
    equiformer_v2, gat_cora, gatedgcn, schnet,
]

ARCHS = {m.ARCH_ID: m for m in _MODULES}

SHAPE_TABLES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES}

# documented skips (DESIGN.md §4): long_500k only for hybrid-attention archs
SKIPS = {
    ("olmo-1b", "long_500k"): "pure full attention — long_500k skipped per brief",
    ("gemma-2b", "long_500k"): "pure full attention — long_500k skipped per brief",
    ("olmoe-1b-7b", "long_500k"): "pure full attention — long_500k skipped per brief",
    ("deepseek-v2-236b", "long_500k"): "pure full attention (MLA) — long_500k skipped per brief",
}


# beyond-paper optimization variants (per family config overrides); used by
# the Perf hillclimb (hlo_analysis over lowered cells).
VARIANTS = {
    "flash": {"lm": dict(attn_impl="blockwise")},
    "noattn": {"lm": dict(attn_impl="stub")},  # measurement surrogate
    "mrestrict": {"gnn": dict(rotate_restrict=True, edge_dtype="bfloat16")},
}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def variant_overrides(variant: str, family: str) -> dict:
    if variant not in VARIANTS:
        raise KeyError(f"unknown variant {variant!r}; known: {sorted(VARIANTS)}")
    return VARIANTS[variant].get(family, {})


def shapes_for(arch_id: str) -> dict:
    return SHAPE_TABLES[get_arch(arch_id).FAMILY]


def all_cells(include_skipped: bool = False):
    for arch_id, mod in ARCHS.items():
        for shape_id in SHAPE_TABLES[mod.FAMILY]:
            skip = SKIPS.get((arch_id, shape_id))
            if skip and not include_skipped:
                continue
            yield arch_id, shape_id, skip
