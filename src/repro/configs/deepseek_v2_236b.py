"""deepseek-v2-236b [arXiv:2405.04434]: 60L d=5120 128H, MLA kv_lora=512
(q_lora=1536, rope=64), v=102400; MoE 160 routed top-6 + 2 shared,
expert ff=1536; layer 0 dense (ff=12288)."""
from repro.models.lm import LMConfig

ARCH_ID = "deepseek-v2-236b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=1536, vocab=102400, act="swiglu",
        attn="mla", q_lora=1536, kv_lora=512, rope_dim=64,
        moe=True, n_experts=160, top_k=6, n_shared=2, moe_dff=1536,
        dense_layers=1, dense_dff=12288, dtype="bfloat16",
        capacity_factor=1.1,
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, act="swiglu",
        attn="mla", q_lora=32, kv_lora=16, rope_dim=8,
        moe=True, n_experts=8, top_k=2, n_shared=1, moe_dff=32,
        dense_layers=1, dense_dff=128, dtype="float32", loss_chunks=4,
        remat=False,
    )
