"""mind [arXiv:1904.08030]: embed_dim=64, 4 interests, 3 routing iters,
multi-interest retrieval over a row-sharded item table."""
from repro.models.recsys.mind import MINDConfig

ARCH_ID = "mind"
FAMILY = "recsys"


def full_config() -> MINDConfig:
    return MINDConfig(name=ARCH_ID, n_items=8_388_608, embed_dim=64,
                      n_interests=4, capsule_iters=3, hist_len=50)


def reduced_config() -> MINDConfig:
    return MINDConfig(name=ARCH_ID + "-reduced", n_items=1024, embed_dim=16,
                      n_interests=4, capsule_iters=3, hist_len=10)
