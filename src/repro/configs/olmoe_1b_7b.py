"""olmoe-1b-7b [arXiv:2409.02060]: 16L d=2048 16H (kv=16) v=50304,
MoE 64 experts top-8, expert ff=1024."""
from repro.models.lm import LMConfig

ARCH_ID = "olmoe-1b-7b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1024, vocab=50304, act="swiglu",
        moe=True, n_experts=64, top_k=8, moe_dff=1024, dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=64, vocab=512, act="swiglu",
        moe=True, n_experts=8, top_k=2, moe_dff=64, dtype="float32",
        loss_chunks=4, remat=False,
    )
