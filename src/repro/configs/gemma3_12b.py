"""gemma3-12b [hf:google/gemma-3-12b-pt]: 48L d=3840 16H (kv=8) hd=256
ff=15360 v=262144, 5 local(window=1024) : 1 global, 128k context."""
from repro.models.lm import LMConfig

ARCH_ID = "gemma3-12b"
FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=15360, vocab=262144, act="geglu",
        window=1024, local_ratio=5, dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-reduced", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=256, vocab=512, act="geglu",
        window=8, local_ratio=5, dtype="float32", loss_chunks=4, remat=False,
    )
