"""gat-cora [arXiv:1710.10903]: 2 layers, 8 hidden x 8 heads, attention agg."""
from repro.models.gnn.gat import GATConfig

ARCH_ID = "gat-cora"
FAMILY = "gnn"
MODEL = "gat"


def full_config(d_feat=1433, n_classes=7, edge_chunks=1) -> GATConfig:
    return GATConfig(name=ARCH_ID, n_layers=2, d_hidden=8, n_heads=8,
                     d_in=d_feat, n_classes=n_classes)


def reduced_config(d_feat=64, n_classes=7) -> GATConfig:
    return GATConfig(name=ARCH_ID + "-reduced", n_layers=2, d_hidden=4,
                     n_heads=2, d_in=d_feat, n_classes=n_classes)
