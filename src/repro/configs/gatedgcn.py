"""gatedgcn [arXiv:2003.00982]: 16 layers, d=70, gated edge aggregation."""
from repro.models.gnn.gatedgcn import GatedGCNConfig

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
MODEL = "gatedgcn"


def full_config(d_feat=1433, n_classes=7, edge_chunks=1) -> GatedGCNConfig:
    return GatedGCNConfig(name=ARCH_ID, n_layers=16, d_hidden=70,
                          d_in=d_feat, n_classes=n_classes)


def reduced_config(d_feat=64, n_classes=7) -> GatedGCNConfig:
    return GatedGCNConfig(name=ARCH_ID + "-reduced", n_layers=3, d_hidden=16,
                          d_in=d_feat, n_classes=n_classes)
