"""Post-SPMD HLO analysis: loop-aware FLOPs, HBM bytes, collective bytes.

``compiled.cost_analysis()`` counts each op ONCE, but layer stacks are
``lax.scan`` while-loops — a 60-layer body would be under-counted 60x.  This
module re-derives the three roofline inputs from the partitioned HLO text
with loop multipliers:

  * computations are split and a call graph built (while bodies/conditions,
    fusion callees, reducers);
  * while trip counts come from the loop-condition constants;
  * FLOPs: every ``dot`` contributes 2 * prod(out_shape) * K (K = product of
    the lhs contracting dims), times its computation's loop multiplier;
  * HBM bytes: per *top-level* op (fusion callees excluded — the callsite
    already carries operand/output shapes), operands + outputs, times
    multiplier — the standard post-fusion traffic model.  Windowed accesses
    are charged what they actually touch: dynamic-slice/slice/gather count
    their OUTPUT bytes, dynamic-update-slice/scatter 2x their update bytes
    (read-modify-write of the window, the array itself aliases in place).
    Fusion callsites get a parameter-usage analysis: a fusion parameter
    consumed ONLY by slicing ops inside the callee is charged those ops'
    output bytes instead of the full array — otherwise a KV-cache scan would
    be billed the whole cache every iteration;
  * collective bytes: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, times multiplier.

All byte counts are PER DEVICE (the module is the per-device program), so
``T = bytes / bw`` directly; global figures are x chips.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_WHILE = re.compile(r"condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-, %]+)\}?")
_CONST = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPREF = re.compile(r"%[\w\.\-]+")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_of(type_str: str):
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        out.append((dt, d))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def analyze_hlo(hlo_text: str) -> dict:
    # --- split into computations --------------------------------------------
    comps: dict = {}
    order = []
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if "->" in line and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                order.append(cur)
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    if entry is None:
        entry = order[-1] if order else None

    # --- per computation: defs, dots, op bytes, collectives, calls ----------
    defs = {}  # comp -> var -> shapes list
    flops_c = defaultdict(float)
    bytes_c = defaultdict(float)
    coll_c = {c: defaultdict(float) for c in comps}
    calls = defaultdict(list)  # comp -> [(callee, trip_comp_or_None)]
    fusion_callees = set()
    fusion_calls = []  # (caller, callee, operand_refs, out_bytes)
    cond_consts = {}
    # param-usage: comp -> param_index -> ("sliced", window_bytes) | "full"
    param_use = defaultdict(dict)
    param_order = defaultdict(list)  # comp -> [param var names]

    WINDOWED_READ = ("dynamic-slice", "slice", "gather")
    WINDOWED_WRITE = ("dynamic-update-slice", "scatter")
    NOBYTES = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota")

    for c, lines in comps.items():
        dd = {}
        defs[c] = dd
        for line in lines:
            m = _DEF.match(line)
            if not m:
                continue
            var, rhs = m.group(1), m.group(2)
            # strip metadata (shapes inside metadata strings would pollute)
            body = rhs.split(", metadata=")[0]
            # output type = everything before the opcode's '('; first shapes
            paren = body.find("(")
            head = body[:paren] if paren > 0 else body
            out_shapes = _shapes_of(head)
            dd[var] = out_shapes

            opm = re.match(r"^[^=]*?\s([a-z][a-z0-9\-]*)\(", " " + body)
            opcode = opm.group(1) if opm else ""
            operand_str = body[paren:] if paren > 0 else ""
            oprefs = _OPREF.findall(operand_str.split("),")[0]) if paren > 0 else []

            if opcode == "dot":
                cm = _CONTRACT.search(body)
                k = 1
                if cm and oprefs:
                    lhs_shapes = dd.get(oprefs[0], [])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        for i in [int(x) for x in cm.group(1).split(",") if x]:
                            if i < len(dims):
                                k *= dims[i]
                n_out = 1
                for dt, dims2 in out_shapes[:1]:
                    for d in dims2:
                        n_out *= d
                flops_c[c] += 2.0 * n_out * k

            # track parameters + their uses (for fusion-callee analysis)
            if opcode == "parameter":
                param_order[c].append(var)
                param_use[c][var] = None  # unseen yet
            else:
                for r in oprefs:
                    if r in param_use[c]:
                        cur = param_use[c][r]
                        if opcode in WINDOWED_READ and cur != "full":
                            w = _bytes_of(out_shapes)
                            param_use[c][r] = ("sliced", (cur[1] if cur else 0) + w)
                        else:
                            param_use[c][r] = "full"

            # bytes: post-fusion HBM traffic model (see module docstring)
            if opcode == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", body)
                fusion_calls.append(
                    (c, fm.group(1) if fm else None, list(oprefs), _bytes_of(out_shapes))
                )
            elif opcode in WINDOWED_READ:
                bytes_c[c] += 2 * _bytes_of(out_shapes)  # window read + write
            elif opcode in WINDOWED_WRITE:
                upd = _bytes_of(dd.get(oprefs[1], [])) if len(oprefs) > 1 else 0
                bytes_c[c] += 2 * upd
            elif opcode not in NOBYTES:
                ob = sum(_bytes_of(dd.get(r, [])) for r in oprefs)
                bytes_c[c] += ob + _bytes_of(out_shapes)

            wm = _WHILE.search(body)
            if wm:
                calls[c].append((wm.group(2), wm.group(1)))
                calls[c].append((wm.group(1), wm.group(1)))
            elif "calls=" in body or "to_apply=" in body or "branch_computations=" in body:
                for cm2 in _CALLS.finditer(body):
                    for callee in cm2.group(1).split(","):
                        callee = callee.strip().lstrip("%")
                        if callee in comps:
                            calls[c].append((callee, None))
                            if opcode == "fusion":
                                fusion_callees.add(callee)

            for op in COLLECTIVES:
                if opcode in (op, op + "-start"):
                    b = sum(_bytes_of(dd.get(r, [])) for r in oprefs)
                    if b == 0:
                        b = _bytes_of(out_shapes)
                    coll_c[c][op] += b
                    break

    # resolve fusion callsite bytes with the callee's parameter usage
    for caller, callee, oprefs, out_b in fusion_calls:
        b = float(out_b)
        params = param_order.get(callee, [])
        dd = defs.get(caller, {})
        for i, opr in enumerate(oprefs):
            full_b = _bytes_of(dd.get(opr, []))
            usage = param_use.get(callee, {}).get(params[i]) if i < len(params) else "full"
            if usage is None:
                continue  # dead parameter
            if isinstance(usage, tuple):  # consumed only via slicing ops
                b += min(full_b, usage[1])
            else:
                b += full_b
        bytes_c[caller] += b

    for c, lines in comps.items():
        consts = [int(x) for line in lines for x in _CONST.findall(line)]
        cond_consts[c] = max(consts) if consts else 1

    # --- multiplier propagation ---------------------------------------------
    mult = defaultdict(float)

    def walk(c, m, depth=0):
        if c not in comps or depth > 32:
            return
        if mult[c] >= m:
            return
        mult[c] = m
        for callee, trip_comp in calls[c]:
            k = m * max(1, cond_consts.get(trip_comp, 1)) if trip_comp else m
            walk(callee, k, depth + 1)

    if entry:
        walk(entry, 1.0)

    flops = sum(f * (mult.get(c, 1.0) or 1.0) for c, f in flops_c.items())
    hbm = sum(
        b * (mult.get(c, 1.0) or 1.0)
        for c, b in bytes_c.items()
        if c not in fusion_callees
    )
    coll = defaultdict(float)
    for c, d in coll_c.items():
        for op, b in d.items():
            coll[op] += b * (mult.get(c, 1.0) or 1.0)
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    return dict(
        flops=flops,
        hbm_bytes=hbm,
        collectives=dict(coll),
        n_computations=len(comps),
    )


def analyze_collectives(hlo_text: str) -> dict:
    """Back-compat helper: collective byte totals only."""
    return analyze_hlo(hlo_text)["collectives"]
