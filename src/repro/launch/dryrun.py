import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract roofline terms from the compiled artifact.

The two lines above MUST stay the first statements of this module: jax
locks the device count at first initialization, and only the dry-run wants
512 placeholder devices (smoke tests and benches see the real single CPU).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results are cached as JSON under reports/dryrun/ (one file per
arch x shape x mesh) so long sweeps are resumable.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import SKIPS, all_cells  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

# TPU v5e hardware model (targets; this host only compiles)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, outdir: Path,
             force: bool = False, variant: str | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch_id}@{variant}" if variant else arch_id
    out_path = outdir / f"{tag}__{shape_id}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    rec = dict(arch=tag, shape=shape_id, mesh=mesh_name, status="error",
               variant=variant)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch_id, shape_id, mesh, variant=variant)
        jfn = jax.jit(cell.fn, in_shardings=cell.shardings(mesh))
        with mesh:
            lowered = jfn.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = {}
            try:
                ma = compiled.memory_analysis()
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                    v = getattr(ma, k, None)
                    if v is not None:
                        mem[k] = int(v)
            except Exception as e:  # noqa: BLE001
                mem["error"] = str(e)

            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float))}
            except Exception as e:  # noqa: BLE001
                cost["error"] = str(e)

            # loop-aware HLO analysis (scan bodies x trip counts) — see
            # hlo_analysis.py; cost_analysis counts loop bodies once.
            hlo = analyze_hlo(compiled.as_text())

        chips = mesh.devices.size
        flops = hlo["flops"]  # per device
        bytes_acc = hlo["hbm_bytes"]
        coll = hlo["collectives"]
        terms = dict(
            t_compute=flops / PEAK_FLOPS,
            t_memory=bytes_acc / HBM_BW,
            t_collective=coll.get("total", 0.0) / ICI_BW,
        )
        dom = max(terms, key=terms.get)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem,
            cost=cost,
            hlo_flops_per_chip=flops,
            hlo_bytes_per_chip=bytes_acc,
            collective_bytes=coll,
            roofline=terms,
            dominant=dom,
            model_flops=cell.model_flops,
            model_flops_per_chip=cell.model_flops / chips,
            useful_ratio=(cell.model_flops / chips) / flops if flops else None,
            meta=cell.meta,
        )
    except Exception:  # noqa: BLE001
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    outdir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="optimization variant from configs.registry.VARIANTS")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()
    outdir = Path(args.out)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = (
        [(a, s, None) for a, s, _ in all_cells()]
        if args.all
        else [(args.arch, args.shape, SKIPS.get((args.arch, args.shape)))]
    )
    for arch_id, shape_id, skip in cells:
        if skip:
            print(f"SKIP {arch_id} x {shape_id}: {skip}")
            continue
        for mp in meshes:
            rec = run_cell(arch_id, shape_id, mp, outdir, force=args.force,
                           variant=args.variant)
            vtag = f"@{args.variant}" if args.variant else ""
            tag = f"{arch_id}{vtag} x {shape_id} x {'multi' if mp else 'single'}"
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(
                    f"OK   {tag}: compile={rec['compile_s']}s "
                    f"compute={r['t_compute']:.3e}s mem={r['t_memory']:.3e}s "
                    f"coll={r['t_collective']:.3e}s dom={rec['dominant']}"
                )
            else:
                print(f"FAIL {tag}\n{rec.get('traceback', '')[-1500:]}")


if __name__ == "__main__":
    main()
