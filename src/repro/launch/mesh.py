"""Production mesh definitions.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax call;
smoke tests see the single CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod outer axis."""
    from repro.utils.jaxcompat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """The pure-data-parallel axes: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)
