"""Sharding rules per model family (GSPMD PartitionSpecs).

LM: FSDP over the data-parallel axes + tensor/expert parallel over 'model'.
GNN: edge/node row sharding.
Every rule guards divisibility — a dimension is only sharded when the axis
size divides it, so one rule set covers gemma-2b (kv=1) and dsv2 (kv=128)
alike.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import all_axes, axis_sizes, dp_axes


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _axes_size(sizes, axes) -> int:
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def lm_param_specs(params_shape, mesh):
    """Path-based PartitionSpec assignment for the LM family."""
    sizes = axis_sizes(mesh)
    fsdp = dp_axes(mesh)
    fs = _axes_size(sizes, fsdp)
    ms = sizes.get("model", 1)

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        name = keys[-1]
        shp = leaf.shape
        scanned = "layers" in keys

        def m(dim):  # 'model' if divisible
            return "model" if _div(shp[dim], ms) else None

        def f(dim):  # fsdp axes if divisible
            return fsdp if _div(shp[dim], fs) else None

        if name == "embed":
            return P(m(0), f(1))
        if name in ("wq", "wk", "wv"):  # (L,) d, H, hd
            o = 1 if scanned else 0
            return P(*([None] * o), f(o), m(o + 1), None)
        if name == "wo" and len(shp) == (4 if scanned else 3):  # attn out
            o = 1 if scanned else 0
            return P(*([None] * o), m(o), None, f(o + 2))
        if name in ("wuq", "wuk", "wuv"):  # (L,) lora, H, hd
            o = 1 if scanned else 0
            return P(*([None] * o), None, m(o + 1), None)
        if name in ("wdq", "wdkv", "wkr"):  # (L,) d, r
            o = 1 if scanned else 0
            return P(*([None] * o), f(o), None)
        if name in ("wi", "wg") and len(shp) == (4 if scanned else 3):  # MoE (L,)E,d,ff
            o = 1 if scanned else 0
            return P(*([None] * o), m(o), f(o + 1), None)
        if name in ("wi", "wg"):  # dense (L,) d, ff
            o = 1 if scanned else 0
            return P(*([None] * o), f(o), m(o + 1))
        if name == "wo":  # dense (L,) ff, d  OR MoE (L,) E, ff, d
            o = 1 if scanned else 0
            if len(shp) - o == 3:  # MoE
                return P(*([None] * o), m(o), None, f(o + 2))
            return P(*([None] * o), m(o), f(o + 1))
        if name == "router":  # (L,) d, E
            o = 1 if scanned else 0
            return P(*([None] * o), f(o), None)
        return P()  # norms & misc: replicated

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def lm_batch_spec(mesh):
    return {k: P(dp_axes(mesh), None) for k in ("tokens", "targets", "mask")}


def lm_cache_specs(cache_shape, mesh):
    """KV caches: batch over dp axes when divisible, else seq over axes."""
    sizes = axis_sizes(mesh)
    fsdp = dp_axes(mesh)
    fs = _axes_size(sizes, fsdp)
    ms = sizes.get("model", 1)

    def rule(path, leaf):
        shp = leaf.shape  # (L, B, S, ...rest)
        B, S = shp[1], shp[2]
        rest = len(shp) - 3
        if _div(B, fs) and B >= fs:
            if rest >= 1 and _div(shp[3], ms):  # shard KV heads / latent dim
                return P(None, fsdp, None, "model", *([None] * (rest - 1)))
            if _div(S, ms):
                return P(None, fsdp, "model", *([None] * rest))
            return P(None, fsdp, *([None] * (rest + 1)))
        # tiny batch (long-context): shard the sequence over everything
        ax = all_axes(mesh)
        if _div(S, _axes_size(sizes, ax)):
            return P(None, None, ax, *([None] * rest))
        if _div(S, ms):
            return P(None, None, "model", *([None] * rest))
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def replicated(tree_shape, mesh):
    return jax.tree.map(lambda _: P(), tree_shape)


def rows_over(axes):
    def rule(leaf_shape):
        return P(axes, *([None] * (len(leaf_shape.shape) - 1)))

    return rule


def gnn_graph_specs(graph_shape, mesh, shard_nodes: bool):
    """Edges always row-sharded; nodes row-sharded on the big graphs."""
    ax = all_axes(mesh)

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        name = keys[-1]
        if name in ("edges", "edge_feat"):
            return P(ax, *([None] * (leaf.ndim - 1)))
        if name in ("nodes", "pos", "species", "labels", "train_mask", "batch_seg"):
            if shard_nodes:
                return P(ax, *([None] * (leaf.ndim - 1)))
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(rule, graph_shape)


def opt_state_specs(param_specs):
    """AdamW mu/nu mirror the parameter shardings; step is replicated."""
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }
