"""Serving launcher: batched LiteMat query serving (the paper's workload).

``python -m repro.launch.serve --universities 2 --requests 1024`` builds a
LUBM-style KB, encodes + lite-materializes it, then serves batches of
parameterized class/member queries through the vmapped plans, reporting
throughput and p50/p99 latencies.

``--concurrent`` switches to the snapshot-isolated request runtime
(serving/runtime.py): N submitter threads drive Q1–Q4 through the bounded
admission queue while a writer thread streams inserts/deletes, and the
report adds shed/deadline/stale counts on top of the latency percentiles.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.engine import PAPER_QUERIES, KnowledgeBase
from repro.rdf.generator import generate_lubm
from repro.serving.engine import QueryServer
from repro.serving.runtime import ServingRuntime

CLASSES = ["Professor", "Student", "Faculty", "Person", "Course",
           "Publication", "Organization", "Department", "Chair",
           "GraduateStudent"]
PROPS = ["memberOf", "worksFor", "degreeFrom", "takesCourse", "advisor"]


def run_concurrent(K, raw, args) -> None:
    """Mixed workload through the snapshot-isolated runtime."""
    queries = list(PAPER_QUERIES.values())
    rt = ServingRuntime(
        K, modes=("litemat",), n_workers=args.workers,
        max_queue=args.max_queue, default_deadline_s=args.deadline_s)
    with rt:
        rt.registry.prewarm(queries)
        s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
        stop = threading.Event()

        def writer():
            rng = np.random.default_rng(args.seed + 1)
            while not stop.is_set():
                i = int(rng.integers(0, max(s.shape[0] - 64, 1)))
                rt.insert((s[i:i + 64], p[i:i + 64], o[i:i + 64]),
                          auto_compact=False)
                if stop.wait(0.01):
                    return

        w = threading.Thread(target=writer, daemon=True)
        w.start()
        futs = [rt.submit(queries[i % len(queries)])
                for i in range(args.requests)]
        outs = [f.result() for f in futs]
        stop.set()
        w.join()
    n_ok = sum(o.ok for o in outs)
    lat = rt.latency_stats()
    print(f"concurrent: {n_ok}/{len(outs)} ok "
          f"p50={lat.get('p50_ms', 0):.2f}ms p99={lat.get('p99_ms', 0):.2f}ms "
          f"stats={rt.stats}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=1)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrent", action="store_true",
                    help="drive the snapshot-isolated request runtime "
                         "(readers + background update stream)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--deadline-s", type=float, default=None)
    args = ap.parse_args()

    print(f"generating LUBM-like KB ({args.universities} universities)...")
    raw = generate_lubm(args.universities, seed=args.seed)
    t0 = time.time()
    K = KnowledgeBase.build(raw)
    print(f"encoded+materialized {raw.n_triples:,} triples in {time.time()-t0:.1f}s "
          f"(sizes: {K.sizes()})")

    if args.concurrent:
        return run_concurrent(K, raw, args)

    srv = QueryServer(K)
    rng = np.random.default_rng(args.seed)
    lat = []
    served = 0
    t0 = time.time()
    while served < args.requests:
        b = min(args.batch, args.requests - served)
        names = [CLASSES[i] for i in rng.integers(0, len(CLASSES), b)]
        t1 = time.time()
        if served % (2 * args.batch) < args.batch:
            counts, _ = srv.class_members(names)
        else:
            props = [PROPS[i] for i in rng.integers(0, len(PROPS), b)]
            counts, _ = srv.class_prop_join(names, props)
        lat.append((time.time() - t1) / b)
        served += b
    wall = time.time() - t0
    lat_ms = np.array(lat) * 1000
    print(f"served {served} queries in {wall:.2f}s -> {served/wall:,.0f} q/s; "
          f"per-query p50={np.percentile(lat_ms,50):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms (amortized)")


if __name__ == "__main__":
    main()
