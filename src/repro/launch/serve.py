"""Serving launcher: batched LiteMat query serving (the paper's workload).

``python -m repro.launch.serve --universities 2 --requests 1024`` builds a
LUBM-style KB, encodes + lite-materializes it, then serves batches of
parameterized class/member queries through the vmapped plans, reporting
throughput and p50/p99 latencies.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.engine import KnowledgeBase
from repro.rdf.generator import generate_lubm
from repro.serving.engine import QueryServer

CLASSES = ["Professor", "Student", "Faculty", "Person", "Course",
           "Publication", "Organization", "Department", "Chair",
           "GraduateStudent"]
PROPS = ["memberOf", "worksFor", "degreeFrom", "takesCourse", "advisor"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=1)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"generating LUBM-like KB ({args.universities} universities)...")
    raw = generate_lubm(args.universities, seed=args.seed)
    t0 = time.time()
    K = KnowledgeBase.build(raw)
    print(f"encoded+materialized {raw.n_triples:,} triples in {time.time()-t0:.1f}s "
          f"(sizes: {K.sizes()})")

    srv = QueryServer(K)
    rng = np.random.default_rng(args.seed)
    lat = []
    served = 0
    t0 = time.time()
    while served < args.requests:
        b = min(args.batch, args.requests - served)
        names = [CLASSES[i] for i in rng.integers(0, len(CLASSES), b)]
        t1 = time.time()
        if served % (2 * args.batch) < args.batch:
            counts, _ = srv.class_members(names)
        else:
            props = [PROPS[i] for i in rng.integers(0, len(PROPS), b)]
            counts, _ = srv.class_prop_join(names, props)
        lat.append((time.time() - t1) / b)
        served += b
    wall = time.time() - t0
    lat_ms = np.array(lat) * 1000
    print(f"served {served} queries in {wall:.2f}s -> {served/wall:,.0f} q/s; "
          f"per-query p50={np.percentile(lat_ms,50):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms (amortized)")


if __name__ == "__main__":
    main()
