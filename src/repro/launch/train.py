"""Training launcher: ``python -m repro.launch.train --arch olmo-1b --reduced``.

On this CPU host you train *reduced* configs (the full configs exist for the
dry-run); on a real fleet the same entry point shards the full config over
the production mesh.  Demonstrates the whole substrate: config -> data ->
jit'd step -> fault-tolerant loop -> checkpoints.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_arch
from repro.data.tokens import TokenStream
from repro.distributed.checkpoint import CheckpointManager
from repro.models import lm as lm_lib
from repro.train.loop import TrainLoop
from repro.train.optimizer import AdamWConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("launch.train drives LM archs; see examples/ for GNN")
    cfg = mod.reduced_config()
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab}")

    params = lm_lib.init_params(jax.random.key(0), cfg)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(lm_lib.make_train_step(cfg, AdamWConfig(lr=args.lr)))
    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=1)

    loop = TrainLoop(
        step_fn=step_fn,
        batch_at=stream.batch_at,
        ckpt=CheckpointManager(args.ckpt_dir),
        ckpt_every=args.ckpt_every,
    )
    loop.install_signal_handlers()
    _, _, last, hist = loop.run(params, opt_state, args.steps)
    print(f"done at step {last}; loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
