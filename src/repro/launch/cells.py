"""Cell builder: (architecture x input-shape) -> lowerable step + shardings.

A *cell* is everything the dry-run needs: the jit-able step function, its
abstract (ShapeDtypeStruct) arguments — no device allocation — the
PartitionSpec tree for in_shardings, and analytic MODEL_FLOPS for the
roofline's useful-compute ratio.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import SHAPE_TABLES, get_arch
from repro.launch import shardings as shd
from repro.launch.mesh import all_axes, dp_axes
from repro.models import lm as lm_lib
from repro.models.gnn import equiformer as eq_lib
from repro.models.gnn import gat as gat_lib
from repro.models.gnn import gatedgcn as ggcn_lib
from repro.models.gnn import schnet as schnet_lib
from repro.models.gnn.common import cross_entropy_nodes, seg_sum
from repro.train.optimizer import init_opt_state

F32 = jnp.float32
I32 = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _pad_up(n: int, m: int) -> int:
    """Row counts of explicitly sharded arrays must divide the mesh size —
    the data pipeline pads with invalid rows (-1 edges / masked nodes), so
    the launcher rounds the static shapes up.  Logical sizes stay in meta."""
    return -(-n // m) * m


@dataclass
class Cell:
    arch_id: str
    shape_id: str
    family: str
    kind: str  # train | prefill | decode | serve
    fn: object
    abstract_args: tuple
    in_specs: tuple  # PartitionSpec pytree matching abstract_args
    model_flops: float
    meta: dict = field(default_factory=dict)

    def shardings(self, mesh):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.in_specs,
            is_leaf=lambda x: isinstance(x, P),
        )


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    import dataclasses

    return dataclasses.replace(cfg, **overrides)


def _lm_cell(mod, shape_id, mesh, overrides=None) -> Cell:
    from repro.configs.shapes import LM_SHAPES

    cfg = _apply_overrides(mod.full_config(), overrides)
    shp = LM_SHAPES[shape_id]
    B, S = shp["global_batch"], shp["seq_len"]
    kind = shp["kind"]
    key = jax.random.key(0)
    params_shape = jax.eval_shape(lambda k: lm_lib.init_params(k, cfg), key)
    pspecs = shd.lm_param_specs(params_shape, mesh)
    nparams = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_shape))
    flops_tok = cfg.model_flops_per_token()  # 6*N_active

    if kind == "train":
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        ospecs = shd.opt_state_specs(pspecs)
        batch = {
            "tokens": sds((B, S), I32),
            "targets": sds((B, S), I32),
            "mask": sds((B, S), F32),
        }
        bspecs = shd.lm_batch_spec(mesh)
        fn = lm_lib.make_train_step(cfg)
        return Cell(mod.ARCH_ID, shape_id, "lm", kind, fn,
                    (params_shape, opt_shape, batch), (pspecs, ospecs, bspecs),
                    model_flops=flops_tok * B * S,
                    meta=dict(n_params=nparams, tokens=B * S))
    if kind == "prefill":
        tokens = sds((B, S), I32)
        fn = lm_lib.make_prefill_step(cfg)
        return Cell(mod.ARCH_ID, shape_id, "lm", kind, fn,
                    (params_shape, tokens), (pspecs, P(dp_axes(mesh), None)),
                    model_flops=flops_tok / 3.0 * B * S,  # fwd-only = 2N
                    meta=dict(n_params=nparams, tokens=B * S))
    # decode
    cache_shape = jax.eval_shape(lambda: lm_lib.init_cache(cfg, B, S))
    cspecs = shd.lm_cache_specs(cache_shape, mesh)
    token = sds((B, 1), I32)
    pos = sds((), I32)
    fn = lm_lib.make_decode_step(cfg)
    return Cell(mod.ARCH_ID, shape_id, "lm", kind, fn,
                (params_shape, cache_shape, token, pos),
                (pspecs, cspecs, P(dp_axes(mesh), None) if B > 1 else P(), P()),
                model_flops=flops_tok / 3.0 * B,
                meta=dict(n_params=nparams, tokens=B, cache_len=S))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_MODELS = {
    "gat": gat_lib, "gatedgcn": ggcn_lib, "schnet": schnet_lib,
    "equiformer": eq_lib,
}


def _gnn_graph_spec(shp: dict, pad_to: int = 1):
    if "batch" in shp:  # molecule: batched small graphs
        G = shp["batch"]
        N = G * shp["n_nodes"]
        E = G * shp["n_edges"]
    elif "batch_nodes" in shp:  # sampled block
        from repro.data.graphs import block_shape_for

        N, E = block_shape_for(shp["batch_nodes"], shp["fanouts"])
        G = 0
    else:
        N, E = shp["n_nodes"], shp["n_edges"]
        G = 0
    N = _pad_up(N, pad_to)
    E = _pad_up(E, pad_to)
    g = {
        "nodes": sds((N, shp["d_feat"]), F32),
        "edges": sds((E, 2), I32),
        "pos": sds((N, 3), F32),
        "species": sds((N,), I32),
    }
    if shp["task"] == "cls":
        g["labels"] = sds((N,), I32)
        g["train_mask"] = sds((N,), F32)
    else:
        g["energy"] = sds((max(G, 1),), F32)
        g["batch_seg"] = sds((N,), I32)
    return g


def gnn_unified_loss(model_id: str, params, graph, cfg, task: str):
    mod = _GNN_MODELS[model_id]
    if task == "cls":
        logits = mod.forward(params, graph, cfg)
        return cross_entropy_nodes(logits, graph["labels"], graph["train_mask"])
    # regression: per-graph energy = sum of node outputs
    out = mod.forward(params, graph, cfg)
    G = graph["energy"].shape[0]
    if out.ndim == 1:  # schnet already returns per-graph energies
        e = out
    else:
        e = seg_sum(out[:, 0], graph["batch_seg"], G)
    return jnp.mean((e - graph["energy"]) ** 2)


def make_gnn_train_step(model_id: str, cfg, task: str, lr: float = 1e-3):
    def step(params, graph):
        loss, grads = jax.value_and_grad(gnn_unified_loss, argnums=1)(
            model_id, params, graph, cfg, task
        )
        params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return params, loss

    return step


def _gnn_analytic_flops(model_id, cfg, N, E, d_feat):
    """Coarse useful-FLOPs estimate (matmul terms only, x3 for fwd+bwd)."""
    if model_id == "gat":
        per = 2 * N * d_feat * cfg.n_heads * cfg.d_hidden + 6 * E * cfg.n_heads * cfg.d_hidden
        f = per * cfg.n_layers
    elif model_id == "gatedgcn":
        d = cfg.d_hidden
        f = cfg.n_layers * (5 * 2 * N * d * d + 4 * E * d) + 2 * N * d_feat * d
    elif model_id == "schnet":
        d, r = cfg.d_hidden, cfg.n_rbf
        f = cfg.n_interactions * (2 * E * (r * d + d * d) + 4 * N * d * d)
    else:  # equiformer: SO(2) conv + 2 constant-J rotations per edge
        C = cfg.channels
        coeff = (cfg.l_max + 1) ** 2
        so2 = sum(
            2 * (2 * n_l * C) * (n_l * C)
            for n_l in [cfg.l_max + 1] + [cfg.l_max + 1 - m for m in range(1, cfg.m_max + 1)]
        )
        rot = 4 * 2 * coeff * coeff * C
        f = cfg.n_layers * E * (so2 + rot)
    return 3.0 * f  # fwd+bwd


def _gnn_cell(mod, shape_id, mesh, overrides=None) -> Cell:
    from repro.configs.shapes import GNN_SHAPES

    shp = GNN_SHAPES[shape_id]
    graph = _gnn_graph_spec(shp, pad_to=int(mesh.devices.size))
    N, E = graph["nodes"].shape[0], graph["edges"].shape[0]
    cfg = mod.full_config(
        d_feat=shp["d_feat"],
        n_classes=(shp["n_classes"] if shp["task"] == "cls" else 1),
        edge_chunks=shp["edge_chunks"],
    )
    ov = dict(overrides or {})
    if not hasattr(cfg, "rotate_restrict"):
        ov.pop("rotate_restrict", None)  # equiformer-only knobs
        ov.pop("edge_dtype", None)
    cfg = _apply_overrides(cfg, ov)
    model_id = mod.MODEL
    key = jax.random.key(0)
    params_shape = jax.eval_shape(
        lambda k: _GNN_MODELS[model_id].init_params(k, cfg), key
    )
    pspecs = jax.tree.map(lambda _: P(), params_shape)
    gspecs = shd.gnn_graph_specs(graph, mesh, shard_nodes=shp["shard_nodes"])
    fn = make_gnn_train_step(model_id, cfg, shp["task"])
    return Cell(mod.ARCH_ID, shape_id, "gnn", "train", fn,
                (params_shape, graph), (pspecs, gspecs),
                model_flops=_gnn_analytic_flops(model_id, cfg, N, E, shp["d_feat"]),
                meta=dict(n_nodes=N, n_edges=E))


def build_cell(arch_id: str, shape_id: str, mesh, variant: str | None = None) -> Cell:
    mod = get_arch(arch_id)
    overrides = None
    if variant:
        from repro.configs.registry import variant_overrides

        overrides = variant_overrides(variant, mod.FAMILY)
    if mod.FAMILY == "lm":
        return _lm_cell(mod, shape_id, mesh, overrides)
    if mod.FAMILY == "gnn":
        return _gnn_cell(mod, shape_id, mesh, overrides)
    raise KeyError(f"unknown cell family {mod.FAMILY!r}")
