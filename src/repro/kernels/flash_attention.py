"""Pallas TPU kernel: FlashAttention (fwd + bwd) with GQA + sliding window.

The LM-family hillclimb (EXPERIMENTS.md §Perf) showed that pure-XLA
blockwise attention still pays ~8 HBM passes over every (qc, kc) f32 score
tile — fusion boundaries around the two dots force tile materialization.
The kernel keeps tiles in VMEM: HBM traffic collapses to Q/K/V/O (+ dQ/dK/dV
and recomputed reads in the backward), which is the FlashAttention
[arXiv:2205.14135] contract.

Layout: q/o are (B, S, H, hd); k/v are (B, S, KV, hd) with G = H // KV
query heads per KV head (GQA).  Causal always; ``window > 0`` adds a
sliding-window mask unless the (runtime) ``is_global`` flag is set —
matching gemma3's interleaved local/global layers with one compiled kernel.

Backward follows the standard recompute scheme: lse is saved by the fwd;
dq and (dk, dv) are two kernels (dk/dv accumulates across the G query heads
of each KV head via output-block revisiting on the innermost grid dim).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _tile_mask(q0, k0, qc, kc, window, is_global):
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    m = kj <= qi
    if window > 0:
        m = m & (is_global | (kj > qi - window))
    return m


def _fwd_kernel(flags_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                *, kc: int, window: int, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (qc, hd)
    qc = q.shape[0]
    S = k_ref.shape[1]
    nk = S // kc
    is_global = flags_ref[0] > 0

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(ki * kc, kc), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * kc, kc), 0, :].astype(jnp.float32)
        s = (q @ k.T) * scale  # (qc, kc)
        msk = _tile_mask(qi * qc, ki * kc, qc, kc, window, is_global)
        s = jnp.where(msk, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((qc,), NEG, jnp.float32)
    l0 = jnp.zeros((qc,), jnp.float32)
    a0 = jnp.zeros((qc, q.shape[1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, :, 0, :] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0, :, 0] = m + jnp.log(l)


def _dq_kernel(flags_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, *, kc: int, window: int, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, :, 0]
    delta = delta_ref[0, :, 0]
    qc = q.shape[0]
    S = k_ref.shape[1]
    nk = S // kc
    is_global = flags_ref[0] > 0

    def body(ki, dq):
        k = k_ref[0, pl.dslice(ki * kc, kc), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * kc, kc), 0, :].astype(jnp.float32)
        s = (q @ k.T) * scale
        msk = _tile_mask(qi * qc, ki * kc, qc, kc, window, is_global)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)
        dp = do @ v.T  # (qc, kc)
        ds = p * (dp - delta[:, None]) * scale
        return dq + ds @ k

    dq = jax.lax.fori_loop(0, nk, body, jnp.zeros_like(q))
    dq_ref[0, :, 0, :] = dq.astype(dq_ref.dtype)


def _dkv_kernel(flags_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, qc: int, window: int, scale: float):
    ki = pl.program_id(2)
    g = pl.program_id(3)

    @pl.when(g == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (kc, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kc = k.shape[0]
    S = q_ref.shape[1]
    nq = S // qc
    is_global = flags_ref[0] > 0

    def body(qi, carry):
        dk, dv = carry
        q = q_ref[0, pl.dslice(qi * qc, qc), 0, :].astype(jnp.float32)
        do = do_ref[0, pl.dslice(qi * qc, qc), 0, :].astype(jnp.float32)
        lse = lse_ref[0, pl.dslice(qi * qc, qc), 0]
        delta = delta_ref[0, pl.dslice(qi * qc, qc), 0]
        s = (q @ k.T) * scale
        msk = _tile_mask(qi * qc, ki * kc, qc, kc, window, is_global)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)  # (qc, kc)
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + ds.T @ q
        return dk, dv

    z = jnp.zeros((kc, k.shape[1]), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, nq, body, (z, z))
    dk_ref[0, :, 0, :] += dk.astype(dk_ref.dtype)
    dv_ref[0, :, 0, :] += dv.astype(dv_ref.dtype)


def _specs(B, S, H, KV, hd, qc, kc, G):
    q_spec = pl.BlockSpec((1, qc, 1, hd), lambda b, h, qi: (b, qi, h, 0))
    kv_spec = pl.BlockSpec((1, S, 1, hd), lambda b, h, qi: (b, 0, h // G, 0))
    lse_spec = pl.BlockSpec((1, qc, 1), lambda b, h, qi: (b, qi, h))
    return q_spec, kv_spec, lse_spec


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_mha(q, k, v, is_global, window: int = 0, qc: int = 512, kc: int = 1024):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd); is_global: () bool -> (B,S,H,hd)."""
    o, _ = _flash_fwd(q, k, v, is_global, window, qc, kc)
    return o


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flash_fwd(q, k, v, is_global, window, qc, kc):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qc = min(qc, S)
    kc = min(kc, S)
    flags = jnp.asarray(is_global, jnp.int32).reshape(1)
    q_spec, kv_spec, lse_spec = _specs(B, S, H, KV, hd, qc, kc, G)
    o, lse = pl.pallas_call(
        partial(_fwd_kernel, kc=kc, window=window, scale=1.0 / np.sqrt(hd)),
        grid=(B, H, S // qc),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), q_spec, kv_spec, kv_spec],
        out_specs=[q_spec, lse_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        ],
        interpret=_interpret(),
    )(flags, q, k, v)
    return o, (q, k, v, o, lse, flags)


def _flash_bwd(window, qc, kc, res, do):
    q, k, v, o, lse, flags = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qc = min(qc, S)
    kc = min(kc, S)
    scale = 1.0 / np.sqrt(hd)
    delta = jnp.einsum("bshd,bshd->bsh", do.astype(jnp.float32),
                       o.astype(jnp.float32))
    q_spec, kv_spec, lse_spec = _specs(B, S, H, KV, hd, qc, kc, G)

    dq = pl.pallas_call(
        partial(_dq_kernel, kc=kc, window=window, scale=scale),
        grid=(B, H, S // qc),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), q_spec, kv_spec,
                  kv_spec, q_spec, lse_spec, lse_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=_interpret(),
    )(flags, q, k, v, do, lse, delta)

    # dk/dv: grid (B, KV, nk, G); q-heads of one group accumulate in-place
    qh_spec = pl.BlockSpec((1, S, 1, hd), lambda b, kv_, ki, g: (b, 0, kv_ * G + g, 0))
    kt_spec = pl.BlockSpec((1, kc, 1, hd), lambda b, kv_, ki, g: (b, ki, kv_, 0))
    ls_spec = pl.BlockSpec((1, S, 1), lambda b, kv_, ki, g: (b, 0, kv_ * G + g))
    dk, dv = pl.pallas_call(
        partial(_dkv_kernel, qc=qc, window=window, scale=scale),
        grid=(B, KV, S // kc, G),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), qh_spec, kt_spec,
                  kt_spec, qh_spec, ls_spec, ls_spec],
        out_specs=[kt_spec, kt_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, KV, hd), k.dtype),
            jax.ShapeDtypeStruct((B, S, KV, hd), v.dtype),
        ],
        interpret=_interpret(),
    )(flags, q, k, v, do, lse, delta)
    return dq, dk, dv, None


flash_mha.defvjp(lambda q, k, v, ig, w, qc, kc: _flash_fwd(q, k, v, ig, w, qc, kc),
                 _flash_bwd)
