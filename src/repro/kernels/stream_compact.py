"""Pallas TPU kernel: stable stream compaction (count -> prefix-sum -> scatter).

The query engine's hot idiom was ``jnp.argsort(~mask, stable=True)[:cap]`` —
an O(N log N) sort just to move matching rows to the front.  Compaction is
the right primitive: each ``block``-sized tile counts its matches, computes
per-match target slots with an intra-tile prefix sum, and scatters its
*global row indices* to the front of its output tile (INVALID padding
behind).  The host wrapper (kernels/ops.py) stitches tiles together with one
exclusive prefix sum over the per-tile counts plus a single gather — O(N)
total, and the per-tile counts double as the match count, so the engine no
longer needs a separate counting pass over the store.

The intra-tile scatter is expressed as a one-hot select-and-reduce — a
(block, block) compare cube — because TPU has no vector scatter; at the
default block of 512 the cube is 1 MB of VMEM and pure VPU work.

Three entry points share the body:

  * ``stream_compact_pallas``   — compacts an arbitrary precomputed mask
    (spill intervals, member sets, rewrite-mode type masks),
  * ``interval_compact_pallas`` — fuses the LiteMat interval predicate
    (kernels/interval_filter.py) with compaction in ONE pass over the
    store: p in [plo, phi) AND o in [olo, ohi), constants in SMEM,
  * ``masked_interval_compact_pallas`` — the live-store variant: the same
    fused predicate ANDed with a per-row liveness (tombstone) mask, so a
    delta-overlaid scan (core/delta.py) filters deleted rows in the same
    single pass instead of compacting twice.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 512
INVALID = np.int32(np.iinfo(np.int32).max)


def _compact_body(m, idx_ref, cnt_ref):
    """m: int32[block] 0/1 -> front-compacted global indices + tile count."""
    block = m.shape[0]
    m2 = m.reshape(1, block)
    pos = jnp.cumsum(m2, axis=1) - 1  # target slot of each match
    cnt = jnp.sum(m2)
    out_slot = lax.broadcasted_iota(jnp.int32, (block, block), 0)
    src_idx = lax.broadcasted_iota(jnp.int32, (block, block), 1)
    sel = (pos == out_slot) & (m2 != 0)  # one-hot: slot j <- source i
    local = jnp.sum(jnp.where(sel, src_idx, 0), axis=1)  # int32[block]
    slot = lax.broadcasted_iota(jnp.int32, (1, block), 1).reshape(block)
    base = pl.program_id(0) * block
    idx_ref[...] = jnp.where(slot < cnt, local + base, INVALID)
    cnt_ref[0] = cnt


def _mask_kernel(mask_ref, idx_ref, cnt_ref):
    _compact_body(mask_ref[...].astype(jnp.int32), idx_ref, cnt_ref)


def _fused_kernel(params_ref, p_ref, o_ref, idx_ref, cnt_ref):
    plo, phi = params_ref[0], params_ref[1]
    olo, ohi = params_ref[2], params_ref[3]
    p = p_ref[...]
    o = o_ref[...]
    m = (p >= plo) & (p < phi) & (o >= olo) & (o < ohi)
    _compact_body(m.astype(jnp.int32), idx_ref, cnt_ref)


def _masked_fused_kernel(params_ref, p_ref, o_ref, alive_ref, idx_ref, cnt_ref):
    plo, phi = params_ref[0], params_ref[1]
    olo, ohi = params_ref[2], params_ref[3]
    p = p_ref[...]
    o = o_ref[...]
    m = (p >= plo) & (p < phi) & (o >= olo) & (o < ohi) & (alive_ref[...] != 0)
    _compact_body(m.astype(jnp.int32), idx_ref, cnt_ref)


def stream_compact_pallas(mask, *, block: int = DEFAULT_BLOCK, interpret: bool = False):
    """mask: int32[N] (N a multiple of block) ->
    (tile-compacted global indices int32[N], per-tile counts int32[N/block])."""
    n = mask.shape[0]
    nb = n // block
    return pl.pallas_call(
        _mask_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ],
        interpret=interpret,
    )(mask)


def interval_compact_pallas(p, o, params, *, block: int = DEFAULT_BLOCK,
                            interpret: bool = False):
    """p, o: int32[N]; params: int32[4] = (plo, phi, olo, ohi) ->
    (tile-compacted match indices, per-tile counts) — predicate fused."""
    n = p.shape[0]
    nb = n // block
    return pl.pallas_call(
        _fused_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ],
        interpret=interpret,
    )(params, p, o)


def masked_interval_compact_pallas(p, o, alive, params, *,
                                   block: int = DEFAULT_BLOCK,
                                   interpret: bool = False):
    """p, o, alive: int32[N]; params: int32[4] = (plo, phi, olo, ohi) ->
    (tile-compacted match indices, per-tile counts) — interval predicate and
    tombstone filter fused in one pass."""
    n = p.shape[0]
    nb = n // block
    return pl.pallas_call(
        _masked_fused_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nb,), jnp.int32),
        ],
        interpret=interpret,
    )(params, p, o, alive)
