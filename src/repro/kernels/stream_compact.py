"""Pallas TPU kernel: stable stream compaction (count -> prefix-sum -> scatter).

The query engine's hot idiom was ``jnp.argsort(~mask, stable=True)[:cap]`` —
an O(N log N) sort just to move matching rows to the front.  Compaction is
the right primitive: each ``block``-sized tile counts its matches, computes
per-match target slots with an intra-tile prefix sum, and scatters its
*global row indices* to the front of its output tile (INVALID padding
behind).  The host wrapper (kernels/ops.py) stitches tiles together with one
exclusive prefix sum over the per-tile counts plus a single gather — O(N)
total, and the per-tile counts double as the match count, so the engine no
longer needs a separate counting pass over the store.

The intra-tile scatter is a CHUNKED cumsum + dynamic-slice store: the tile
is cut into ``chunk``-sized pieces (default 256); each piece resolves its
matches with a (chunk, chunk) one-hot select-and-reduce (TPU has no vector
scatter, so the smallest compare cube that fits the VPU is the scatter),
and the piece's compacted run is stored at the tile-local running offset
with one ``pl.ds`` dynamic-slice write.  VMEM for the cube is O(chunk^2)
*independent of block*, so blocks grow to 4096+ (the old formulation was a
(block, block) cube — 64 MB at block=4096 — which capped blocks at 512);
larger blocks mean 8x fewer grid steps and tile-count segments per store
pass, the difference between "toy" and multi-million-row scans.

Four entry points share the body:

  * ``stream_compact_pallas``   — compacts an arbitrary precomputed mask
    (spill intervals, member sets, rewrite-mode type masks),
  * ``interval_compact_pallas`` — fuses the LiteMat interval predicate
    (kernels/interval_filter.py) with compaction in ONE pass over the
    store: p in [plo, phi) AND o in [olo, ohi), constants in SMEM,
  * ``masked_interval_compact_pallas`` — the live-store variant: the same
    fused predicate ANDed with a per-row liveness (tombstone) mask, so a
    delta-overlaid scan (core/delta.py) filters deleted rows in the same
    single pass instead of compacting twice,
  * ``dual_compact_pallas``     — TWO masks over the same rows compacted
    into two independent output streams in one grid pass.  The rewrite-mode
    dual-branch type pattern (dom∩rng predicates bind BOTH endpoints,
    core/query.py) needs a subject-binding and an object-binding compaction
    over the same store; emitting both per tile halves its kernel passes.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 512
DEFAULT_CHUNK = 256
INVALID = np.int32(np.iinfo(np.int32).max)


def _chunk_of(block: int, chunk: int) -> int:
    """Effective chunk: never larger than the tile, must divide it."""
    c = min(chunk, block)
    if block % c:
        raise ValueError(f"chunk {c} must divide block {block}")
    return c


def _compact_body(m, idx_ref, cnt_ref, chunk: int):
    """m: int32[block] 0/1 -> front-compacted global indices + tile count.

    Chunked: each ``chunk`` of the tile resolves its own matches with a
    (chunk, chunk) one-hot reduce, then lands at the tile-local running
    offset (the exclusive cumsum of chunk counts, carried through the loop)
    with one dynamic-slice store.  A chunk's local run is INVALID past its
    own count, and chunk c's store begins exactly where chunk c-1's matches
    end, so every stale INVALID tail is overwritten by the next chunk's
    run and the final tail stays INVALID — the tile's output is the tile's
    matches in ascending order, INVALID-padded, same contract as before.
    """
    block = m.shape[0]
    chunk = _chunk_of(block, chunk)
    n_chunks = block // chunk
    base = pl.program_id(0) * block
    if n_chunks == 1:
        vals, cnt = _chunk_compact(m, base)
        idx_ref[...] = vals
        cnt_ref[0] = cnt
        return
    idx_ref[...] = jnp.full((block,), INVALID, jnp.int32)

    def body(c, off):
        mc = lax.dynamic_slice(m, (c * chunk,), (chunk,))
        vals, cnt = _chunk_compact(mc, base + c * chunk)
        idx_ref[pl.ds(off, chunk)] = vals
        return off + cnt

    cnt_ref[0] = lax.fori_loop(0, n_chunks, body, jnp.int32(0))


def _chunk_compact(m, gbase):
    """int32[chunk] 0/1 -> (compacted global indices, INVALID-padded; count)."""
    chunk = m.shape[0]
    m2 = m.reshape(1, chunk)
    pos = jnp.cumsum(m2, axis=1) - 1  # target slot of each match
    cnt = jnp.sum(m2)
    out_slot = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    src_idx = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    sel = (pos == out_slot) & (m2 != 0)  # one-hot: slot j <- source i
    local = jnp.sum(jnp.where(sel, src_idx, 0), axis=1)  # int32[chunk]
    slot = lax.broadcasted_iota(jnp.int32, (1, chunk), 1).reshape(chunk)
    return jnp.where(slot < cnt, local + gbase, INVALID), cnt


def _mask_kernel(mask_ref, idx_ref, cnt_ref, *, chunk):
    _compact_body(mask_ref[...].astype(jnp.int32), idx_ref, cnt_ref, chunk)


def _fused_kernel(params_ref, p_ref, o_ref, idx_ref, cnt_ref, *, chunk):
    plo, phi = params_ref[0], params_ref[1]
    olo, ohi = params_ref[2], params_ref[3]
    p = p_ref[...]
    o = o_ref[...]
    m = (p >= plo) & (p < phi) & (o >= olo) & (o < ohi)
    _compact_body(m.astype(jnp.int32), idx_ref, cnt_ref, chunk)


def _masked_fused_kernel(params_ref, p_ref, o_ref, alive_ref, idx_ref,
                         cnt_ref, *, chunk):
    plo, phi = params_ref[0], params_ref[1]
    olo, ohi = params_ref[2], params_ref[3]
    p = p_ref[...]
    o = o_ref[...]
    m = (p >= plo) & (p < phi) & (o >= olo) & (o < ohi) & (alive_ref[...] != 0)
    _compact_body(m.astype(jnp.int32), idx_ref, cnt_ref, chunk)


def _dual_kernel(ma_ref, mb_ref, idxa_ref, cnta_ref, idxb_ref, cntb_ref,
                 *, chunk):
    _compact_body(ma_ref[...].astype(jnp.int32), idxa_ref, cnta_ref, chunk)
    _compact_body(mb_ref[...].astype(jnp.int32), idxb_ref, cntb_ref, chunk)


def _in_set_tile(col, arr):
    """Vectorized sorted-membership test inside a kernel tile.

    ``arr`` is a lex-sorted INT32_MAX-padded pow2-length id set resident
    on-chip for the whole grid pass.  log2(K) binary-search steps with
    vector gathers (the merge-path kernels' ref-gather idiom) stand in
    for ``jnp.searchsorted``, which does not lower inside Pallas bodies.
    """
    K = arr.shape[0]
    lo = jnp.zeros(col.shape, jnp.int32)
    hi = jnp.full(col.shape, K, jnp.int32)

    def step(_, lh):
        l, h = lh
        mid = (l + h) // 2
        v = arr[jnp.clip(mid, 0, K - 1)]
        right = v < col
        return jnp.where(right, mid + 1, l), jnp.where(right, h, mid)

    lo, hi = lax.fori_loop(0, max(int(K).bit_length(), 1), step, (lo, hi))
    pos = jnp.clip(lo, 0, K - 1)
    return (arr[pos] == col) & (col != INVALID)


def _member_kernel(params_ref, mem_ref, dom_ref, rng_ref, s_ref, p_ref,
                   o_ref, alive_ref, *out_refs, chunk, has_dom, has_rng):
    """Rewrite-mode type-pattern masks fused with compaction.

    Computes the subject-binding mask ``(p == tid & o ∈ mem) [| p ∈ dom]``
    and (statically gated) the object-binding mask ``p ∈ rng`` per tile —
    the member/domain/range id sets stay on-chip across the whole grid
    pass, so the full-store boolean masks the host path materialized
    never exist: each tile resolves its own membership tests and compacts
    in place.  ``tid`` rides in SMEM; absent branches compile to nothing.
    """
    tid = params_ref[0]
    s = s_ref[...]
    p = p_ref[...]
    o = o_ref[...]
    valid = (s != INVALID) & (alive_ref[...] != 0)
    m_s = (p == tid) & _in_set_tile(o, mem_ref[...])
    if has_dom:
        m_s = m_s | _in_set_tile(p, dom_ref[...])
    _compact_body((m_s & valid).astype(jnp.int32), out_refs[0], out_refs[1],
                  chunk)
    if has_rng:
        m_o = _in_set_tile(p, rng_ref[...]) & valid
        _compact_body(m_o.astype(jnp.int32), out_refs[2], out_refs[3], chunk)


def _compact_specs(block: int, nb: int, n: int, streams: int = 1):
    out_specs, out_shape = [], []
    for _ in range(streams):
        out_specs += [pl.BlockSpec((block,), lambda i: (i,)),
                      pl.BlockSpec((1,), lambda i: (i,))]
        out_shape += [jax.ShapeDtypeStruct((n,), jnp.int32),
                      jax.ShapeDtypeStruct((nb,), jnp.int32)]
    return out_specs, out_shape


def stream_compact_pallas(mask, *, block: int = DEFAULT_BLOCK,
                          chunk: int = DEFAULT_CHUNK, interpret: bool = False):
    """mask: int32[N] (N a multiple of block) ->
    (tile-compacted global indices int32[N], per-tile counts int32[N/block])."""
    n = mask.shape[0]
    nb = n // block
    out_specs, out_shape = _compact_specs(block, nb, n)
    return pl.pallas_call(
        partial(_mask_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(mask)


def interval_compact_pallas(p, o, params, *, block: int = DEFAULT_BLOCK,
                            chunk: int = DEFAULT_CHUNK,
                            interpret: bool = False):
    """p, o: int32[N]; params: int32[4] = (plo, phi, olo, ohi) ->
    (tile-compacted match indices, per-tile counts) — predicate fused."""
    n = p.shape[0]
    nb = n // block
    out_specs, out_shape = _compact_specs(block, nb, n)
    return pl.pallas_call(
        partial(_fused_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(params, p, o)


def masked_interval_compact_pallas(p, o, alive, params, *,
                                   block: int = DEFAULT_BLOCK,
                                   chunk: int = DEFAULT_CHUNK,
                                   interpret: bool = False):
    """p, o, alive: int32[N]; params: int32[4] = (plo, phi, olo, ohi) ->
    (tile-compacted match indices, per-tile counts) — interval predicate and
    tombstone filter fused in one pass."""
    n = p.shape[0]
    nb = n // block
    out_specs, out_shape = _compact_specs(block, nb, n)
    return pl.pallas_call(
        partial(_masked_fused_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(params, p, o, alive)


def member_compact_pallas(params, mem, dom, rng, s, p, o, alive, *,
                          has_dom: bool, has_rng: bool,
                          block: int = DEFAULT_BLOCK,
                          chunk: int = DEFAULT_CHUNK,
                          interpret: bool = False):
    """Fused rewrite-mode type-pattern predicate + compaction.

    ``params`` = int32[1] (tid) in SMEM; ``mem``/``dom``/``rng`` are
    lex-sorted INT32_MAX-padded id sets resident on-chip (constant index
    maps — one DMA for the whole grid); ``s``/``p``/``o``/``alive`` tile.
    Emits the subject-binding stream, plus the object-binding stream when
    ``has_rng`` — each satisfying the ``stream_compact_pallas`` contract.
    """
    n = s.shape[0]
    nb = n // block
    streams = 2 if has_rng else 1
    out_specs, out_shape = _compact_specs(block, nb, n, streams)
    resident = [pl.BlockSpec((a.shape[0],), lambda i: (0,))
                for a in (mem, dom, rng)]
    return pl.pallas_call(
        partial(_member_kernel, chunk=chunk, has_dom=has_dom,
                has_rng=has_rng),
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), *resident,
                  pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(params, mem, dom, rng, s, p, o, alive)


def dual_compact_pallas(mask_a, mask_b, *, block: int = DEFAULT_BLOCK,
                        chunk: int = DEFAULT_CHUNK, interpret: bool = False):
    """Two int32[N] masks -> two (indices, per-tile counts) streams, one pass.

    Each stream independently satisfies the ``stream_compact_pallas``
    contract; the tile's rows are resident once while BOTH masks resolve,
    so the dual-branch consumer pays one grid pass instead of two.
    """
    n = mask_a.shape[0]
    nb = n // block
    out_specs, out_shape = _compact_specs(block, nb, n, streams=2)
    return pl.pallas_call(
        partial(_dual_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(mask_a, mask_b)
