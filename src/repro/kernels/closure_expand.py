"""Pallas TPU kernel: RDFS closure expansion via the prefix encoding.

The hot loop of the full-materialization baseline (paper Table V): for every
type assertion, emit the concept's ancestor id row.  Thanks to LiteMat's
encoding, ancestors are a precomputed (C, D) table indexed by a binary
search over the sorted concept ids — both of which fit comfortably in VMEM
(Wikidata-scale: 213K x 4B ids = 0.9 MB; ancestor table a few MB).

The kernel fuses search + row gather per ``block`` of query ids: the concept
table is resident (constant index map), queries stream through.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _kernel(ids_ref, anc_ref, q_ref, out_ref):
    q = q_ref[...]  # (B,)
    C = ids_ref.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(C, 2)))) + 1)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        mv = ids_ref[mid]  # vector gather from the VMEM-resident table
        go = mv < q
        lo = jnp.where(go & (lo < hi), mid + 1, lo)
        hi = jnp.where((~go) & (lo < hi), mid, hi)
        return lo, hi

    lo0 = jnp.zeros(q.shape, jnp.int32)
    hi0 = jnp.full(q.shape, C, jnp.int32)
    pos, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    pos = jnp.clip(pos, 0, C - 1)
    hit = ids_ref[pos] == q
    rows = anc_ref[pos]  # (B, D) row gather
    out_ref[...] = jnp.where(hit[:, None], rows, -1)


def closure_expand_pallas(conc, sorted_ids, anc_table, *, block: int = DEFAULT_BLOCK,
                          interpret: bool = False):
    """conc: int32[N]; sorted_ids: int32[C]; anc_table: int32[C, D] -> [N, D]."""
    n = conc.shape[0]
    C, D = anc_table.shape
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C,), lambda i: (0,)),  # resident table
            pl.BlockSpec((C, D), lambda i: (0, 0)),  # resident ancestors
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, D), jnp.int32),
        interpret=interpret,
    )(sorted_ids, anc_table, conc)
