"""Pallas TPU kernel: LiteMat interval triple filter.

The hottest loop of the paper's query processor (§V): for every stored
triple, decide ``plo <= p < phi AND olo <= o < ohi`` — one fused compare
replacing the UNION over a whole sub-hierarchy.  Pure streaming VPU work:
triples flow HBM -> VMEM in ``block``-sized column tiles; the four interval
constants sit in SMEM (they are per-query runtime values, not compile-time
constants, so serving does not re-specialize).

Block shape: 1-D tiles of ``block`` elements per column (multiple of 1024 =
8 sublanes x 128 lanes on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 4096


def _kernel(params_ref, p_ref, o_ref, out_ref):
    plo = params_ref[0]
    phi = params_ref[1]
    olo = params_ref[2]
    ohi = params_ref[3]
    p = p_ref[...]
    o = o_ref[...]
    m = (p >= plo) & (p < phi) & (o >= olo) & (o < ohi)
    out_ref[...] = m.astype(jnp.int32)


def interval_filter_pallas(p, o, params, *, block: int = DEFAULT_BLOCK, interpret: bool = False):
    """p, o: int32[N]; params: int32[4] = (plo, phi, olo, ohi) -> int32 mask."""
    n = p.shape[0]
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(params, p, o)
