"""Public jit'd wrappers for the Pallas kernels.

Each wrapper pads inputs to kernel block multiples, dispatches to the Pallas
implementation (interpret mode on CPU — the kernels TARGET TPU; interpret
executes the same kernel body for validation), slices padding off, and
matches the corresponding ``ref.py`` oracle exactly.
"""
from __future__ import annotations

import threading
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.closure_expand import closure_expand_pallas
from repro.kernels.interval_filter import interval_filter_pallas
from repro.kernels.merge_sorted import (
    merge_path_pallas, merge_path_partitioned_pallas,
)
from repro.kernels.msc_select import msc_select_pallas
from repro.kernels.pair_search import pair_search_pallas
from repro.kernels.stream_compact import (
    dual_compact_pallas, interval_compact_pallas,
    masked_interval_compact_pallas, member_compact_pallas,
    stream_compact_pallas,
)

INVALID = np.int32(np.iinfo(np.int32).max)

# Trace-time kernel-pass accounting.  Each counter bumps while a wrapper's
# body is being TRACED (once per compiled executable, not per execution), so
# "how many kernel passes does this plan make over the store" is a
# deterministic, timing-free signal: reset, trace a cold plan, read.  The
# rewrite-mode dual-branch pin (one dual-mask pass instead of two
# single-mask passes) and the bench pass-count rows gate on these.
#
# Compiles can race under the threaded serving runtime (two workers tracing
# different plans concurrently), so every bump goes through _bump_pass: a
# lock guards the dict's read-modify-write, and each bump is mirrored into
# the process metrics registry (kernels/passes{kind=...}) where the obs
# exporters read it.  The dict itself stays the public read surface.
pass_counters = {"compact": 0, "dual_compact": 0, "member_compact": 0,
                 "merge_resident": 0, "merge_partitioned": 0}
_PASS_LOCK = threading.Lock()


def _bump_pass(kind: str) -> None:
    from repro.obs.metrics import REGISTRY

    with _PASS_LOCK:
        pass_counters[kind] += 1
    REGISTRY.counter("kernels/passes", kind=kind).inc()


def reset_pass_counters() -> dict:
    """Zero the trace-time pass counters; returns the pre-reset snapshot."""
    with _PASS_LOCK:
        snap = dict(pass_counters)
        for k in pass_counters:
            pass_counters[k] = 0
    return snap


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# Block-size selection for the compaction kernels.  The chunked-cumsum
# body's VMEM is O(chunk^2) regardless of block, so large stores take
# 4096-row tiles (8x fewer grid steps + stitch segments than the old
# 512 ceiling); small stores keep small tiles so padding stays bounded.
LARGE_BLOCK = 4096
_LARGE_N = 1 << 16


def auto_block(n: int) -> int:
    """Compaction tile size for an n-row store (static at trace time)."""
    return LARGE_BLOCK if n >= _LARGE_N else 512


def _pad1(x, m, fill):
    n = x.shape[0]
    p = (-n) % m
    if n == 0:
        p = m  # empty inputs still launch one (all-padding) tile: kernel
        # grids must be non-empty, and a delta-only store has a 0-row base
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p, *x.shape[1:]), fill, x.dtype)])


@partial(jax.jit, static_argnames=("block",))
def interval_filter(p, o, params, block: int = 4096):
    """LiteMat triple filter; params = int32[4] (plo, phi, olo, ohi) -> bool[N]."""
    n = p.shape[0]
    pp = _pad1(p, block, np.int32(np.iinfo(np.int32).max))
    po = _pad1(o, block, np.int32(np.iinfo(np.int32).max))
    out = interval_filter_pallas(pp, po, params, block=block, interpret=_interpret())
    return out[:n].astype(bool)


@partial(jax.jit, static_argnames=("group_block",))
def msc_select(conc, bounds, group_block: int = 128):
    """Grouped MSC keep-mask; conc/bounds int32[G, K] (-1 pad) -> bool[G, K]."""
    G = conc.shape[0]
    pc = _pad1(conc, group_block, np.int32(-1))
    pb = _pad1(bounds, group_block, np.int32(-1))
    out = msc_select_pallas(pc, pb, group_block=group_block, interpret=_interpret())
    return out[:G].astype(bool)


@partial(jax.jit, static_argnames=("block",))
def closure_expand(conc, sorted_ids, anc_table, block: int = 1024):
    """Ancestor-row expansion; conc int32[N] -> int32[N, D]."""
    n = conc.shape[0]
    pc = _pad1(conc, block, np.int32(-1))
    out = closure_expand_pallas(pc, sorted_ids, anc_table, block=block,
                                interpret=_interpret())
    return out[:n]


@partial(jax.jit, static_argnames=("block",))
def pair_search(table_hi, table_lo, qhi, qlo, block: int = 1024):
    """Lexicographic binary search (left); -> int32 positions."""
    n = qhi.shape[0]
    if table_hi.shape[0] == 0:  # empty table: every query lands at 0
        return jnp.zeros((n,), jnp.int32)
    mx = np.int32(np.iinfo(np.int32).max)
    ph = _pad1(qhi, block, mx)
    pl_ = _pad1(qlo, block, mx)
    out = pair_search_pallas(table_hi, table_lo, ph, pl_, block=block,
                             interpret=_interpret())
    return out[:n]


@partial(jax.jit, static_argnames=("block",))
def pair_search_windowed(table_hi, table_lo, qhi, qlo, block: int = 1024):
    """Lexicographic binary search with NO whole-table VMEM residency.

    ``pair_search`` keeps both table planes VMEM-resident (constant index
    maps) — fine up to ~1M rows, the ceiling that used to disqualify the
    index-nested-loop join on large stores.  This path re-expresses the
    batch search as a stable merge, reusing the diagonal-partitioned
    merge-path kernel: sort the queries (the probe side is small), merge
    the sorted query run against the table run (per-tile DMA'd windows,
    O(block) VMEM at any table size), and read each query's position off
    its merge slot — query rank ``r`` landing at merged slot ``i`` has
    exactly ``i - r`` table keys before it.  Ties keep queries before
    equal table keys (run A first), so positions match the 'left' contract
    of ``pair_search`` / ``ref.ref_pair_search`` bit-exactly.
    """
    n = qhi.shape[0]
    perm = jnp.lexsort((qlo, qhi))
    qh_s, ql_s = qhi[perm], qlo[perm]
    pad = max(block - n, 0)  # static: >= block queries forces the
    if pad:  # partitioned dispatch whenever the table reaches block too
        qh_s = jnp.concatenate([qh_s, jnp.full((pad,), INVALID, jnp.int32)])
        ql_s = jnp.concatenate([ql_s, jnp.full((pad,), INVALID, jnp.int32)])
    nq = n + pad
    g = merge_gather(qh_s, ql_s, table_hi, table_lo, block=block)
    idx = jnp.arange(g.shape[0], dtype=jnp.int32)
    slots = jnp.zeros((nq,), jnp.int32).at[
        jnp.where(g < nq, g, nq)].set(idx, mode="drop")
    pos = slots - jnp.arange(nq, dtype=jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[perm].set(pos[:n])


@partial(jax.jit, static_argnames=("block",))
def merge_gather(a_hi, a_lo, b_hi, b_lo, block: int = 1024):
    """Stable-merge gather map of two lex-sorted (hi, lo) pair runs.

    Returns int32[n + m]: values < n select run A, values >= n select
    ``B[value - n]`` — merged rows are one device gather away, so folding
    a sorted delta into a sorted base never assembles the merged array on
    the host.  Ties keep A-before-B order (the ``index.merge_sorted``
    contract; ``ref.ref_merge_sorted`` is the oracle).

    Dispatch: when BOTH runs reach ``block`` rows the diagonal-partitioned
    kernel runs (per-tile DMA windows, O(block) VMEM — no ceiling on n+m);
    smaller runs take the resident kernel, whose whole-table VMEM footprint
    is then trivially affordable.
    """
    n, m = a_hi.shape[0], b_hi.shape[0]
    if m == 0:
        return jnp.arange(n, dtype=jnp.int32)
    if n == 0:
        return jnp.arange(m, dtype=jnp.int32)
    if n >= block and m >= block:
        _bump_pass("merge_partitioned")
        out = merge_path_partitioned_pallas(a_hi, a_lo, b_hi, b_lo,
                                            block=block,
                                            interpret=_interpret())
    else:
        _bump_pass("merge_resident")
        out = merge_path_pallas(a_hi, a_lo, b_hi, b_lo, block=block,
                                interpret=_interpret())
    return out[: n + m]


def two_source_gather(base, delta, idx):
    """Gather rows addressed in combined [base | delta] coordinates.

    ``idx < base_n`` selects ``base[idx]``; the rest select
    ``delta[idx - base_n]`` — the virtual-concat addressing every live
    store view uses (core/delta.py keeps base and delta as SEPARATE device
    arrays so mutations never re-concatenate the base).  ``delta=None``
    (a delta-free view: combined coords never exceed the base) collapses
    to a plain base gather, so static stores pay no two-source overhead.
    """
    bn = base.shape[0]
    if delta is None or delta.shape[0] == 0:
        return base[jnp.clip(idx, 0, bn - 1)]
    if bn == 0:  # fully-compacted-away base: every coord is a delta coord
        return delta[jnp.clip(idx, 0, delta.shape[0] - 1)]
    b = base[jnp.clip(idx, 0, bn - 1)]
    from_d = idx >= bn
    d = delta[jnp.clip(idx - bn, 0, delta.shape[0] - 1)]
    if base.ndim > 1:
        from_d = from_d.reshape(from_d.shape + (1,) * (base.ndim - 1))
    return jnp.where(from_d, d, b)


def segment_positions(starts, lens, cap: int):
    """Map output slots [0, cap) onto k variable-length segments.

    One exclusive prefix sum over ``lens`` assigns every output slot j a
    (segment, rank-in-segment); returns (src = starts[seg] + rank,
    ok = j < total, total, seg).  Shared by the kernel-tile stitch below,
    the sorted-index range gather, and the index-nested-loop join (which
    needs ``seg`` to map each output row back to its probe row) in
    core/query.py — the searchsorted(side="right") addressing lives in
    exactly one place.
    """
    offsets = jnp.cumsum(lens)
    total = offsets[-1]
    begin = offsets - lens
    j = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.clip(jnp.searchsorted(offsets, j, side="right"),
                   0, lens.shape[0] - 1)
    src = starts[seg] + (j - begin[seg])
    return src, j < total, total, seg


def _assemble_compact(local, counts, cap: int, block: int):
    """Stitch tile-compacted indices into one front-compacted [cap] gather.

    The per-tile counts are the segment lengths (tile t's matches start at
    t*block); the total match count rides along for free — callers use it
    for overflow accounting instead of a second full counting pass.
    """
    tile_starts = jnp.arange(counts.shape[0], dtype=jnp.int32) * block
    src, ok, total, _ = segment_positions(tile_starts, counts, cap)
    take = jnp.where(ok, local[jnp.clip(src, 0, local.shape[0] - 1)], 0)
    return take, ok, total


@partial(jax.jit, static_argnames=("cap", "block"))
def compact_indices(mask, cap: int, block: int = 512):
    """Stable compaction of an arbitrary bool mask.

    Returns (take int32[cap] — indices of the first cap True positions,
    0-filled past the end; ok bool[cap]; total int32 match count).  Replaces
    the ``jnp.argsort(~mask, stable=True)[:cap]`` idiom in O(N).
    """
    _bump_pass("compact")
    m = _pad1(mask.astype(jnp.int32), block, np.int32(0))
    local, counts = stream_compact_pallas(m, block=block, interpret=_interpret())
    return _assemble_compact(local, counts, cap, block)


@partial(jax.jit, static_argnames=("cap", "block"))
def dual_compact_indices(mask_a, mask_b, cap: int, block: int = 512):
    """Stable compaction of TWO bool masks over the same rows in ONE pass.

    Returns (take_a, ok_a, total_a, take_b, ok_b, total_b) — each triple
    exactly what ``compact_indices`` returns for its mask, but the store is
    streamed through the kernel once (the rewrite-mode dual-branch type
    pattern compacts a subject-binding and an object-binding mask over the
    same rows; this halves its kernel passes).
    """
    _bump_pass("dual_compact")
    ma = _pad1(mask_a.astype(jnp.int32), block, np.int32(0))
    mb = _pad1(mask_b.astype(jnp.int32), block, np.int32(0))
    la, ca, lb, cb = dual_compact_pallas(ma, mb, block=block,
                                         interpret=_interpret())
    return (*_assemble_compact(la, ca, cap, block),
            *_assemble_compact(lb, cb, cap, block))


@partial(jax.jit, static_argnames=("cap", "block", "has_dom", "has_rng"))
def rewrite_member_compact(spo, alive, tid, mem, dom, rng, cap: int,
                           has_dom: bool, has_rng: bool, block: int = 512):
    """Fused rewrite-mode type-pattern member-set masks + compaction.

    One kernel pass over ``spo`` evaluates the full RDFS reformulation of
    ``(?x rdf:type C)`` — subject branch ``(p == tid & o ∈ mem) | p ∈ dom``
    and object branch ``p ∈ rng`` — with the sorted id sets resident
    on-chip, and compacts the matching row indices in the same pass: the
    full-store boolean masks the old ``_in_set`` path materialized before
    compaction never exist.  Returns ``(take_s, ok_s, total_s)``, extended
    with ``(take_o, ok_o, total_o)`` when ``has_rng``; each triple matches
    the ``compact_indices`` contract.  ``has_dom``/``has_rng`` are static,
    so absent branches compile to nothing.
    """
    _bump_pass("member_compact")
    s = _pad1(spo[:, 0], block, INVALID)
    p = _pad1(spo[:, 1], block, INVALID)
    o = _pad1(spo[:, 2], block, INVALID)
    pa = _pad1(alive.astype(jnp.int32), block, np.int32(0))
    params = jnp.stack([tid]).astype(jnp.int32)
    outs = member_compact_pallas(
        params, mem, dom, rng, s, p, o, pa, has_dom=has_dom,
        has_rng=has_rng, block=block, interpret=_interpret())
    if has_rng:
        ls, cs, lo_, co = outs
        return (*_assemble_compact(ls, cs, cap, block),
                *_assemble_compact(lo_, co, cap, block))
    ls, cs = outs
    return _assemble_compact(ls, cs, cap, block)


@partial(jax.jit, static_argnames=("cap", "block"))
def interval_compact(p, o, params, cap: int, block: int = 512):
    """Fused LiteMat interval predicate + compaction in one pass.

    params = int32[4] (plo, phi, olo, ohi); padding uses INT32_MAX which can
    never satisfy ``p < phi`` for any real predicate bound.  Same returns as
    ``compact_indices``.
    """
    _bump_pass("compact")
    pp = _pad1(p, block, INVALID)
    po = _pad1(o, block, INVALID)
    local, counts = interval_compact_pallas(pp, po, params, block=block,
                                            interpret=_interpret())
    return _assemble_compact(local, counts, cap, block)


@partial(jax.jit, static_argnames=("cap", "block"))
def masked_interval_compact(p, o, alive, params, cap: int, block: int = 512):
    """Fused interval predicate + liveness mask + compaction in one pass.

    The live-store scan primitive: ``alive`` carries tombstones from the
    delta overlay (core/delta.py), so a deleted row is filtered in the same
    kernel pass that evaluates the LiteMat interval predicate.  Same
    returns as ``compact_indices``.
    """
    _bump_pass("compact")
    pp = _pad1(p, block, INVALID)
    po = _pad1(o, block, INVALID)
    pa = _pad1(alive.astype(jnp.int32), block, np.int32(0))
    local, counts = masked_interval_compact_pallas(
        pp, po, pa, params, block=block, interpret=_interpret())
    return _assemble_compact(local, counts, cap, block)


__all__ = [
    "interval_filter", "msc_select", "closure_expand", "pair_search",
    "pair_search_windowed", "compact_indices", "dual_compact_indices",
    "interval_compact", "masked_interval_compact", "merge_gather",
    "rewrite_member_compact",
    "two_source_gather", "segment_positions", "auto_block", "LARGE_BLOCK",
    "pass_counters", "reset_pass_counters", "ref",
]
