"""Pallas TPU kernel: merge-path interleave of two lex-sorted runs.

Compaction's primitive (core/delta.py) is "fold a small sorted delta run
into a large sorted base run of the same permutation".  The host version
(core/index.py::merge_sorted) assembles the merged array in numpy — an
O(base) host materialization per store, exactly what keeps large-scale
compaction off the accelerator.  This kernel computes the *gather map* of
the stable merge instead: for every output slot ``i`` of the merged run it
emits the source index (``< n`` → run A, ``>= n`` → ``n +`` run B index),
so the merged rows themselves are produced by one device gather and never
touch the host.

Keys are lexicographic (hi, lo) int32 pairs — the same two-plane encoding
pair_search.py uses, because TPUs have no fast int64 and every store
permutation is already sorted by a (primary, secondary) column pair.

Each output element finds its source with a *merge-path diagonal search*:
``ia`` (the number of A elements among the first ``i`` outputs) is the
unique point on diagonal ``i`` where ``A[ia-1] <= B[i-ia] < A[ia]`` under
the stable ordering (ties take A first).  That is a ~log2(n) binary search
per element — both key tables stay VMEM-resident (constant index map, like
pair_search) and every probe is a vector gather, so a block of outputs
resolves in ~log2(n) gather steps with no sequential two-pointer walk.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _le_pair(a_hi, a_lo, b_hi, b_lo):
    """Lexicographic (a_hi, a_lo) <= (b_hi, b_lo)."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def _kernel(ahi_ref, alo_ref, bhi_ref, blo_ref, out_ref):
    n = ahi_ref.shape[0]
    m = bhi_ref.shape[0]
    block = out_ref.shape[0]
    # diagonal index of each output slot (2D iota: TPU has no 1D iota)
    i = (pl.program_id(0) * block
         + lax.broadcasted_iota(jnp.int32, (1, block), 1).reshape(block))
    i = jnp.minimum(i, n + m - 1)  # grid padding: clamp, wrapper slices off

    # binary search the merge path: smallest ia in [max(0, i-m), min(i, n)]
    # such that NOT (A[ia] <= B[i-ia-1]); ties resolve A-before-B, matching
    # the host merge (searchsorted side='right' for the B run).
    lo0 = jnp.maximum(i - m, 0)
    hi0 = jnp.minimum(i, n)
    steps = max(1, int(np.ceil(np.log2(max(n, 1) + 1))) + 1)

    def body(_, carry):
        lo_b, hi_b = carry
        cont = lo_b < hi_b
        mid = (lo_b + hi_b) >> 1  # in [lo_b, hi_b) when cont: mid < n, i-mid >= 1
        a_h = ahi_ref[jnp.clip(mid, 0, n - 1)]
        a_l = alo_ref[jnp.clip(mid, 0, n - 1)]
        jb = jnp.clip(i - mid - 1, 0, m - 1)
        go = _le_pair(a_h, a_l, bhi_ref[jb], blo_ref[jb])  # A[mid] still <= B
        lo_n = jnp.where(cont & go, mid + 1, lo_b)
        hi_n = jnp.where(cont & ~go, mid, hi_b)
        return lo_n, hi_n

    ia, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    ib = i - ia

    # slot i holds A[ia] iff A still has rows and A[ia] <= B[ib] (stable)
    iac = jnp.clip(ia, 0, n - 1)
    ibc = jnp.clip(ib, 0, m - 1)
    a_le_b = _le_pair(ahi_ref[iac], alo_ref[iac], bhi_ref[ibc], blo_ref[ibc])
    take_a = (ia < n) & ((ib >= m) | a_le_b)
    out_ref[...] = jnp.where(take_a, ia, n + ib)


def merge_path_pallas(a_hi, a_lo, b_hi, b_lo, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = False):
    """Lex-sorted pair runs int32[n] / int32[m] -> gather map int32[P].

    ``P`` is ``n + m`` rounded up to a block multiple; callers slice to
    ``n + m``.  ``out[i] < n`` selects ``A[out[i]]``, otherwise
    ``B[out[i] - n]``.  Requires n >= 1 and m >= 1 (degenerate runs are
    identity maps — the ops wrapper short-circuits them).
    """
    n = a_hi.shape[0]
    m = b_hi.shape[0]
    total = n + m
    nb = pl.cdiv(total, block)
    tbl_a = pl.BlockSpec((n,), lambda i: (0,))
    tbl_b = pl.BlockSpec((m,), lambda i: (0,))
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[tbl_a, tbl_a, tbl_b, tbl_b],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.int32),
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)
