"""Pallas TPU kernels: merge-path interleave of two lex-sorted runs.

Compaction's primitive (core/delta.py) is "fold a small sorted delta run
into a large sorted base run of the same permutation".  The host version
(core/index.py::merge_sorted) assembles the merged array in numpy — an
O(base) host materialization per store, exactly what keeps large-scale
compaction off the accelerator.  These kernels compute the *gather map* of
the stable merge instead: for every output slot ``i`` of the merged run it
emits the source index (``< n`` → run A, ``>= n`` → ``n +`` run B index),
so the merged rows themselves are produced by one device gather and never
touch the host.

Keys are lexicographic (hi, lo) int32 pairs — the same two-plane encoding
pair_search.py uses, because TPUs have no fast int64 and every store
permutation is already sorted by a (primary, secondary) column pair.

Each output element finds its source with a *merge-path diagonal search*:
``ia`` (the number of A elements among the first ``i`` outputs) is the
unique point on diagonal ``i`` where ``A[ia-1] <= B[i-ia] < A[ia]`` under
the stable ordering (ties take A first).  Two variants share that math:

  * ``merge_path_pallas`` — both key tables VMEM-resident (constant index
    maps, like pair_search); every probe is a vector gather, so a block of
    outputs resolves in ~log2(n) gather steps.  Simple, but 8*(n+m) bytes
    of VMEM caps it near ~1M combined rows.
  * ``merge_path_partitioned_pallas`` — the A/B fetches are PARTITIONED
    along merge-path diagonals: the wrapper binary-searches the path once
    per tile boundary (``_diag_splits``, plain XLA over the full arrays in
    HBM), and each grid step DMAs only its own ≤block-long A-run and B-run
    windows from ``ANY`` memory into VMEM scratch before a purely local
    merge-path search.  VMEM is O(block) regardless of n and m, lifting
    the ceiling from "both tables resident" to multi-million-row bases;
    the in-kernel search also shortens from log2(n) to log2(block) steps.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 1024


def _le_pair(a_hi, a_lo, b_hi, b_lo):
    """Lexicographic (a_hi, a_lo) <= (b_hi, b_lo)."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def _kernel(ahi_ref, alo_ref, bhi_ref, blo_ref, out_ref):
    n = ahi_ref.shape[0]
    m = bhi_ref.shape[0]
    block = out_ref.shape[0]
    # diagonal index of each output slot (2D iota: TPU has no 1D iota)
    i = (pl.program_id(0) * block
         + lax.broadcasted_iota(jnp.int32, (1, block), 1).reshape(block))
    i = jnp.minimum(i, n + m - 1)  # grid padding: clamp, wrapper slices off

    # binary search the merge path: smallest ia in [max(0, i-m), min(i, n)]
    # such that NOT (A[ia] <= B[i-ia-1]); ties resolve A-before-B, matching
    # the host merge (searchsorted side='right' for the B run).
    lo0 = jnp.maximum(i - m, 0)
    hi0 = jnp.minimum(i, n)
    steps = max(1, int(np.ceil(np.log2(max(n, 1) + 1))) + 1)

    def body(_, carry):
        lo_b, hi_b = carry
        cont = lo_b < hi_b
        mid = (lo_b + hi_b) >> 1  # in [lo_b, hi_b) when cont: mid < n, i-mid >= 1
        a_h = ahi_ref[jnp.clip(mid, 0, n - 1)]
        a_l = alo_ref[jnp.clip(mid, 0, n - 1)]
        jb = jnp.clip(i - mid - 1, 0, m - 1)
        go = _le_pair(a_h, a_l, bhi_ref[jb], blo_ref[jb])  # A[mid] still <= B
        lo_n = jnp.where(cont & go, mid + 1, lo_b)
        hi_n = jnp.where(cont & ~go, mid, hi_b)
        return lo_n, hi_n

    ia, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    ib = i - ia

    # slot i holds A[ia] iff A still has rows and A[ia] <= B[ib] (stable)
    iac = jnp.clip(ia, 0, n - 1)
    ibc = jnp.clip(ib, 0, m - 1)
    a_le_b = _le_pair(ahi_ref[iac], alo_ref[iac], bhi_ref[ibc], blo_ref[ibc])
    take_a = (ia < n) & ((ib >= m) | a_le_b)
    out_ref[...] = jnp.where(take_a, ia, n + ib)


def merge_path_pallas(a_hi, a_lo, b_hi, b_lo, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = False):
    """Lex-sorted pair runs int32[n] / int32[m] -> gather map int32[P].

    ``P`` is ``n + m`` rounded up to a block multiple; callers slice to
    ``n + m``.  ``out[i] < n`` selects ``A[out[i]]``, otherwise
    ``B[out[i] - n]``.  Requires n >= 1 and m >= 1 (degenerate runs are
    identity maps — the ops wrapper short-circuits them).  Both key tables
    stay fully VMEM-resident; use the partitioned variant for runs past
    the VMEM ceiling.
    """
    n = a_hi.shape[0]
    m = b_hi.shape[0]
    total = n + m
    nb = pl.cdiv(total, block)
    tbl_a = pl.BlockSpec((n,), lambda i: (0,))
    tbl_b = pl.BlockSpec((m,), lambda i: (0,))
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[tbl_a, tbl_a, tbl_b, tbl_b],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.int32),
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)


# ---------------------------------------------------------------------------
# Diagonal-partitioned variant: per-tile A/B windows, O(block) VMEM
# ---------------------------------------------------------------------------


def _diag_splits(a_hi, a_lo, b_hi, b_lo, block: int):
    """A-side merge-path split at every tile-boundary diagonal.

    Returns int32[nb + 1]: ``splits[t]`` is the number of A elements among
    the first ``min(t*block, n+m)`` outputs of the stable merge — the same
    "smallest ia with NOT (A[ia] <= B[d-ia-1])" search the kernels run per
    element, vectorized over the nb+1 boundaries with plain XLA gathers
    (the full tables never enter VMEM; this is O(nb log n) scalar work).
    """
    n, m = a_hi.shape[0], b_hi.shape[0]
    nb = pl.cdiv(n + m, block)
    d = jnp.minimum(jnp.arange(nb + 1, dtype=jnp.int32) * block, n + m)
    lo0 = jnp.maximum(d - m, 0)
    hi0 = jnp.minimum(d, n)
    steps = max(1, int(np.ceil(np.log2(max(n, 1) + 1))) + 1)

    def body(_, carry):
        lo_b, hi_b = carry
        cont = lo_b < hi_b
        mid = (lo_b + hi_b) >> 1
        a_h = a_hi[jnp.clip(mid, 0, n - 1)]
        a_l = a_lo[jnp.clip(mid, 0, n - 1)]
        jb = jnp.clip(d - mid - 1, 0, m - 1)
        go = _le_pair(a_h, a_l, b_hi[jb], b_lo[jb])
        lo_n = jnp.where(cont & go, mid + 1, lo_b)
        hi_n = jnp.where(cont & ~go, mid, hi_b)
        return lo_n, hi_n

    ia, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    return ia.astype(jnp.int32)


def _part_kernel(splits_ref, ahi_ref, alo_ref, bhi_ref, blo_ref, out_ref,
                 wa_hi, wa_lo, wb_hi, wb_lo, sems, *, n, m, block):
    """One output tile: DMA its own A/B run windows, merge them locally.

    The tile's outputs are global diagonals [t*block, min((t+1)*block, n+m));
    the merge-path splits bound its A-run to [a0, a1) and its B-run to
    [d0 - a0, d1 - a1), each at most ``block`` long, so a ``block``-sized
    window per key plane (start clamped so the window stays inside the
    table — requires n, m >= block, the wrapper's dispatch condition)
    always covers the run.  All four DMAs overlap, then a purely local
    merge-path binary search (log2(block) steps over VMEM scratch) places
    every output slot.
    """
    t = pl.program_id(0)
    a0 = splits_ref[t]
    a1 = splits_ref[t + 1]
    d0 = t * block
    d1 = jnp.minimum(d0 + block, n + m)
    b0 = d0 - a0
    len_a = a1 - a0
    len_b = (d1 - d0) - len_a

    sa = jnp.clip(a0, 0, n - block)
    sb = jnp.clip(b0, 0, m - block)
    copies = [
        pltpu.make_async_copy(ahi_ref.at[pl.ds(sa, block)], wa_hi, sems.at[0]),
        pltpu.make_async_copy(alo_ref.at[pl.ds(sa, block)], wa_lo, sems.at[1]),
        pltpu.make_async_copy(bhi_ref.at[pl.ds(sb, block)], wb_hi, sems.at[2]),
        pltpu.make_async_copy(blo_ref.at[pl.ds(sb, block)], wb_lo, sems.at[3]),
    ]
    for c in copies:
        c.start()
    for c in copies:
        c.wait()

    offa = a0 - sa  # local offset of the run inside its window
    offb = b0 - sb
    j = lax.broadcasted_iota(jnp.int32, (1, block), 1).reshape(block)
    j = jnp.minimum(j, jnp.maximum(d1 - d0 - 1, 0))  # partial-tile clamp

    lo0 = jnp.maximum(j - len_b, 0)
    hi0 = jnp.minimum(j, len_a)
    steps = max(1, int(np.ceil(np.log2(block + 1))) + 1)

    def wa(i):  # window gathers, indices pre-clipped to the window
        ic = jnp.clip(offa + i, 0, block - 1)
        return wa_hi[ic], wa_lo[ic]

    def wb(i):
        ic = jnp.clip(offb + i, 0, block - 1)
        return wb_hi[ic], wb_lo[ic]

    def body(_, carry):
        lo_b, hi_b = carry
        cont = lo_b < hi_b
        mid = (lo_b + hi_b) >> 1
        a_h, a_l = wa(jnp.clip(mid, 0, jnp.maximum(len_a - 1, 0)))
        b_h, b_l = wb(jnp.clip(j - mid - 1, 0, jnp.maximum(len_b - 1, 0)))
        go = _le_pair(a_h, a_l, b_h, b_l)
        lo_n = jnp.where(cont & go, mid + 1, lo_b)
        hi_n = jnp.where(cont & ~go, mid, hi_b)
        return lo_n, hi_n

    ia, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    ib = j - ia
    a_h, a_l = wa(jnp.clip(ia, 0, jnp.maximum(len_a - 1, 0)))
    b_h, b_l = wb(jnp.clip(ib, 0, jnp.maximum(len_b - 1, 0)))
    a_le_b = _le_pair(a_h, a_l, b_h, b_l)
    take_a = (ia < len_a) & ((ib >= len_b) | a_le_b)
    out_ref[...] = jnp.where(take_a, a0 + ia, n + b0 + ib)


def merge_path_partitioned_pallas(a_hi, a_lo, b_hi, b_lo, *,
                                  block: int = DEFAULT_BLOCK,
                                  interpret: bool = False):
    """Diagonal-partitioned merge gather map — O(block) VMEM per grid step.

    Same contract as ``merge_path_pallas`` (int32[P] gather map, P = n+m
    rounded up to a block multiple).  Requires n >= block and m >= block so
    the clamped per-tile windows always fit inside the tables; the ops
    wrapper falls back to the resident kernel for smaller runs (where the
    VMEM ceiling is not a concern anyway).
    """
    n = a_hi.shape[0]
    m = b_hi.shape[0]
    if n < block or m < block:
        raise ValueError(
            f"partitioned merge needs both runs >= block ({block}); "
            f"got n={n}, m={m} — use merge_path_pallas")
    total = n + m
    nb = pl.cdiv(total, block)
    splits = _diag_splits(a_hi, a_lo, b_hi, b_lo, block)
    tbl = pl.BlockSpec(memory_space=pltpu.ANY)
    return pl.pallas_call(
        partial(_part_kernel, n=n, m=m, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), tbl, tbl, tbl, tbl],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((block,), jnp.int32),
            pltpu.VMEM((block,), jnp.int32),
            pltpu.VMEM((block,), jnp.int32),
            pltpu.VMEM((block,), jnp.int32),
            pltpu.SemaphoreType.DMA((4,)),
        ],
        interpret=interpret,
    )(splits, a_hi, a_lo, b_hi, b_lo)
