"""Pallas TPU kernel: EmbeddingBag (gather + bag-reduce) for recsys.

JAX has no native EmbeddingBag; the recsys family (MIND) needs ragged
multi-hot lookups over large tables.  TPU-native formulation: the bag
indices are *scalar-prefetched* so the BlockSpec index_map can steer the
HBM->VMEM DMA of exactly the embedding rows needed — the canonical Pallas
embedding-gather pattern.  The grid is (B bags x L slots); the output block
for bag b stays resident across the L inner steps and accumulates (slot 0
initializes), so each row is touched once and reduction happens in VMEM.

-1 indices are padding: their DMA is redirected to row 0 and their
contribution multiplied by 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, out_ref):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    scale = jnp.where(idx_ref[b, l] >= 0, 1.0, 0.0).astype(out_ref.dtype)
    out_ref[...] += scale * row_ref[...]


def embedding_bag_pallas(table, indices, *, interpret: bool = False):
    """table: f32[V, E]; indices: int32[B, L] (-1 pad) -> f32[B, E] (sum)."""
    B, L = indices.shape
    V, E = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, E), lambda b, l, idx: (jnp.maximum(idx[b, l], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda b, l, idx: (b, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, E), table.dtype),
        interpret=interpret,
    )(indices, table)
