"""Pallas TPU kernel: binary search over lexicographic (hi, lo) pair tables.

The dictionary hot op (paper §III.B locate): TPUs have no fast int64, so
62-bit fingerprints live as two int32 planes and every lookup is a
lexicographic binary search.  The sorted table planes are VMEM-resident
(constant index map); queries stream in blocks; ~log2(T) vector-gather steps
per block.  Contract: ref_pair_search (= pair64.searchsorted_pair 'left').
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _kernel(thi_ref, tlo_ref, qhi_ref, qlo_ref, out_ref):
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]
    T = thi_ref.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(T, 2)))) + 1)

    def body(_, carry):
        lo_b, hi_b = carry
        mid = (lo_b + hi_b) >> 1
        mh = thi_ref[mid]
        ml = tlo_ref[mid]
        go = (mh < qhi) | ((mh == qhi) & (ml < qlo))
        lo_n = jnp.where(go & (lo_b < hi_b), mid + 1, lo_b)
        hi_n = jnp.where((~go) & (lo_b < hi_b), mid, hi_b)
        return lo_n, hi_n

    lo0 = jnp.zeros(qhi.shape, jnp.int32)
    hi0 = jnp.full(qhi.shape, T, jnp.int32)
    pos, _ = lax.fori_loop(0, steps, body, (lo0, hi0))
    out_ref[...] = pos


def pair_search_pallas(table_hi, table_lo, qhi, qlo, *, block: int = DEFAULT_BLOCK,
                       interpret: bool = False):
    """Lex-sorted table planes int32[T]; queries int32[N] -> int32[N]."""
    T = table_hi.shape[0]
    n = qhi.shape[0]
    grid = (pl.cdiv(n, block),)
    tbl = pl.BlockSpec((T,), lambda i: (0,))
    q = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[tbl, tbl, q, q],
        out_specs=q,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(table_hi, table_lo, qhi, qlo)
