"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``ref_*`` function defines the exact semantics its kernel must match;
tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-ref (interpret
mode on CPU, compiled on real TPUs).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

INVALID = jnp.int32(np.iinfo(np.int32).max)


def ref_interval_filter(s, p, o, plo, phi, olo, ohi, type_id):
    """LiteMat triple-pattern mask: p in [plo, phi) AND (o-interval applies
    only when the pattern is an rdf:type pattern, signalled by plo==type_id
    and phi==type_id+1; otherwise o in [olo, ohi) with olo=INT_MIN meaning
    'unconstrained')."""
    m = (p >= plo) & (p < phi)
    m = m & ((o >= olo) & (o < ohi))
    return m


def ref_msc_select(conc, bounds):
    """Grouped MSC: conc/bounds are (G, K) candidate concept ids (-1 pad).

    keep[g, j] = candidate j is valid and no other candidate of group g lies
    strictly inside (conc[g, j], bounds[g, j]) and no duplicate with a lower
    index exists (first occurrence wins).
    """
    valid = conc >= 0
    c1 = conc[:, :, None]  # candidate under test (j)
    b1 = bounds[:, :, None]
    c2 = conc[:, None, :]  # the other candidates (k)
    v2 = valid[:, None, :]
    strict_desc = v2 & (c2 > c1) & (c2 < b1)
    K = conc.shape[1]
    earlier = jnp.arange(K)[None, :, None] > jnp.arange(K)[None, None, :]
    dup = v2 & (c2 == c1) & earlier
    drop = (strict_desc | dup).any(axis=2)
    return valid & ~drop


def ref_closure_expand(conc, sorted_ids, anc_table):
    """For each concept id, its DAG-ancestor id row (-1 where absent/pad)."""
    pos = jnp.clip(jnp.searchsorted(sorted_ids, conc), 0, sorted_ids.shape[0] - 1)
    hit = sorted_ids[pos] == conc
    return jnp.where(hit[:, None], anc_table[pos], -1)


def ref_stream_compact(mask, block: int):
    """Tile-local stable compaction: (global match indices, per-tile counts).

    mask length must be a multiple of ``block``.  Tile t's output slice
    ``[t*block:(t+1)*block]`` holds the global indices of its set mask bits
    in ascending order, INVALID-padded — the contract of
    ``stream_compact_pallas`` / ``interval_compact_pallas``.
    """
    n = mask.shape[0]
    nb = n // block
    m = jnp.asarray(mask).astype(jnp.int32).reshape(nb, block)
    cnt = m.sum(axis=1)
    order = jnp.argsort(1 - m, axis=1, stable=True)  # matches first, in order
    gidx = jnp.arange(nb, dtype=jnp.int32)[:, None] * block + order.astype(jnp.int32)
    slot = jnp.arange(block, dtype=jnp.int32)[None, :]
    local = jnp.where(slot < cnt[:, None], gidx, INVALID)
    return local.reshape(-1), cnt.astype(jnp.int32)


def ref_dual_compact(mask_a, mask_b, block: int):
    """Two independent tile-local compactions of masks over the same rows.

    The dual-mask kernel streams the tile once and emits both streams; its
    contract is simply ``ref_stream_compact`` applied to each mask — order
    of streams preserved, no interaction between them.
    """
    la, ca = ref_stream_compact(mask_a, block)
    lb, cb = ref_stream_compact(mask_b, block)
    return la, ca, lb, cb


def ref_pair_search(table_hi, table_lo, qhi, qlo):
    """Left insertion point of each query pair in a lex-sorted pair table."""
    from repro.utils import pair64

    return pair64.searchsorted_pair(table_hi, table_lo, qhi, qlo, side="left")


def ref_merge_sorted(a_hi, a_lo, b_hi, b_lo):
    """Gather map of the stable merge of two lex-sorted (hi, lo) runs.

    out[i] < n means merged slot i holds A[out[i]]; out[i] >= n means it
    holds B[out[i] - n].  Ties place A rows before B rows (the host
    ``index.merge_sorted`` contract: searchsorted side='right' for B) —
    the semantics ``merge_path_pallas`` must match exactly.
    """
    from repro.utils import pair64

    n, m = a_hi.shape[0], b_hi.shape[0]
    if m == 0:
        return jnp.arange(n, dtype=jnp.int32)
    if n == 0:
        return jnp.arange(m, dtype=jnp.int32)
    pos_a = pair64.searchsorted_pair(b_hi, b_lo, a_hi, a_lo, side="left")
    pos_b = pair64.searchsorted_pair(a_hi, a_lo, b_hi, b_lo, side="right")
    out = jnp.zeros(n + m, dtype=jnp.int32)
    out = out.at[pos_a + jnp.arange(n, dtype=jnp.int32)].set(
        jnp.arange(n, dtype=jnp.int32))
    out = out.at[pos_b + jnp.arange(m, dtype=jnp.int32)].set(
        n + jnp.arange(m, dtype=jnp.int32))
    return out
