"""Pallas TPU kernel: grouped Most-Specific-Concept selection (paper §IV).

Input layout is the TPU-native form of the MSC pass: the data pipeline
groups each instance's candidate concepts into padded rows (G groups x K
candidate slots, -1 padding).  A candidate is kept iff no other candidate of
the same group is a strict descendant (id strictly inside its subsumption
interval) and it is not a duplicate of an earlier slot.

K is small (an instance rarely has more than a few dozen candidate types —
DBPedia averages 8), so the O(K^2) broadcast compare is ideal VPU work: a
(Bg, K, K) bool cube per tile, no gathers, no sorts.  This replaces the
sort-based one-pass scan the distributed path uses — same contract
(ref_msc_select), different memory-access pattern, chosen because on TPU the
pairwise form keeps everything in registers/VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_GROUP_BLOCK = 128


def _kernel(conc_ref, bnd_ref, keep_ref):
    c = conc_ref[...]  # (Bg, K) int32
    b = bnd_ref[...]
    valid = c >= 0
    c1 = c[:, :, None]  # candidate under test
    b1 = b[:, :, None]
    c2 = c[:, None, :]  # the other candidates
    v2 = valid[:, None, :]
    strict_desc = v2 & (c2 > c1) & (c2 < b1)
    K = c.shape[1]
    j_idx = lax.broadcasted_iota(jnp.int32, (1, K, K), 1)
    k_idx = lax.broadcasted_iota(jnp.int32, (1, K, K), 2)
    dup = v2 & (c2 == c1) & (j_idx > k_idx)  # earlier slot wins
    drop = (strict_desc | dup).any(axis=2)
    keep_ref[...] = (valid & ~drop).astype(jnp.int32)


def msc_select_pallas(conc, bounds, *, group_block: int = DEFAULT_GROUP_BLOCK,
                      interpret: bool = False):
    """conc/bounds: int32[G, K] (-1 padded) -> int32 keep mask [G, K]."""
    G, K = conc.shape
    grid = (pl.cdiv(G, group_block),)
    spec = pl.BlockSpec((group_block, K), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((G, K), jnp.int32),
        interpret=interpret,
    )(conc, bounds)
