"""Pallas TPU kernel: ELL-format SpMM (padded-neighbor message passing).

The GNN hot loop in the sampled-training regime: neighbor lists are padded
to a fixed fan-out K (exactly what the neighbor sampler emits), giving an
ELL sparse layout — (N, K) neighbor ids + (N, K) edge weights.  Each output
row accumulates K weighted feature rows.

Same scalar-prefetch DMA-steering pattern as embedding_bag: neighbor ids
drive the feature-row index_map, the out block is revisited across the K
inner grid steps.  -1 neighbors are padding (zero contribution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nbr_ref, w_ref, row_ref, out_ref):
    n = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = nbr_ref[n, k] >= 0
    w = jnp.where(valid, w_ref[n, k], 0.0).astype(out_ref.dtype)
    out_ref[...] += w * row_ref[...]


def ell_spmm_pallas(x, neighbors, weights, *, interpret: bool = False):
    """x: f32[Ns, F]; neighbors: int32[N, K]; weights: f32[N, K] -> f32[N, F]."""
    N, K = neighbors.shape
    _, F = x.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, K),
        in_specs=[
            pl.BlockSpec((1, F), lambda n, k, nbr, w: (jnp.maximum(nbr[n, k], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, F), lambda n, k, nbr, w: (n, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(neighbors, weights, x)
