"""Full RDFS materialization — the paper's baseline (Table V).

Forward-chains the RDFS rules the paper targets (rdfs2/3 domain/range,
rdfs5/7 sub-property, rdfs9/11 sub-class) in one pass: thanks to the prefix
encoding, the sub-class/sub-property closure of an id is just its DAG
ancestor row (precomputed table; pure gathers on device — no joins), and the
one candidate pass of materialize.py already folds domain/range through
effective property-ancestor tables.  Synthetic roots (our __root__ nodes,
id 0) are not materialized, matching the paper's datasets which never store
owl:Thing types.

Output is a padded, lexicographically sorted, deduplicated triple array —
the "much longer + bigger store" whose cost Table V measures.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.materialize import INVALID, DeviceTBox, candidate_types


def _dedup_rows(s, p, o):
    """Sort rows lexicographically; return sorted cols + unique&valid mask."""
    perm = jnp.lexsort((o, p, s))
    s, p, o = s[perm], p[perm], o[perm]
    valid = s != INVALID
    first = jnp.concatenate(
        [
            jnp.ones((1,), bool),
            (s[1:] != s[:-1]) | (p[1:] != p[:-1]) | (o[1:] != o[:-1]),
        ]
    )
    return s, p, o, first & valid


@jax.jit
def _full_materialize_device(spo, dtb: DeviceTBox):
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    is_type = p == dtb.rdf_type_id
    type_id = jnp.int32(dtb.rdf_type_id)

    # 1. property closure on non-type triples: (s, anc(p), o) --------------
    ppos = jnp.searchsorted(dtb.prop_sorted_ids, p)
    ppos = jnp.clip(ppos, 0, dtb.prop_sorted_ids.shape[0] - 1)
    p_known = (dtb.prop_sorted_ids[ppos] == p) & ~is_type
    pancs = dtb.prop_ancestors[ppos]  # (N, DP)
    panc_ok = p_known[:, None] & (pancs > 0)  # exclude synthetic root (id 0)
    ps = jnp.where(panc_ok, s[:, None], INVALID).reshape(-1)
    pp = jnp.where(panc_ok, pancs, INVALID).reshape(-1)
    po = jnp.where(panc_ok, o[:, None], INVALID).reshape(-1)

    # 2. type candidates (explicit + effective domain/range) ---------------
    inst, conc, _ = candidate_types(spo, dtb)
    cvalid = inst != INVALID

    # 3. concept closure on every candidate: (inst, type, anc(conc)) -------
    cpos = jnp.searchsorted(dtb.concept_sorted_ids, conc)
    cpos = jnp.clip(cpos, 0, dtb.concept_sorted_ids.shape[0] - 1)
    c_known = cvalid & (dtb.concept_sorted_ids[cpos] == conc)
    cancs = dtb.concept_ancestors[cpos]  # (M, D)
    canc_ok = c_known[:, None] & (cancs > 0)
    cs = jnp.where(canc_ok, inst[:, None], INVALID).reshape(-1)
    co = jnp.where(canc_ok, cancs, INVALID).reshape(-1)

    # 4. union + dedup ------------------------------------------------------
    all_s = jnp.concatenate([s, ps, jnp.where(cvalid, inst, INVALID), cs])
    all_p = jnp.concatenate(
        [p, pp, jnp.where(cvalid, type_id, INVALID), jnp.full(cs.shape, type_id)]
    )
    all_o = jnp.concatenate([o, po, jnp.where(cvalid, conc, INVALID), co])
    all_p = jnp.where(all_s == INVALID, INVALID, all_p)
    all_o = jnp.where(all_s == INVALID, INVALID, all_o)
    s_s, p_s, o_s, uniq = _dedup_rows(all_s, all_p, all_o)

    # original-dataset unique count (denominator of the paper's "+%")
    _, _, _, ouniq = _dedup_rows(s, p, o)
    stats = dict(
        n_closure=uniq.astype(jnp.int32).sum(),
        n_original_unique=ouniq.astype(jnp.int32).sum(),
    )
    return jnp.stack([s_s, p_s, o_s], axis=1), uniq, stats


def full_materialize(kb, dtb: DeviceTBox | None = None):
    """kb.spo -> (closed spo (sorted, padded), valid mask, stats)."""
    dtb = dtb or DeviceTBox.build(kb.tbox)
    out, valid, stats = _full_materialize_device(kb.spo, dtb)
    st = {k: int(v) for k, v in stats.items()}
    st["added_pct"] = 100.0 * (st["n_closure"] - st["n_original_unique"]) / max(
        st["n_original_unique"], 1
    )
    return out, valid, st
