"""Delta overlay: mutable state layered over immutable base triple stores.

LiteMat's interval encoding reserves unused local bits in every concept and
property id precisely so the KB can grow without re-encoding — this module
supplies the storage half of that promise.  A ``KnowledgeBase`` keeps its
base stores (raw / lite-materialized / fully-materialized) immutable and
routes every mutation through a :class:`DeltaKB`:

  * inserts append *encoded* rows to per-store :class:`DeltaLog` s
    (append-only, like an LSM memtable),
  * deletes flip per-row ``alive`` bits — tombstones — on both the base
    stores and the delta logs; nothing is ever moved until compaction.

Queries see the union through a :class:`StoreView`: host-side range lookups
run against the base :class:`StoreIndex` *and* a small delta index, and the
device work gathers from a concatenated ``[base | delta]`` view whose rows
carry a parallel liveness mask (dead rows are filtered by the stream-
compaction kernel / gather validity, never branched on).  The delta side of
the view is padded to power-of-two capacity buckets so repeated insert
batches reuse compiled executables instead of retracing XLA at every new
delta length.

``compact()`` (driven by core/engine.py) folds a delta into its base with
one sorted-merge pass per materialized permutation (index.merge_sorted) —
the base is never re-sorted, so compaction is O(delta · log base + base)
rather than a rebuild.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.index import (
    PERMUTATIONS, StoreIndex, merge_sorted, pow2_bucket as _pow2,
)

INVALID = np.int32(np.iinfo(np.int32).max)

MODES = ("rewrite", "litemat", "full")  # raw / lite / full store names


@dataclass
class DeltaLog:
    """Append-only encoded triple log with a tombstone (``alive``) mask."""

    rows: np.ndarray = field(
        default_factory=lambda: np.zeros((0, 3), dtype=np.int32))
    alive: np.ndarray = field(default_factory=lambda: np.zeros(0, dtype=bool))

    @property
    def n(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    def append(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        self.rows = np.concatenate([self.rows, rows])
        self.alive = np.concatenate(
            [self.alive, np.ones(rows.shape[0], dtype=bool)])

    def live_rows(self) -> np.ndarray:
        return self.rows[self.alive]


@dataclass
class DeltaKB:
    """Mutable overlay for one KnowledgeBase: per-store logs + base tombstones.

    ``base_alive[mode]`` stays ``None`` (meaning all-alive) until the first
    delete touches that store, so insert-only workloads never materialize or
    ship O(base) masks.
    """

    logs: dict = field(default_factory=lambda: {m: DeltaLog() for m in MODES})
    base_alive: dict = field(
        default_factory=lambda: {m: None for m in MODES})
    n_new_terms: int = 0

    def log(self, mode: str) -> DeltaLog:
        return self.logs[mode]

    def kill_base(self, mode: str, base_n: int, row_idx: np.ndarray) -> int:
        """Tombstone base rows by index; returns how many were newly killed."""
        if self.base_alive[mode] is None:
            self.base_alive[mode] = np.ones(base_n, dtype=bool)
        mask = self.base_alive[mode]
        newly = int(mask[row_idx].sum())
        mask[row_idx] = False
        return newly

    def n_rows(self, mode: str) -> int:
        return self.logs[mode].n

    @property
    def empty(self) -> bool:
        return (
            all(log.n == 0 for log in self.logs.values())
            and all(a is None for a in self.base_alive.values())
        )

    def ratio(self, base_sizes: dict) -> float:
        """Overlay pressure: (delta rows + base tombstones) / base rows."""
        num = den = 0
        for m in MODES:
            n_base = int(base_sizes.get(m, 0))
            den += n_base
            num += self.logs[m].n
            if self.base_alive[m] is not None:
                num += n_base - int(self.base_alive[m].sum())
        return num / max(den, 1)


# ---------------------------------------------------------------------------
# StoreView: what a QueryEngine executes against
# ---------------------------------------------------------------------------


@dataclass
class StoreView:
    """Union of an immutable base store and a (small) delta overlay.

    Presents the same range-lookup surface as StoreIndex, but every lookup
    returns a *list* of ranges in combined coordinates: base ranges first,
    then delta ranges offset by the base row count.  Device consumers gather
    from ``perm_rows(name)`` / ``perm_alive(name)`` (or ``scan_rows`` /
    ``scan_alive`` for full scans), which are concatenated ``[base | delta]``
    arrays with the delta padded to a power-of-two bucket — INVALID rows,
    ``alive=False`` — so executables compiled for one delta bucket serve
    every delta length up to it.
    """

    base_rows: jnp.ndarray  # device [Nb, 3] — the original store array
    base_h: np.ndarray  # host copy (shared with the base StoreIndex)
    base_alive_h: np.ndarray | None = None  # None = every base row live
    delta_h: np.ndarray | None = None  # host [M, 3] delta log rows
    delta_alive_h: np.ndarray | None = None  # bool[M]
    base_index: StoreIndex | None = None
    _delta_index: StoreIndex | None = field(default=None, repr=False)
    _dev: dict = field(default_factory=dict, repr=False)

    @classmethod
    def static(cls, spo) -> "StoreView":
        """A view over a plain store: no delta, no tombstones."""
        return cls(base_rows=jnp.asarray(spo), base_h=np.asarray(spo))

    @classmethod
    def overlay(cls, base_rows, base_index: StoreIndex,
                log: DeltaLog, base_alive: np.ndarray | None) -> "StoreView":
        # snapshot the liveness masks: deletes flip tombstone bits IN PLACE
        # on the DeltaKB arrays, and a view must stay a consistent snapshot
        # of its version even if it is held across later mutations (its
        # per-permutation device masks materialize lazily).
        return cls(
            base_rows=base_rows,
            base_h=base_index._h,
            base_alive_h=None if base_alive is None else base_alive.copy(),
            delta_h=log.rows if log.n else None,
            delta_alive_h=log.alive.copy() if log.n else None,
            base_index=base_index,
        )

    def __post_init__(self):
        if self.base_index is None:
            self.base_index = StoreIndex(_h=self.base_h)

    # -- shape bookkeeping ---------------------------------------------------
    @property
    def base_n(self) -> int:
        return int(self.base_h.shape[0])

    @property
    def delta_n(self) -> int:
        return 0 if self.delta_h is None else int(self.delta_h.shape[0])

    @property
    def delta_cap(self) -> int:
        """Power-of-two bucket the delta side is padded to (0 = no delta)."""
        return _pow2(self.delta_n) if self.delta_n else 0

    @property
    def has_delta(self) -> bool:
        return self.delta_n > 0

    @property
    def n(self) -> int:
        """Total addressable rows (planning upper bound, tombstones included)."""
        return self.base_n + self.delta_n

    @property
    def n_live(self) -> int:
        live = self.base_n if self.base_alive_h is None else int(
            self.base_alive_h.sum())
        if self.delta_alive_h is not None:
            live += int(self.delta_alive_h.sum())
        return live

    def live_rows(self) -> np.ndarray:
        """Host compaction of the view: all live rows, base-then-delta order."""
        base = (self.base_h if self.base_alive_h is None
                else self.base_h[self.base_alive_h])
        if self.delta_h is None:
            return base
        return np.concatenate([base, self.delta_h[self.delta_alive_h]])

    @property
    def delta_index(self) -> StoreIndex:
        if self._delta_index is None:
            self._delta_index = StoreIndex.build(self.delta_h)
        return self._delta_index

    # -- device views --------------------------------------------------------
    def _pad_delta_rows(self, rows: np.ndarray) -> np.ndarray:
        pad = self.delta_cap - rows.shape[0]
        if pad <= 0:
            return rows
        return np.concatenate(
            [rows, np.full((pad, 3), INVALID, dtype=np.int32)])

    def _pad_delta_alive(self, alive: np.ndarray) -> np.ndarray:
        pad = self.delta_cap - alive.shape[0]
        if pad <= 0:
            return alive
        return np.concatenate([alive, np.zeros(pad, dtype=bool)])

    @property
    def scan_rows(self) -> jnp.ndarray:
        """[Nb + Dcap, 3] device rows for full scans (INVALID-padded delta)."""
        if "scan_rows" not in self._dev:
            if self.delta_h is None:
                self._dev["scan_rows"] = self.base_rows
            else:
                self._dev["scan_rows"] = jnp.concatenate(
                    [self.base_rows,
                     jnp.asarray(self._pad_delta_rows(self.delta_h))])
        return self._dev["scan_rows"]

    @property
    def scan_alive(self) -> jnp.ndarray:
        """bool[Nb + Dcap] liveness aligned with ``scan_rows``."""
        if "scan_alive" not in self._dev:
            base = (np.ones(self.base_n, dtype=bool)
                    if self.base_alive_h is None else self.base_alive_h)
            alive = base if self.delta_h is None else np.concatenate(
                [base, self._pad_delta_alive(self.delta_alive_h)])
            self._dev["scan_alive"] = jnp.asarray(alive)
        return self._dev["scan_alive"]

    def perm_rows(self, name: str) -> jnp.ndarray:
        """[Nb + Dcap, 3] device rows in permutation order: base run | delta run."""
        key = f"{name}_rows"
        if key not in self._dev:
            base = self.base_index.perm(name).rows
            if self.delta_h is None:
                self._dev[key] = base
            else:
                drows = np.asarray(self.delta_index.perm(name).rows)
                self._dev[key] = jnp.concatenate(
                    [base, jnp.asarray(self._pad_delta_rows(drows))])
        return self._dev[key]

    def perm_alive(self, name: str) -> jnp.ndarray:
        """bool[Nb + Dcap] liveness aligned with ``perm_rows(name)``."""
        key = f"{name}_alive"
        if key not in self._dev:
            if self.base_alive_h is None:
                base = np.ones(self.base_n, dtype=bool)
            else:
                base = self.base_alive_h[self.base_index.perm(name).perm]
            if self.delta_h is None:
                alive = base
            else:
                d = self.delta_alive_h[self.delta_index.perm(name).perm]
                alive = np.concatenate([base, self._pad_delta_alive(d)])
            self._dev[key] = jnp.asarray(alive)
        return self._dev[key]

    @property
    def all_alive(self) -> bool:
        """True iff no tombstone exists anywhere in the view."""
        return (
            self.base_alive_h is None
            and (self.delta_alive_h is None or bool(self.delta_alive_h.all()))
        )

    # -- combined range lookups ---------------------------------------------
    def _combine(self, base_range, delta_range):
        out = [base_range]
        if self.has_delta:
            r0, r1 = delta_range
            out.append((self.base_n + r0, self.base_n + r1))
        return out

    def p_ranges(self, plo: int, phi: int):
        base = self.base_index.p_range(plo, phi)
        return self._combine(
            base, self.delta_index.p_range(plo, phi) if self.has_delta else None)

    def po_ranges(self, p_id: int, olo: int, ohi: int):
        return self._combine(
            self.base_index.po_range(p_id, olo, ohi),
            self.delta_index.po_range(p_id, olo, ohi) if self.has_delta else None)

    def ps_ranges(self, p_id: int, slo: int, shi: int):
        return self._combine(
            self.base_index.ps_range(p_id, slo, shi),
            self.delta_index.ps_range(p_id, slo, shi) if self.has_delta else None)

    def s_ranges(self, slo: int, shi: int):
        return self._combine(
            self.base_index.s_range(slo, shi),
            self.delta_index.s_range(slo, shi) if self.has_delta else None)

    def o_ranges(self, olo: int, ohi: int):
        return self._combine(
            self.base_index.o_range(olo, ohi),
            self.delta_index.o_range(olo, ohi) if self.has_delta else None)

    def single_p_run(self, plo: int, phi: int):
        """Unique predicate id inside [plo, phi) across base AND delta."""
        b0, b1 = self.base_index.p_range(plo, phi)
        pid = self.base_index.single_p_run(b0, b1)
        if not self.has_delta:
            return pid
        r0, r1 = self.delta_index.p_range(plo, phi)
        dpid = self.delta_index.single_p_run(r0, r1)
        if r1 <= r0:  # delta has no rows in the interval: base decides
            return pid
        if b1 <= b0:  # base empty: delta decides
            return dpid
        return pid if (pid is not None and pid == dpid) else None


# ---------------------------------------------------------------------------
# Compaction: fold a view into a fresh base store
# ---------------------------------------------------------------------------


def compact_view(view: StoreView) -> tuple[np.ndarray, StoreIndex]:
    """Merge a view's live rows into one array + pre-sorted StoreIndex.

    The merged array is produced in POS order with one sorted-merge pass
    (base POS run ⋈ delta POS run), so the returned index gets its POS
    permutation — the one every predicate/type pattern hits — for free;
    tombstones are dropped during the merge.  The other permutations stay
    lazy in the new index and re-sort on first use.
    """
    base_idx = view.base_index
    bp = base_idx.perm("pos")
    b_keep = (slice(None) if view.base_alive_h is None
              else view.base_alive_h[bp.perm])
    b_rows, b_key = np.asarray(bp.rows)[b_keep], bp.key[b_keep]
    if not view.has_delta:
        merged, _ = b_rows, b_key
        return merged, StoreIndex.from_sorted(merged, "pos")
    dp = view.delta_index.perm("pos")
    d_keep = view.delta_alive_h[dp.perm]
    merged, _ = merge_sorted(
        b_rows, b_key, np.asarray(dp.rows)[d_keep], dp.key[d_keep])
    return merged, StoreIndex.from_sorted(merged, "pos")


__all__ = ["DeltaLog", "DeltaKB", "StoreView", "compact_view", "MODES",
           "PERMUTATIONS"]
