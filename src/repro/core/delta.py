"""Delta overlay: mutable state layered over immutable base triple stores.

LiteMat's interval encoding reserves unused local bits in every concept and
property id precisely so the KB can grow without re-encoding — this module
supplies the storage half of that promise.  A ``KnowledgeBase`` keeps its
base stores (raw / lite-materialized / fully-materialized) immutable and
routes every mutation through a :class:`DeltaKB`:

  * inserts append *encoded* rows to per-store :class:`DeltaLog` s
    (append-only, like an LSM memtable),
  * deletes flip per-row ``alive`` bits — tombstones — on both the base
    stores and the delta logs; nothing is ever moved until compaction.

Queries see the union through a :class:`StoreView`: host-side range lookups
run against the base :class:`StoreIndex` *and* a small delta index, and the
device work gathers from a *virtual* ``[base | delta]`` concatenation —
``StoreView.dev(key)`` hands the executor the base array and a
power-of-two-capacity delta bucket as SEPARATE device arrays, addressed in
combined coordinates (delta rows offset by the base row count).  Because
the base array is never re-concatenated, the device work of refreshing a
view after a mutation is O(delta), not O(base):

  * :class:`DeviceStoreCache` (one per store, owned by the KnowledgeBase,
    surviving version bumps) keeps each key's delta bucket resident and
    ``lax.dynamic_update_slice`` s only the appended tail (scan order) or
    re-uploads the O(delta) bucket (permutation orders, whose sort
    interleaves on every append),
  * base tombstones are applied as point scatters of the per-version kill
    events — O(#killed), never an O(base) mask re-upload,
  * buckets are powers of two, so executables compiled for one delta
    length serve every length up to the bucket, and the buffers themselves
    are reallocated only when a bucket boundary is crossed.

``compact()`` (driven by core/engine.py) folds a delta into its base with
one sorted-merge pass per materialized permutation.  The device path runs
the merge-path Pallas kernel (kernels/merge_sorted.py) over the resident
buffers and drops tombstones with the stream-compaction kernel, so the
merged store is assembled on the accelerator; the host only pulls the
final array once to mirror it into the new StoreIndex's search keys.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.index import (
    INVALID, PERMUTATIONS, StoreIndex, merge_sorted, pad_rows as _pad_rows,
    pow2_bucket as _pow2,
)
from repro.kernels import ops
from repro.obs.metrics import REGISTRY

MODES = ("rewrite", "litemat", "full")  # raw / lite / full store names


@dataclass
class DeltaLog:
    """Append-only encoded triple log with a tombstone (``alive``) mask."""

    rows: np.ndarray = field(
        default_factory=lambda: np.zeros((0, 3), dtype=np.int32))
    alive: np.ndarray = field(default_factory=lambda: np.zeros(0, dtype=bool))
    tombstone_mut: int = 0  # bumps whenever alive bits flip (device resync)

    @property
    def n(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    def append(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        self.rows = np.concatenate([self.rows, rows])
        self.alive = np.concatenate(
            [self.alive, np.ones(rows.shape[0], dtype=bool)])

    def tombstone(self, mask_or_idx) -> None:
        """Kill log rows by bool mask or index array.

        The mut counter bumps only when a bit actually flips — a no-op
        tombstone pass must not invalidate resident device buckets (the
        counter is what DeviceStoreCache keys its O(cap) re-uploads on).
        """
        sel = self.alive[mask_or_idx]
        if sel.size == 0 or not sel.any():
            return
        self.alive[mask_or_idx] = False
        self.tombstone_mut += 1

    def live_rows(self) -> np.ndarray:
        return self.rows[self.alive]


@dataclass
class DeltaKB:
    """Mutable overlay for one KnowledgeBase: per-store logs + base tombstones.

    ``base_alive[mode]`` stays ``None`` (meaning all-alive) until the first
    delete touches that store, so insert-only workloads never materialize or
    ship O(base) masks.  ``kills[mode]`` records each delete's newly-killed
    base row indices (original store coordinates) so device caches can apply
    tombstones as point scatters instead of re-uploading O(base) masks.
    """

    logs: dict = field(default_factory=lambda: {m: DeltaLog() for m in MODES})
    base_alive: dict = field(
        default_factory=lambda: {m: None for m in MODES})
    kills: dict = field(default_factory=lambda: {m: [] for m in MODES})
    n_new_terms: int = 0

    def log(self, mode: str) -> DeltaLog:
        return self.logs[mode]

    def kill_base(self, mode: str, base_n: int, row_idx: np.ndarray) -> int:
        """Tombstone base rows by index; returns how many were newly killed."""
        row_idx = np.asarray(row_idx, dtype=np.int64).reshape(-1)
        if row_idx.size == 0:
            return 0  # never materialize the O(base) mask for a no-op
        if self.base_alive[mode] is None:
            self.base_alive[mode] = np.ones(base_n, dtype=bool)
        mask = self.base_alive[mode]
        newly = row_idx[mask[row_idx]]
        if newly.size:
            mask[newly] = False
            self.kills[mode].append(newly)
        return int(newly.size)

    def n_rows(self, mode: str) -> int:
        return self.logs[mode].n

    @property
    def empty(self) -> bool:
        return (
            all(log.n == 0 for log in self.logs.values())
            and all(a is None for a in self.base_alive.values())
        )

    def ratio(self, base_sizes: dict, extra_rows: int = 0) -> float:
        """Overlay pressure: (delta rows + base tombstones) / base rows.

        ``extra_rows`` accounts for insert batches whose lite/full
        materialization is still pending (lazy per-mode derivation).
        """
        num, den = extra_rows, 0
        for m in MODES:
            n_base = int(base_sizes.get(m, 0))
            den += n_base
            num += self.logs[m].n
            if self.base_alive[m] is not None:
                num += n_base - int(self.base_alive[m].sum())
        return num / max(den, 1)


# ---------------------------------------------------------------------------
# Device-resident [base | delta-bucket] buffers
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["base", "base_alive", "delta", "delta_alive"],
    meta_fields=[],
)
@dataclass
class DevStore:
    """One key's device arrays, addressed in combined [base | delta] coords.

    A registered pytree: executables take DevStores as traced arguments, so
    swapping in a refreshed delta bucket of the same shape reuses the
    compiled plan.  ``delta``/``delta_alive`` are ``None`` for delta-free
    views — the pytree structure then differs, so static stores compile
    single-source plans with zero overlay overhead, and the two-source
    plan is traced (once per bucket) only while a delta actually exists.
    """

    base: jnp.ndarray  # [Nb, 3] (or the scan-order store itself)
    base_alive: jnp.ndarray  # bool[Nb]
    delta: jnp.ndarray | None  # [Dcap, 3], INVALID-padded; None = no delta
    delta_alive: jnp.ndarray | None  # bool[Dcap]


def _pad_alive(alive: np.ndarray, cap: int) -> np.ndarray:
    pad = cap - alive.shape[0]
    if pad <= 0:
        return alive
    return np.concatenate([alive, np.zeros(pad, dtype=bool)])


def _delta_host(view: "StoreView", key: str):
    """(rows, alive) of the delta in ``key`` order — pure host, no uploads."""
    if key == "scan":
        return view.delta_h, view.delta_alive_h
    p = view.delta_index.perm(key)
    return view.delta_index._h[p.perm], view.delta_alive_h[p.perm]


@dataclass
class _DevState:
    """Cache entry: one (store, key) pair's resident buffers + provenance."""

    base_token: int
    base_alive: jnp.ndarray
    n_kills: int
    delta: jnp.ndarray
    delta_alive: jnp.ndarray
    cap: int
    delta_len: int
    tombstone_mut: int
    owns_alive: bool = False  # True once base_alive is a private buffer
    leased: bool = False  # True while a pinned snapshot may still hold
    # this base_alive buffer: the next kill batch must copy-then-donate
    # instead of donating the leased buffer out from under the snapshot


@partial(jax.jit, donate_argnums=(0,))
def _kill_scatter(alive, idx):
    """Tombstone point scatter with the alive buffer DONATED.

    Donation lets XLA flip the bits IN PLACE instead of realizing the
    ``.at[].set`` as an O(base) copy-then-scatter — a delete batch then
    costs O(#killed) device work AND zero base-sized allocations.  ``idx``
    is padded to a power-of-two bucket with out-of-range indices (dropped
    by the scatter) so kill batches of any size share a few executables.
    """
    return alive.at[idx].set(False, mode="drop")


def _pad_kill_idx(idx: np.ndarray, n: int) -> jnp.ndarray:
    """Kill indices -> pow2-padded int32 device array (pad rows dropped)."""
    cap = _pow2(idx.shape[0])
    pad = np.full(cap - idx.shape[0], n, dtype=np.int64)
    return jnp.asarray(np.concatenate([idx, pad]).astype(np.int32))


class DeviceStoreCache:
    """Per-store persistent device buffers, surviving KnowledgeBase versions.

    ``sync(view, key)`` brings the key's buffers up to the view's state with
    work *independent of the base size*: delta buckets are updated in place
    (appended tail for scan order, O(cap) re-upload for permutation orders)
    and base tombstones are applied as point scatters of the recorded kill
    events.  ``stats`` counts every host->device transfer in row units so
    tests/benchmarks can pin the O(delta) contract.
    """

    def __init__(self):
        self._states: dict = {}
        self._ones: dict = {}  # (token, n) -> shared all-alive mask
        self._lock = threading.RLock()  # sync() is reader-reentrant
        self.stats = {
            "base_rebuilds": 0,  # fresh states (new base / first touch)
            "delta_allocs": 0,  # delta bucket (re)allocations
            "upload_delta_rows": 0,  # delta rows shipped host->device
            "upload_alive_rows": 0,  # delta liveness bits shipped
            "upload_base_alive_rows": 0,  # full base masks shipped (fresh only)
            "kill_scatter_rows": 0,  # base tombstones applied as scatters
            "alive_privatize_rows": 0,  # one-time shared-mask copies (first
            # delete against a key whose resident mask is the SHARED
            # all-alive buffer; donation needs a private one)
            "lease_copy_rows": 0,  # copies forced by a pinned snapshot
            # leasing the resident mask (donation would invalidate it)
            "stale_view_builds": 0,  # one-off builds for out-of-date views
        }

    def _stat(self, key: str, n: int = 1) -> None:
        """Bump the local dict AND the process registry mirror.

        Row-unit upload counters also feed ``device/transfer_bytes``
        (12 B per [s,p,o] int32 row, 1 B per liveness bit) so the
        observability layer sees host->device traffic in one unit.
        """
        self.stats[key] += n
        REGISTRY.counter("device/" + key, src="store_cache").inc(n)
        if key == "upload_delta_rows":
            REGISTRY.counter("device/transfer_bytes",
                             src="store_cache").inc(n * 12)
        elif key in ("upload_alive_rows", "upload_base_alive_rows"):
            REGISTRY.counter("device/transfer_bytes",
                             src="store_cache").inc(n)

    def _all_alive(self, token: int, n: int) -> jnp.ndarray:
        key = (token, n)
        if key not in self._ones:
            # evict masks of superseded bases: without this, every
            # compaction (new token) would pin another O(base) device
            # array here for the cache's lifetime
            self._ones = {k: v for k, v in self._ones.items()
                          if k[0] == token}
            self._ones[key] = jnp.ones(n, dtype=bool)
        return self._ones[key]

    def _upload_delta(self, view: "StoreView", key: str, cap: int):
        if not view.has_delta:
            return None, None  # delta-free: single-source executables
        rows, alive = _delta_host(view, key)
        self._stat("upload_delta_rows", cap)
        self._stat("upload_alive_rows", cap)
        self._stat("delta_allocs")
        return (jnp.asarray(_pad_rows(rows, cap)),
                jnp.asarray(_pad_alive(alive, cap)))

    def _base_arrays(self, view: "StoreView", key: str):
        if key == "scan":
            return view.base_rows
        return view.base_index.perm(key).rows

    def _fresh(self, view: "StoreView", key: str, cap: int) -> _DevState:
        self._stat("base_rebuilds")
        token = view.base_index.token
        if view.base_alive_h is None:
            base_alive = self._all_alive(token, view.base_n)
        else:
            alive_h = (view.base_alive_h if key == "scan"
                       else view.base_alive_h[view.base_index.perm(key).perm])
            self._stat("upload_base_alive_rows", view.base_n)
            base_alive = jnp.asarray(alive_h)
        delta, dalive = self._upload_delta(view, key, cap)
        return _DevState(
            base_token=token, base_alive=base_alive,
            n_kills=len(view.kills), delta=delta, delta_alive=dalive,
            cap=cap if delta is not None else 0, delta_len=view.delta_n,
            tombstone_mut=view.delta_mut,
            owns_alive=view.base_alive_h is not None,
        )

    def sync(self, view: "StoreView", key: str) -> DevStore:
        # one writer xor many readers reach here concurrently only through
        # pinned snapshots; the lock makes resident-state updates atomic so
        # a reader can never observe a half-applied delta splice
        with self._lock:
            return self._sync_locked(view, key)

    def _sync_locked(self, view: "StoreView", key: str) -> DevStore:
        base = self._base_arrays(view, key)
        token = view.base_index.token
        cap = _pow2(view.delta_n) if view.has_delta else 0
        st = self._states.get(key)

        if st is not None and (
                token < st.base_token  # tokens are monotonic: older base
                or (st.base_token == token and (
                    view.delta_n < st.delta_len
                    or len(view.kills) < st.n_kills
                    or view.delta_mut < st.tombstone_mut))):
            # a view older than the resident state (held across later
            # mutations or a compaction): serve it a one-off build, never
            # rewind the cache — rewinding would make alternating
            # old-snapshot/live queries thrash O(base) rebuilds
            self._stat("stale_view_builds")
            return _one_off_dev(view, key, base)

        if st is None or st.base_token != token:
            st = self._fresh(view, key, cap)
            self._states[key] = st
        else:
            if cap != st.cap:
                # bucket boundary crossed (or first delta after an empty
                # state): reallocate the delta bucket (O(new cap)); the
                # base array is untouched either way
                st.delta, st.delta_alive = self._upload_delta(view, key, cap)
                st.cap, st.delta_len = cap, view.delta_n
                st.tombstone_mut = view.delta_mut
            elif st.delta is not None and (
                    view.delta_n != st.delta_len
                    or view.delta_mut != st.tombstone_mut):
                grew = view.delta_n - st.delta_len
                if grew > 0:
                    if key == "scan":
                        # append order: splice ONLY the appended tail
                        tail = np.asarray(view.delta_h[st.delta_len:],
                                          dtype=np.int32)
                        st.delta = lax.dynamic_update_slice(
                            st.delta, jnp.asarray(tail), (st.delta_len, 0))
                        self._stat("upload_delta_rows", grew)
                    else:
                        rows, _ = _delta_host(view, key)
                        st.delta = jnp.asarray(_pad_rows(rows, cap))
                        self._stat("upload_delta_rows", cap)
                # grew == 0 means a tombstone-only change: the log is
                # append-only, so the resident ROW buckets are already
                # correct in every order — refresh just the alive bits
                _, alive = _delta_host(view, key)
                st.delta_alive = jnp.asarray(_pad_alive(alive, cap))
                self._stat("upload_alive_rows", cap)
                st.delta_len = view.delta_n
                st.tombstone_mut = view.delta_mut
            if len(view.kills) > st.n_kills:
                idx = np.concatenate(view.kills[st.n_kills:])
                if key != "scan":
                    idx = view.base_index.inv_perm(key)[idx]
                if not st.owns_alive or st.leased:
                    # resident mask is either the SHARED all-alive buffer or
                    # LEASED to a pinned snapshot: copy it once so the kill
                    # batch donates a private buffer — the snapshot (or the
                    # shared mask) keeps the original, and every later kill
                    # donates the copy back in place at zero extra cost
                    stat = ("lease_copy_rows" if st.owns_alive
                            else "alive_privatize_rows")
                    st.base_alive = jnp.array(st.base_alive)
                    st.owns_alive = True
                    st.leased = False
                    self._stat(stat, int(st.base_alive.shape[0]))
                st.base_alive = _kill_scatter(
                    st.base_alive,
                    _pad_kill_idx(idx, int(st.base_alive.shape[0])))
                self._stat("kill_scatter_rows", int(idx.shape[0]))
                st.n_kills = len(view.kills)

        if view.pinned:
            # a pinned snapshot now references the resident buffers: mark
            # the base mask leased so the next delete copies instead of
            # donating it out from under the snapshot's DevStore
            st.leased = True
        return DevStore(base=base, base_alive=st.base_alive,
                        delta=st.delta, delta_alive=st.delta_alive)

    def buffer_shapes(self, key: str):
        """(delta bucket shape, capacity) — test hook for the O(delta) pins."""
        st = self._states.get(key)
        if st is None:
            return None
        shape = (0, 3) if st.delta is None else tuple(st.delta.shape)
        return shape, st.cap

    def device_buffers(self) -> list:
        """Resident device buffers as (component, id, nbytes) records.

        The :class:`~repro.obs.ledger.ResourceLedger` feed: pow2 delta
        buckets under ``delta``, liveness masks (delta, privatized base,
        and the shared all-alive buffers) under ``alive``.  Ids let the
        ledger dedupe buffers shared across owners (e.g. a snapshot still
        leasing a resident mask).  Side-effect-free: walks existing
        state, never materializes anything.
        """
        out = []
        with self._lock:
            for st in self._states.values():
                if st.delta is not None:
                    out.append(("delta", id(st.delta), st.delta.nbytes))
                    out.append(("alive", id(st.delta_alive),
                                st.delta_alive.nbytes))
                if st.owns_alive:
                    out.append(("alive", id(st.base_alive),
                                st.base_alive.nbytes))
            for mask in self._ones.values():
                out.append(("alive", id(mask), mask.nbytes))
        return out


def _one_off_dev(view: "StoreView", key: str, base) -> DevStore:
    """Cacheless DevStore build (static views, stale snapshots, tests)."""
    if view.base_alive_h is None:
        base_alive = jnp.ones(view.base_n, dtype=bool)
    else:
        alive_h = (view.base_alive_h if key == "scan"
                   else view.base_alive_h[view.base_index.perm(key).perm])
        base_alive = jnp.asarray(alive_h)
    if not view.has_delta:
        delta = dalive = None
    else:
        cap = _pow2(view.delta_n)
        rows, alive = _delta_host(view, key)
        delta = jnp.asarray(_pad_rows(rows, cap))
        dalive = jnp.asarray(_pad_alive(alive, cap))
    return DevStore(base=base, base_alive=base_alive,
                    delta=delta, delta_alive=dalive)


# ---------------------------------------------------------------------------
# StoreView: what a QueryEngine executes against
# ---------------------------------------------------------------------------


@dataclass
class StoreView:
    """Union of an immutable base store and a (small) delta overlay.

    Presents the same range-lookup surface as StoreIndex, but every lookup
    returns a *list* of ranges in combined coordinates: base ranges first,
    then delta ranges offset by the base row count.  Device consumers call
    ``dev(key)`` for the matching :class:`DevStore` — base array plus a
    power-of-two delta bucket as separate device arrays (INVALID rows and
    ``alive=False`` padding), so executables compiled for one delta bucket
    serve every delta length up to it and a mutation never re-concatenates
    the base on device.
    """

    base_rows: jnp.ndarray  # device [Nb, 3] — the original store array
    base_h: np.ndarray  # host copy (shared with the base StoreIndex)
    base_alive_h: np.ndarray | None = None  # None = every base row live
    delta_h: np.ndarray | None = None  # host [M, 3] delta log rows
    delta_alive_h: np.ndarray | None = None  # bool[M]
    base_index: StoreIndex | None = None
    cache: DeviceStoreCache | None = None  # persistent device buffers
    kills: tuple = ()  # snapshot of DeltaKB.kills[mode] (original coords)
    delta_mut: int = 0  # DeltaLog.tombstone_mut at snapshot time
    pinned: bool = False  # held by a Snapshot: cache leases (never donates)
    # any resident buffer it hands this view — see DeviceStoreCache.sync
    _delta_index: StoreIndex | None = field(default=None, repr=False)
    _dev: dict = field(default_factory=dict, repr=False)

    @classmethod
    def static(cls, spo) -> "StoreView":
        """A view over a plain store: no delta, no tombstones."""
        return cls(base_rows=jnp.asarray(spo), base_h=np.asarray(spo))

    @classmethod
    def overlay(cls, base_rows, base_index: StoreIndex,
                log: DeltaLog, base_alive: np.ndarray | None,
                cache: DeviceStoreCache | None = None,
                kills: tuple = ()) -> "StoreView":
        # snapshot the liveness masks: deletes flip tombstone bits IN PLACE
        # on the DeltaKB arrays, and a view must stay a consistent snapshot
        # of its version even if it is held across later mutations (its
        # per-permutation device buffers materialize lazily).
        return cls(
            base_rows=base_rows,
            base_h=base_index._h,
            base_alive_h=None if base_alive is None else base_alive.copy(),
            delta_h=log.rows if log.n else None,
            delta_alive_h=log.alive.copy() if log.n else None,
            base_index=base_index,
            cache=cache,
            kills=tuple(kills),
            delta_mut=log.tombstone_mut,
        )

    def __post_init__(self):
        if self.base_index is None:
            self.base_index = StoreIndex(_h=self.base_h)

    # -- shape bookkeeping ---------------------------------------------------
    @property
    def base_n(self) -> int:
        return int(self.base_h.shape[0])

    @property
    def delta_n(self) -> int:
        return 0 if self.delta_h is None else int(self.delta_h.shape[0])

    @property
    def delta_cap(self) -> int:
        """Power-of-two bucket the delta side is padded to on device."""
        return _pow2(self.delta_n)

    @property
    def has_delta(self) -> bool:
        return self.delta_n > 0

    @property
    def n(self) -> int:
        """Total addressable rows (planning upper bound, tombstones included)."""
        return self.base_n + self.delta_n

    @property
    def n_live(self) -> int:
        live = self.base_n if self.base_alive_h is None else int(
            self.base_alive_h.sum())
        if self.delta_alive_h is not None:
            live += int(self.delta_alive_h.sum())
        return live

    def live_rows(self) -> np.ndarray:
        """Host compaction of the view: all live rows, base-then-delta order."""
        base = (self.base_h if self.base_alive_h is None
                else self.base_h[self.base_alive_h])
        if self.delta_h is None:
            return base
        return np.concatenate([base, self.delta_h[self.delta_alive_h]])

    @property
    def delta_index(self) -> StoreIndex:
        if self._delta_index is None:
            self._delta_index = StoreIndex.build(self.delta_h)
        return self._delta_index

    # -- device views --------------------------------------------------------
    def dev(self, key: str) -> DevStore:
        """Device arrays of one view key ('scan' or a permutation name).

        Routed through the owning store's :class:`DeviceStoreCache` when one
        is attached (the live KnowledgeBase path — O(delta) refresh);
        otherwise built once per view and memoized (static stores, tests).
        """
        if self.cache is not None:
            return self.cache.sync(self, key)
        if key not in self._dev:
            base = (self.base_rows if key == "scan"
                    else self.base_index.perm(key).rows)
            self._dev[key] = _one_off_dev(self, key, base)
        return self._dev[key]

    def warm_device(self, keys=("scan", "pos")):
        """Materialize device buffers for ``keys``; returns them (blocking).

        The benchmarkable unit of post-mutation warmup: everything a first
        query needs beyond cached executables.
        """
        import jax

        out = [self.dev(k) for k in keys]
        for ds in out:
            jax.block_until_ready([a for a in (ds.base, ds.base_alive,
                                               ds.delta, ds.delta_alive)
                                   if a is not None])
        return out

    def device_buffers(self) -> list:
        """Device buffers this view references — ledger feed records.

        Covers the base store array and any one-off :class:`DevStore`
        memos (static views, stale snapshots); cache-routed buffers are
        reported by the owning :class:`DeviceStoreCache` instead.  Ids
        dedupe the walk against other owners of the same arrays.
        """
        out = [("base", id(self.base_rows), self.base_rows.nbytes)]
        if self.base_index is not None:
            for p in self.base_index._perms.values():
                out.append(("base", id(p.rows), p.rows.nbytes))
        for ds in self._dev.values():
            out.append(("base", id(ds.base), ds.base.nbytes))
            out.append(("alive", id(ds.base_alive), ds.base_alive.nbytes))
            if ds.delta is not None:
                out.append(("delta", id(ds.delta), ds.delta.nbytes))
                out.append(("alive", id(ds.delta_alive),
                            ds.delta_alive.nbytes))
        return out

    @property
    def all_alive(self) -> bool:
        """True iff no tombstone exists anywhere in the view."""
        return (
            self.base_alive_h is None
            and (self.delta_alive_h is None or bool(self.delta_alive_h.all()))
        )

    # -- combined range lookups ---------------------------------------------
    def _combine(self, base_range, delta_range):
        out = [base_range]
        if self.has_delta:
            r0, r1 = delta_range
            out.append((self.base_n + r0, self.base_n + r1))
        return out

    def p_ranges(self, plo: int, phi: int):
        base = self.base_index.p_range(plo, phi)
        return self._combine(
            base, self.delta_index.p_range(plo, phi) if self.has_delta else None)

    def po_ranges(self, p_id: int, olo: int, ohi: int):
        return self._combine(
            self.base_index.po_range(p_id, olo, ohi),
            self.delta_index.po_range(p_id, olo, ohi) if self.has_delta else None)

    def ps_ranges(self, p_id: int, slo: int, shi: int):
        return self._combine(
            self.base_index.ps_range(p_id, slo, shi),
            self.delta_index.ps_range(p_id, slo, shi) if self.has_delta else None)

    def s_ranges(self, slo: int, shi: int):
        return self._combine(
            self.base_index.s_range(slo, shi),
            self.delta_index.s_range(slo, shi) if self.has_delta else None)

    def o_ranges(self, olo: int, ohi: int):
        return self._combine(
            self.base_index.o_range(olo, ohi),
            self.delta_index.o_range(olo, ohi) if self.has_delta else None)

    def distinct_p_ids(self, plo: int, phi: int, limit: int = 8):
        """Distinct predicate ids in [plo, phi) across base AND delta.

        None when either side is too mixed (past ``limit``) — the
        index-nested-loop planner then leaves the pattern on its
        slice/scan strategy.
        """
        base = self.base_index.distinct_p_ids(plo, phi, limit)
        if base is None:
            return None
        if not self.has_delta:
            return base
        extra = self.delta_index.distinct_p_ids(plo, phi, limit)
        if extra is None:
            return None
        out = sorted(set(base) | set(extra))
        return out if len(out) <= limit else None

    def single_p_run(self, plo: int, phi: int):
        """Unique predicate id inside [plo, phi) across base AND delta."""
        b0, b1 = self.base_index.p_range(plo, phi)
        pid = self.base_index.single_p_run(b0, b1)
        if not self.has_delta:
            return pid
        r0, r1 = self.delta_index.p_range(plo, phi)
        dpid = self.delta_index.single_p_run(r0, r1)
        if r1 <= r0:  # delta has no rows in the interval: base decides
            return pid
        if b1 <= b0:  # base empty: delta decides
            return dpid
        return pid if (pid is not None and pid == dpid) else None


# ---------------------------------------------------------------------------
# Compaction: fold a view into a fresh base store
# ---------------------------------------------------------------------------


def compact_view(view: StoreView, device: bool = False):
    """Merge a view's live rows -> (device rows, pre-sorted StoreIndex).

    The merged array is produced in POS order with one sorted-merge pass
    (base POS run ⋈ delta POS run), so the returned index gets its POS
    permutation — the one every predicate/type pattern hits — for free;
    tombstones are dropped during the merge.  The other permutations stay
    lazy in the new index and re-sort on first use.

    ``device=True`` runs the merge on the accelerator: the merge-path
    Pallas kernel computes the interleave over the resident [base | delta]
    buffers, the stream-compaction kernel drops tombstones, and the merged
    store is materialized by device gathers — bit-identical to the host
    path (pinned by tests), with the host only pulling the finished array
    once to mirror it into the new index's search keys.
    """
    if device:
        return _compact_view_device(view)
    base_idx = view.base_index
    bp = base_idx.perm("pos")
    b_keep = (slice(None) if view.base_alive_h is None
              else view.base_alive_h[bp.perm])
    b_rows, b_key = np.asarray(bp.rows)[b_keep], bp.key[b_keep]
    if not view.has_delta:
        merged = b_rows
        idx = StoreIndex.from_sorted(merged, "pos")
        return idx.perm("pos").rows, idx
    dp = view.delta_index.perm("pos")
    d_keep = view.delta_alive_h[dp.perm]
    merged, _ = merge_sorted(
        b_rows, b_key, np.asarray(dp.rows)[d_keep], dp.key[d_keep])
    idx = StoreIndex.from_sorted(merged, "pos")
    return idx.perm("pos").rows, idx


def _compact_view_device(view: StoreView):
    """Device-side compaction over the resident POS buffers."""
    ds = view.dev("pos")
    if ds.delta is None:  # tombstone-only fold: no merge, just compact
        dk = jnp.zeros((0,), dtype=jnp.int32)
        gidx = ops.merge_gather(ds.base[:, 1], ds.base[:, 2], dk, dk)
        alive = ops.two_source_gather(ds.base_alive, None, gidx)
    else:
        # merge EVERYTHING (tombstones and bucket padding included: INVALID
        # keys sort last and are dead) then compact by liveness — a stable
        # merge followed by a stable filter equals the merge of the
        # filtered runs.
        gidx = ops.merge_gather(ds.base[:, 1], ds.base[:, 2],
                                ds.delta[:, 1], ds.delta[:, 2])
        alive = ops.two_source_gather(ds.base_alive, ds.delta_alive, gidx)
    n_live = view.n_live
    take, _, _ = ops.compact_indices(alive, _pow2(n_live))
    src = gidx[take]
    merged_dev = ops.two_source_gather(ds.base, ds.delta, src)[:n_live]
    merged_h = np.asarray(merged_dev)
    idx = StoreIndex.from_sorted(merged_h, "pos", dev_rows=merged_dev)
    return merged_dev, idx


__all__ = ["DeltaLog", "DeltaKB", "StoreView", "DevStore", "DeviceStoreCache",
           "compact_view", "MODES", "PERMUTATIONS"]
