"""Host-side taxonomy machinery (the paper's HermiT-classification stage).

The paper feeds the ontology through an OWL reasoner (HermiT) to obtain the
*inferred* entity hierarchy before encoding.  We implement the RDFS-level
fragment of that classification ourselves:

  * transitive closure of subClassOf / subPropertyOf,
  * equivalence-cycle merging (A <= B <= A  =>  same encoding slot),
  * attachment of parentless entities under the root (owl:Thing / the
    property root),
  * DAG -> tree reduction for the bit-prefix encoder: each node keeps its
    *deepest* parent as the primary (tree) parent; remaining non-redundant
    parents become *secondary edges*.  Secondary edges are what multiple
    inheritance leaves behind; the encoder turns them into per-concept
    "spill intervals" so interval queries stay complete (DESIGN.md §2.2).

Everything here is plain Python/numpy on the host — it mirrors the paper's
single-machine TBox stage.  The *encoding* itself (tbox.py) additionally has
a parallel JAX path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

ROOT = "__root__"


@dataclass
class Taxonomy:
    """A classified entity hierarchy, ready for prefix encoding.

    ``parent[i]`` is the primary (tree) parent index, -1 for the root.
    ``secondary`` holds the remaining direct-parent edges ``(child, parent)``
    that the tree could not represent.  ``merged`` maps each original name to
    its representative (equivalence classes from subsumption cycles).
    """

    names: list  # representative names, index == node id; names[0] == ROOT
    parent: np.ndarray  # int32[C] primary parent index
    depth: np.ndarray  # int32[C] depth in the *tree* (root = 0)
    secondary: list  # list[(child_idx, parent_idx)]
    merged: dict  # original name -> representative name
    index: dict = field(default_factory=dict)  # representative name -> idx

    def __post_init__(self):
        if not self.index:
            self.index = {n: i for i, n in enumerate(self.names)}

    @property
    def n(self) -> int:
        return len(self.names)

    def children(self):
        """children[i] = sorted list of primary children of i."""
        ch = [[] for _ in range(self.n)]
        for i, p in enumerate(self.parent.tolist()):
            if p >= 0:
                ch[p].append(i)
        return ch

    def idx_of(self, name: str) -> int:
        return self.index[self.merged.get(name, name)]

    def dag_parents(self):
        """parents[i] = all direct parents (primary + secondary)."""
        par = [[] for _ in range(self.n)]
        for i, p in enumerate(self.parent.tolist()):
            if p >= 0:
                par[i].append(p)
        for c, p in self.secondary:
            par[c].append(p)
        return par

    def dag_ancestors(self, i: int) -> set:
        """All strict DAG ancestors of node i (primary + secondary edges)."""
        par = self.dag_parents()
        seen, stack = set(), [i]
        while stack:
            for p in par[stack.pop()]:
                if p not in seen:
                    seen.add(p)
                    stack.append(p)
        return seen

    def dag_descendants(self, i: int) -> set:
        """All strict DAG descendants of node i."""
        ch = [[] for _ in range(self.n)]
        for c, ps in enumerate(self.dag_parents()):
            for p in ps:
                ch[p].append(c)
        seen, stack = set(), [i]
        while stack:
            for c in ch[stack.pop()]:
                if c not in seen:
                    seen.add(c)
                    stack.append(c)
        return seen


def _tarjan_scc(n: int, adj) -> np.ndarray:
    """Iterative Tarjan; returns comp[i] = SCC id (reverse topological)."""
    index = np.full(n, -1, dtype=np.int64)
    low = np.zeros(n, dtype=np.int64)
    on_stack = np.zeros(n, dtype=bool)
    comp = np.full(n, -1, dtype=np.int64)
    stack: list = []
    next_index = 0
    n_comp = 0
    for root in range(n):
        if index[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = next_index
                next_index += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            for j in range(pi, len(adj[v])):
                w = adj[v][j]
                if index[w] == -1:
                    work[-1] = (v, j + 1)
                    work.append((w, 0))
                    recurse = True
                    break
                elif on_stack[w]:
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp[w] = n_comp
                    if w == v:
                        break
                n_comp += 1
            work.pop()
            if work:
                u, _ = work[-1]
                low[u] = min(low[u], low[v])
    return comp


def build_taxonomy(entities, sub_edges, root_name: str = ROOT) -> Taxonomy:
    """Classify (entity names, (sub, super) axioms) into a Taxonomy.

    This is the reasoner-lite stage: cycles are merged into equivalence
    classes, parentless entities hang off the root, transitively-redundant
    direct parents are dropped, and the deepest remaining parent becomes the
    tree parent.
    """
    names = list(dict.fromkeys([root_name, *entities]))
    for s, o in sub_edges:
        for t in (s, o):
            if t not in names:
                names.append(t)
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)

    # --- SCC merge (equivalence cycles) -----------------------------------
    adj = [[] for _ in range(n)]
    for s, o in sub_edges:
        if s != o:
            adj[idx[s]].append(idx[o])
    comp = _tarjan_scc(n, adj)
    # representative of each SCC = smallest original index (keeps ROOT first)
    rep_of_comp: dict = {}
    for i in range(n):
        c = int(comp[i])
        if c not in rep_of_comp or i < rep_of_comp[c]:
            rep_of_comp[c] = i
    merged = {}
    for i in range(n):
        r = rep_of_comp[int(comp[i])]
        if r != i:
            merged[names[i]] = names[r]

    kept = sorted({rep_of_comp[int(c)] for c in comp})
    remap = {old: new for new, old in enumerate(kept)}
    rep_names = [names[i] for i in kept]
    root = remap[idx[root_name]]
    assert root == 0, "root must stay at index 0"
    m = len(kept)

    # --- direct-parent sets on the merged DAG -----------------------------
    parents = [set() for _ in range(m)]
    for s, o in sub_edges:
        si = remap[rep_of_comp[int(comp[idx[s]])]]
        oi = remap[rep_of_comp[int(comp[idx[o]])]]
        if si != oi:
            parents[si].add(oi)
    for i in range(m):
        if i != root and not parents[i]:
            parents[i].add(root)

    # --- longest-path depth (topological over the DAG) --------------------
    children = [set() for _ in range(m)]
    indeg = np.zeros(m, dtype=np.int64)
    for c in range(m):
        for p in parents[c]:
            children[p].add(c)
            indeg[c] += 1
    depth = np.zeros(m, dtype=np.int32)
    queue = [i for i in range(m) if indeg[i] == 0]
    order = []
    while queue:
        v = queue.pop()
        order.append(v)
        for c in children[v]:
            depth[c] = max(depth[c], depth[v] + 1)
            indeg[c] -= 1
            if indeg[c] == 0:
                queue.append(c)
    if len(order) != m:
        raise ValueError("cycle survived SCC merge — classification bug")

    # --- transitive reduction of direct parents, primary = deepest --------
    anc_cache: dict = {}

    def ancestors(i: int) -> set:
        if i in anc_cache:
            return anc_cache[i]
        acc = set()
        for p in parents[i]:
            acc.add(p)
            acc |= ancestors(p)
        anc_cache[i] = acc
        return acc

    parent_arr = np.full(m, -1, dtype=np.int32)
    secondary = []
    for i in range(m):
        if i == root:
            continue
        ps = parents[i]
        # drop parents that are ancestors of another parent (redundant)
        reduced = {p for p in ps if not any(p in ancestors(q) for q in ps if q != p)}
        primary = max(reduced, key=lambda p: (int(depth[p]), -p))
        parent_arr[i] = primary
        for p in sorted(reduced - {primary}):
            secondary.append((i, p))

    # tree depth (may differ from DAG depth once secondary edges are split)
    tree_depth = np.zeros(m, dtype=np.int32)
    for v in order:
        p = parent_arr[v]
        if p >= 0:
            tree_depth[v] = tree_depth[p] + 1

    return Taxonomy(
        names=rep_names,
        parent=parent_arr,
        depth=tree_depth,
        secondary=secondary,
        merged=merged,
    )
