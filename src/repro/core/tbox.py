"""TBox encoding — the paper's §III.A, plus a parallel (JAX) encoder.

Given a classified Taxonomy (hierarchy.py), assign each entity a prefix-
coded integer id:

  * a node with N primary children reserves ``ceil(log2(N+1))`` bits for its
    child slots (local code 0 = the node itself, children get 1..N),
  * ids are left-aligned in ``total_bits`` and zero-padded on the right,
  * descendants of A therefore occupy exactly ``[idA, idA + 2**(total_bits -
    used_bits(A)))`` — the paper's ``bound`` function.

Two encoders produce bit-identical results:

  * ``encode_hierarchy``          — host numpy / Python bigints (reference;
                                     also the only path for >62-bit codes).
  * ``encode_hierarchy_parallel`` — level-synchronous JAX implementation
                                     (segment ranks + prefix reductions) that
                                     removes the paper's single-machine TBox
                                     bottleneck (their Wikidata case: 122 s).

Multiple inheritance: the tree encoder covers primary edges; every secondary
edge contributes *spill intervals* (extra [lo, hi) ranges per concept) so
that ``subsumes(a, b)`` remains complete on DAGs (DESIGN.md §2.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.hierarchy import ROOT, Taxonomy, build_taxonomy
from repro.core.intervals import pack_wide, words_needed

MAX_NARROW_BITS = 62  # beyond this we only keep bigint + wide-word forms


# ---------------------------------------------------------------------------
# Ontology (host axiom container — what the .owl file boils down to)
# ---------------------------------------------------------------------------


@dataclass
class Ontology:
    """RDFS-level ontology: hierarchies + property domain/range axioms."""

    concepts: list
    properties: list
    subclass: list = field(default_factory=list)  # (sub, sup) names
    subprop: list = field(default_factory=list)  # (sub, sup) names
    domain: dict = field(default_factory=dict)  # prop -> set/list of concepts
    range_: dict = field(default_factory=dict)  # prop -> set/list of concepts

    def stats(self):
        return dict(
            n_concepts=len(self.concepts),
            n_properties=len(self.properties),
            n_subclass=len(self.subclass),
            n_subprop=len(self.subprop),
            n_domain=sum(len(v) for v in self.domain.values()),
            n_range=sum(len(v) for v in self.range_.values()),
        )


# ---------------------------------------------------------------------------
# Encoded hierarchy
# ---------------------------------------------------------------------------


@dataclass
class EncodedHierarchy:
    """One encoded entity hierarchy (concepts or properties)."""

    tax: Taxonomy
    total_bits: int
    ids: np.ndarray  # int64[C] in node order (valid iff total_bits <= 62)
    used_bits: np.ndarray  # int32[C] in node order
    bounds: np.ndarray  # int64[C] in node order
    # device-friendly, sorted-by-id views -----------------------------------
    sorted_ids: np.ndarray
    sorted_bounds: np.ndarray
    sorted_used: np.ndarray
    sorted_ancestors: np.ndarray  # int64[C, D] DAG-ancestor ids, -1 padded
    sorted_spill_lo: np.ndarray  # int64[C, S] secondary-edge intervals
    sorted_spill_hi: np.ndarray
    # wide form (always present; required when total_bits > 62) -------------
    ids_big: list  # Python bigints, node order (exact for any width)
    wide_words: int
    ids_wide: np.ndarray  # int32[C, W]
    bounds_wide: np.ndarray  # int32[C, W]
    spill_big: dict  # node -> [(lo, hi) bigints] secondary-edge intervals

    def __post_init__(self):
        self.narrow = self.total_bits <= MAX_NARROW_BITS
        self.name_to_id = {n: self.ids_big[i] for i, n in enumerate(self.tax.names)}
        self._id_to_node = {v: i for i, v in enumerate(self.ids_big)}

    # -- host conveniences ---------------------------------------------------
    @property
    def n(self) -> int:
        return self.tax.n

    def id_of(self, name: str) -> int:
        return self.name_to_id[self.tax.merged.get(name, name)]

    def name_of(self, ident: int) -> str:
        return self.tax.names[self._id_to_node[int(ident)]]

    def interval_of(self, name: str):
        """Primary [lo, hi) + spill intervals — everything name subsumes."""
        node = self.tax.idx_of(name)
        lo = self.ids_big[node]
        hi = lo + (1 << (self.total_bits - int(self.used_bits[node])))
        spills = [(a, b) for a, b in self.spill_big.get(node, []) if a < b]
        return (lo, hi), spills

    def subsumees(self, name: str):
        """All entity ids subsumed by ``name`` (incl. itself) — host oracle."""
        (lo, hi), spills = self.interval_of(name)
        out = []
        for v in self.ids_big:
            if lo <= v < hi or any(a <= v < b for a, b in spills):
                out.append(v)
        return sorted(set(out))

    def max_spills(self) -> int:
        return int(self.sorted_spill_lo.shape[1])


def _child_lists(tax: Taxonomy):
    ch = [[] for _ in range(tax.n)]
    for i, p in enumerate(tax.parent.tolist()):
        if p >= 0:
            ch[p].append(i)
    return ch


def _bit_length(n: int) -> int:
    return int(n).bit_length()


def encode_hierarchy(tax: Taxonomy) -> EncodedHierarchy:
    """Reference (host) encoder: two passes, exactly the paper's algorithm."""
    n = tax.n
    children = _child_lists(tax)
    width = np.array([_bit_length(len(c)) for c in children], dtype=np.int32)

    # pass 1: used_bits top-down
    used = np.zeros(n, dtype=np.int32)
    order = np.argsort(tax.depth, kind="stable")  # parents before children
    for v in order.tolist():
        p = int(tax.parent[v])
        if p >= 0:
            used[v] = used[p] + width[p]
    total = max(1, int(used.max()))

    # pass 2: ids top-down (bigints so >62-bit codes are exact)
    rank_of = {}
    for p, ch in enumerate(children):
        for k, v in enumerate(ch):
            rank_of[v] = k + 1  # local code, 1-based
    ids_big = [0] * n
    for v in order.tolist():
        p = int(tax.parent[v])
        if p < 0:
            continue
        ids_big[v] = ids_big[p] | (rank_of[v] << (total - int(used[v])))

    bounds_big = [ids_big[i] + (1 << (total - int(used[i]))) for i in range(n)]
    return _finalize(tax, total, used, ids_big, bounds_big)


def encode_hierarchy_parallel(tax: Taxonomy) -> EncodedHierarchy:
    """Level-synchronous parallel encoder (JAX ops; beyond-paper).

    Identical output to ``encode_hierarchy``.  Each level is O(nodes at
    level) of segment-rank + gather work — no sequential DFS.  Restricted to
    total_bits <= 31 (device int32); wider hierarchies use the host path.
    """
    n = tax.n
    parent = jnp.asarray(tax.parent, dtype=jnp.int32)
    depth = jnp.asarray(tax.depth, dtype=jnp.int32)

    # children counts per node -> per-node slot width
    is_child = parent >= 0
    counts = jnp.zeros((n,), dtype=jnp.int32).at[jnp.where(is_child, parent, 0)].add(
        is_child.astype(jnp.int32)
    )
    # width = bit_length(count) = #{k : 2^k <= count} — exact integer form
    # (fp32 log2 would mis-round near powers of two for large fan-outs).
    powers = jnp.left_shift(jnp.int32(1), jnp.arange(31, dtype=jnp.int32))
    width = (counts[:, None] >= powers[None, :]).sum(axis=1).astype(jnp.int32)

    # local rank of each child within its parent (1-based), by node index —
    # matches the host encoder's sorted-children order.  lexsort keeps all
    # keys int32 (device x64 is off); roots are pushed to the end.
    parent_key = jnp.where(is_child, parent, jnp.int32(n))
    perm = jnp.lexsort((jnp.arange(n, dtype=jnp.int32), parent_key))
    sorted_parent = parent_key[perm]
    first_pos = jnp.searchsorted(sorted_parent, sorted_parent, side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - first_pos.astype(jnp.int32) + 1
    rank = jnp.zeros((n,), dtype=jnp.int32).at[perm].set(rank_sorted)

    # level loop: used_bits then ids (gather from parents, already final)
    max_depth = int(tax.depth.max()) if n > 1 else 0
    used = jnp.zeros((n,), dtype=jnp.int32)
    for _ in range(max_depth):
        cand = jnp.where(is_child, used[jnp.maximum(parent, 0)] + width[jnp.maximum(parent, 0)], 0)
        used = jnp.where(is_child, cand, used)  # converges: level l final after l iters
    total = int(jnp.maximum(1, used.max()))
    if total > 31:
        raise ValueError(f"parallel encoder limited to 31 bits, need {total}; use encode_hierarchy")

    ids = jnp.zeros((n,), dtype=jnp.int32)
    for _ in range(max_depth):
        pid = ids[jnp.maximum(parent, 0)]
        cand = pid | (rank << (total - used))
        ids = jnp.where(is_child, cand, ids)

    used_np = np.asarray(used, dtype=np.int32)
    ids_big = [int(v) for v in np.asarray(ids)]
    bounds_big = [ids_big[i] + (1 << (total - int(used_np[i]))) for i in range(n)]
    return _finalize(tax, total, used_np, ids_big, bounds_big)


def _finalize(tax: Taxonomy, total: int, used: np.ndarray, ids_big: list, bounds_big: list):
    n = tax.n
    narrow = total <= MAX_NARROW_BITS
    ids = np.array(ids_big, dtype=np.int64) if narrow else np.zeros(n, dtype=np.int64)
    bounds = np.array(bounds_big, dtype=np.int64) if narrow else np.zeros(n, dtype=np.int64)

    # wide packed form (always computed; exercised by tests + >62-bit path)
    W = words_needed(total)
    ids_wide = np.stack([pack_wide(v, W) for v in ids_big])
    bounds_wide = np.stack([pack_wide(v, W) for v in bounds_big])

    order = (
        np.argsort(ids, kind="stable")
        if narrow
        else np.array(sorted(range(n), key=lambda i: ids_big[i]), dtype=np.int64)
    )
    sorted_ids = ids[order]
    sorted_bounds = bounds[order]
    sorted_used = used[order]

    # DAG-ancestor table (ids, -1 padded), in sorted-by-id row order --------
    tmp_tax = tax
    anc_sets = [sorted(tmp_tax.dag_ancestors(i)) for i in range(n)]
    D = max(1, max(len(a) for a in anc_sets))
    anc_tbl = np.full((n, D), -1, dtype=np.int64)
    for i, a in enumerate(anc_sets):
        for j, node in enumerate(a):
            anc_tbl[i, j] = ids[node] if narrow else -1
    sorted_ancestors = anc_tbl[order]

    # spill intervals from secondary edges ----------------------------------
    spill: dict = {i: [] for i in range(n)}
    for child, sec_parent in tax.secondary:
        lo_c, hi_c = int(ids_big[child]), int(bounds_big[child])
        # child's subtree must also count as descendants of sec_parent and
        # of every DAG ancestor of sec_parent whose interval misses it.
        targets = {sec_parent} | tax.dag_ancestors(sec_parent)
        for t in targets:
            lo_t, hi_t = int(ids_big[t]), int(bounds_big[t])
            if not (lo_t <= lo_c and hi_c <= hi_t):
                ivs = spill[t]
                if not any(a <= lo_c and hi_c <= b for a, b in ivs):
                    ivs.append((lo_c, hi_c))
    S = max(1, max((len(v) for v in spill.values()), default=0))
    spill_lo = np.zeros((n, S), dtype=np.int64)
    spill_hi = np.zeros((n, S), dtype=np.int64)
    if narrow:  # int64 tables only exist on the narrow path
        for i, ivs in spill.items():
            for j, (a, b) in enumerate(sorted(ivs)):
                spill_lo[i, j] = a
                spill_hi[i, j] = b

    return EncodedHierarchy(
        tax=tax,
        total_bits=total,
        ids=ids,
        used_bits=used,
        bounds=bounds,
        sorted_ids=sorted_ids,
        sorted_bounds=sorted_bounds,
        sorted_used=sorted_used,
        sorted_ancestors=sorted_ancestors,
        sorted_spill_lo=spill_lo[order],
        sorted_spill_hi=spill_hi[order],
        ids_big=ids_big,
        wide_words=W,
        ids_wide=ids_wide,
        bounds_wide=bounds_wide,
        spill_big={i: sorted(v) for i, v in spill.items() if v},
    )


# ---------------------------------------------------------------------------
# Full TBox = concept hierarchy + property hierarchy + domain/range tables
# ---------------------------------------------------------------------------

RDF_TYPE = "rdf:type"
PROP_ROOT = "__prop_root__"


@dataclass
class TBox:
    concepts: EncodedHierarchy
    properties: EncodedHierarchy
    rdf_type_id: int
    # domain/range: sorted by property id, padded with -1
    dr_prop_ids: np.ndarray  # int64[Pdr]
    domain_table: np.ndarray  # int64[Pdr, Kd]
    range_table: np.ndarray  # int64[Pdr, Kr]
    instance_base: int

    def concept_id(self, name: str) -> int:
        return self.concepts.id_of(name)

    def property_id(self, name: str) -> int:
        return self.properties.id_of(name)

    def summary(self) -> dict:
        return dict(
            concept_bits=self.concepts.total_bits,
            property_bits=self.properties.total_bits,
            n_concepts=self.concepts.n,
            n_properties=self.properties.n,
            instance_base=self.instance_base,
            max_concept_spills=self.concepts.max_spills(),
        )


def build_tbox(onto: Ontology, parallel: bool = False) -> TBox:
    """Classify + encode an Ontology into device-ready TBox tables."""
    ctax = build_taxonomy(onto.concepts, onto.subclass, root_name=ROOT)
    props = list(onto.properties)
    if RDF_TYPE not in props:
        props.append(RDF_TYPE)
    ptax = build_taxonomy(props, onto.subprop, root_name=PROP_ROOT)

    def enc(tax):
        if parallel:
            try:
                return encode_hierarchy_parallel(tax)
            except ValueError:  # >31-bit codes: fall back to bigint host path
                pass
        return encode_hierarchy(tax)

    cenc = enc(ctax)
    penc = enc(ptax)

    # domain/range tables, sorted by property id ----------------------------
    dr_props = sorted(set(onto.domain) | set(onto.range_), key=penc.id_of)
    Kd = max(1, max((len(onto.domain.get(p, ())) for p in dr_props), default=0))
    Kr = max(1, max((len(onto.range_.get(p, ())) for p in dr_props), default=0))
    P = max(1, len(dr_props))
    dr_prop_ids = np.full((P,), -1, dtype=np.int64)
    domain_table = np.full((P, Kd), -1, dtype=np.int64)
    range_table = np.full((P, Kr), -1, dtype=np.int64)
    if cenc.narrow and penc.narrow:  # int64 tables need narrow ids; wide
        for i, p in enumerate(dr_props):  # hierarchies keep axioms host-side
            dr_prop_ids[i] = penc.id_of(p)
            for j, c in enumerate(sorted(onto.domain.get(p, ()), key=cenc.id_of)):
                domain_table[i, j] = cenc.id_of(c)
            for j, c in enumerate(sorted(onto.range_.get(p, ()), key=cenc.id_of)):
                range_table[i, j] = cenc.id_of(c)

    instance_base = 1 << max(cenc.total_bits, penc.total_bits)
    return TBox(
        concepts=cenc,
        properties=penc,
        rdf_type_id=penc.id_of(RDF_TYPE),
        dr_prop_ids=dr_prop_ids,
        domain_table=domain_table,
        range_table=range_table,
        instance_base=instance_base,
    )
