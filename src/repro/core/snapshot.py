"""Snapshot-isolated MVCC reads over a live (Sharded)KnowledgeBase.

``KnowledgeBase.version`` was always the MVCC hook — every mutation bumps
it, and :class:`~repro.core.delta.StoreView` objects are already immutable
snapshots of one version (liveness masks copied at build, delta arrays
append-only).  What was missing is the *coordination*: a reader that grabs
views while a writer is mid-mutation can see a half-applied delete, and the
:class:`~repro.core.delta.DeviceStoreCache`'s donated tombstone scatters
can invalidate device buffers a long-running reader is still executing
against.  This module closes both holes:

  * Writers serialize through ``kb.write_lock`` (insert / delete / compact
    hold it for their whole mutate-and-bump critical section).
  * Readers **pin** a :class:`Snapshot` from the :class:`SnapshotRegistry`:
    an immutable bundle of per-mode StoreViews captured at a quiescent
    point (under the write lock), refcounted so compaction/retirement can
    never pull a pinned version out from under a running query.
  * Pinned views are flagged ``pinned=True``; the DeviceStoreCache then
    *leases* any resident buffer it hands them and copies (instead of
    donating) the base-alive mask on the next kill scatter — an O(base)
    copy paid at most once per (pin, delete) pair, zero cost when nothing
    is pinned (the donation fast path is untouched).
  * ``pin()`` degrades gracefully: when a writer holds the lock past
    ``lock_timeout_s`` (or the capture itself fails — e.g. an injected
    mid-flush crash), the reader is served the **last published** snapshot
    tagged ``stale=True`` instead of blocking or erroring.

Snapshots work for both the single-device :class:`KnowledgeBase` and the
multi-device :class:`~repro.core.shard.ShardedKB` (per-shard views, queries
run through per-shard engines + the ordinary cross-shard combine).  Query
plans compile into registry-level caches shared across snapshots, so
pinning is cheap: no recompilation, no buffer copies, just refcounts.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.query import QueryEngine
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.testing import faults


def _is_sharded(kb) -> bool:
    return hasattr(kb, "shards")


@dataclass
class Snapshot:
    """Immutable per-mode views of ONE published version, refcounted.

    ``views[mode]`` is a StoreView (single store) or a per-shard list
    (ShardedKB).  Engines lazily attach to the pinned views and share the
    registry's plan caches, so repeated pins of the same version — and
    fresh pins after small mutations — reuse every compiled executable.
    """

    version: int
    kb: object
    modes: tuple
    views: dict
    use_index: bool = True
    refs: int = 0
    _plan_caches: dict = field(default_factory=dict, repr=False)
    # PatternSig -> observed selectivity, shared across snapshots via the
    # registry so planner feedback survives version churn
    _selectivity: dict = field(default_factory=dict, repr=False)
    _engines: dict = field(default_factory=dict, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    @property
    def sharded(self) -> bool:
        return _is_sharded(self.kb)

    def _check_mode(self, mode: str) -> str:
        mode = mode or self.modes[0]
        if mode not in self.views:
            raise KeyError(
                f"mode {mode!r} not captured by this snapshot (captured: "
                f"{tuple(self.views)}) — pass modes=(...) to the registry")
        return mode

    def _plan_cache(self, mode: str) -> dict:
        return self._plan_caches.setdefault((mode, self.use_index), {})

    def engine(self, mode: str = None) -> QueryEngine:
        """A QueryEngine bound to this snapshot's pinned view (single store)."""
        mode = self._check_mode(mode)
        if self.sharded:
            raise ValueError("sharded snapshots query per shard — use query()")
        with self._lock:
            eng = self._engines.get(mode)
            if eng is None:
                view = self.views[mode]
                eng = QueryEngine(
                    kb=self.kb.kb, spo=view.base_rows, mode=mode,
                    dtb=self.kb.dtb, use_index=self.use_index, view=view,
                    _exec_cache=self._plan_cache(mode),
                    observed_selectivity=self._selectivity)
                self._engines[mode] = eng
            return eng

    def _shard_engines(self, mode: str) -> list:
        with self._lock:
            engines = self._engines.get(mode)
            if engines is None:
                cache = self._plan_cache(mode)
                engines = [
                    QueryEngine(kb=K.kb, spo=v.base_rows, mode=mode,
                                dtb=self.kb.dtb, use_index=self.use_index,
                                view=v, _exec_cache=cache,
                                observed_selectivity=self._selectivity)
                    for K, v in zip(self.kb.shards, self.views[mode])]
                self._engines[mode] = engines
            return engines

    def query(self, patterns, select=None, mode: str = None):
        """Evaluate against the pinned version — never the live store."""
        mode = self._check_mode(mode)
        if self.sharded:
            return self._query_sharded(patterns, select, mode)
        return self.engine(mode).run(patterns, select=select)

    def _query_sharded(self, patterns, select, mode: str):
        """Per-shard dispatch over the pinned views + global combine.

        Snapshot reads always take the per-shard loop (the degradation
        target of the shard_map path as well): each shard's plan runs
        against that shard's pinned view, then the groups combine exactly
        like the live ShardedQueryEngine.
        """
        from repro.core.shard import _group_vars, combine_groups, plan_groups

        patterns = list(patterns)
        groups = plan_groups(patterns, mode, self.kb.tbox)
        engines = self._shard_engines(mode)
        views = self.views[mode]
        evaluated = []
        with obs_trace.span("shard_dispatch", path="loop",
                            n_groups=len(groups), n_shards=len(engines)):
            for g in groups:
                gpats = [patterns[i] for i in g]
                gvars = _group_vars(gpats)
                parts = []
                for i, eng in enumerate(engines):
                    if views[i].n == 0:
                        continue
                    faults.fire("shard.query_shard", shard=i)
                    with self.kb._device_ctx(i):
                        rows, _ = eng.run(gpats, select=gvars)
                    if rows.shape[0]:
                        parts.append(np.asarray(rows, dtype=np.int32))
                evaluated.append((gvars, parts))
        return combine_groups(evaluated, patterns, select)

    def query_batch(self, requests, mode: str = None):
        """Evaluate a batch of (patterns, select) requests at the pinned
        version with shared dispatches; returns per-request (rows, sel).

        Single store: straight to the engine's vmapped
        :meth:`~repro.core.query.QueryEngine.run_batch`.  Sharded: every
        member is decomposed into its pattern groups (exactly like
        :meth:`_query_sharded`) and ALL members' groups ride one
        ``run_batch`` per shard — same-signature groups from different
        requests coalesce inside the engine — before each member combines
        its own groups.
        """
        mode = self._check_mode(mode)
        if not self.sharded:
            return self.engine(mode).run_batch(requests)
        return self._query_batch_sharded(requests, mode)

    def _query_batch_sharded(self, requests, mode: str):
        from repro.core.shard import _group_vars, combine_groups, plan_groups

        engines = self._shard_engines(mode)
        views = self.views[mode]
        members = []     # (patterns, select, [gvars...], [flat idx...])
        shard_reqs = []  # flattened (group patterns, group vars)
        for pats, select in requests:
            pats = list(pats)
            groups = plan_groups(pats, mode, self.kb.tbox)
            metas, idxs = [], []
            for g in groups:
                gpats = [pats[i] for i in g]
                gvars = _group_vars(gpats)
                idxs.append(len(shard_reqs))
                shard_reqs.append((gpats, gvars))
                metas.append(gvars)
            members.append((pats, select, metas, idxs))
        parts_by_flat = [[] for _ in shard_reqs]
        with obs_trace.span("shard_dispatch", path="batch",
                            n_groups=len(shard_reqs),
                            n_shards=len(engines)):
            for i, eng in enumerate(engines):
                if views[i].n == 0:
                    continue
                faults.fire("shard.query_shard", shard=i)
                with self.kb._device_ctx(i):
                    res = eng.run_batch(shard_reqs)
                for f, (rows, _) in enumerate(res):
                    if rows.shape[0]:
                        parts_by_flat[f].append(
                            np.asarray(rows, dtype=np.int32))
        out = []
        for pats, select, metas, idxs in members:
            evaluated = [(metas[j], parts_by_flat[f])
                         for j, f in enumerate(idxs)]
            out.append(combine_groups(evaluated, pats, select))
        return out

    def answers(self, patterns, select=None, mode: str = None) -> set:
        rows, _ = self.query(patterns, select=select, mode=mode)
        return {tuple(r) for r in rows.tolist()}

    def device_buffers(self) -> list:
        """Device buffers this snapshot's pinned views keep alive.

        Reported under the ``snapshot`` component: after a compaction the
        live store swaps to fresh arrays, and whatever a pinned version
        still references — superseded bases, leased liveness masks — is
        memory *retained by MVCC*, exactly what an operator needs to see
        attributed separately.  Buffer ids let the ledger dedupe against
        the live store's own records, so only genuinely retained bytes
        surface here when the live KB registers first.
        """
        out = []
        for views in self.views.values():
            for v in views if isinstance(views, list) else (views,):
                for _comp, buf_id, nbytes in v.device_buffers():
                    out.append(("snapshot", buf_id, nbytes))
        return out

    def store_rows(self, mode: str = None) -> np.ndarray:
        """Live rows at the pinned version (host; shards concatenated)."""
        mode = self._check_mode(mode)
        if self.sharded:
            return np.concatenate(
                [np.asarray(v.live_rows()) for v in self.views[mode]])
        return np.asarray(self.views[mode].live_rows())


class Pin:
    """One reader's lease on a snapshot: context-managed refcount + tag.

    ``stale=True`` marks a degraded pin — the store had moved (or the
    writer held the lock) and the reader was served the last *published*
    version instead of the newest one.  Queries still answer exactly at
    ``version``; the tag just tells the client which version that is.
    """

    def __init__(self, registry: "SnapshotRegistry", snapshot: Snapshot,
                 stale: bool):
        self._registry = registry
        self.snapshot = snapshot
        self.stale = stale
        self._released = False

    @property
    def version(self) -> int:
        return self.snapshot.version

    def query(self, patterns, select=None, mode: str = None):
        return self.snapshot.query(patterns, select=select, mode=mode)

    def query_batch(self, requests, mode: str = None):
        return self.snapshot.query_batch(requests, mode=mode)

    def answers(self, patterns, select=None, mode: str = None) -> set:
        return self.snapshot.answers(patterns, select=select, mode=mode)

    def store_rows(self, mode: str = None):
        return self.snapshot.store_rows(mode)

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._registry._release(self.snapshot)

    def __enter__(self) -> "Pin":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SnapshotRegistry:
    """Publish/pin/retire lifecycle for MVCC snapshots of one store.

    * ``publish()`` captures the current version under the write lock and
      makes it the registry's serving snapshot.
    * ``pin()`` hands a reader a refcounted :class:`Pin`.  Fast path: the
      published snapshot already matches ``kb.version``.  Slow path: grab
      the write lock (bounded by ``lock_timeout_s``) and capture a fresh
      one.  Degraded path: the lock is contended or the capture failed —
      serve the last published snapshot tagged stale (never block a
      reader on a writer).
    * ``retire()`` drops refcount-zero snapshots that are no longer
      published; pinned versions survive any number of writes and
      compactions (their views keep the superseded base arrays alive).
    """

    def __init__(self, kb, modes=("litemat",), use_index: bool = True,
                 lock_timeout_s: float = 0.2,
                 metrics: MetricsRegistry | None = None):
        self.kb = kb
        self.modes = tuple(modes)
        self.use_index = use_index
        self.lock_timeout_s = lock_timeout_s
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._lock = threading.Lock()
        self._snaps: dict = {}  # version -> Snapshot
        self._published: Snapshot | None = None
        self._plan_caches: dict = {}  # shared across snapshots
        self._selectivity: dict = {}  # PatternSig -> observed, ditto

    @property
    def stats(self) -> dict:
        """Counter dict view over the registry (kept PR-6-shaped)."""
        m = self.metrics
        return {
            "publishes": m.counter_value("snapshot/publishes"),
            "pins": m.counter_value("snapshot/pins"),
            "stale_pins": m.counter_value("snapshot/stale_pins"),
            "fresh_captures": m.counter_value("snapshot/fresh_captures"),
            "retired": m.counter_value("snapshot/retired"),
            "capture_failures": m.counter_value("snapshot/capture_failures"),
        }

    def _refresh_gauges_locked(self) -> None:
        """Version/refcount gauges; caller holds self._lock."""
        m = self.metrics
        m.gauge("snapshot/live_versions").set(len(self._snaps))
        m.gauge("snapshot/pinned_versions").set(
            sum(1 for s in self._snaps.values() if s.refs > 0))
        m.gauge("snapshot/pinned_refs").set(
            sum(s.refs for s in self._snaps.values()))

    # -- capture / publish ---------------------------------------------------
    def _capture(self) -> dict:
        """Build per-mode views at the current version (write lock held)."""
        kb = self.kb
        views: dict = {}
        for mode in self.modes:
            if _is_sharded(kb):
                if mode in ("litemat", "full"):
                    kb._flush(mode)
                vs = []
                for i, K in enumerate(kb.shards):
                    with kb._device_ctx(i):
                        vs.append(K.view(mode))
                for v in vs:
                    v.pinned = True
                views[mode] = vs
            else:
                v = kb.view(mode)
                v.pinned = True
                views[mode] = v
        return views

    def _publish_locked(self) -> Snapshot:
        """Capture-or-reuse the snapshot of kb.version (write lock held)."""
        v = self.kb.version
        with self._lock:
            snap = self._snaps.get(v)
        if snap is None:
            with obs_trace.span("capture", version=v):
                t0 = time.perf_counter()
                faults.fire("snapshot.publish", version=v)
                views = self._capture()
                self.metrics.histogram("snapshot/capture_s").observe(
                    time.perf_counter() - t0)
            snap = Snapshot(version=v, kb=self.kb, modes=self.modes,
                            views=views, use_index=self.use_index,
                            _plan_caches=self._plan_caches,
                            _selectivity=self._selectivity)
            with self._lock:
                # another thread may have captured v concurrently; keep the
                # first registered one so refcounts aggregate correctly
                snap = self._snaps.setdefault(v, snap)
        with self._lock:
            self._published = snap
            self._refresh_gauges_locked()
        self.metrics.counter("snapshot/publishes").inc()
        self.retire()
        return snap

    def publish(self) -> Snapshot:
        """Capture the current version as the serving snapshot."""
        with self.kb.write_lock:
            return self._publish_locked()

    @property
    def published(self) -> Snapshot | None:
        with self._lock:
            return self._published

    # -- pin / release -------------------------------------------------------
    def pin(self, lock_timeout_s: float | None = None) -> Pin:
        """Pin a snapshot for reading; degrade to the last published one
        (stale tag) rather than blocking on a busy writer."""
        t0 = time.perf_counter()
        try:
            return self._pin(lock_timeout_s)
        finally:
            self.metrics.histogram("snapshot/pin_wait_s").observe(
                time.perf_counter() - t0)

    def _pin(self, lock_timeout_s: float | None) -> Pin:
        m = self.metrics
        m.counter("snapshot/pins").inc()
        with self._lock:
            snap = self._published
            if snap is not None and snap.version == self.kb.version:
                snap.refs += 1
                self._refresh_gauges_locked()
                m.counter("snapshot/pin_path", path="fast").inc()
                return Pin(self, snap, stale=False)

        # the store moved past the published snapshot: try a fresh capture
        timeout = (self.lock_timeout_s if lock_timeout_s is None
                   else lock_timeout_s)
        got = self.kb.write_lock.acquire(timeout=timeout)
        if got:
            try:
                snap = self._publish_locked()
            except Exception:
                m.counter("snapshot/capture_failures").inc()
                obs_trace.event("capture_failed")
                snap = None
            finally:
                self.kb.write_lock.release()
            if snap is not None:
                m.counter("snapshot/fresh_captures").inc()
                m.counter("snapshot/pin_path", path="fresh").inc()
                with self._lock:
                    snap.refs += 1
                    self._refresh_gauges_locked()
                    return Pin(self, snap, stale=False)

        # degraded: writer holds the flush lock (or the capture crashed) —
        # serve the last published version with a staleness tag
        with self._lock:
            snap = self._published
            if snap is not None:
                m.counter("snapshot/stale_pins").inc()
                m.counter("snapshot/pin_path", path="stale").inc()
                obs_trace.event("stale_pin", version=snap.version)
                snap.refs += 1
                self._refresh_gauges_locked()
                return Pin(self, snap, stale=True)
        if got is False and snap is None:
            # nothing ever published: block once for the first capture
            with self.kb.write_lock:
                snap = self._publish_locked()
            m.counter("snapshot/pin_path", path="first").inc()
            with self._lock:
                snap.refs += 1
                self._refresh_gauges_locked()
                return Pin(self, snap, stale=False)
        raise RuntimeError("snapshot capture failed and nothing is published")

    def pin_version(self, version: int) -> Pin | None:
        """Re-pin a SPECIFIC live version — the cursor-continuation path.

        Pagination needs page K+1 to read the exact rows page K saw, so a
        cursor re-pins its version by number.  Returns None when that
        version has been retired (no reader kept it alive between pages);
        the caller degrades to a fresh pin + ``stale`` cursor rather than
        erroring.  The Pin is tagged stale when the store has moved past
        the cursor's version — answers are still exact at that version.
        """
        m = self.metrics
        with self._lock:
            snap = self._snaps.get(version)
            if snap is None:
                m.counter("snapshot/pin_path", path="cursor_miss").inc()
                return None
            m.counter("snapshot/pins").inc()
            m.counter("snapshot/pin_path", path="cursor").inc()
            snap.refs += 1
            self._refresh_gauges_locked()
            return Pin(self, snap, stale=snap.version != self.kb.version)

    def _release(self, snap: Snapshot) -> None:
        with self._lock:
            snap.refs -= 1
            self._refresh_gauges_locked()
        self.retire()

    # -- retirement ----------------------------------------------------------
    def retire(self) -> int:
        """Drop refcount-zero snapshots that are no longer published.

        Two-phase on purpose: victims picked under the lock, then the
        ``snapshot.retire`` fault site fires (the race window a concurrent
        pin could hit), then each victim is re-checked under the lock
        before removal — a pin that raced in keeps its snapshot.
        """
        t0 = time.perf_counter()
        with self._lock:
            victims = [v for v, s in self._snaps.items()
                       if s.refs == 0 and s is not self._published]
        if not victims:
            return 0
        faults.fire("snapshot.retire", versions=tuple(victims))
        dropped = 0
        with self._lock:
            for v in victims:
                s = self._snaps.get(v)
                if s is not None and s.refs == 0 and s is not self._published:
                    del self._snaps[v]
                    dropped += 1
            self._refresh_gauges_locked()
        if dropped:
            self.metrics.counter("snapshot/retired").inc(dropped)
            self.metrics.histogram("snapshot/retire_s").observe(
                time.perf_counter() - t0)
        return dropped

    def device_buffers(self) -> list:
        """Ledger feed: buffers retained by live snapshot versions.

        Deduped across versions here (two snapshots of nearby versions
        share almost every array); deduped against the live store by the
        ledger's global id pass.  Also publishes per-version
        ``snapshot/retained_bytes{version=}`` gauges into this registry's
        metrics — the "leased/pinned buffer bytes per version" series —
        zeroing versions that retired since the last walk.  Pull-based:
        runs only when the ledger samples, never on the pin fast path.
        """
        with self._lock:
            snaps = sorted(self._snaps.items())
        out = []
        seen: set = set()
        published: set = set()
        for version, snap in snaps:
            retained = 0
            for comp, buf_id, nbytes in snap.device_buffers():
                if buf_id in seen:
                    continue
                seen.add(buf_id)
                out.append((comp, buf_id, nbytes))
                retained += int(nbytes)
            self.metrics.gauge("snapshot/retained_bytes",
                               version=version).set(retained)
            published.add(version)
        stale = getattr(self, "_bytes_versions", set()) - published
        for version in stale:
            self.metrics.gauge("snapshot/retained_bytes",
                               version=version).set(0)
        self._bytes_versions = published
        return out

    def live_versions(self) -> list:
        with self._lock:
            return sorted(self._snaps)

    def pinned_versions(self) -> list:
        with self._lock:
            return sorted(v for v, s in self._snaps.items() if s.refs > 0)

    def prewarm(self, queries=None, modes=None) -> None:
        """Compile the plan caches once so serving pays no cold starts."""
        from repro.core.engine import PAPER_QUERIES

        queries = (list(queries) if queries is not None
                   else list(PAPER_QUERIES.values()))
        with self.pin() as pin:
            for mode in (modes or self.modes):
                for q in queries:
                    pin.query(q, mode=mode)


__all__ = ["Snapshot", "SnapshotRegistry", "Pin"]
