"""Sharded multi-device stores: the ABox subject-hash partitioned.

LiteMat's headline claim is that the encoding is computed and served by a
scalable *parallel* algorithm; this module supplies the partitioned store
layer.  A :class:`ShardedKB` splits every ABox store across ``n_shards``
shards (one per device when the host has several) while replicating the
things that make RDFS inference shard-local:

Partitioning invariants
-----------------------
  * Every ABox row lives on ``shard_of(subject id)``: raw triples by their
    subject, *derived* rows by THEIR subject — range-derived type rows
    ``(o rdf:type C)`` migrate to ``shard(o)`` in the post-materialization
    exchange, so the subject-hash invariant holds for all three stores
    (rewrite / litemat / full).
  * The TBox (interval tables, DeviceTBox) and the term dictionary are
    REPLICATED: every interval containment test, MSC selection, and
    closure gather is shard-local; the dictionary grows through ONE shared
    :class:`DynamicDictionary` whose new-term chunks are absorbed into
    every shard's ``EncodedKB``.
  * Each shard is a full single-device :class:`KnowledgeBase` — its own
    POS/PSO/SPO/OSP :class:`StoreIndex`, :class:`DeviceStoreCache`, and
    pow2 delta buckets — so the whole incremental lifecycle (insert /
    delete / compact, version bumps, O(delta) post-mutation warmup) runs
    per shard, unchanged.

Join locality rules
-------------------
Two patterns' matching rows are guaranteed co-resident iff they bind a
shared variable from their SUBJECT position on both sides (both sides then
hash the binding to the same shard).  A chain of such links forces one
common subject variable, so the group planner simply buckets patterns by
subject variable: each group evaluates *entirely shard-local* through the
ordinary per-shard ``QueryEngine`` plans (slice / scan / INL, plan caches
and all).  Cross-group joins — object-keyed, e.g. Q4's ``?y`` — run as
DEVICE-SIDE HASH-REPARTITION JOINS: both sides bin their rows by a hash
of the join key, exchange the bins via ``lax.all_to_all`` inside one
shard_map, and each shard folds its received key-sorted runs with the
balanced partitioned-merge tree before joining SHARD-LOCAL — matching
rows co-hash, so the per-shard outputs union to exactly the global join
and no intermediate relation ever crosses back to the host.  A host fold
(all-gather the per-shard relations, balanced ``_merge_tree``, presorted
merge join) survives as the no-device dispatch path and the degradation
target for exchange faults.  Rewrite-mode type patterns bind ``?x`` from
BOTH endpoints (the range branch binds the object), so they are never
treated as co-hashed.

Execution lowers through ``jax.shard_map`` when the host actually has
``n_shards`` devices (the CI leg forces 8 with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``): per-shard stores
stack into ``[n_shards, ...]`` device buffers (a :class:`ShardStack`
mirrors the per-shard views with O(delta) refresh) and one shard-mapped
executable runs the group plan on every shard at once.  With fewer
devices the engine falls back to a per-shard dispatch loop — bit-identical
results, pinned by tests/test_shard.py.

Bulk ingest (``ShardedKB.ingest``) loads LUBM-100-class synthetic stores
(~1e7 triples): each part is encoded against the shared dictionary (host
searchsorted — the driver side of the paper's Spark pipeline), partitioned
by subject hash, and appended to the per-shard delta logs; lite/full
derivation happens lazily PER SHARD on first service of a mode, so no
single device ever materializes the whole store.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.abox import EncodedKB, encode_obe, tbox_term_map
from repro.core.closure import full_materialize
from repro.core.delta import DevStore, MODES, _delta_host
from repro.core.dictionary import (
    SENTINEL, sharded_dictionary_fn, sharded_out_specs, table_from_host,
)
from repro.core.engine import KnowledgeBase, PAPER_QUERIES, _raw_columns
from repro.core.index import pow2_bucket as _pow2
from repro.core.materialize import DeviceTBox, compact_rows, lite_materialize
from repro.core.query import (
    INVALID, Pattern, Relation, distinct, is_var, join, sig_label,
)
from repro.core.tbox import TBox, build_tbox
from repro.core.update import (
    DynamicDictionary, affected_instances, encode_delta,
    materialize_delta_mode, mentions_mask,
)
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY
from repro.testing import faults
from repro.testing.faults import FaultCrash, FaultError
from repro.utils import pair64
from repro.utils.jaxcompat import make_mesh, shard_map

_EMPTY = np.zeros((0, 3), dtype=np.int32)
_HASH_MULT = np.uint64(0x9E3779B1)  # Fibonacci multiplicative hash

# failures the stacked shard_map path treats as "device down, fall back to
# the per-shard dispatch loop": injected transients + XLA runtime errors
try:
    from jax.errors import JaxRuntimeError as _JaxRuntimeError
    _DEVICE_FAILURES = (FaultError, _JaxRuntimeError)
except ImportError:  # older jax: no public runtime-error class
    _DEVICE_FAILURES = (FaultError,)


def _local_mesh(n_shards: int, axis_name: str):
    """A 1-D mesh over this PROCESS's addressable devices.

    Single-process runtimes see every device, so this is `make_mesh`
    verbatim there; under `jax.distributed` each process's stores live on
    its local devices only, and a mesh built from the global device list
    would try to address remote buffers.  (Cross-process global-mesh
    sharding is the remaining ROADMAP item-2 step.)
    """
    if jax.process_count() == 1:
        return make_mesh((n_shards,), (axis_name,))
    devs = jax.local_devices()[:n_shards]
    return jax.sharding.Mesh(np.asarray(devs), (axis_name,))


def shard_of(ids, n_shards: int) -> np.ndarray:
    """Subject id -> shard id (deterministic multiplicative hash).

    Instance ids are dense ranks, so a plain modulo would couple shard
    choice to allocation order; the golden-ratio multiply decorrelates it.
    """
    h = (np.asarray(ids).astype(np.uint64) * _HASH_MULT) >> np.uint64(16)
    return (h % np.uint64(max(n_shards, 1))).astype(np.int64)


def partition_rows(rows: np.ndarray, n_shards: int) -> list:
    """Split (N, 3) encoded rows into per-shard arrays by subject hash."""
    rows = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
    if rows.shape[0] == 0:
        return [_EMPTY] * n_shards
    sh = shard_of(rows[:, 0], n_shards)
    order = np.argsort(sh, kind="stable")
    rows_s, sh_s = rows[order], sh[order]
    bounds = np.searchsorted(sh_s, np.arange(n_shards + 1))
    return [rows_s[bounds[i]:bounds[i + 1]] for i in range(n_shards)]


def _exchange(parts_by_src: list, n_shards: int) -> list:
    """All-to-all: re-partition per-source derived rows by subject hash."""
    outs = [[] for _ in range(n_shards)]
    for rows in parts_by_src:
        for j, pr in enumerate(partition_rows(rows, n_shards)):
            if pr.shape[0]:
                outs[j].append(pr)
    return [np.concatenate(o) if o else _EMPTY for o in outs]


# ---------------------------------------------------------------------------
# ShardedKB: the partitioned KnowledgeBase facade
# ---------------------------------------------------------------------------


@dataclass
class IngestReport:
    """Structured per-part outcome of a streaming ingest.

    One entry per input part: ``dict(part=, ok=, attempts=, n_inserted=,
    version=)`` on success, ``dict(part=, ok=False, attempts=, error=)``
    after the retry budget is spent.  A failed part is *skipped* — the
    store stays at the consistent version the last successful part
    published — so callers inspect ``ok`` / ``failed`` instead of fishing
    a half-ingested store out of an exception.
    """

    parts: list = field(default_factory=list)
    n_retries: int = 0

    @property
    def failed(self) -> list:
        return [p for p in self.parts if not p["ok"]]

    @property
    def ok(self) -> bool:
        return not self.failed

    @property
    def n_rows(self) -> int:
        return sum(p.get("n_inserted", 0) for p in self.parts if p["ok"])


@dataclass
class ShardedKB:
    """Subject-hash partitioned KnowledgeBase with replicated TBox/dictionary.

    Mirrors the :class:`KnowledgeBase` surface (query / answers / insert /
    delete / compact / prewarm / warm_device / sizes) so servers and tests
    swap between the two; every result is pinned bit-identical to the
    single-device store in tests/test_shard.py.
    """

    shards: list  # per-shard KnowledgeBase
    dtb: DeviceTBox
    n_shards: int
    compact_threshold: float = 0.25
    version: int = 0
    n_new_terms: int = 0
    mat_counts: dict = field(
        default_factory=lambda: {"litemat": 0, "full": 0})
    _dyn: DynamicDictionary | None = field(default=None, repr=False)
    _engines: dict = field(default_factory=dict, repr=False)
    _pending: list = field(default_factory=list, repr=False)  # per-shard parts
    _mat_cursor: dict = field(
        default_factory=lambda: {"litemat": 0, "full": 0}, repr=False)
    # writers serialize here (same contract as KnowledgeBase.write_lock);
    # snapshot captures take it briefly to see a quiescent global version
    write_lock: threading.RLock = field(
        default_factory=threading.RLock, repr=False, compare=False)
    ingest_report: "IngestReport | None" = field(default=None, repr=False)
    # device-parallel dictionary encode (paper §III.B) for inserts: the
    # BULK-INGEST path flips this on — ids then assign in hash-partitioned
    # owner order, not global fp-rank order, so interactively built stores
    # keep the host encode (their id-space parity with a single
    # KnowledgeBase is pinned by the update oracle)
    use_sharded_encode: bool = False
    _enc_cache: dict = field(default_factory=dict, repr=False)

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, raw, tbox: TBox | None = None, n_shards: int | None = None,
              parallel_tbox: bool = False) -> "ShardedKB":
        """Encode + partition + per-shard materialize (with exchange).

        The encode is the shared driver step (ids identical to the
        single-device build, so parity tests compare raw id sets); the
        lite/full materializers then run per shard over that shard's raw
        partition, and the derived rows are exchanged to THEIR subject's
        shard.  Per-shard MSC may keep a concept alongside a descendant
        held by another shard — answer-equivalent under interval
        evaluation, the same invariant the incremental-insert path pins.
        """
        tbox = tbox or build_tbox(raw.onto, parallel=parallel_tbox)
        n_shards = n_shards or max(jax.local_device_count(), 1)
        kbg = encode_obe(raw, tbox)
        dtb = DeviceTBox.build(tbox)
        parts = partition_rows(np.asarray(kbg.spo), n_shards)

        skb = cls(shards=[], dtb=dtb, n_shards=n_shards)
        lite_src, full_src, built = [], [], []
        for i, part in enumerate(parts):
            with skb._device_ctx(i):
                kb_i = EncodedKB(
                    spo=jnp.asarray(part), tables=kbg.tables, tbox=tbox,
                    n_instance_terms=kbg.n_instance_terms,
                    term_strings=kbg.term_strings)
                if part.shape[0]:
                    lite, lv, lstats = lite_materialize(kb_i, dtb)
                    full, fv, fstats = full_materialize(kb_i, dtb)
                    lite_src.append(np.asarray(compact_rows(lite, lv)))
                    full_src.append(np.asarray(compact_rows(full, fv)))
                else:
                    lstats = fstats = {}
                    lite_src.append(_EMPTY)
                    full_src.append(_EMPTY)
                built.append((kb_i, lstats, fstats))
        lite_parts = _exchange(lite_src, n_shards)
        full_parts = _exchange(full_src, n_shards)
        for i, (kb_i, lstats, fstats) in enumerate(built):
            with skb._device_ctx(i):
                K = KnowledgeBase(
                    kb=kb_i, dtb=dtb,
                    lite_spo=jnp.asarray(lite_parts[i]),
                    full_spo=jnp.asarray(full_parts[i]),
                    lite_stats=lstats, full_stats=fstats)
                skb.shards.append(K)
        skb._dyn = DynamicDictionary.from_kb(kbg)
        for K in skb.shards:
            K._dyn = skb._dyn  # one replicated growable dictionary
        return skb

    @classmethod
    def empty(cls, tbox: TBox, n_shards: int | None = None) -> "ShardedKB":
        """Shards over an empty ABox — the bulk-ingest starting point."""
        n_shards = n_shards or max(jax.local_device_count(), 1)
        fps, ids = tbox_term_map(tbox)
        ttable = table_from_host(fps, ids)
        dtb = DeviceTBox.build(tbox)
        skb = cls(shards=[], dtb=dtb, n_shards=n_shards)
        for i in range(n_shards):
            with skb._device_ctx(i):
                kb_i = EncodedKB(spo=jnp.asarray(_EMPTY), tables=(ttable,),
                                 tbox=tbox, n_instance_terms=0)
                skb.shards.append(KnowledgeBase(
                    kb=kb_i, dtb=dtb, lite_spo=jnp.asarray(_EMPTY),
                    full_spo=jnp.asarray(_EMPTY),
                    lite_stats={}, full_stats={}))
        skb._dyn = DynamicDictionary.from_kb(skb.shards[0].kb)
        for K in skb.shards:
            K._dyn = skb._dyn
        return skb

    @classmethod
    def ingest(cls, parts, tbox: TBox | None = None, onto=None,
               n_shards: int | None = None, max_part_retries: int = 3,
               backoff_s: float = 0.01, backoff_cap_s: float = 0.5,
               seed: int = 0) -> "ShardedKB":
        """Bulk-load an iterable of raw parts, never materializing globally.

        Each part (RawDataset or (s, p, o) fingerprint columns) is encoded
        against the growing replicated dictionary, hash-partitioned by
        subject, and appended to the per-shard raw logs; per-shard sorted
        indexes build lazily on first query and lite/full derivation is
        lazy per mode AND per shard (`_flush` derives each shard's backlog
        on its own device and exchanges the output) — the ROADMAP's
        LUBM-100-class loads stay out of single-device memory.

        The streaming loop is fault-tolerant: a part whose encode/partition
        fails transiently is retried up to ``max_part_retries`` times with
        jittered exponential backoff; a part that exhausts its budget (or
        hard-crashes with :class:`FaultCrash`) is recorded in the returned
        store's ``ingest_report`` and *skipped*, so a 10k-part stream never
        dies at part 7k — and because ``insert`` commits atomically (all
        fallible work precedes any store mutation), a failed part leaves
        the store at the consistent version the previous part published.
        """
        parts = iter(parts)
        if tbox is None:
            first = next(parts)
            tbox = build_tbox(onto or first.onto)
            parts = iter([first, *parts])
        skb = cls.empty(tbox, n_shards=n_shards)
        # encode is the ingest bottleneck: bulk loads take the device-side
        # parallel dictionary build whenever a device per shard exists
        skb.use_sharded_encode = True
        report = IngestReport()
        rng = np.random.default_rng(seed)
        for k, part in enumerate(parts):
            attempt = 0
            while True:
                v0 = skb.version
                try:
                    stats = skb.insert(part, auto_compact=False)
                    report.parts.append(dict(
                        part=k, ok=True, attempts=attempt + 1,
                        n_inserted=stats["n_inserted"],
                        version=skb.version))
                    break
                except Exception as e:  # noqa: BLE001 — classified below
                    retryable = (not isinstance(e, FaultCrash)
                                 and skb.version == v0  # nothing committed
                                 and attempt < max_part_retries)
                    if not retryable:
                        report.parts.append(dict(
                            part=k, ok=False, attempts=attempt + 1,
                            error=f"{type(e).__name__}: {e}"))
                        REGISTRY.counter("shard/ingest_failed_parts").inc()
                        break
                    report.n_retries += 1
                    REGISTRY.counter("shard/ingest_retries").inc()
                    delay = min(backoff_cap_s, backoff_s * (2 ** attempt))
                    time.sleep(delay * (0.5 + 0.5 * rng.random()))
                    attempt += 1
        skb.ingest_report = report
        return skb

    # -- shard plumbing ------------------------------------------------------
    @property
    def kb(self) -> EncodedKB:
        """Replicated dictionary/TBox handle (shard 0's EncodedKB)."""
        return self.shards[0].kb

    @property
    def tbox(self) -> TBox:
        return self.kb.tbox

    def _device_ctx(self, i: int):
        devs = jax.local_devices()  # addressable from THIS process
        return jax.default_device(devs[i % len(devs)])

    def shard_devices(self) -> list:
        devs = jax.local_devices()
        return [devs[i % len(devs)] for i in range(self.n_shards)]

    def _sharded_encode_on(self) -> bool:
        return jax.local_device_count() >= self.n_shards > 1

    def _enc_executable(self, cap: int):
        """Cached shard_mapped sharded-dictionary build for one bin shape.

        Ids assign RELATIVE to 0 inside the executable; the host adds
        ``next_id`` afterwards — so the compiled build is reusable across
        batches as the dictionary grows.
        """
        fn = self._enc_cache.get(cap)
        if fn is None:
            body = sharded_dictionary_fn("d", self.n_shards, cap, base=0)
            mesh = _local_mesh(self.n_shards, "d")
            d = P("d")
            fn = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(d, d, d),
                out_specs=sharded_out_specs(), check_vma=False))
            self._enc_cache[cap] = fn
        return fn

    def _encode_sharded(self, s_fp, p_fp, o_fp):
        """Device-parallel dictionary encode (the paper's §III.B) of a part.

        Predicates validate against the host mirror (the TBox-fixed OBE
        invariant ``encode_delta`` enforces); known s/o terms resolve by
        one host lookup; the UNKNOWN tail goes through ONE
        ``sharded_dictionary_fn`` pass — hash-partition to owner shards,
        per-owner unique + all_gather prefix-sum id ranges, reverse
        all_to_all — and the assigned (fp, id) pairs splice back into the
        host mirror via :meth:`DynamicDictionary.register`, so absorb /
        lookup / later host encodes see exactly the same dictionary.
        """
        p_ids = self._dyn.lookup(p_fp)
        bad = (p_ids < 0) | (p_ids >= self._dyn.instance_base)
        if bad.any():
            raise ValueError(
                "delta contains predicates outside the TBox property map — "
                "schema growth needs a re-encode (KnowledgeBase.build), the "
                "incremental path only grows the ABox")
        so_fp = np.concatenate([s_fp, o_fp])
        so_ids = self._dyn.lookup(so_fp)
        missing = so_ids < 0
        n_new = 0
        if missing.any():
            miss_fp = so_fp[missing]
            hi, lo = pair64.split_np(miss_fp)
            S, n = self.n_shards, hi.shape[0]
            cap = _pow2(-(-n // S), floor=256)
            hi_p = np.full(S * cap, int(SENTINEL), np.int32)
            lo_p = np.full(S * cap, int(SENTINEL), np.int32)
            valid = np.zeros(S * cap, bool)
            hi_p[:n], lo_p[:n], valid[:n] = hi, lo, True
            occ, table, overflow, _ = self._enc_executable(cap)(
                jnp.asarray(hi_p), jnp.asarray(lo_p), jnp.asarray(valid))
            if int(np.asarray(overflow).sum()):
                # a source shard holds at most cap occurrences and every
                # bin holds cap slots, so this is unreachable; guard the
                # invariant rather than silently dropping terms
                raise RuntimeError("sharded encode owner bins overflowed")
            base = self._dyn.next_id
            occ = np.asarray(occ).reshape(-1)[:n] + base
            thi = np.asarray(table[0]).reshape(-1)
            tlo = np.asarray(table[1]).reshape(-1)
            tids = np.asarray(table[2]).reshape(-1)
            real = tids >= 0
            fps_r = pair64.combine_np(thi[real], tlo[real])
            ufp, uidx = np.unique(fps_r, return_index=True)
            n_new = self._dyn.register(ufp, tids[real][uidx] + base)
            so_ids = so_ids.copy()
            so_ids[missing] = occ.astype(np.int32)
        s_ids, o_ids = np.split(so_ids, 2)
        spo = np.stack([s_ids, p_ids, o_ids], axis=1).astype(np.int32)
        return spo, n_new

    def _absorb(self, strings=None) -> int:
        """Fold freshly allocated dictionary terms into EVERY shard."""
        chunk = self._dyn.take_new_terms()
        if chunk is None:
            return 0
        fps, ids = chunk
        tbl = table_from_host(fps, ids)
        for K in self.shards:
            K.kb.tables = (*K.kb.tables, tbl)
            K.kb._merged = None
            K.kb.n_instance_terms += int(ids.shape[0])
        if strings:
            if self.kb.term_strings is None:
                shared = {}  # ONE dict, replicated by reference — every
                for K in self.shards:  # shard's extract sees every IRI
                    K.kb.term_strings = shared
            self.kb.term_strings.update(strings)
        return int(ids.shape[0])

    # -- lazy per-mode, per-shard derivation ---------------------------------
    def _flush(self, *modes: str) -> None:
        """Derive pending insert batches per shard, exchange, append.

        Each shard's share of the backlog is materialized on that shard's
        device (row-local derivation), then the derived rows are exchanged
        to their own subject's shard — range-derived type rows migrate,
        keeping the partition invariant.  Lazy per mode: a lite-only
        deployment never runs the full closure of its ingest.

        Crash-atomic per mode (same contract as KnowledgeBase._flush_mat):
        every batch is derived AND exchanged before any shard's log is
        appended, so a failure mid-derivation (fault site
        ``shard.flush_mat``) leaves every shard's published store
        consistent and a later flush retries the whole backlog.
        """
        n = len(self._pending)
        for mode in modes:
            if mode not in self._mat_cursor:
                continue
            cur = self._mat_cursor[mode]
            if cur >= n:
                continue
            t0 = time.perf_counter()
            with obs_trace.span("flush_mat", mode=mode, n_batches=n - cur,
                                sharded=True):
                staged = []
                for b, parts in enumerate(self._pending[cur:]):
                    derived_src = []
                    for i, part in enumerate(parts):
                        if part.shape[0] == 0:
                            derived_src.append(_EMPTY)
                            continue
                        faults.fire("shard.flush_mat", mode=mode, shard=i,
                                    batch=cur + b)
                        with self._device_ctx(i):
                            derived_src.append(
                                materialize_delta_mode(part, self.dtb, mode))
                    staged.append(_exchange(derived_src, self.n_shards))
                derived_rows = 0
                for exchanged in staged:
                    for j, rows in enumerate(exchanged):
                        self.shards[j].append_derived(mode, rows)
                        derived_rows += int(rows.shape[0])
                    self.mat_counts[mode] += 1
                self._mat_cursor[mode] = n
                for K in self.shards:
                    K._bump()
            REGISTRY.histogram("shard/flush_s", mode=mode).observe(
                time.perf_counter() - t0)
            REGISTRY.counter("shard/derived_rows", mode=mode).inc(
                derived_rows)
        if self._pending and all(
                c >= n for c in self._mat_cursor.values()):
            self._pending.clear()
            self._mat_cursor = {m: 0 for m in self._mat_cursor}

    def _pending_rows(self, mode: str) -> int:
        if mode not in self._mat_cursor:
            return 0
        return sum(sum(int(p.shape[0]) for p in parts)
                   for parts in self._pending[self._mat_cursor[mode]:])

    # -- mutations -----------------------------------------------------------
    @property
    def delta_ratio(self) -> float:
        num = sum(self._pending_rows(m) for m in ("litemat", "full"))
        den = 0
        for K in self.shards:
            sizes = {"rewrite": K.kb.n,
                     "litemat": int(K.lite_spo.shape[0]),
                     "full": int(K.full_spo.shape[0])}
            den += sum(sizes.values())
            if K._delta is not None:
                for m in MODES:
                    num += K._delta.logs[m].n
                    if K._delta.base_alive[m] is not None:
                        num += sizes[m] - int(K._delta.base_alive[m].sum())
        return num / max(den, 1)

    def insert(self, raw, auto_compact: bool = True) -> dict:
        """Encode once (replicated dictionary), partition, append per shard.

        Commit-atomic: everything that can fail — the ``shard.ingest_encode``
        fault site, the host encode, the partition — runs BEFORE any shard
        log is touched; the per-shard appends are plain array concats.  The
        ingest retry loop relies on this: an exception here means nothing
        was committed and the published version is unchanged.
        """
        s_fp, p_fp, o_fp, strings = _raw_columns(raw)
        if s_fp.shape[0] == 0:
            return dict(n_inserted=0, n_new_terms=0)
        with self.write_lock:
            faults.fire("shard.ingest_encode", n=int(s_fp.shape[0]))
            if self.use_sharded_encode and self._sharded_encode_on():
                spo, n_new = self._encode_sharded(s_fp, p_fp, o_fp)
            else:
                spo, n_new = encode_delta(self._dyn, s_fp, p_fp, o_fp)
            parts = partition_rows(spo, self.n_shards)
            # -- commit point: nothing below raises -------------------------
            self._absorb(strings)
            for i, part in enumerate(parts):
                if part.shape[0]:
                    with self._device_ctx(i):
                        self.shards[i].append_raw(part)
                self.shards[i]._bump()
            self._pending.append(parts)
            self.n_new_terms += n_new
            self.version += 1
            stats = dict(
                n_inserted=int(spo.shape[0]), n_new_terms=n_new,
                n_pending_mat=sum(
                    self._pending_rows(m) for m in ("litemat", "full")),
                delta_ratio=round(self.delta_ratio, 4), version=self.version,
            )
            if auto_compact and self.delta_ratio > self.compact_threshold:
                stats["compacted"] = self.compact()
            return stats

    def delete(self, raw, auto_compact: bool = True) -> dict:
        """Coordinated delete: local tombstones, global repair frontier.

        Raw kills are shard-local (the triples live on their subject's
        shard); the affected-instance set is global, so every shard
        tombstones its derived mentions and contributes its live raw
        mentions to the frontier; the re-derived rows are exchanged back
        to their subjects' shards — the same exact-repair argument as the
        single-store delete, distributed.
        """
        s_fp, p_fp, o_fp, _ = _raw_columns(raw)
        if s_fp.shape[0] == 0:
            return dict(n_deleted=0)
        with self.write_lock:
            self._flush("litemat", "full")
            ids = np.stack([self._dyn.lookup(s_fp), self._dyn.lookup(p_fp),
                            self._dyn.lookup(o_fp)], axis=1)
            q = ids[(ids >= 0).all(axis=1)]
            deleted = []
            for i, part in enumerate(partition_rows(q, self.n_shards)):
                if part.shape[0]:
                    with self._device_ctx(i):
                        d = self.shards[i].kill_raw_rows(part)
                    if d.shape[0]:
                        deleted.append(d)
            if not deleted:
                return dict(n_deleted=0)
            deleted = np.concatenate(deleted)
            inst = affected_instances(deleted, self.tbox.instance_base)

            frontier_src = []
            for i, K in enumerate(self.shards):
                with self._device_ctx(i):
                    K.kill_derived_mentions(inst)
                    frontier_src.append(K.live_raw_mentions(inst))
            for mode in ("litemat", "full"):
                derived_src = []
                for i, rows in enumerate(frontier_src):
                    if rows.shape[0] == 0:
                        derived_src.append(_EMPTY)
                        continue
                    with self._device_ctx(i):
                        derived = materialize_delta_mode(rows, self.dtb, mode)
                        derived_src.append(
                            derived[mentions_mask(derived, inst)])
                for j, rows in enumerate(
                        _exchange(derived_src, self.n_shards)):
                    self.shards[j].append_derived(mode, rows)
            for K in self.shards:
                K._bump()
            self.version += 1
            stats = dict(
                n_deleted=int(deleted.shape[0]),
                n_affected_instances=int(inst.shape[0]),
                delta_ratio=round(self.delta_ratio, 4), version=self.version,
            )
            if auto_compact and self.delta_ratio > self.compact_threshold:
                stats["compacted"] = self.compact()
            return stats

    def compact(self, device: bool | None = None) -> dict:
        """Fold every shard's overlay into fresh per-shard bases."""
        with self.write_lock:
            if (all(K._delta is None or K._delta.empty for K in self.shards)
                    and not self._pending):
                return dict(compacted=False)
            t0 = time.perf_counter()
            with obs_trace.span("compact", sharded=True,
                                n_shards=self.n_shards):
                self._flush("litemat", "full")
                sizes = {m: 0 for m in MODES}
                for i, K in enumerate(self.shards):
                    with self._device_ctx(i):
                        out = K.compact(device=device)
                    for m in MODES:
                        sizes[m] += int(out.get(m, 0))
                self.version += 1
            REGISTRY.counter("shard/compactions").inc()
            REGISTRY.histogram("shard/compact_s").observe(
                time.perf_counter() - t0)
            return dict(compacted=True, version=self.version, **sizes)

    # -- query surface -------------------------------------------------------
    def engine(self, mode: str = "litemat",
               use_index: bool = True) -> "ShardedQueryEngine":
        key = (mode, use_index)
        if key not in self._engines:
            self._engines[key] = ShardedQueryEngine(
                skb=self, mode=mode, use_index=use_index)
        return self._engines[key]

    def query(self, patterns, select=None, mode: str = "litemat",
              use_index: bool = True):
        return self.engine(mode, use_index).run(patterns, select=select)

    def answers(self, patterns, select=None, mode: str = "litemat",
                use_index: bool = True) -> set:
        rows, _ = self.query(patterns, select=select, mode=mode,
                             use_index=use_index)
        return {tuple(r) for r in rows.tolist()}

    def prewarm(self, queries=None, modes=("litemat",), buckets=(),
                use_index: bool = True) -> int:
        queries = (list(queries) if queries is not None
                   else list(PAPER_QUERIES.values()))
        return sum(self.engine(m, use_index).prewarm(queries, buckets=buckets)
                   for m in modes)

    def warm_device(self, mode: str = "litemat", keys=("scan", "pos")):
        """Per-shard device warmup (the O(delta)-per-shard unit)."""
        if mode in ("litemat", "full"):
            self._flush(mode)
        out = []
        for i, K in enumerate(self.shards):
            with self._device_ctx(i):
                out.append(K.warm_device(mode, keys=keys))
        return out

    def store_rows(self, mode: str = "litemat") -> np.ndarray:
        """Live rows of one store, all shards concatenated (host order)."""
        if mode in ("litemat", "full"):
            self._flush(mode)
        return np.concatenate(
            [np.asarray(K.store_rows(mode)) for K in self.shards])

    def device_buffers(self) -> list:
        """Sharded-engine device footprint beyond the per-shard stores:
        the ShardStack slabs every ShardedQueryEngine keeps resident.
        (Per-shard store buffers are reported by each shard's own
        KnowledgeBase, registered separately by :meth:`track_ledger`.)"""
        out = []
        for eng in self._engines.values():
            for stack in eng._stacks.values():
                out.extend(stack.device_buffers())
        return out

    def track_ledger(self) -> None:
        """Register this sharded store with the global resource ledger:
        each shard's KnowledgeBase under its shard index (per-shard
        ``hbm_bytes{shard=i}`` / live-triple gauges), plus the stacked
        shard_map slabs under ``shard="stack"``.  Idempotent; the ledger
        holds only weakrefs."""
        if getattr(self, "_ledger_handles", None):
            return
        from repro.obs.ledger import LEDGER

        self._ledger_handles = [
            LEDGER.track(str(i), K) for i, K in enumerate(self.shards)]
        self._ledger_handles.append(LEDGER.track("stack", self))

    def sizes(self) -> dict:
        out = {"original": 0, "lite": 0, "full": 0}
        for K in self.shards:
            s = K.sizes()
            out["original"] += s["original"]
            out["lite"] += s["lite"]
            out["full"] += s["full"]
        pending = sum(self._pending_rows(m) for m in ("litemat", "full"))
        delta = sum(K._delta.logs[m].n for K in self.shards
                    for m in MODES if K._delta is not None)
        if delta:
            out["delta_rows"] = delta
        if pending:
            out["delta_rows_pending_mat"] = pending
        return out


# ---------------------------------------------------------------------------
# Group planning: which joins stay shard-local
# ---------------------------------------------------------------------------


def _is_type_pattern(pat: Pattern, tbox) -> bool:
    return (not is_var(pat.p)) and (
        pat.p in ("rdf:type", "a") or pat.p == tbox.rdf_type_id)


def plan_groups(patterns, mode: str, tbox) -> list:
    """Bucket pattern indices by co-hashed subject variable.

    A pattern binds its subject variable from the co-hashed subject column
    — EXCEPT rewrite-mode type patterns, whose range branch binds the
    object — so patterns sharing a subject variable evaluate and join
    entirely shard-local; everything else is a singleton group combined
    globally.
    """
    groups: dict = {}
    for idx, pat in enumerate(patterns):
        local = is_var(pat.s) and not (
            mode == "rewrite" and _is_type_pattern(pat, tbox)
            and not is_var(pat.o))
        key = ("var", pat.s) if local else ("solo", idx)
        groups.setdefault(key, []).append(idx)
    return list(groups.values())


def _merge_tree(runs: list, key_col: int):
    """Balanced pairwise fold of key-sorted device runs into ONE sorted run.

    log2(k) merge levels instead of a left-deep fold: the accumulated run
    is never re-merged against every remaining part, so each row moves
    O(log k) times rather than O(k).  Each level pairs neighbours through
    ``ops.merge_gather`` (the partitioned-merge kernel) + one row gather;
    INVALID keys sort last, so padded rows sink to the fold's tail.
    Shared by the host-fallback combine and the device repartition join's
    shard-local fold of exchanged partitions.
    """
    runs = list(runs)
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            a, b = runs[i], runs[i + 1]
            ka, kb = a[:, key_col], b[:, key_col]
            g = ops.merge_gather(ka, jnp.zeros_like(ka), kb,
                                 jnp.zeros_like(kb))
            nxt.append(ops.two_source_gather(a, b, g))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]


def _merge_shard_parts(parts: list, key_col: int):
    """Fold per-shard result rows into one key-sorted array on device.

    Each shard's rows sort locally (small — post-distinct relations), then
    fold through the balanced ``_merge_tree`` — so the combined relation
    arrives presorted for the join's build side without a global re-sort,
    and the single pad to the join capacity happens once downstream in
    ``_host_relation``, not per merge step.
    """
    live = [p for p in parts if p.shape[0]]
    if not live:
        return np.zeros((0, parts[0].shape[1]), np.int32)
    runs = [jnp.asarray(p[np.argsort(p[:, key_col], kind="stable")])
            for p in live]
    return np.asarray(_merge_tree(runs, key_col))


def _host_relation(gvars: tuple, rows: np.ndarray, cap: int) -> Relation:
    """(N, k) host rows -> INVALID-padded device Relation of capacity cap.

    This is the host-fold combine's re-upload point: every merged relation
    crosses host->device here.  The device repartition path never calls it
    mid-join, which the ``device/transfer_bytes{src=combine_upload}``
    counter pins in tests.
    """
    n = rows.shape[0]
    cols = np.full((len(gvars), cap), np.iinfo(np.int32).max, np.int32)
    cols[:, :n] = rows.T
    REGISTRY.counter("device/transfer_bytes",
                     src="combine_upload").inc(int(cols.nbytes))
    return Relation(
        vars=gvars, cols=jnp.asarray(cols),
        valid=jnp.arange(cap) < n, overflow=jnp.int32(max(n - cap, 0)))


def _bin_by_key(cols, valid, key_idx: int, n_shards: int):
    """Route one shard's relation rows to hash(join key) partitions.

    ``cols`` int32[V, cap] / ``valid`` bool[cap] -> int32[S, cap, V] send
    bins: bin t holds this shard's rows whose key hashes to t, ascending
    by key, INVALID-padded.  A bin can never overflow its ``cap`` slots —
    the source shard holds at most ``cap`` rows in total — so the exchange
    itself needs no overflow accounting (receive-side skew lands in the
    [S, cap] receive buffer, which holds the worst case of EVERY row
    hashing to one shard).  Invalid rows route nowhere.
    """
    n_vars, cap = cols.shape
    key = jnp.where(valid, cols[key_idx], INVALID)
    h = (key.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)) >> jnp.uint32(16)
    tgt = jnp.where(valid & (key != INVALID),
                    (h % jnp.uint32(n_shards)).astype(jnp.int32),
                    jnp.int32(n_shards))
    order = jnp.lexsort((key, tgt))
    tgt_s = tgt[order]
    rows_s = cols.T[order]
    first = jnp.searchsorted(tgt_s, jnp.arange(n_shards, dtype=jnp.int32))
    slot = (jnp.arange(cap, dtype=jnp.int32)
            - first[jnp.clip(tgt_s, 0, n_shards - 1)])
    idx = jnp.where(tgt_s < n_shards, tgt_s * cap + slot, n_shards * cap)
    flat = jnp.full((n_shards * cap, n_vars), INVALID, jnp.int32)
    flat = flat.at[idx].set(rows_s, mode="drop")
    return flat.reshape(n_shards, cap, n_vars)


def _stack_parts(parts: list, n_vars: int, n_shards: int):
    """Host result parts -> stacked [S, V, cap] device relation.

    The repartition fold doesn't care how rows were distributed before the
    exchange (bins are computed from the rows themselves), so parts slot
    round-robin.  This is the single-device EMULATED entry into the device
    combine — the shard_map path hands over stacked buffers directly and
    never passes through here.
    """
    cap = _pow2(max((p.shape[0] for p in parts), default=1), floor=256)
    cols = np.full((n_shards, n_vars, cap), np.iinfo(np.int32).max, np.int32)
    valid = np.zeros((n_shards, cap), bool)
    for i, p in enumerate(parts):
        j = i % n_shards
        cols[j, :, :p.shape[0]] = p.T
        valid[j, :p.shape[0]] = True
    return jnp.asarray(cols), jnp.asarray(valid)


# ---------------------------------------------------------------------------
# ShardStack: stacked [n_shards, ...] device buffers for shard_map plans
# ---------------------------------------------------------------------------


class ShardStack:
    """Per-key stacked device buffers mirroring every shard's StoreView.

    The shard_map executables take ONE array per view key with a leading
    shard axis; this cache keeps those stacks resident and refreshes them
    with work independent of the base sizes: delta buckets re-upload
    O(n_shards * delta cap) rows, base tombstones land as point scatters,
    and base slabs re-upload only when a shard's base token changes
    (compaction) or the common pow2 capacity grows.
    """

    def __init__(self):
        self._states: dict = {}
        self._lock = threading.RLock()  # same contract as DeviceStoreCache
        self.stats = {"base_rebuilds": 0, "upload_base_rows": 0,
                      "upload_delta_rows": 0, "kill_scatter_rows": 0}

    def device_buffers(self) -> list:
        """Resident stacked slabs as ``(component, buf_id, nbytes)`` for
        the resource ledger — the shard_map path's device footprint."""
        out = []
        with self._lock:
            for st in self._states.values():
                out.append(("stack", id(st["base"]), st["base"].nbytes))
                out.append(("alive", id(st["alive"]), st["alive"].nbytes))
                if st["delta"] is not None:
                    out.append(("delta", id(st["delta"]),
                                st["delta"].nbytes))
                    out.append(("alive", id(st["dalive"]),
                                st["dalive"].nbytes))
        return out

    def _base_host(self, view, key):
        if key == "scan":
            return np.asarray(view.base_h)
        return view.base_index._h[view.base_index.perm(key).perm]

    def sync(self, views: list, key: str):
        with self._lock:
            return self._sync_locked(views, key)

    def _sync_locked(self, views: list, key: str):
        S = len(views)
        ncap = _pow2(max(v.base_n for v in views))
        has_delta = any(v.has_delta for v in views)
        dcap = _pow2(max(v.delta_n for v in views)) if has_delta else 0
        tokens = tuple(v.base_index.token for v in views)
        st = self._states.get(key)

        if st is None or st["ncap"] != ncap or st["tokens"] != tokens:
            self.stats["base_rebuilds"] += 1
            REGISTRY.counter("device/base_rebuilds", src="shard_stack").inc()
            base = np.full((S, ncap, 3), np.iinfo(np.int32).max, np.int32)
            alive = np.zeros((S, ncap), bool)
            for i, v in enumerate(views):
                h = self._base_host(v, key)
                base[i, :h.shape[0]] = h
                if v.base_alive_h is None:
                    alive[i, :h.shape[0]] = True
                else:
                    ah = (v.base_alive_h if key == "scan"
                          else v.base_alive_h[v.base_index.perm(key).perm])
                    alive[i, :ah.shape[0]] = ah
                self.stats["upload_base_rows"] += int(h.shape[0])
                REGISTRY.counter("device/upload_rows", src="shard_stack",
                                 kind="base").inc(int(h.shape[0]))
                REGISTRY.counter("device/transfer_bytes",
                                 src="shard_stack").inc(int(h.nbytes))
            st = {"ncap": ncap, "tokens": tokens,
                  "base": jnp.asarray(base), "alive": jnp.asarray(alive),
                  "n_kills": [len(v.kills) for v in views],
                  "dcap": -1, "delta": None, "dalive": None,
                  "dstate": [None] * S}
            self._states[key] = st
        else:
            for i, v in enumerate(views):
                if len(v.kills) > st["n_kills"][i]:
                    idx = np.concatenate(v.kills[st["n_kills"][i]:])
                    if key != "scan":
                        idx = v.base_index.inv_perm(key)[idx]
                    pad = _pow2(idx.shape[0])
                    full = np.full(pad, ncap, np.int64)
                    full[:idx.shape[0]] = idx
                    st["alive"] = st["alive"].at[
                        i, jnp.asarray(full.astype(np.int32))].set(
                        False, mode="drop")
                    self.stats["kill_scatter_rows"] += int(idx.shape[0])
                    REGISTRY.counter("device/kill_scatter_rows",
                                     src="shard_stack").inc(int(idx.shape[0]))
                    st["n_kills"][i] = len(v.kills)

        dstate = [(v.delta_n, v.delta_mut) for v in views]
        if dcap != st["dcap"] or dstate != st["dstate"]:
            if not has_delta:
                st["delta"] = st["dalive"] = None
            else:
                drows = np.full((S, dcap, 3), np.iinfo(np.int32).max,
                                np.int32)
                dalive = np.zeros((S, dcap), bool)
                for i, v in enumerate(views):
                    if not v.has_delta:
                        continue
                    rows, al = _delta_host(v, key)
                    drows[i, :rows.shape[0]] = rows
                    dalive[i, :al.shape[0]] = al
                    self.stats["upload_delta_rows"] += dcap
                    REGISTRY.counter("device/upload_rows", src="shard_stack",
                                     kind="delta").inc(dcap)
                    REGISTRY.counter("device/transfer_bytes",
                                     src="shard_stack").inc(dcap * 12)
                st["delta"] = jnp.asarray(drows)
                st["dalive"] = jnp.asarray(dalive)
            st["dcap"] = dcap
            st["dstate"] = dstate
        return DevStore(base=st["base"], base_alive=st["alive"],
                        delta=st["delta"], delta_alive=st["dalive"])


# ---------------------------------------------------------------------------
# ShardedQueryEngine: group-local plans, global combine
# ---------------------------------------------------------------------------


@dataclass
class ShardedQueryEngine:
    """Executes conjunctive plans across a ShardedKB's shards.

    Subject-co-hashed groups run the full per-shard QueryEngine plans —
    through ONE shard_mapped executable when the host has a device per
    shard (per-shard sigs must agree; capacities unify to the max), else a
    per-shard dispatch loop (async across devices).  Cross-group joins
    all-gather the per-shard relations, fold them key-sorted with the
    partitioned-merge kernel, and finish with the ordinary sort-merge join
    + distinct — bit-identical to the single-store engine.
    """

    skb: ShardedKB
    mode: str = "litemat"
    use_index: bool = True
    use_shard_map: bool | None = None  # None: auto (device per shard)
    # None: auto (repartition joins whenever shard_map is on); True forces
    # the device combine even on the per-shard loop path — the exchange
    # then runs its single-device EMULATION (transpose-as-all-to-all), the
    # same traced math minus the collective, which is how tests exercise
    # the fold on a one-device host
    use_repartition_join: bool | None = None
    _exec_cache: dict = field(default_factory=dict, repr=False)
    _stacks: dict = field(default_factory=dict, repr=False)
    _mesh: object = field(default=None, repr=False)
    cache_stats: dict = field(
        default_factory=lambda: {"hits": 0, "misses": 0,
                                 "shard_map_runs": 0, "loop_runs": 0,
                                 "shard_map_faults": 0,
                                 "repartition_runs": 0,
                                 "exchange_faults": 0},
        repr=False)

    def _engines(self):
        return [K.engine(self.mode, self.use_index) for K in self.skb.shards]

    def _shard_map_on(self) -> bool:
        if self.use_shard_map is not None:
            return self.use_shard_map
        return jax.local_device_count() >= self.skb.n_shards > 1

    def _repartition_on(self) -> bool:
        if self.use_repartition_join is not None:
            return self.use_repartition_join
        return self._shard_map_on()

    def prewarm(self, queries, buckets=(), select=None) -> int:
        n = 0
        if self.mode in ("litemat", "full"):
            self.skb._flush(self.mode)  # derive backlog: plans must see
        for pats in queries:  # the stores run() will execute against
            groups = plan_groups(pats, self.mode, self.skb.tbox)
            for g in groups:
                gpats = [pats[i] for i in g]
                gvars = _group_vars(gpats)
                for i, eng in enumerate(self._engines()):
                    if self.skb.shards[i].view(self.mode).n == 0:
                        continue
                    with self.skb._device_ctx(i):
                        n += eng.prewarm([gpats], buckets=buckets,
                                         select=gvars)
                if self._shard_map_on():
                    # the multi-device run() path executes the shard_mapped
                    # executable, not the per-shard plans — compile it too
                    before = self.cache_stats["misses"]
                    self._run_group_shard_map(gpats, gvars)
                    n += self.cache_stats["misses"] - before
        return n

    # -- group evaluation ----------------------------------------------------
    def _route_shards(self, gpats):
        """Constant-subject singleton groups touch only their owner shard."""
        if len(gpats) == 1 and not is_var(gpats[0].s):
            engines = self._engines()
            try:
                t = engines[0]._resolve(
                    gpats[0].s, "s",
                    _is_type_pattern(gpats[0], self.skb.tbox))
            except KeyError:
                return list(range(self.skb.n_shards))
            if t.hi == t.lo + 1 and not t.spills and t.members is None:
                return [int(shard_of(np.asarray([t.lo]),
                                     self.skb.n_shards)[0])]
        return list(range(self.skb.n_shards))

    def _run_group_loop(self, gpats, gvars):
        """Per-shard dispatch: each shard's own engine runs the group plan."""
        self.cache_stats["loop_runs"] += 1
        REGISTRY.counter("shard/group_runs", path="loop").inc()
        engines = self._engines()
        parts = []
        with obs_trace.span("shard_dispatch", path="loop",
                            n_shards=self.skb.n_shards):
            for i in self._route_shards(gpats):
                if self.skb.shards[i].view(self.mode).n == 0:
                    continue
                faults.fire("shard.query_shard", shard=i)
                with self.skb._device_ctx(i):
                    rows, _ = engines[i].run(gpats, select=gvars)
                if rows.shape[0]:
                    parts.append(np.asarray(rows, dtype=np.int32))
        return parts

    def _run_group_shard_map(self, gpats, gvars):
        """Shard_mapped group evaluation, results pulled back as host parts.

        Returns None (caller falls back to the loop) when per-shard plans
        disagree on signatures.  The repartition combine bypasses this
        wrapper and keeps ``_run_group_device``'s stacked buffers on
        device.
        """
        res = self._run_group_device(gpats, gvars)
        if res is None:
            return None
        cols, valid = res
        parts = []
        for i in range(self.skb.n_shards):
            n = int(valid[i].sum())
            if n:
                parts.append(np.asarray(cols[i])[:, :n].T.astype(np.int32))
        return parts

    def _run_group_device(self, gpats, gvars):
        """One shard_mapped executable evaluating the group plan per shard.

        Returns stacked device buffers ``(cols [S, V, cap], valid
        [S, cap])`` — or None when per-shard plans disagree on signatures:
        data-dependent strategy choices (single-predicate-run detection,
        INL conversion) can differ across shards.
        """
        engines = self._engines()
        plans = []
        for i, eng in enumerate(engines):
            with self.skb._device_ctx(i):
                plans.append(eng._plan(gpats, gvars))
        sigs0 = plans[0][0]
        if any(p[0] != sigs0 for p in plans[1:]):
            return None
        caps = tuple(max(p[2][j] for p in plans)
                     for j in range(len(plans[0][2])))
        join_cap = max(p[3] for p in plans)
        sel = plans[0][4]
        views = [K.view(self.mode) for K in self.skb.shards]
        ncap = _pow2(max(v.base_n for v in views))
        # slice-plan ranges address each shard's [real base | delta]
        # combined coordinates; the stacked slabs pad every base to ncap
        # rows, so per-shard delta ranges shift to start at ncap
        dyns_h = []
        for p, v in zip(plans, views):
            dyn = list(p[1])
            for j, sig in enumerate(sigs0):
                if sig.strategy == "slice" and v.base_n < ncap:
                    d = dict(dyn[j])
                    d["starts"] = jnp.where(
                        d["starts"] >= v.base_n,
                        d["starts"] + (ncap - v.base_n), d["starts"])
                    dyn[j] = d
            dyns_h.append(tuple(dyn))
        slabel = sig_label(sigs0)
        for attempt in range(6):
            stores = {}
            for k in {s.store for s in sigs0 if s.strategy in ("slice", "inl")}:
                stores[k] = self._stack(k).sync(views, k)
            if any(s.strategy == "scan" for s in sigs0):
                stores["scan"] = self._stack("scan").sync(views, "scan")
            has_delta = stores[next(iter(stores))].delta is not None
            dyns = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *dyns_h)
            fn = self._sm_executable(sigs0, caps, join_cap, sel, has_delta)
            cols, valid, overflow = fn(stores, dyns)
            ovf = np.asarray(overflow).reshape(-1)
            if int(ovf.max()) == 0:
                if attempt:
                    REGISTRY.histogram("join/capacity_depth",
                                       site="shard_map",
                                       sig=slabel).observe(attempt)
                self.cache_stats["shard_map_runs"] += 1
                REGISTRY.counter("shard/group_runs", path="shard_map").inc()
                return cols, valid
            # overflow is per shard: attribute the retry to each shard
            # whose buckets burst — lopsided counters here are the
            # hot-key-skew signal EXPLAIN surfaces host-side
            for i in np.nonzero(ovf)[0]:
                REGISTRY.counter("join/capacity_retry", site="shard_map",
                                 sig=slabel, shard=str(int(i))).inc()
            caps = tuple(c * 2 for c in caps)
            join_cap *= 2
        raise RuntimeError("sharded query kept overflowing its buckets")

    def _stack(self, key: str) -> ShardStack:
        if key not in self._stacks:
            self._stacks[key] = ShardStack()
        return self._stacks[key]

    def _sm_executable(self, sigs, caps, join_cap, sel, has_delta):
        from repro.core.query import _eval_inl, _eval_pattern

        key = ("sm", sigs, caps, join_cap, sel, has_delta)
        fn = self._exec_cache.get(key)
        if fn is not None:
            self.cache_stats["hits"] += 1
            REGISTRY.counter("shard/exec_cache", event="hit").inc()
            return fn
        self.cache_stats["misses"] += 1
        REGISTRY.counter("shard/exec_cache", event="miss").inc()
        if self._mesh is None:
            self._mesh = _local_mesh(self.skb.n_shards, "shard")

        def body(stores, dyns):
            st1 = {k: DevStore(
                base=v.base[0], base_alive=v.base_alive[0],
                delta=None if v.delta is None else v.delta[0],
                delta_alive=(None if v.delta_alive is None
                             else v.delta_alive[0]))
                for k, v in stores.items()}
            dyns1 = jax.tree_util.tree_map(lambda x: x[0], dyns)
            rel = None
            for sig, cap, dyn in zip(sigs, caps, dyns1):
                if sig.strategy == "inl":
                    rel, _ = _eval_inl(sig, cap, st1, dyn, rel)
                    continue
                r, _ = _eval_pattern(sig, cap, st1, dyn)
                rel = r if rel is None else join(rel, r, join_cap)
            out = distinct(rel, sel, join_cap)
            return out.cols[None], out.valid[None], out.overflow[None]

        f = shard_map(body, mesh=self._mesh,
                      in_specs=(P("shard"), P("shard")),
                      out_specs=(P("shard"), P("shard"), P("shard")),
                      check_vma=False)
        fn = jax.jit(f)
        self._exec_cache[key] = fn
        return fn

    def _run_group(self, gpats, gvars):
        if self._shard_map_on():
            try:
                with obs_trace.span("shard_dispatch", path="shard_map",
                                    n_shards=self.skb.n_shards) as sp:
                    faults.fire("shard.shard_map")
                    parts = self._run_group_shard_map(gpats, gvars)
                    if parts is None:
                        sp.set_attr(plan_mismatch=True)
            except _DEVICE_FAILURES:
                # a device died under the stacked executable (or a test
                # injected one dying): degrade to the per-shard dispatch
                # loop, which re-syncs each shard independently
                self.cache_stats["shard_map_faults"] += 1
                REGISTRY.counter("shard/shard_map_faults").inc()
                obs_trace.event("shard_map_fallback")
                parts = None
            if parts is not None:
                return parts
        return self._run_group_loop(gpats, gvars)

    # -- device repartition combine ------------------------------------------
    def _cx_executable(self, acc_vars, rel_vars, key, acap, rcap, jcap):
        """One hash-repartition join step, cached per static shape/config.

        Both sides bin by hash(join key), exchange partitions (all-to-all
        under shard_map; a transpose in the single-device emulation), then
        each shard folds its received key-sorted runs with the balanced
        merge tree and runs the ordinary presorted merge join SHARD-LOCAL.
        Matching rows co-hash, so the per-shard join outputs union to
        exactly the global join — no intermediate relation ever crosses
        back to the host.
        """
        ck = ("cx", acc_vars, rel_vars, key, acap, rcap, jcap,
              self._shard_map_on())
        fn = self._exec_cache.get(ck)
        if fn is not None:
            self.cache_stats["hits"] += 1
            REGISTRY.counter("shard/exec_cache", event="hit").inc()
            return fn
        self.cache_stats["misses"] += 1
        REGISTRY.counter("shard/exec_cache", event="miss").inc()
        S = self.skb.n_shards
        ai, ri = acc_vars.index(key), rel_vars.index(key)

        def local_join(arecv, rrecv):
            # arecv [S, acap, Va] rows; rrecv [S, rcap, Vr] key-sorted runs
            m = _merge_tree([rrecv[i] for i in range(S)], ri)
            rel1 = Relation(vars=rel_vars, cols=m.T,
                            valid=m[:, ri] != INVALID,
                            overflow=jnp.int32(0))
            af = arecv.reshape(S * acap, len(acc_vars))
            acc1 = Relation(vars=acc_vars, cols=af.T,
                            valid=af[:, ai] != INVALID,
                            overflow=jnp.int32(0))
            out = join(rel1, acc1, jcap, a_sorted=True)
            return out.cols, out.valid, out.overflow

        if self._shard_map_on():
            if self._mesh is None:
                self._mesh = _local_mesh(S, "shard")

            def body(ac, av, rc, rv):
                abins = _bin_by_key(ac[0], av[0], ai, S)
                rbins = _bin_by_key(rc[0], rv[0], ri, S)
                arecv = jax.lax.all_to_all(abins, "shard", 0, 0)
                rrecv = jax.lax.all_to_all(rbins, "shard", 0, 0)
                cols, valid, ovf = local_join(arecv, rrecv)
                return cols[None], valid[None], ovf[None]

            f = shard_map(body, mesh=self._mesh,
                          in_specs=(P("shard"),) * 4,
                          out_specs=(P("shard"),) * 3, check_vma=False)
        else:
            def f(ac, av, rc, rv):
                abins = jnp.stack(
                    [_bin_by_key(ac[i], av[i], ai, S) for i in range(S)])
                rbins = jnp.stack(
                    [_bin_by_key(rc[i], rv[i], ri, S) for i in range(S)])
                arecv = jnp.swapaxes(abins, 0, 1)
                rrecv = jnp.swapaxes(rbins, 0, 1)
                outs = [local_join(arecv[i], rrecv[i]) for i in range(S)]
                return (jnp.stack([o[0] for o in outs]),
                        jnp.stack([o[1] for o in outs]),
                        jnp.stack([o[2] for o in outs]))

        fn = jax.jit(f)
        self._exec_cache[ck] = fn
        return fn

    def _dx_executable(self, rvars, sel, cap):
        """Per-shard DISTINCT projection, cached per static shape/config."""
        ck = ("dx", rvars, sel, cap, self._shard_map_on())
        fn = self._exec_cache.get(ck)
        if fn is not None:
            self.cache_stats["hits"] += 1
            REGISTRY.counter("shard/exec_cache", event="hit").inc()
            return fn
        self.cache_stats["misses"] += 1
        REGISTRY.counter("shard/exec_cache", event="miss").inc()
        S = self.skb.n_shards

        def local(c, v):
            out = distinct(Relation(vars=rvars, cols=c, valid=v,
                                    overflow=jnp.int32(0)), sel, cap)
            return out.cols, out.valid

        if self._shard_map_on():
            if self._mesh is None:
                self._mesh = _local_mesh(S, "shard")

            def body(c, v):
                oc, ov = local(c[0], v[0])
                return oc[None], ov[None]

            f = shard_map(body, mesh=self._mesh,
                          in_specs=(P("shard"),) * 2,
                          out_specs=(P("shard"),) * 2, check_vma=False)
        else:
            def f(c, v):
                outs = [local(c[i], v[i]) for i in range(S)]
                return (jnp.stack([o[0] for o in outs]),
                        jnp.stack([o[1] for o in outs]))

        fn = jax.jit(f)
        self._exec_cache[ck] = fn
        return fn

    def _run_repartition(self, patterns, groups, select, max_retries):
        """Evaluate groups, fold them with the device repartition join.

        Returns (rows, sel), or None when a shard_map group plan
        mismatched across shards — the caller then degrades to the host
        fold, exactly like the single-group dispatch does.
        """
        evaluated = []
        with obs_trace.span("shard_combine", path="repartition",
                            n_groups=len(groups)):
            for g in groups:
                gpats = [patterns[i] for i in g]
                gvars = _group_vars(gpats)
                if self._shard_map_on():
                    faults.fire("shard.shard_map")
                    res = self._run_group_device(gpats, gvars)
                    if res is None:
                        return None
                else:
                    res = _stack_parts(self._run_group_loop(gpats, gvars),
                                       len(gvars), self.skb.n_shards)
                evaluated.append((gvars, res))
            return self._combine_groups_device(evaluated, patterns, select,
                                               max_retries)

    def _combine_groups_device(self, evaluated, patterns, select,
                               max_retries):
        """Fold stacked per-shard group results entirely on device.

        Mirrors ``combine_groups``'s order (fewest rows first, greedy
        connected) and capacities, but every cross-group join runs as a
        hash-repartition join: intermediate relations stay stacked on
        devices between steps.  Only the final per-shard DISTINCT rows
        come back, and one host-side sorted-unique pass reproduces the
        global distinct's lexicographic order bit-for-bit.
        """
        all_vars = tuple(dict.fromkeys(
            v for pat in patterns for v in (pat.s, pat.p, pat.o)
            if is_var(v)))
        sel = tuple(select) if select else all_vars
        totals = [int(valid.sum()) for _, (_, valid) in evaluated]
        order = sorted(range(len(evaluated)), key=lambda i: totals[i])
        acc = None  # (vars, cols [S, V, cap], valid [S, cap])
        done = set()
        while len(done) < len(order):
            pick = None
            for i in order:
                if i in done:
                    continue
                gvars = evaluated[i][0]
                if acc is None or set(gvars) & set(acc[0]):
                    pick = i
                    break
            if pick is None:
                raise ValueError(
                    "cartesian products not supported — reorder the plan")
            done.add(pick)
            gvars, (cols, valid) = evaluated[pick]
            if acc is None:
                acc = (gvars, cols, valid)
                continue
            key = next(v for v in gvars if v in acc[0])
            faults.fire("shard.exchange")
            jcap = _pow2(max(totals[pick], int(acc[2].sum()), 1) * 2,
                         floor=256)
            plabel = sig_label(tuple((p.s, p.p, p.o) for p in patterns))
            for attempt in range(max_retries):
                fn = self._cx_executable(
                    acc[0], gvars, key, int(acc[1].shape[2]),
                    int(cols.shape[2]), jcap)
                ocols, ovalid, oovf = fn(acc[1], acc[2], cols, valid)
                if int(jnp.max(oovf)) == 0:
                    if attempt:
                        REGISTRY.histogram(
                            "join/capacity_depth", site="repartition",
                            sig=plabel, key=key).observe(attempt)
                    break
                ovf = np.asarray(oovf).reshape(-1)
                for i in (np.nonzero(ovf)[0] if ovf.shape[0] > 1 else [0]):
                    REGISTRY.counter("join/capacity_retry",
                                     site="repartition", sig=plabel,
                                     shard=str(int(i))).inc()
                jcap *= 2
            else:
                raise RuntimeError("sharded join kept overflowing")
            out_vars = tuple(gvars) + tuple(
                v for v in acc[0] if v not in gvars)
            acc = (out_vars, ocols, ovalid)
        self.cache_stats["repartition_runs"] += 1
        REGISTRY.counter("shard/combine_runs", path="repartition").inc()
        # per-shard distinct shrinks the readback; identical sel-tuples can
        # still straddle shards when sel drops the last join key, so one
        # host-side sorted-unique pass finishes the global dedup in the
        # same ascending-lexicographic order `distinct` emits
        dfn = self._dx_executable(acc[0], sel, int(acc[1].shape[2]))
        dcols, dvalid = dfn(acc[1], acc[2])
        parts = []
        for i in range(self.skb.n_shards):
            n = int(dvalid[i].sum())
            if n:
                parts.append(np.asarray(dcols[i])[:, :n].T.astype(np.int32))
        if not parts:
            return np.zeros((0, len(sel)), np.int32), sel
        return np.unique(np.concatenate(parts), axis=0), sel

    # -- the full query ------------------------------------------------------
    def run(self, patterns, select=None, max_retries: int = 6):
        """Execute; returns (rows int32[k, n_select], select var names).

        Same contract as QueryEngine.run: rows are DISTINCT bindings of the
        selected variables, in the global lexicographic order the distinct
        pass produces — bit-identical to the single-device engine given the
        same ``select``.  Multi-group plans (cross-shard, object-keyed
        joins) fold through the device-side hash-repartition join when
        enabled, degrading to the host fold on exchange faults or plan
        mismatches.
        """
        patterns = list(patterns)
        if self.mode in ("litemat", "full"):
            self.skb._flush(self.mode)
        groups = plan_groups(patterns, self.mode, self.skb.tbox)
        if len(groups) > 1 and self._repartition_on():
            try:
                out = self._run_repartition(patterns, groups, select,
                                            max_retries)
                if out is not None:
                    return out
            except _DEVICE_FAILURES:
                self.cache_stats["exchange_faults"] += 1
                REGISTRY.counter("shard/exchange_faults").inc()
                obs_trace.event("repartition_fallback")
            REGISTRY.counter("shard/combine_runs", path="host_fallback").inc()
        else:
            REGISTRY.counter("shard/combine_runs", path="host").inc()
        evaluated = []
        for g in groups:
            gpats = [patterns[i] for i in g]
            gvars = _group_vars(gpats)
            evaluated.append((gvars, self._run_group(gpats, gvars)))
        return combine_groups(evaluated, patterns, select,
                              max_retries=max_retries)


def combine_groups(evaluated, patterns, select=None, max_retries: int = 6):
    """Fold per-group, per-shard result parts into the final distinct rows.

    ``evaluated`` is ``[(group_vars, [int32[k_i, |vars|] per shard]), ...]``
    in plan-group order.  Groups fold through presorted merge joins, then
    one global distinct (cross-shard duplicates of object-keyed bindings
    collapse here) — shared by the live ShardedQueryEngine and the pinned
    per-shard snapshot reads (core/snapshot.py), so both produce
    bit-identical rows from identical parts.
    """
    all_vars = tuple(dict.fromkeys(
        v for pat in patterns for v in (pat.s, pat.p, pat.o)
        if is_var(v)))
    sel = tuple(select) if select else all_vars

    order = sorted(range(len(evaluated)),
                   key=lambda i: sum(p.shape[0] for p in evaluated[i][1]))
    acc = None
    done = set()
    while len(done) < len(order):
        pick = None
        for i in order:
            if i in done:
                continue
            gvars = evaluated[i][0]
            if acc is None or set(gvars) & set(acc.vars):
                pick = i
                break
        if pick is None:
            raise ValueError(
                "cartesian products not supported — reorder the plan")
        done.add(pick)
        gvars, parts = evaluated[pick]
        total = sum(p.shape[0] for p in parts)
        if acc is None:
            cap = _pow2(total, floor=256)
            rows = (np.concatenate(parts) if parts
                    else np.zeros((0, len(gvars)), np.int32))
            acc = _host_relation(gvars, rows, cap)
            continue
        key = next(v for v in gvars if v in acc.vars)
        merged = _merge_shard_parts(
            parts, gvars.index(key)) if parts else np.zeros(
            (0, len(gvars)), np.int32)
        rel = _host_relation(gvars, merged, _pow2(total, floor=256))
        jcap = _pow2(max(total, _acc_rows(acc), 1) * 2, floor=256)
        plabel = sig_label(tuple((p.s, p.p, p.o) for p in patterns))
        for attempt in range(max_retries):
            out = join(rel, acc, jcap, a_sorted=True)
            if int(out.overflow) == 0:
                if attempt:
                    REGISTRY.histogram("join/capacity_depth",
                                       site="host_fold", sig=plabel,
                                       key=key).observe(attempt)
                break
            # host fold sees already-merged parts: no per-shard overflow
            # attribution exists, so the retry lands on shard="global"
            REGISTRY.counter("join/capacity_retry", site="host_fold",
                             sig=plabel, shard="global").inc()
            jcap *= 2
        else:
            raise RuntimeError("sharded join kept overflowing")
        acc = out
    out = distinct(acc, sel, _pow2(_acc_rows(acc), floor=256))
    n = int(out.valid.sum())
    rows = np.asarray(out.cols)[:, :n].T
    return rows, sel


def _acc_rows(rel: Relation) -> int:
    return int(rel.valid.sum())


def _group_vars(gpats) -> tuple:
    return tuple(dict.fromkeys(
        v for pat in gpats for v in (pat.s, pat.p, pat.o) if is_var(v)))


def assert_partitioned(skb: ShardedKB) -> None:
    """Test hook: every live row of every store sits on its subject's shard."""
    for mode in MODES:
        skb._flush(mode) if mode in ("litemat", "full") else None
        for i, K in enumerate(skb.shards):
            rows = np.asarray(K.store_rows(mode))
            if rows.shape[0] == 0:
                continue
            sh = shard_of(rows[:, 0], skb.n_shards)
            assert (sh == i).all(), (mode, i, rows[sh != i][:5])


__all__ = ["ShardedKB", "ShardedQueryEngine", "ShardStack", "IngestReport",
           "shard_of", "partition_rows", "plan_groups", "combine_groups",
           "assert_partitioned"]
