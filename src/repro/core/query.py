"""Conjunctive SPARQL evaluation over encoded triples — the paper's §V.

Three execution modes, matching the paper's Table VI columns:

  * ``litemat``  — interval predicates (one compare per sub-hierarchy) over
                   the lite-materialized store,
  * ``full``     — plain equality over the fully materialized store,
  * ``rewrite``  — the no-materialization baseline: constants expanded
                   host-side to their sub-concept/property id sets,
                   evaluated as OR-filters (the paper's optimized
                   "conjunction of OR subqueries" formulation).

The algebra is the paper's filter→map→join pipeline, in XLA static-shape
discipline: every operator carries a static capacity + validity mask +
overflow counter, and the engine re-executes with doubled capacities if an
overflow is reported (power-of-two buckets keep recompiles bounded).

Stores are *live*: the engine executes against a StoreView (core/delta.py)
— an immutable base plus a small delta overlay with tombstones — so the
same compiled plans serve a store that is being mutated between queries.
Patterns union base-index slices with delta-index slices, and every row
carries a liveness bit that the gather/compaction paths filter.  Each view
key reaches the device as a PAIR of arrays — the base store (resident,
untouched by mutations) and a power-of-two delta bucket — addressed in
combined coordinates, so refreshing the executable's inputs after an
insert/delete moves O(delta) bytes, never an O(base) re-concatenation.

Execution strategy per pattern (chosen host-side during planning):

  * ``slice`` — any litemat/full pattern with at least one pure-interval
    constant resolves against the sorted store permutations (core/index.py
    via the view): POS/PSO for constant predicates, SPO/OSP for constant
    subject/object patterns with a *variable* predicate.  O(log N) host
    binary searches yield contiguous row ranges (base + delta, one per
    spill interval), and the device work is a single contiguous gather.
    The range lengths give the planner cardinalities with zero device
    passes.
  * ``scan``  — residual patterns (rewrite mode, member sets) stream the
    store once through the Pallas compaction kernel
    (kernels/stream_compact.py).  Simple interval predicates fuse the
    filter AND the tombstone mask into the same kernel pass; the
    compaction's total doubles as the match count, so there is no separate
    counting pass at execution time.

Every (mode, pattern-signature, capacity-bucket) combination is lowered to
ONE jitted executable and memoized in ``QueryEngine._exec_cache``: repeated
queries — and *parameterized* queries that differ only in constants, which
enter the trace as device scalars — reuse the compiled plan instead of
retracing XLA.  ``prewarm`` pre-traces the executables for a query set at
its natural capacity buckets (plus caller-chosen growth buckets), removing
the first-query-per-bucket cold start.

Beyond the paper (it declares join ordering out of scope): the planner joins
in ascending-cardinality order, which also gives capacity estimates.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.abox import EncodedKB
from repro.core.delta import StoreView
from repro.core.index import StoreIndex, key_cols, pow2_bucket as _pow2
from repro.core.materialize import DeviceTBox
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY

INVALID = jnp.int32(np.iinfo(np.int32).max)
_I32_MIN = int(np.iinfo(np.int32).min)
_I32_MAX = int(np.iinfo(np.int32).max)


def is_var(t) -> bool:
    return isinstance(t, str) and t.startswith("?")


def sig_label(sigs) -> str:
    """Compact, stable metric label for a plan's signature tuple.

    ``"<n>p:<hex10>"`` — pattern count plus a 10-hex-digit blake2s digest
    of the PatternSig tuple's repr.  PatternSig fields are primitives, so
    the repr (and hence the label) is identical across processes: the
    per-signature compile/retry metrics labelled with it merge cleanly in
    a fleet aggregation, and label cardinality stays bounded by the number
    of distinct plans rather than distinct queries.
    """
    digest = hashlib.blake2s(repr(tuple(sigs)).encode(),
                             digest_size=5).hexdigest()
    return f"{len(sigs)}p:{digest}"


@dataclass(frozen=True)
class Pattern:
    s: object  # '?var' | name str | raw int id
    p: object
    o: object


@dataclass
class Term:
    """A resolved pattern constant: interval [lo, hi) + optional spills/set."""

    lo: int
    hi: int
    spills: tuple = ()  # ((lo, hi), ...)
    members: np.ndarray | None = None  # explicit id set (rewrite mode)

    def intervals(self):
        return [(self.lo, self.hi)] + list(self.spills)


# ---------------------------------------------------------------------------
# Static plan signatures vs dynamic (traced) constants
#
# A query plan is split into a hashable *signature* — everything that shapes
# the XLA computation — and a pytree of device scalars/arrays that enter the
# trace as arguments.  Two queries with the same signature share one
# compiled executable regardless of their constants.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TermSig:
    kind: str  # 'interval' | 'members'
    n_spills: int = 0
    mem_cap: int = 0  # padded power-of-two member-set length


@dataclass(frozen=True)
class PatternSig:
    pvars: tuple  # per-position var name or None
    strategy: str  # 'slice' | 'scan' | 'inl'
    s_sig: TermSig | None = None
    p_sig: TermSig | None = None
    o_sig: TermSig | None = None
    store: str = "pos"  # slice/inl: which sorted permutation
    k: int = 1  # slice: number of contiguous ranges
    residual: tuple = ()  # slice/inl: positions re-checked after the gather
    # rewrite type pattern: (dom_cap, rng_cap, has_dom, has_rng) — the flags
    # are static so empty domain/range branches compile to nothing
    extra_caps: tuple | None = None
    fused: bool = False  # scan: predicate fused into the compaction kernel
    probe_pos: int = -1  # inl: pattern position the bound var probes (0|2)
    n_pids: int = 0  # inl: how many distinct store pids are probed


def _clip32(v) -> int:
    return int(np.clip(int(v), _I32_MIN, _I32_MAX))


def _pad_set(ids: np.ndarray):
    """Sorted id set -> (pow2 bucket, INT32_MAX-padded device array)."""
    cap = _pow2(len(ids))
    out = np.full(cap, _I32_MAX, np.int32)
    out[: len(ids)] = ids
    return cap, jnp.asarray(out)


def _lower_term(t: Term | None):
    """Host Term -> (static TermSig, traced int32 array) or (None, None)."""
    if t is None:
        return None, None
    if t.members is not None:
        cap, mem = _pad_set(t.members)
        return TermSig("members", mem_cap=cap), mem
    vals = [_clip32(t.lo), _clip32(t.hi)]
    for lo, hi in t.spills:
        vals += [_clip32(lo), _clip32(hi)]
    return (TermSig("interval", n_spills=len(t.spills)),
            jnp.asarray(np.asarray(vals, np.int32)))


def _term_mask_dyn(col, sig: TermSig, vals):
    """Per-column membership mask with traced bounds (spill count static)."""
    if sig.kind == "members":
        pos = jnp.clip(jnp.searchsorted(vals, col), 0, vals.shape[0] - 1)
        return (vals[pos] == col) & (col != INVALID)
    m = (col >= vals[0]) & (col < vals[1])
    for i in range(sig.n_spills):
        m = m | ((col >= vals[2 + 2 * i]) & (col < vals[3 + 2 * i]))
    return m


def _in_set(col, arr):
    """Sorted-membership test; arr is INT32_MAX-padded (possibly all-pad)."""
    pos = jnp.clip(jnp.searchsorted(arr, col), 0, arr.shape[0] - 1)
    return (arr[pos] == col) & (col != INVALID)


def _pattern_const_key(terms):
    """Hashable snapshot of a pattern's resolved constants.

    The probe-constant half of the ``(PatternSig, bucket)`` selectivity
    key: two patterns lowering to the same signature but resolving
    different constants (Q3's Professors vs Q4's Chairs) get distinct
    buckets, so one's observation never aliases the other's plan.
    """
    return tuple(
        None if t is None else
        (t.lo, t.hi, t.spills,
         None if t.members is None else t.members.tobytes())
        for t in terms)


def _type_rewrite_masks_dyn(spo, alive, mem, tid, dom, rng, has_dom, has_rng):
    """Rewrite-mode (?x rdf:type C): explicit ∪ domain ∪ range branches.

    Returns (mask_s, mask_o): rows binding ?x to their SUBJECT (explicit
    type triples and domain-entailing predicates) and rows binding ?x to
    their OBJECT (range-entailing predicates; None when the target has no
    range-entailing properties — statically known, so the branch compiles
    to nothing) — the full RDFS reformulation the paper's Q4' illustrates.
    The branches are NOT exclusive: a triple whose predicate entails the
    target through both its domain and its range contributes BOTH
    endpoints, so the two masks must be compacted separately (collapsing
    them to one row/one binding silently undercounts — the drift the
    differential oracle caught).
    """
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    valid = (s != INVALID) & alive
    m_s = (p == tid) & _in_set(o, mem)
    if has_dom:
        m_s = m_s | _in_set(p, dom)
    m_o = (_in_set(p, rng) & valid) if has_rng else None
    return m_s & valid, m_o


def _scan_mask(sig: PatternSig, spo, alive, dyn):
    """Full-store boolean mask for a scan pattern (non-fused path)."""
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    mask = (s != INVALID) & alive
    for tsig, col, key in ((sig.s_sig, s, "s"), (sig.p_sig, p, "p"),
                           (sig.o_sig, o, "o")):
        if tsig is not None:
            mask = mask & _term_mask_dyn(col, tsig, dyn[key])
    return mask, None


# ---------------------------------------------------------------------------
# Relations: struct-of-arrays with validity + overflow accounting
# ---------------------------------------------------------------------------


@dataclass
class Relation:
    vars: tuple  # var names, host
    cols: jnp.ndarray  # int32[n_vars, cap]
    valid: jnp.ndarray  # bool[cap]
    overflow: jnp.ndarray  # int32 scalar (rows that did not fit)

    @property
    def cap(self) -> int:
        return int(self.valid.shape[0])

    def col(self, v) -> jnp.ndarray:
        return self.cols[self.vars.index(v)]


def _build_relation(pvars, s, p, o, ok, total, cap: int) -> Relation:
    """Assemble a Relation from gathered columns + validity.

    Handles repeated variables within one pattern (equality constraint) the
    same way for both strategies.
    """
    cols = []
    seen = {}
    eq = None
    for v, colv in zip(pvars, (s, p, o)):
        if v is None:
            continue
        if v in seen:  # repeated var in one pattern: equality constraint
            eq = (seen[v], colv)
            continue
        seen[v] = colv
        cols.append(colv)
    if eq is not None:
        ok = ok & (eq[0] == eq[1])
    cols = [jnp.where(ok, c, INVALID) for c in cols]
    return Relation(
        vars=tuple(seen),
        cols=jnp.stack(cols) if cols else jnp.zeros((0, cap), jnp.int32),
        valid=ok,
        overflow=jnp.maximum(total - cap, 0),
    )


def _gather_ranges(base, base_alive, delta, delta_alive, starts, lens,
                   cap: int):
    """Concatenate k contiguous row ranges of a sorted view into [cap] rows.

    Ranges address the virtual [base | delta-bucket] concatenation (delta
    offset by the base row count); rows resolve through a two-source gather
    so the base array is never physically concatenated with the delta.
    Liveness filters tombstoned rows out of the gathered slice: dead rows
    keep their slot (totals stay exact range lengths for overflow
    accounting) but are invalidated before the relation is built.
    """
    src, ok, total, _ = ops.segment_positions(starts, lens, cap)
    rows = ops.two_source_gather(base, delta, src)
    alive = ops.two_source_gather(base_alive, delta_alive, src)
    return rows, ok & alive, total


def _stitch_compact(take_b, total_b, take_d, total_d, base_n: int, cap: int):
    """Fuse two per-source compactions into one combined-coordinate take.

    Base matches come first (they are base-store row indices as-is), delta
    matches follow offset by ``base_n`` — the same combined addressing the
    range lookups use, so downstream gathers are shared with the slice path.
    """
    j = jnp.arange(cap, dtype=jnp.int32)
    use_b = j < total_b
    di = jnp.clip(j - total_b, 0, cap - 1)
    take = jnp.where(use_b, take_b, base_n + take_d[di])
    total = total_b + total_d
    return take, j < jnp.minimum(total, cap), total


def _masked_compact_both(ds, mask_b, mask_d, cap: int):
    """Compact one mask per source and stitch into combined coordinates."""
    take_b, ok_b, tb = ops.compact_indices(
        mask_b, cap, block=ops.auto_block(mask_b.shape[0]))
    if mask_d is None:  # delta-free view: single-source plan
        return take_b, ok_b, tb
    take_d, _, td = ops.compact_indices(
        mask_d, cap, block=ops.auto_block(mask_d.shape[0]))
    return _stitch_compact(take_b, tb, take_d, td, ds.base.shape[0], cap)


def _dual_masked_compact_both(ds, ms_b, mo_b, ms_d, mo_d, cap: int):
    """Compact BOTH rewrite branches of each source in one dual-mask pass.

    The subject-binding and object-binding masks cover the same rows, so
    the dual-mask kernel emits both compacted streams per tile — one grid
    pass over each source instead of two.  Returns the two stitched
    (take, ok, total) triples in combined [base | delta] coordinates.
    """
    take_s_b, ok_s_b, ts_b, take_o_b, ok_o_b, to_b = ops.dual_compact_indices(
        ms_b, mo_b, cap, block=ops.auto_block(ms_b.shape[0]))
    if ms_d is None:  # delta-free view
        return (take_s_b, ok_s_b, ts_b), (take_o_b, ok_o_b, to_b)
    take_s_d, _, ts_d, take_o_d, _, to_d = ops.dual_compact_indices(
        ms_d, mo_d, cap, block=ops.auto_block(ms_d.shape[0]))
    base_n = ds.base.shape[0]
    return (_stitch_compact(take_s_b, ts_b, take_s_d, ts_d, base_n, cap),
            _stitch_compact(take_o_b, to_b, take_o_d, to_d, base_n, cap))


def _rewrite_type_bindings(sig: PatternSig, ds, dyn, cap: int):
    """Rewrite-mode type pattern -> (ok, total, xcol of ?x bindings).

    Subject-binding rows (explicit/domain) and object-binding rows (range)
    are compacted INDEPENDENTLY per source and their bound values stitched:
    a row entailing the target through both branches yields two bindings.
    Both branches' member-set predicates are fused INTO the compaction
    kernel (``ops.rewrite_member_compact``): the sorted id sets stay
    on-chip and each tile resolves its own membership tests, so the
    full-store boolean masks the old ``_in_set`` path materialized before
    compacting no longer exist (``_type_rewrite_masks_dyn`` survives only
    for the planner's counting pass).
    """
    _, _, has_dom, has_rng = sig.extra_caps
    mem, tid = dyn["o"], dyn["tid"]
    dom, rng = dyn["dom"], dyn["rng"]
    base_n = ds.base.shape[0]
    out_b = ops.rewrite_member_compact(
        ds.base, ds.base_alive, tid, mem, dom, rng, cap, has_dom, has_rng,
        block=ops.auto_block(base_n))
    out_d = None
    if ds.delta is not None:
        out_d = ops.rewrite_member_compact(
            ds.delta, ds.delta_alive, tid, mem, dom, rng, cap, has_dom,
            has_rng, block=ops.auto_block(ds.delta.shape[0]))
    if not has_rng:  # no object branch: the subject stream is the answer
        take_s, ok_s, total_s = out_b
        if out_d is not None:
            take_s, ok_s, total_s = _stitch_compact(
                out_b[0], out_b[2], out_d[0], out_d[2], base_n, cap)
        vals_s = ops.two_source_gather(ds.base, ds.delta, take_s)[:, 0]
        return ok_s, total_s, vals_s
    take_s, ok_s, total_s = out_b[0:3]
    take_o, total_o = out_b[3], out_b[5]
    if out_d is not None:
        take_s, ok_s, total_s = _stitch_compact(
            out_b[0], out_b[2], out_d[0], out_d[2], base_n, cap)
        take_o, _, total_o = _stitch_compact(
            out_b[3], out_b[5], out_d[3], out_d[5], base_n, cap)
    vals_s = ops.two_source_gather(ds.base, ds.delta, take_s)[:, 0]
    vals_o = ops.two_source_gather(ds.base, ds.delta, take_o)[:, 2]
    j = jnp.arange(cap, dtype=jnp.int32)
    use_s = j < total_s
    vo = vals_o[jnp.clip(j - total_s, 0, cap - 1)]
    xcol = jnp.where(use_s, vals_s, vo)
    total = total_s + total_o
    return j < jnp.minimum(total, cap), total, xcol


def _scan_compact(sig: PatternSig, ds, dyn, cap: int):
    """Scan both sources of a view key -> (take, ok, total)."""
    base_n = ds.base.shape[0]
    if sig.fused:
        pv, ov = dyn.get("p"), dyn.get("o")
        plo = pv[0] if pv is not None else jnp.int32(_I32_MIN)
        phi = pv[1] if pv is not None else jnp.int32(_I32_MAX)
        olo = ov[0] if ov is not None else jnp.int32(_I32_MIN)
        ohi = ov[1] if ov is not None else jnp.int32(_I32_MAX)
        params = jnp.stack([plo, phi, olo, ohi]).astype(jnp.int32)
        take_b, ok_b, tb = ops.masked_interval_compact(
            ds.base[:, 1], ds.base[:, 2], ds.base_alive, params, cap,
            block=ops.auto_block(base_n))
        if ds.delta is None:
            return take_b, ok_b, tb
        take_d, _, td = ops.masked_interval_compact(
            ds.delta[:, 1], ds.delta[:, 2], ds.delta_alive, params, cap,
            block=ops.auto_block(ds.delta.shape[0]))
        return _stitch_compact(take_b, tb, take_d, td, base_n, cap)
    mask_b, _ = _scan_mask(sig, ds.base, ds.base_alive, dyn)
    mask_d = (None if ds.delta is None
              else _scan_mask(sig, ds.delta, ds.delta_alive, dyn)[0])
    return _masked_compact_both(ds, mask_b, mask_d, cap)


def _eval_pattern(sig: PatternSig, cap: int, stores, dyn):
    """One pattern -> (Relation, match count), inside the jitted executable."""
    if sig.strategy == "slice":
        ds = stores[sig.store]
        g, ok, total = _gather_ranges(ds.base, ds.base_alive, ds.delta,
                                      ds.delta_alive, dyn["starts"],
                                      dyn["lens"], cap)
        s, p, o = g[:, 0], g[:, 1], g[:, 2]
        for posi in sig.residual:
            tsig = (sig.s_sig, sig.p_sig, sig.o_sig)[posi]
            key = ("s", "p", "o")[posi]
            ok = ok & _term_mask_dyn((s, p, o)[posi], tsig, dyn[key])
        return _build_relation(sig.pvars, s, p, o, ok, total, cap), total

    ds = stores["scan"]
    if sig.extra_caps is not None:  # rewrite-mode type pattern (?x rdf:type C)
        ok, total, xcol = _rewrite_type_bindings(sig, ds, dyn, cap)
        var = next(v for v in sig.pvars if v is not None)
        cols = [jnp.where(ok, xcol, INVALID)]
        rel = Relation(vars=(var,), cols=jnp.stack(cols), valid=ok,
                       overflow=jnp.maximum(total - cap, 0))
        return rel, total
    take, ok, total = _scan_compact(sig, ds, dyn, cap)
    g = ops.two_source_gather(ds.base, ds.delta, take)
    return _build_relation(sig.pvars, g[:, 0], g[:, 1], g[:, 2], ok, total,
                           cap), total


# Above this many rows, INL probes take the windowed pair search (the
# merge-path-partitioned reuse in kernels/ops.py) instead of the resident
# kernel whose table planes must fit in VMEM — the last whole-table VMEM
# residency in the query path, now a dispatch bound instead of a planner
# disqualifier.
INL_RESIDENT_MAX = 1 << 20


def _inl_ranges(ds, prim: int, sec: int, qhi, qlo, valid):
    """Probe one source's key planes -> (starts, lens), all pids batched.

    The sorted permutation's key planes are simply two columns of its
    device-resident rows (core/index.py::key_cols), so the rows matching
    (pid, key) form a composite-key range — start at (pid, key), end at
    (pid, key + 1).  ``qhi``/``qlo``/``valid`` carry ALL pid groups
    concatenated (k probes per pid), so one source costs exactly two
    pair-search launches regardless of how many pids are probed.
    Invalid probe rows get zero-length ranges.  Tables past
    ``INL_RESIDENT_MAX`` rows probe through the windowed (merge-path
    partitioned) search — O(block) VMEM at any table size.
    """
    t_hi, t_lo = ds[:, prim], ds[:, sec]
    search = (ops.pair_search_windowed if ds.shape[0] > INL_RESIDENT_MAX
              else ops.pair_search)
    starts = search(t_hi, t_lo, qhi, qlo)
    ends = search(t_hi, t_lo, qhi, qlo + 1)
    lens = jnp.where(valid, jnp.maximum(ends - starts, 0), 0)
    return starts, lens


def _eval_inl(sig: PatternSig, cap: int, stores, dyn, rel: Relation):
    """Index-nested-loop join: probe a sorted store with the current relation.

    Returns (joined Relation, match count) — the count is the expanded hit
    total before capacity clipping, the INL analogue of ``_eval_pattern``'s
    per-pattern total (EXPLAIN reads both through the executable).

    The Q4-style fallback: when the accumulated relation is tiny next to a
    pattern's row count, evaluating the pattern in full (a huge slice or
    scan) just to sort-merge-join it away is wasted work.  Instead, each
    bound value of the shared variable probes the pattern's composite-key
    permutation (PSO for a subject probe, POS for an object probe) with the
    pair-search kernel; the hit ranges expand through one segment mapping,
    and every output row carries its probe row's bindings plus the
    pattern's newly bound columns.  Both view sources are probed (delta
    ranges offset by the base row count) and tombstones filter through the
    gathered liveness bits — semantics identical to eval-then-join.
    """
    ds = stores[sig.store]
    prim, sec = key_cols(sig.store)
    var = sig.pvars[sig.probe_pos]
    probe = rel.col(var)
    k = probe.shape[0]
    pid_arr = dyn["pid"]  # int32[n_pids] — distinct store ids in the interval
    qlo1 = jnp.where(rel.valid, probe, 0)  # avoid key+1 overflow on INVALID
    base_n = ds.base.shape[0]
    # one probe batch per pid, concatenated: [pid0 x k, pid1 x k, ...] —
    # a source then costs two pair-search launches total (not per pid)
    valid = jnp.tile(rel.valid, sig.n_pids)
    qlo = jnp.tile(qlo1, sig.n_pids)
    qhi = jnp.where(valid, jnp.repeat(pid_arr, k), INVALID)
    seg_starts, seg_lens = [], []
    for src_rows, offset in (((ds.base, 0),) if ds.delta is None
                             else ((ds.base, 0), (ds.delta, base_n))):
        st, ln = _inl_ranges(src_rows, prim, sec, qhi, qlo, valid)
        seg_starts.append(st + offset)
        seg_lens.append(ln)
    starts = jnp.concatenate(seg_starts)
    lens = jnp.concatenate(seg_lens)
    src, ok, total, seg = ops.segment_positions(starts, lens, cap)
    rows = ops.two_source_gather(ds.base, ds.delta, src)
    alive = ops.two_source_gather(ds.base_alive, ds.delta_alive, src)
    ok = ok & alive
    probe_row = jnp.mod(seg, k)  # every segment group is one probe batch

    s, p, o = rows[:, 0], rows[:, 1], rows[:, 2]
    for posi in sig.residual:  # constant terms re-checked on the hit rows
        tsig = (sig.s_sig, sig.p_sig, sig.o_sig)[posi]
        key = ("s", "p", "o")[posi]
        ok = ok & _term_mask_dyn((s, p, o)[posi], tsig, dyn[key])

    carried = rel.cols[:, probe_row]  # probe bindings ride along
    out_vars = list(rel.vars)
    out_cols = [carried[i] for i in range(len(rel.vars))]
    seen = dict(zip(rel.vars, out_cols))
    for v, colv in zip(sig.pvars, (s, p, o)):
        if v is None:
            continue
        if v in seen:  # shared var: probe key (equal by construction) or
            ok = ok & (seen[v] == colv)  # a repeated var inside the pattern
            continue
        seen[v] = colv
        out_vars.append(v)
        out_cols.append(colv)
    out_cols = [jnp.where(ok, c, INVALID) for c in out_cols]
    return Relation(
        vars=tuple(out_vars),
        cols=jnp.stack(out_cols),
        valid=ok,
        overflow=rel.overflow + jnp.maximum(total - cap, 0),
    ), total


def scan_relation(spo, pattern_vars, pat_terms, mode: str, cap: int, extra=None):
    """Filter the store and compact matching rows into a Relation.

    Standalone oracle entry point (the engine lowers patterns once and runs
    them through cached executables instead).
    """
    from repro.core.delta import DevStore

    sig, dyn = _lower_scan(pattern_vars, pat_terms, extra, mode)
    stores = {"scan": DevStore(
        base=spo,
        base_alive=jnp.ones(spo.shape[0], dtype=bool),
        delta=None,
        delta_alive=None,
    )}
    rel, total = _eval_pattern(sig, cap, stores, dyn)
    return rel, total


def _lower_scan(pvars, terms, extra, mode: str):
    """Lower one pattern to a scan signature + traced constants."""
    s_sig, s_dyn = _lower_term(terms[0])
    p_sig, p_dyn = _lower_term(terms[1])
    o_sig, o_dyn = _lower_term(terms[2])
    dyn = {}
    if s_dyn is not None:
        dyn["s"] = s_dyn
    if p_dyn is not None:
        dyn["p"] = p_dyn
    if o_dyn is not None:
        dyn["o"] = o_dyn
    if extra is not None:
        tid, dom, rng = extra
        dom_cap, dom_arr = _pad_set(dom)
        rng_cap, rng_arr = _pad_set(rng)
        dyn.update(tid=jnp.int32(tid), dom=dom_arr, rng=rng_arr)
        return PatternSig(
            pvars=pvars, strategy="scan", o_sig=o_sig,
            extra_caps=(dom_cap, rng_cap, bool(len(dom)), bool(len(rng))),
        ), dyn
    # litemat/full stores are compacted (no INVALID rows), so pure-interval
    # predicates on p/o can fuse into the compaction kernel's one pass
    fused = (
        mode in ("litemat", "full")
        and s_sig is None
        and (p_sig is None or (p_sig.kind == "interval" and p_sig.n_spills == 0))
        and (o_sig is None or (o_sig.kind == "interval" and o_sig.n_spills == 0))
    )
    return PatternSig(pvars=pvars, strategy="scan", s_sig=s_sig, p_sig=p_sig,
                      o_sig=o_sig, fused=fused), dyn


def join(a: Relation, b: Relation, cap: int, a_sorted: bool = False) -> Relation:
    """Sort-merge equi-join on all shared vars (first var = sort key).

    ``a_sorted=True`` asserts the build side already sits in ascending
    ``shared[0]`` order with invalid rows last (the shard combine produces
    exactly that via the partitioned-merge kernel), skipping the argsort.
    """
    shared = [v for v in a.vars if v in b.vars]
    if not shared:
        raise ValueError("cartesian products not supported — reorder the plan")
    key = shared[0]

    # sort build side (a) by key; invalid rows sink
    ka = jnp.where(a.valid, a.col(key), INVALID)
    if a_sorted:
        a_cols, ka_s = a.cols, ka
    else:
        aperm = jnp.argsort(ka)
        a_cols = a.cols[:, aperm]
        ka_s = ka[aperm]

    kb_ = jnp.where(b.valid, b.col(key), INVALID)
    L = jnp.searchsorted(ka_s, kb_, side="left")
    R = jnp.searchsorted(ka_s, kb_, side="right")
    counts = jnp.where(b.valid & (kb_ != INVALID), R - L, 0)
    offsets = jnp.cumsum(counts)
    total = offsets[-1]
    starts = offsets - counts

    # expand: output slot -> (probe row, match rank)
    out_idx = jnp.arange(cap, dtype=jnp.int32)
    probe = jnp.searchsorted(offsets, out_idx, side="right")
    probe_c = jnp.clip(probe, 0, counts.shape[0] - 1)
    rank = out_idx - starts[probe_c]
    build_row = jnp.clip(L[probe_c] + rank, 0, ka_s.shape[0] - 1)
    ok = out_idx < jnp.minimum(total, cap)

    # verify remaining shared vars
    a_g = a_cols[:, build_row]
    b_g = b.cols[:, probe_c]
    for v in shared[1:]:
        ok = ok & (a_g[a.vars.index(v)] == b_g[b.vars.index(v)])

    out_vars = tuple(a.vars) + tuple(v for v in b.vars if v not in a.vars)
    rows = [jnp.where(ok, a_g[i], INVALID) for i in range(len(a.vars))]
    for j, v in enumerate(b.vars):
        if v not in a.vars:
            rows.append(jnp.where(ok, b_g[j], INVALID))
    overflow = jnp.maximum(total - cap, 0) + a.overflow + b.overflow
    return Relation(vars=out_vars, cols=jnp.stack(rows), valid=ok, overflow=overflow)


def distinct(rel: Relation, select: tuple, cap: int) -> Relation:
    """Project onto ``select`` vars and deduplicate rows."""
    cols = [jnp.where(rel.valid, rel.col(v), INVALID) for v in select]
    perm = jnp.lexsort(tuple(reversed(cols)))
    cols = [c[perm] for c in cols]
    valid = rel.valid[perm]
    neq = jnp.zeros(valid.shape[0] - 1, dtype=bool)
    for c in cols:
        neq = neq | (c[1:] != c[:-1])
    first = jnp.concatenate([jnp.ones((1,), bool), neq])
    keep = first & valid
    take, ok, n = ops.compact_indices(keep, cap)
    out = jnp.stack([jnp.where(ok, c[take], INVALID) for c in cols])
    return Relation(
        vars=select, cols=out, valid=ok,
        overflow=rel.overflow + jnp.maximum(n - cap, 0),
    )


# ---------------------------------------------------------------------------
# The engine: host-side resolution + planning, device execution
# ---------------------------------------------------------------------------


@dataclass
class QueryEngine:
    kb: EncodedKB
    spo: jnp.ndarray  # the store to query (lite / full / original)
    mode: str = "litemat"  # litemat | full | rewrite
    dtb: DeviceTBox | None = None
    slack: float = 1.5
    use_index: bool = True  # resolve eligible patterns via sorted indexes
    use_inl: bool = True  # index-nested-loop joins when one side is tiny
    inl_factor: int = 8  # pattern must outweigh the probe side by this much
    inl_max_probe: int = 4096  # never INL above this probe-side estimate
    view: StoreView | None = None  # live base+delta view (None: static store)
    _exec_cache: dict = field(default_factory=dict, repr=False)
    cache_stats: dict = field(default_factory=lambda: {"hits": 0, "misses": 0},
                              repr=False)
    # (PatternSig, probe-constant bucket) -> last observed selectivity
    # (observed rows / store rows); filled by every successful run/explain,
    # read by planner consumers.  The bucket is the tuple of
    # ``_pattern_const_key`` snapshots of every pattern up to and including
    # this one in plan order — the probe side's provenance — so two probe
    # sides sharing one signature (Q3's Professors, Q4's Chairs) never
    # alias each other's observation.
    observed_selectivity: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.dtb is None and self.kb.tbox is not None:
            self.dtb = DeviceTBox.build(self.kb.tbox)
        if self.view is None:
            self.view = StoreView.static(self.spo)

    def set_view(self, view: StoreView) -> None:
        """Swap in a fresh store view after a mutation.

        The plan cache survives: executables are keyed on signatures and
        capacity buckets, and jit re-specializes on the new store shapes
        only where they actually changed (delta buckets are powers of two
        precisely to keep that rare).
        """
        self.view = view
        self.spo = view.base_rows

    @property
    def index(self) -> StoreIndex:
        """Sorted permutations of this engine's base store."""
        return self.view.base_index

    # -- constant resolution (context-aware, paper §III intro) --------------
    def _resolve(self, term, position: str, type_pattern: bool) -> Term:
        tbox = self.kb.tbox
        if isinstance(term, (int, np.integer)):
            return Term(lo=int(term), hi=int(term) + 1)
        name = term
        if position == "p" and tbox is not None:
            enc = tbox.properties
        elif position == "o" and type_pattern and tbox is not None:
            enc = tbox.concepts
        else:
            enc = None
        if enc is not None and (name in enc.name_to_id or name in enc.tax.merged):
            if self.mode == "rewrite":
                return Term(lo=0, hi=0, members=np.sort(np.array(enc.subsumees(name), dtype=np.int32)))
            if self.mode == "full":
                i = enc.id_of(name)
                return Term(lo=i, hi=i + 1)
            (lo, hi), spills = enc.interval_of(name)
            return Term(lo=lo, hi=hi, spills=tuple(spills))
        ids = self.kb.locate([name])
        if ids[0] < 0:
            raise KeyError(f"unknown term {name!r}")
        return Term(lo=int(ids[0]), hi=int(ids[0]) + 1)

    def _prepare(self, patterns):
        """Resolve constants; attach rewrite extras for type patterns."""
        prepared = []
        for pat in patterns:
            p_is_const = not is_var(pat.p)
            type_pat = p_is_const and self.kb.tbox is not None and (
                pat.p in ("rdf:type", "a") or pat.p == self.kb.tbox.rdf_type_id
            )
            terms = (
                None if is_var(pat.s) else self._resolve(pat.s, "s", False),
                None if is_var(pat.p) else self._resolve(pat.p, "p", type_pat),
                None if is_var(pat.o) else self._resolve(pat.o, "o", type_pat),
            )
            pvars = tuple(t if is_var(t) else None for t in (pat.s, pat.p, pat.o))
            extra = None
            if self.mode == "rewrite" and type_pat and terms[2] is not None and is_var(pat.s):
                extra = self._rewrite_extra(terms[2])
            prepared.append((pvars, terms, extra))
        return prepared

    def _rewrite_extra(self, o_term: Term):
        """Property sets whose (effective) domain/range entails the target."""
        tbox = self.kb.tbox
        targets = set(o_term.members.tolist())
        dom_set, rng_set = [], []
        dr_ids = np.asarray(self.dtb.dr_prop_ids)
        dom_tbl = np.asarray(self.dtb.domain_table)
        rng_tbl = np.asarray(self.dtb.range_table)
        penc = tbox.properties
        for i, pid in enumerate(dr_ids.tolist()):
            if pid < 0:
                continue
            doms = [v for v in dom_tbl[i].tolist() if v >= 0]
            rngs = [v for v in rng_tbl[i].tolist() if v >= 0]
            subs = penc.subsumees(penc.name_of(pid))  # sub-properties inherit
            if any(d in targets for d in doms):
                dom_set.extend(subs)
            if any(r in targets for r in rngs):
                rng_set.extend(subs)
        return (
            int(tbox.rdf_type_id),
            np.sort(np.unique(np.array(dom_set, dtype=np.int32))),
            np.sort(np.unique(np.array(rng_set, dtype=np.int32))),
        )

    # -- pattern lowering: strategy choice + cardinality ---------------------
    def _lower(self, pvars, terms, extra):
        """-> (PatternSig, dyn pytree, host count or None).

        ``count`` is exact* and free (range lengths) for slice patterns
        (*an upper bound when tombstones sit inside a range); scan patterns
        report None and are counted by one cached device pass.
        """
        s_t, p_t, o_t = terms
        indexable = (
            self.use_index
            and extra is None
            and self.mode in ("litemat", "full")
            and all(t is None or t.members is None for t in terms)
        )
        if indexable and p_t is not None:
            view = self.view
            # effective predicate id: exact single-width interval, or a wide
            # interval whose store run holds only one distinct predicate
            # (the common rdf:type case) — both collapse to composite ranges
            pid = p_t.lo if (p_t.hi == p_t.lo + 1 and not p_t.spills) else None
            if pid is None and not p_t.spills:
                pid = view.single_p_run(p_t.lo, p_t.hi)
            ranges = None
            store = "pos"
            residual = ()
            o_sig = o_dyn = None
            if s_t is None and o_t is None:
                ranges = [r for a, b in p_t.intervals()
                          for r in view.p_ranges(a, b)]
            elif s_t is None and o_t is not None:
                if pid is not None:
                    ranges = [r for a, b in o_t.intervals()
                              for r in view.po_ranges(pid, a, b)]
                else:  # mixed p run sliced, o re-checked on the gathered rows
                    ranges = [r for a, b in p_t.intervals()
                              for r in view.p_ranges(a, b)]
                    residual = (2,)
                    o_sig, o_dyn = _lower_term(o_t)
            elif s_t is not None and pid is not None:
                ranges = [r for a, b in s_t.intervals()
                          for r in view.ps_ranges(pid, a, b)]
                store = "pso"
                if o_t is not None:  # o re-checked on the gathered rows
                    residual = (2,)
                    o_sig, o_dyn = _lower_term(o_t)
            if ranges is not None:
                return self._slice_plan(pvars, ranges, store, residual,
                                        o_sig=o_sig, o_dyn=o_dyn)
        if indexable and p_t is None and (s_t is not None or o_t is not None):
            # variable predicate: SPO (constant subject) / OSP (constant
            # object) permutations keep these off the full-scan path
            view = self.view
            if s_t is not None:
                ranges = [r for a, b in s_t.intervals()
                          for r in view.s_ranges(a, b)]
                store = "spo"
                residual, o_sig, o_dyn = (), None, None
                if o_t is not None:  # (s ?p o): o re-checked after the gather
                    residual = (2,)
                    o_sig, o_dyn = _lower_term(o_t)
                return self._slice_plan(pvars, ranges, store, residual,
                                        o_sig=o_sig, o_dyn=o_dyn)
            ranges = [r for a, b in o_t.intervals()
                      for r in view.o_ranges(a, b)]
            return self._slice_plan(pvars, ranges, "osp", ())
        sig, dyn = _lower_scan(pvars, terms, extra, self.mode)
        return sig, dyn, None

    @staticmethod
    def _slice_plan(pvars, ranges, store, residual, o_sig=None, o_dyn=None):
        lens = [max(r1 - r0, 0) for r0, r1 in ranges]
        sig = PatternSig(pvars=pvars, strategy="slice", store=store,
                         k=len(ranges), o_sig=o_sig, residual=residual)
        dyn = {
            "starts": jnp.asarray([r0 for r0, _ in ranges], jnp.int32),
            "lens": jnp.asarray(lens, jnp.int32),
        }
        if o_dyn is not None:
            dyn["o"] = o_dyn
        return sig, dyn, sum(lens)

    def _pattern_count(self, sig: PatternSig, dyn) -> int:
        """Planning cardinality of a scan pattern (cached jitted reduction)."""
        if self.view.n == 0:  # empty store (e.g. a fresh shard): no device pass
            return 0
        key = ("count", sig)
        fn = self._exec_cache.get(key)
        if fn is None:
            def count_device(ds, d, _sig=sig):
                sources = [(ds.base, ds.base_alive)]
                if ds.delta is not None:
                    sources.append((ds.delta, ds.delta_alive))
                total = jnp.int32(0)
                for spo, alive in sources:
                    if _sig.extra_caps is not None:
                        # a row can bind through BOTH branches: count both
                        ms, mo = _type_rewrite_masks_dyn(
                            spo, alive, d["o"], d["tid"], d["dom"],
                            d["rng"], _sig.extra_caps[2], _sig.extra_caps[3])
                        total += ms.astype(jnp.int32).sum()
                        if mo is not None:
                            total += mo.astype(jnp.int32).sum()
                    else:
                        m, _ = _scan_mask(_sig, spo, alive, d)
                        total += m.astype(jnp.int32).sum()
                return total
            fn = jax.jit(count_device)
            self._exec_cache[key] = fn
        return int(fn(self.view.dev("scan"), dyn))

    @staticmethod
    def _make_run_device(sigs, caps, join_cap: int, select):
        """Build the device-side plan body shared by the solo and batched
        executables.

        The function returns (cols, valid, overflow, totals): ``totals``
        is int32[n_patterns] — each pattern's OBSERVED match count before
        capacity clipping, in plan order — computed inside the same trace
        (no extra device pass; the scalars ride the overflow fetch).
        EXPLAIN and the selectivity capture read their observed-vs-estimated
        row counts off it.
        """

        def run_device(stores, dyns):
            rel = None
            totals = []
            for sig, cap, dyn in zip(sigs, caps, dyns):
                if sig.strategy == "inl":  # consumes the running relation
                    rel, t = _eval_inl(sig, cap, stores, dyn, rel)
                else:
                    r, t = _eval_pattern(sig, cap, stores, dyn)
                    rel = r if rel is None else join(rel, r, join_cap)
                totals.append(t)
            out = distinct(rel, select, join_cap)
            return (out.cols, out.valid, out.overflow,
                    jnp.stack(totals).astype(jnp.int32))

        return run_device

    @staticmethod
    def _timed_compile(fn, label: str, kind: str):
        """Wrap a fresh jitted plan so its FIRST call — the one that pays
        trace+compile — is timed into ``query/compile_seconds{sig=}``.

        jax.jit compiles lazily, so the only honest place to measure is
        the first dispatch; ``block_until_ready`` there folds device
        execution into the sample, but compile dominates by orders of
        magnitude and the sync happens exactly once per executable.
        """
        state = {"pending": True}

        def wrapper(*args):
            if not state["pending"]:
                return fn(*args)
            state["pending"] = False
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            REGISTRY.counter("query/compiles", sig=label, kind=kind).inc()
            REGISTRY.histogram("query/compile_seconds",
                               sig=label).observe(dt)
            return out

        return wrapper

    def _executable(self, key, sigs, caps, join_cap: int, select):
        """Memoized jitted plan: signature + buckets -> compiled function."""
        fn = self._exec_cache.get(key)
        slabel = sig_label(sigs)
        if fn is None:
            self.cache_stats["misses"] += 1
            REGISTRY.counter("query/plan_cache", event="miss",
                             sig=slabel).inc()
            fn = self._timed_compile(
                jax.jit(self._make_run_device(sigs, caps, join_cap, select)),
                slabel, "solo")
            self._exec_cache[key] = fn
        else:
            self.cache_stats["hits"] += 1
            REGISTRY.counter("query/plan_cache", event="hit",
                             sig=slabel).inc()
        return fn

    def _batch_executable(self, key, sigs, caps, join_cap: int, select):
        """Memoized VMAPPED plan: one dispatch answers a whole request batch.

        The stores axis is shared (all batch members execute against the
        same pinned view); the dyn-constant pytree carries a leading batch
        axis.  Every kernel in the plan body (stream compaction, merge
        path, pair search) lifts through ``jax.vmap``, so a batch of B
        same-signature requests costs ONE XLA dispatch instead of B.
        """
        fn = self._exec_cache.get(key)
        slabel = sig_label(sigs)
        if fn is None:
            self.cache_stats["misses"] += 1
            REGISTRY.counter("query/plan_cache", event="miss_batch",
                             sig=slabel).inc()
            fn = self._timed_compile(
                jax.jit(jax.vmap(
                    self._make_run_device(sigs, caps, join_cap, select),
                    in_axes=(None, 0))),
                slabel, "batch")
            self._exec_cache[key] = fn
        else:
            self.cache_stats["hits"] += 1
            REGISTRY.counter("query/plan_cache", event="hit_batch",
                             sig=slabel).inc()
        return fn

    @staticmethod
    def _bucket(n: int) -> int:
        return _pow2(n, floor=256)

    @staticmethod
    def _plan_order(prepared, counts):
        """Greedy join order: smallest first, stay connected when possible."""
        remaining = list(range(len(prepared)))
        remaining.sort(key=lambda i: counts[i])
        order = [remaining.pop(0)]
        bound_vars = set(v for v in prepared[order[0]][0] if v)
        while remaining:
            connected = [i for i in remaining if bound_vars & {v for v in prepared[i][0] if v}]
            pick = min(connected or remaining, key=lambda i: counts[i])
            remaining.remove(pick)
            order.append(pick)
            bound_vars |= {v for v in prepared[pick][0] if v}
        return order

    def _stores(self, sigs):
        """DevStores the executable takes as inputs, keyed per signature.

        Each key resolves through the view's device cache: the base arrays
        are the resident index copies and only the O(delta) bucket (plus
        any tombstone scatters) moves per mutation.
        """
        v = self.view
        stores = {}
        if any(sig.strategy == "scan" for sig in sigs):
            stores["scan"] = v.dev("scan")
        for perm in {sig.store for sig in sigs
                     if sig.strategy in ("slice", "inl")}:
            stores[perm] = v.dev(perm)
        return stores

    def _inl_pids(self, p_t: Term, limit: int = 4):
        """Distinct store predicate ids of a constant p term, or None.

        A LiteMat property interval usually covers a handful of store ids
        (the property and its sub-properties); each becomes one composite-
        key probe group.  None (too many / spilled) leaves the pattern on
        its slice or scan strategy.
        """
        if p_t.spills:
            return None
        if p_t.hi == p_t.lo + 1:
            return [p_t.lo]
        return self.view.distinct_p_ids(p_t.lo, p_t.hi, limit)

    def _apply_inl(self, prepared, lowered, counts, order, ckeys):
        """Convert eligible joins to index-nested-loop probes (in place).

        Walking the join order with a running probe-side estimate (the
        smallest relation seen so far — the greedy order starts tiny), a
        later pattern whose row count dwarfs that estimate is re-lowered
        from evaluate-then-merge-join to an INL probe of its composite-key
        permutation (PSO when the shared variable is the subject, POS when
        it is the object) — the Q4 shape: a huge (?x worksFor ?y) pattern
        probed by a handful of Chairs instead of materialized and sorted.
        Its planning count drops to the probe-side estimate times a fanout
        allowance, shrinking every downstream capacity (overflow retries
        still protect underestimates).

        Once a candidate probe shape has actually executed, its OBSERVED
        output row count (``observed_selectivity``, keyed by the INL
        PatternSig PLUS the probe-constant bucket — the const keys of
        every pattern walked so far, i.e. this probe side's provenance)
        feeds back into the call and then DECIDES alone: a pattern whose
        probe-side ESTIMATE was too big for the heuristic still converts
        when the observed INL output times ``inl_factor`` undercuts the
        merge-side row count, and a pattern the heuristic would have
        converted is VETOED when the observation says the probe fans out
        past the merge-side cost.  The bucket keying is what makes the
        veto safe: Q3's Professors and Q4's Chairs lower to the same
        worksFor signature but carry different upstream constants, so
        neither's observation can ever speak for the other.  Capacity is
        sized with a 2x margin over both the observation and the probe
        estimate; overflow retries protect the rest.
        """
        indexable = (self.use_inl and self.use_index
                     and self.mode in ("litemat", "full"))
        if not indexable or len(order) < 2:
            return
        store_n = max(self.view.n, 1)
        bound = {v for v in prepared[order[0]][0] if v}
        est = counts[order[0]]
        ctx = [ckeys[order[0]]]  # probe provenance: const keys walked so far
        for i in order[1:]:
            pvars, terms, extra = prepared[i]
            pat_vars = {v for v in pvars if v}
            heuristic = counts[i] >= self.inl_factor * max(est, 1)
            # candidate construction costs a distinct-pid probe, so only
            # pay it when the heuristic already says INL or when prior
            # observations exist that could overturn it
            eligible = (
                extra is None
                and est <= self.inl_max_probe
                and terms[1] is not None
                and all(t is None or t.members is None for t in terms)
                and (heuristic or bool(self.observed_selectivity))
            )
            if eligible:
                pids = self._inl_pids(terms[1])
                probe_pos = store = None
                if pids:
                    if pvars[0] is not None and pvars[0] in bound:
                        probe_pos, store = 0, "pso"
                        res_t, res_pos = terms[2], 2
                    elif pvars[2] is not None and pvars[2] in bound:
                        probe_pos, store = 2, "pos"
                        res_t, res_pos = terms[0], 0
                if probe_pos is not None:
                    dyn = {"pid": jnp.asarray(
                        np.asarray([_clip32(p) for p in pids], np.int32))}
                    residual = ()
                    r_sig = None
                    if res_t is not None:
                        r_sig, r_dyn = _lower_term(res_t)
                        residual = (res_pos,)
                        dyn[("s", "p", "o")[res_pos]] = r_dyn
                    sig = PatternSig(
                        pvars=pvars, strategy="inl", store=store,
                        probe_pos=probe_pos, residual=residual,
                        n_pids=len(pids),
                        s_sig=r_sig if res_pos == 0 else None,
                        o_sig=r_sig if res_pos == 2 else None,
                    )
                    bucket = tuple(ctx) + (ckeys[i],)
                    obs = self.observed_selectivity.get((sig, bucket))
                    if obs is not None:
                        # bucketed observation: it speaks for exactly this
                        # probe side, so it decides alone — including the
                        # veto of a heuristic-approved conversion
                        inl_rows = max(int(round(obs * store_n)), 1)
                        convert = inl_rows * self.inl_factor <= counts[i]
                        sized = max(inl_rows * 2, max(est, 1) * 2)
                        src = "observed"
                    else:
                        convert = heuristic
                        sized = max(est, 1) * 32
                        src = "estimate"
                    if convert:
                        REGISTRY.counter("planner/inl_decision",
                                         source=src).inc()
                        counts[i] = min(counts[i], sized)
                        lowered[i] = (sig, dyn, counts[i])
                    elif src == "observed" and heuristic:
                        REGISTRY.counter("planner/inl_decision",
                                         source="observed_veto").inc()
            bound |= pat_vars
            ctx.append(ckeys[i])
            est = min(est, counts[i])

    def _plan(self, patterns, select):
        """Host planning: -> (sigs, dyns, ordered caps, join_cap, sel,
        stores, order, est, buckets).

        The first six elements are the PR-5 contract (core/shard.py indexes
        them positionally); ``order`` maps plan position -> original pattern
        index, ``est`` carries the planner's per-pattern cardinality
        estimates in plan order (what EXPLAIN compares observed counts to),
        and ``buckets`` the per-pattern probe-constant buckets in plan
        order — pattern j's bucket is the const keys of plan positions
        0..j, the key half that de-aliases ``observed_selectivity``.
        """
        prepared = self._prepare(patterns)
        lowered = [self._lower(*pre) for pre in prepared]
        counts = [
            c if c is not None else self._pattern_count(sig, dyn)
            for sig, dyn, c in lowered
        ]
        ckeys = [_pattern_const_key(pre[1]) for pre in prepared]
        order = self._plan_order(prepared, counts)
        self._apply_inl(prepared, lowered, counts, order, ckeys)
        caps = [self._bucket(int(counts[i] * self.slack) + 16) for i in order]
        join_cap = self._bucket(int(max(counts) * self.slack) + 16)

        sigs = tuple(lowered[i][0] for i in order)
        dyns = tuple(lowered[i][1] for i in order)
        all_vars = tuple(dict.fromkeys(
            v for sig in sigs for v in sig.pvars if v is not None))
        sel = tuple(select) if select else all_vars
        buckets = tuple(tuple(ckeys[i] for i in order[: j + 1])
                        for j in range(len(order)))
        return (sigs, dyns, caps, join_cap, sel, self._stores(sigs),
                tuple(order), tuple(counts[i] for i in order), buckets)

    def _record_observed(self, sigs, est, totals, buckets) -> None:
        """Land observed per-pattern row counts in the process registry.

        ``observed_selectivity`` (engine-local, keyed by ``(PatternSig,
        probe-constant bucket)``) is the exact read-back surface for the
        planner; the registry histograms aggregate observed rows and
        estimate error (est/obs ratio) by strategy for the exporters and
        the ROADMAP item-1 batcher.
        """
        store_n = max(self.view.n, 1)
        for sig, e, obs, bucket in zip(sigs, est, totals, buckets):
            obs = int(obs)
            self.observed_selectivity[(sig, bucket)] = obs / store_n
            REGISTRY.histogram("planner/observed_rows",
                               strategy=sig.strategy).observe(obs)
            REGISTRY.histogram("planner/est_ratio",
                               strategy=sig.strategy).observe(
                (int(e) + 1) / (obs + 1))
            REGISTRY.gauge("planner/selectivity", strategy=sig.strategy,
                           store=sig.store).set(obs / store_n)

    def run(self, patterns, select=None, max_retries: int = 6):
        """Execute; returns (rows int32[k, n_select], select var names)."""
        with obs_trace.span("plan", mode=self.mode,
                            n_patterns=len(patterns)):
            planned = self._plan(patterns, select)
        return self._run_planned(planned, max_retries)

    def _run_planned(self, planned, max_retries: int = 6):
        """Execute an already-planned query (the solo dispatch path)."""
        (sigs, dyns, caps, join_cap, sel, stores, order, est,
         buckets) = planned
        slabel = sig_label(sigs)
        for attempt in range(max_retries):
            key = ("exec", self.mode, sigs, tuple(caps), join_cap, sel)
            misses0 = self.cache_stats["misses"]
            fn = self._executable(key, sigs, tuple(caps), join_cap, sel)
            with obs_trace.span("dispatch",
                                cached=self.cache_stats["misses"] == misses0,
                                join_cap=join_cap) as dsp:
                t0 = time.perf_counter()
                cols, valid, overflow, totals = fn(stores, dyns)
                done = int(overflow) == 0  # blocks on the dispatch
                REGISTRY.histogram("query/exec_seconds", sig=slabel).observe(
                    time.perf_counter() - t0)
                dsp.set_attr(overflow=not done)
            if done:
                if attempt:
                    REGISTRY.histogram("join/capacity_depth", site="query",
                                       sig=slabel,
                                       shard="local").observe(attempt)
                self._record_observed(sigs, est, np.asarray(totals), buckets)
                n = int(valid.sum())
                rows = np.asarray(cols)[:, :n].T
                return rows, sel
            obs_trace.event("overflow_retry", attempt=attempt,
                            join_cap=join_cap)
            REGISTRY.counter("query/overflow_retries").inc()
            REGISTRY.counter("join/capacity_retry", site="query", sig=slabel,
                             shard="local").inc()
            join_cap *= 2
            caps = [c * 2 for c in caps]
        raise RuntimeError("query kept overflowing its capacity buckets")

    # -- micro-batched execution (ROADMAP item 1) ---------------------------
    def _batch_caps(self, planned_group):
        """Unified capacity buckets for a same-signature batch.

        Member caps start at the elementwise max (the shared executable
        must hold the largest member), then observed selectivities —
        looked up per member by ``(sig, probe-constant bucket)`` — adjust
        them.  When EVERY member of the batch has been observed, the cap
        becomes the largest member's observed floor, which may SHRINK an
        over-provisioned planner estimate (the bucketed keying makes that
        safe: each member's floor speaks for exactly its own constants).
        While any member is still unobserved, observations only grow the
        cap — shrinking on partial evidence would trade the unobserved
        member's overflow retry for the whole batch's.
        """
        sigs = planned_group[0][0]
        caps = [max(p[2][j] for p in planned_group)
                for j in range(len(sigs))]
        join_cap = max(p[3] for p in planned_group)
        store_n = max(self.view.n, 1)
        for j, sig in enumerate(sigs):
            obs = [self.observed_selectivity.get((sig, p[8][j]))
                   for p in planned_group]
            known = [o for o in obs if o is not None]
            if not known:
                continue
            floor = max(self._bucket(int(o * store_n * self.slack) + 16)
                        for o in known)
            if len(known) == len(obs):
                caps[j] = floor  # complete evidence: shrink allowed
            else:
                caps[j] = max(caps[j], floor)
        return caps, max(join_cap, max(caps))

    def run_batch(self, requests, max_retries: int = 6):
        """Execute a batch of (patterns, select) requests in shared
        dispatches; returns [(rows, sel), ...] aligned with ``requests``.

        The batcher's engine half: every request is planned individually,
        structurally identical requests are answered ONCE and fanned out,
        and distinct requests whose patterns lower to the same signature
        tuple (projecting the same variables) execute as one vmapped
        dispatch over batch-stacked dyn constants — capacities unified by
        :meth:`_batch_caps` and the batch axis padded to a power of two so
        nearby batch sizes reuse one compiled executable.  Requests whose
        signatures match nobody else's fall back to the solo path; every
        member still lands its own observed-selectivity sample.
        """
        results = [None] * len(requests)
        uniq_keys, uniq = {}, []  # structural dedupe: answer once, fan out
        for i, (pats, select) in enumerate(requests):
            k = (tuple((p.s, p.p, p.o) for p in pats),
                 tuple(select) if select is not None else None)
            j = uniq_keys.get(k)
            if j is None:
                uniq_keys[k] = len(uniq)
                uniq.append((self._plan(pats, select), [i]))
            else:
                uniq[j][1].append(i)
        groups = {}
        for planned, members in uniq:
            groups.setdefault((planned[0], planned[4]), []).append(
                (planned, members))
        for (sigs, sel), entries in groups.items():
            if len(entries) == 1:
                planned, members = entries[0]
                rows, _ = self._run_planned(planned, max_retries)
                for i in members:
                    results[i] = (rows, sel)
                continue
            caps, join_cap = self._batch_caps([e[0] for e in entries])
            stores = entries[0][0][5]
            B = len(entries)
            Bp = _pow2(B, floor=2)  # pad slots repeat the last member
            dyn_list = ([e[0][1] for e in entries]
                        + [entries[-1][0][1]] * (Bp - B))
            dyn_stack = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *dyn_list)
            REGISTRY.histogram("query/batch_size", mode=self.mode).observe(B)
            slabel = sig_label(sigs)
            for attempt in range(max_retries):
                key = ("bexec", self.mode, sigs, tuple(caps), join_cap,
                       sel, Bp)
                fn = self._batch_executable(key, sigs, tuple(caps),
                                            join_cap, sel)
                t0 = time.perf_counter()
                cols, valid, overflow, totals = fn(stores, dyn_stack)
                ok = int(np.asarray(overflow)[:B].max()) == 0
                REGISTRY.histogram("query/exec_seconds", sig=slabel).observe(
                    time.perf_counter() - t0)
                if ok:
                    if attempt:
                        REGISTRY.histogram(
                            "join/capacity_depth", site="batch", sig=slabel,
                            shard="local").observe(attempt)
                    break
                obs_trace.event("overflow_retry", attempt=attempt,
                                join_cap=join_cap, batch=B)
                REGISTRY.counter("query/overflow_retries").inc()
                REGISTRY.counter("join/capacity_retry", site="batch",
                                 sig=slabel, shard="local").inc()
                join_cap *= 2
                caps = [c * 2 for c in caps]
            else:
                raise RuntimeError(
                    "batched query kept overflowing its capacity buckets")
            cols_h = np.asarray(cols)
            valid_h = np.asarray(valid)
            totals_h = np.asarray(totals)
            for b, (planned, members) in enumerate(entries):
                self._record_observed(sigs, planned[7], totals_h[b],
                                      planned[8])
                n = int(valid_h[b].sum())
                rows = cols_h[b][:, :n].T
                for i in members:
                    results[i] = (rows, sel)
        return results

    def explain(self, patterns, select=None, execute: bool = True) -> dict:
        """EXPLAIN: per-pattern strategy, buckets, estimated-vs-observed rows.

        Plans exactly like ``run`` and (by default) executes once through
        the same cached executable to read each pattern's observed match
        count off the device — estimates vs observed is the signal the
        INL-vs-merge choice and the ROADMAP item-1 batcher need.  Observed
        selectivities land in the process registry via
        :meth:`_record_observed`.  ``execute=False`` reports the plan only.
        """
        (sigs, dyns, caps, join_cap, sel, stores,
         order, est, buckets) = self._plan(patterns, select)
        observed = [None] * len(sigs)
        n_rows = None
        hot_keys = {}
        if execute and self.view.n:
            key = ("exec", self.mode, sigs, tuple(caps), join_cap, sel)
            fn = self._executable(key, sigs, tuple(caps), join_cap, sel)
            cols, valid, overflow, totals = fn(stores, dyns)
            observed = [int(t) for t in np.asarray(totals)]
            n_rows = int(valid.sum())
            self._record_observed(sigs, est, observed, buckets)
            # observed hot-key skew: for every join variable we can read
            # off the result (selected + shared by >= 2 patterns), how
            # lopsided is the per-key row distribution?  This is the
            # host-visible face of the device-side capacity-retry metrics:
            # a skew near 1.0 means uniform keys; a large max/mean ratio
            # explains join/capacity_retry doublings for this signature.
            if n_rows:
                rows_h = np.asarray(cols)[:, :n_rows].T
                uses = {}
                for sig in sigs:
                    for v in sig.pvars:
                        if v is not None:
                            uses[v] = uses.get(v, 0) + 1
                for v in sel:
                    if uses.get(v, 0) < 2:
                        continue
                    _, cnt = np.unique(rows_h[:, sel.index(v)],
                                       return_counts=True)
                    top, mean = int(cnt.max()), float(cnt.mean())
                    hot_keys[v] = {
                        "max_rows_per_key": top,
                        "mean_rows_per_key": mean,
                        "skew": top / mean,
                    }
                    REGISTRY.gauge("join/hot_key_skew", var=v,
                                   sig=sig_label(sigs)).set(top / mean)
        store_n = max(self.view.n, 1)
        pats = []
        for j, sig in enumerate(sigs):
            entry = {
                "pattern_index": order[j],
                "strategy": sig.strategy,
                "store": sig.store,
                "cap": caps[j],
                "estimated_rows": int(est[j]),
                "observed_rows": observed[j],
            }
            if sig.strategy == "slice":
                entry["n_ranges"] = sig.k
            if sig.strategy == "scan":
                entry["fused"] = sig.fused
            if sig.strategy == "inl":
                entry["n_pids"] = sig.n_pids
                entry["probe_pos"] = sig.probe_pos
            if observed[j] is not None:
                entry["selectivity"] = observed[j] / store_n
            pats.append(entry)
        return {
            "mode": self.mode,
            "select": list(sel),
            "store_rows": int(self.view.n),
            "join_cap": join_cap,
            "n_result_rows": n_rows,
            "patterns": pats,
            "hot_keys": hot_keys,
        }

    def prewarm(self, queries, buckets=(), select=None) -> int:
        """Pre-trace executables for a query set; returns #plans compiled.

        Each query is compiled at its *natural* capacity buckets (what
        ``run`` would pick against the current store) and additionally at
        every floor in ``buckets``: caps are raised to at least the floor,
        covering the bucket sizes the store will grow into.  Subsequent
        ``run`` calls whose buckets land on a prewarmed combination skip
        the trace+compile cold start entirely.
        """
        before = self.cache_stats["misses"]
        for pats in queries:
            sigs, dyns, caps, join_cap, sel, stores = \
                self._plan(pats, select)[:6]
            capsets = {(tuple(caps), join_cap)}
            for b in buckets:
                b = self._bucket(int(b))
                capsets.add((tuple(max(c, b) for c in caps),
                             max(join_cap, b)))
            for cs, jc in sorted(capsets):
                key = ("exec", self.mode, sigs, cs, jc, sel)
                fn = self._executable(key, sigs, cs, jc, sel)
                jax.block_until_ready(fn(stores, dyns))
        return self.cache_stats["misses"] - before
