"""Conjunctive SPARQL evaluation over encoded triples — the paper's §V.

Three execution modes, matching the paper's Table VI columns:

  * ``litemat``  — interval predicates (one compare per sub-hierarchy) over
                   the lite-materialized store,
  * ``full``     — plain equality over the fully materialized store,
  * ``rewrite``  — the no-materialization baseline: constants expanded
                   host-side to their sub-concept/property id sets,
                   evaluated as OR-filters (the paper's optimized
                   "conjunction of OR subqueries" formulation).

The algebra is the paper's filter→map→join pipeline, in XLA static-shape
discipline: every operator carries a static capacity + validity mask +
overflow counter, and the engine re-executes with doubled capacities if an
overflow is reported (power-of-two buckets keep recompiles bounded).

Beyond the paper (it declares join ordering out of scope): the planner runs
each pattern's filter *count* first — one cheap reduction pass — and joins
in ascending-cardinality order, which also gives capacity estimates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.abox import EncodedKB
from repro.core.materialize import DeviceTBox
from repro.utils.hashing import fingerprint_string
from repro.utils import pair64

INVALID = jnp.int32(np.iinfo(np.int32).max)


def is_var(t) -> bool:
    return isinstance(t, str) and t.startswith("?")


@dataclass(frozen=True)
class Pattern:
    s: object  # '?var' | name str | raw int id
    p: object
    o: object


@dataclass
class Term:
    """A resolved pattern constant: interval [lo, hi) + optional spills/set."""

    lo: int
    hi: int
    spills: tuple = ()  # ((lo, hi), ...)
    members: np.ndarray | None = None  # explicit id set (rewrite mode)


# ---------------------------------------------------------------------------
# Relations: struct-of-arrays with validity + overflow accounting
# ---------------------------------------------------------------------------


@dataclass
class Relation:
    vars: tuple  # var names, host
    cols: jnp.ndarray  # int32[n_vars, cap]
    valid: jnp.ndarray  # bool[cap]
    overflow: jnp.ndarray  # int32 scalar (rows that did not fit)

    @property
    def cap(self) -> int:
        return int(self.valid.shape[0])

    def col(self, v) -> jnp.ndarray:
        return self.cols[self.vars.index(v)]


def _filter_matches(spo, pat_terms, mode: str):
    """Boolean mask over the triple store for one pattern's constants."""
    s_t, p_t, o_t = pat_terms
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    mask = spo[:, 0] != INVALID

    def term_mask(col, term: Term, use_intervals: bool):
        if term.members is not None:  # rewrite mode: OR over id set
            mem = jnp.asarray(term.members, dtype=jnp.int32)
            pos = jnp.clip(jnp.searchsorted(mem, col), 0, mem.shape[0] - 1)
            return mem[pos] == col
        if not use_intervals or term.hi == term.lo + 1:
            return col == term.lo
        m = (col >= term.lo) & (col < term.hi)
        for lo, hi in term.spills:
            m = m | ((col >= lo) & (col < hi))
        return m

    inference = mode == "litemat"
    if s_t is not None:
        mask &= term_mask(s, s_t, False)
    if p_t is not None:
        mask &= term_mask(p, p_t, inference)
    if o_t is not None:
        mask &= term_mask(o, o_t, inference)
    return mask


def _type_rewrite_masks(spo, o_term: Term, extra):
    """Rewrite-mode (?x rdf:type C): explicit ∪ domain ∪ range branches.

    Returns (mask, xcol): which triples contribute and which column binds ?x
    (subjects for explicit/domain branches, objects for range branches) —
    the full RDFS reformulation the paper's Q4' illustrates.
    """
    type_id, dom_set, rng_set = extra
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]

    def in_set(col, ids):
        if ids.size == 0:
            return jnp.zeros(col.shape, bool)
        arr = jnp.asarray(ids, dtype=jnp.int32)
        pos = jnp.clip(jnp.searchsorted(arr, col), 0, arr.shape[0] - 1)
        return arr[pos] == col

    mem = jnp.asarray(o_term.members, dtype=jnp.int32)
    pos = jnp.clip(jnp.searchsorted(mem, o), 0, mem.shape[0] - 1)
    m_expl = (p == type_id) & (mem[pos] == o)
    m_dom = in_set(p, dom_set)
    m_rng = in_set(p, rng_set)
    mask = (m_expl | m_dom | m_rng) & (s != INVALID)
    xcol = jnp.where(m_rng & ~(m_expl | m_dom), o, s)
    return mask, xcol


def scan_relation(spo, pattern_vars, pat_terms, mode: str, cap: int, extra=None):
    """Filter the store and compact matching rows into a Relation."""
    if extra is not None:  # rewrite-mode type pattern (?x rdf:type C)
        mask, xcol = _type_rewrite_masks(spo, pat_terms[2], extra)
        n_match = mask.astype(jnp.int32).sum()
        order = jnp.argsort(~mask, stable=True)
        take = order[:cap]
        ok = mask[take]
        var = next(v for v in pattern_vars if v is not None)
        cols = [jnp.where(ok, xcol[take], INVALID)]
        return Relation(
            vars=(var,), cols=jnp.stack(cols), valid=ok,
            overflow=jnp.maximum(n_match - cap, 0),
        ), n_match
    mask = _filter_matches(spo, pat_terms, mode)
    n_match = mask.astype(jnp.int32).sum()
    order = jnp.argsort(~mask, stable=True)  # matches first, original order
    take = order[:cap]
    ok = mask[take]
    cols = []
    seen = {}
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    eq_extra = None
    for v, colv in zip(pattern_vars, (s, p, o)):
        if v is None:
            continue
        if v in seen:  # repeated var in one pattern: equality constraint
            eq_extra = (seen[v], colv)
            continue
        seen[v] = colv
        cols.append(jnp.where(ok, colv[take], INVALID))
    if eq_extra is not None:
        same = eq_extra[0][take] == eq_extra[1][take]
        ok = ok & same
        cols = [jnp.where(ok, c, INVALID) for c in cols]
    overflow = jnp.maximum(n_match - cap, 0)
    return Relation(
        vars=tuple(v for v in dict.fromkeys(v for v in pattern_vars if v is not None)),
        cols=jnp.stack(cols) if cols else jnp.zeros((0, cap), jnp.int32),
        valid=ok,
        overflow=overflow,
    ), n_match


def join(a: Relation, b: Relation, cap: int) -> Relation:
    """Sort-merge equi-join on all shared vars (first var = sort key)."""
    shared = [v for v in a.vars if v in b.vars]
    if not shared:
        raise ValueError("cartesian products not supported — reorder the plan")
    key = shared[0]

    # sort build side (a) by key; invalid rows sink
    ka = jnp.where(a.valid, a.col(key), INVALID)
    aperm = jnp.argsort(ka)
    a_cols = a.cols[:, aperm]
    ka_s = ka[aperm]

    kb_ = jnp.where(b.valid, b.col(key), INVALID)
    L = jnp.searchsorted(ka_s, kb_, side="left")
    R = jnp.searchsorted(ka_s, kb_, side="right")
    counts = jnp.where(b.valid & (kb_ != INVALID), R - L, 0)
    offsets = jnp.cumsum(counts)
    total = offsets[-1]
    starts = offsets - counts

    # expand: output slot -> (probe row, match rank)
    out_idx = jnp.arange(cap, dtype=jnp.int32)
    probe = jnp.searchsorted(offsets, out_idx, side="right")
    probe_c = jnp.clip(probe, 0, counts.shape[0] - 1)
    rank = out_idx - starts[probe_c]
    build_row = jnp.clip(L[probe_c] + rank, 0, ka_s.shape[0] - 1)
    ok = out_idx < jnp.minimum(total, cap)

    # verify remaining shared vars
    a_g = a_cols[:, build_row]
    b_g = b.cols[:, probe_c]
    for v in shared[1:]:
        ok = ok & (a_g[a.vars.index(v)] == b_g[b.vars.index(v)])

    out_vars = tuple(a.vars) + tuple(v for v in b.vars if v not in a.vars)
    rows = [jnp.where(ok, a_g[i], INVALID) for i in range(len(a.vars))]
    for j, v in enumerate(b.vars):
        if v not in a.vars:
            rows.append(jnp.where(ok, b_g[j], INVALID))
    overflow = jnp.maximum(total - cap, 0) + a.overflow + b.overflow
    return Relation(vars=out_vars, cols=jnp.stack(rows), valid=ok, overflow=overflow)


def distinct(rel: Relation, select: tuple, cap: int) -> Relation:
    """Project onto ``select`` vars and deduplicate rows."""
    cols = [jnp.where(rel.valid, rel.col(v), INVALID) for v in select]
    perm = jnp.lexsort(tuple(reversed(cols)))
    cols = [c[perm] for c in cols]
    valid = rel.valid[perm]
    neq = jnp.zeros(valid.shape[0] - 1, dtype=bool)
    for c in cols:
        neq = neq | (c[1:] != c[:-1])
    first = jnp.concatenate([jnp.ones((1,), bool), neq])
    keep = first & valid
    n = keep.astype(jnp.int32).sum()
    order = jnp.argsort(~keep, stable=True)[:cap]
    ok = keep[order]
    out = jnp.stack([jnp.where(ok, c[order], INVALID) for c in cols])
    return Relation(
        vars=select, cols=out, valid=ok,
        overflow=rel.overflow + jnp.maximum(n - cap, 0),
    )


# ---------------------------------------------------------------------------
# The engine: host-side resolution + planning, device execution
# ---------------------------------------------------------------------------


@dataclass
class QueryEngine:
    kb: EncodedKB
    spo: jnp.ndarray  # the store to query (lite / full / original)
    mode: str = "litemat"  # litemat | full | rewrite
    dtb: DeviceTBox | None = None
    slack: float = 1.5
    _exec_cache: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.dtb is None and self.kb.tbox is not None:
            self.dtb = DeviceTBox.build(self.kb.tbox)

    # -- constant resolution (context-aware, paper §III intro) --------------
    def _resolve(self, term, position: str, type_pattern: bool) -> Term:
        tbox = self.kb.tbox
        if isinstance(term, (int, np.integer)):
            return Term(lo=int(term), hi=int(term) + 1)
        name = term
        if position == "p" and tbox is not None:
            enc = tbox.properties
        elif position == "o" and type_pattern and tbox is not None:
            enc = tbox.concepts
        else:
            enc = None
        if enc is not None and (name in enc.name_to_id or name in enc.tax.merged):
            if self.mode == "rewrite":
                return Term(lo=0, hi=0, members=np.sort(np.array(enc.subsumees(name), dtype=np.int32)))
            if self.mode == "full":
                i = enc.id_of(name)
                return Term(lo=i, hi=i + 1)
            (lo, hi), spills = enc.interval_of(name)
            return Term(lo=lo, hi=hi, spills=tuple(spills))
        ids = self.kb.locate([name])
        if ids[0] < 0:
            raise KeyError(f"unknown term {name!r}")
        return Term(lo=int(ids[0]), hi=int(ids[0]) + 1)

    def _prepare(self, patterns):
        """Resolve constants; attach rewrite extras for type patterns."""
        prepared = []
        for pat in patterns:
            p_is_const = not is_var(pat.p)
            type_pat = p_is_const and self.kb.tbox is not None and (
                pat.p in ("rdf:type", "a") or pat.p == self.kb.tbox.rdf_type_id
            )
            terms = (
                None if is_var(pat.s) else self._resolve(pat.s, "s", False),
                None if is_var(pat.p) else self._resolve(pat.p, "p", type_pat),
                None if is_var(pat.o) else self._resolve(pat.o, "o", type_pat),
            )
            pvars = tuple(t if is_var(t) else None for t in (pat.s, pat.p, pat.o))
            extra = None
            if self.mode == "rewrite" and type_pat and terms[2] is not None and is_var(pat.s):
                extra = self._rewrite_extra(terms[2])
            prepared.append((pvars, terms, extra))
        return prepared

    def _rewrite_extra(self, o_term: Term):
        """Property sets whose (effective) domain/range entails the target."""
        tbox = self.kb.tbox
        targets = set(o_term.members.tolist())
        dom_set, rng_set = [], []
        dr_ids = np.asarray(self.dtb.dr_prop_ids)
        dom_tbl = np.asarray(self.dtb.domain_table)
        rng_tbl = np.asarray(self.dtb.range_table)
        penc = tbox.properties
        for i, pid in enumerate(dr_ids.tolist()):
            if pid < 0:
                continue
            doms = [v for v in dom_tbl[i].tolist() if v >= 0]
            rngs = [v for v in rng_tbl[i].tolist() if v >= 0]
            subs = penc.subsumees(penc.name_of(pid))  # sub-properties inherit
            if any(d in targets for d in doms):
                dom_set.extend(subs)
            if any(r in targets for r in rngs):
                rng_set.extend(subs)
        return (
            int(tbox.rdf_type_id),
            np.sort(np.unique(np.array(dom_set, dtype=np.int32))),
            np.sort(np.unique(np.array(rng_set, dtype=np.int32))),
        )

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << max(8, int(np.ceil(np.log2(max(n, 1)))))

    @staticmethod
    def _plan_order(prepared, counts):
        """Greedy join order: smallest first, stay connected when possible."""
        remaining = list(range(len(prepared)))
        remaining.sort(key=lambda i: counts[i])
        order = [remaining.pop(0)]
        bound_vars = set(v for v in prepared[order[0]][0] if v)
        while remaining:
            connected = [i for i in remaining if bound_vars & {v for v in prepared[i][0] if v}]
            pick = min(connected or remaining, key=lambda i: counts[i])
            remaining.remove(pick)
            order.append(pick)
            bound_vars |= {v for v in prepared[pick][0] if v}
        return order

    def run(self, patterns, select=None, max_retries: int = 6):
        """Execute; returns (rows int32[k, n_select], select var names)."""
        prepared = self._prepare(patterns)
        counts = [
            int(_count_matches(self.spo, terms, self.mode, extra))
            for _, terms, extra in prepared
        ]
        order = self._plan_order(prepared, counts)
        caps = [self._bucket(int(c * self.slack) + 16) for c in counts]
        join_cap = self._bucket(int(max(counts) * self.slack) + 16)

        for _ in range(max_retries):
            rel = None
            for oi in order:
                pvars, terms, extra = prepared[oi]
                r, _ = scan_relation(self.spo, pvars, terms, self.mode, caps[oi], extra)
                rel = r if rel is None else join(rel, r, join_cap)
            sel = tuple(select) if select else rel.vars
            out = distinct(rel, sel, join_cap)
            if int(out.overflow) == 0:
                n = int(out.valid.sum())
                rows = np.asarray(out.cols)[:, :n].T
                return rows, sel
            join_cap *= 2
            caps = [c * 2 for c in caps]
        raise RuntimeError("query kept overflowing its capacity buckets")


def _count_matches(spo, terms, mode: str, extra=None) -> jnp.ndarray:
    if extra is not None:
        mask, _ = _type_rewrite_masks(spo, terms[2], extra)
    else:
        mask = _filter_matches(spo, terms, mode)
    return mask.astype(jnp.int32).sum()
