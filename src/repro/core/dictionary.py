"""Distributed dictionary encoding (the paper's §III.B, in JAX).

The paper's Spark algorithm:

  1. partition the dataset; each partition extracts its distinct new terms,
  2. the driver sums per-partition distinct counts into disjoint id ranges
     (an exclusive prefix sum),
  3. each partition assigns ids within its range,
  4. the dataset is re-encoded via joins against the resulting map
     (broadcast when small, partitioned when large).

We keep that exact structure.  Single-shard build = sort + adjacent-unique +
cumsum (rank == id offset).  Multi-shard build (``sharded_dictionary_fn``) =
hash-partition terms with ``all_to_all`` so each distinct term has one owner
shard, then the per-shard counts + ``all_gather``-prefix-sum reproduce steps
2–3; lookups route queries to owners with the same pattern.

All device keys are (hi, lo) int32 fingerprint pairs (utils/pair64.py);
``extract`` resolves fp -> string on the host, mirroring the paper's
driver-side string world.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils import pair64

SENTINEL = np.int32(np.iinfo(np.int32).max)  # > any real 30-bit hi word


@jax.tree_util.register_pytree_node_class
@dataclass
class TermTable:
    """Device dictionary: lex-sorted fp pairs -> int32 ids (+reverse view)."""

    fp_hi: jnp.ndarray  # int32[T], sorted (pairs with SENTINEL padding tail)
    fp_lo: jnp.ndarray
    ids: jnp.ndarray  # int32[T], -1 on padding rows
    rev_ids: jnp.ndarray  # int32[T] ids sorted ascending (padding: INT32_MAX)
    rev_hi: jnp.ndarray  # fp planes aligned with rev_ids
    rev_lo: jnp.ndarray
    count: jnp.ndarray  # int32 scalar: number of real entries

    def tree_flatten(self):
        return (
            (self.fp_hi, self.fp_lo, self.ids, self.rev_ids, self.rev_hi, self.rev_lo, self.count),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def locate(self, qhi, qlo):
        """fp pairs -> (ids, hit_mask); -1 where absent."""
        return pair64.lookup_pair(self.fp_hi, self.fp_lo, self.ids, qhi, qlo)

    def extract_fp(self, q_ids):
        """ids -> (fp_hi, fp_lo, hit_mask)."""
        pos = jnp.searchsorted(self.rev_ids, q_ids)
        pos_c = jnp.clip(pos, 0, self.rev_ids.shape[0] - 1)
        hit = self.rev_ids[pos_c] == q_ids
        return (
            jnp.where(hit, self.rev_hi[pos_c], -1),
            jnp.where(hit, self.rev_lo[pos_c], -1),
            hit,
        )


def table_from_host(fps: np.ndarray, ids: np.ndarray) -> TermTable:
    """Small host-built map (e.g. the TBox term map) -> TermTable."""
    hi, lo = pair64.split_np(fps)
    order = np.lexsort((lo, hi))
    hi, lo, ids = hi[order], lo[order], np.asarray(ids, dtype=np.int32)[order]
    rorder = np.argsort(ids, kind="stable")
    return TermTable(
        fp_hi=jnp.asarray(hi),
        fp_lo=jnp.asarray(lo),
        ids=jnp.asarray(ids),
        rev_ids=jnp.asarray(ids[rorder]),
        rev_hi=jnp.asarray(hi[rorder]),
        rev_lo=jnp.asarray(lo[rorder]),
        count=jnp.asarray(np.int32(len(ids))),
    )


def build_local_dictionary(hi, lo, valid, base):
    """Single-shard dictionary build (jit-safe, static shapes).

    ``(hi, lo)`` are term-occurrence fingerprints, ``valid`` masks real
    occurrences.  Returns a TermTable of size len(hi) (padding rows carry
    SENTINEL fps / -1 ids) whose ids are ``base + rank`` in fp order.
    """
    hi = jnp.where(valid, hi, SENTINEL)
    lo = jnp.where(valid, lo, SENTINEL)
    hi_s, lo_s, _ = pair64.sort_pairs(hi, lo)
    valid_s = hi_s != SENTINEL
    uniq = pair64.unique_mask_sorted(hi_s, lo_s) & valid_s
    ranks = jnp.cumsum(uniq.astype(jnp.int32)) - 1  # dup rows share their head's rank
    ids = jnp.where(valid_s, base + ranks, -1).astype(jnp.int32)
    count = uniq.astype(jnp.int32).sum()

    # compact unique rows to the front so the reverse view is dense in id
    # order (ids are assigned in fp order, so fp order == id order here).
    T = hi_s.shape[0]
    dest = jnp.where(uniq, ranks, T - 1)  # losers overwrite the scratch tail
    rev_hi = jnp.full((T,), SENTINEL, dtype=jnp.int32).at[dest].set(hi_s, mode="drop")
    rev_lo = jnp.full((T,), SENTINEL, dtype=jnp.int32).at[dest].set(lo_s, mode="drop")
    rev_ids = jnp.where(jnp.arange(T) < count, base + jnp.arange(T, dtype=jnp.int32), np.iinfo(np.int32).max)
    # fix scratch slot T-1 if it is real
    last_real = count > (T - 1)
    rev_hi = rev_hi.at[T - 1].set(jnp.where(last_real, rev_hi[T - 1], SENTINEL))
    rev_lo = rev_lo.at[T - 1].set(jnp.where(last_real, rev_lo[T - 1], SENTINEL))
    return TermTable(hi_s, lo_s, ids, rev_ids, rev_hi, rev_lo, count)


@jax.jit
def merge_tables(a: TermTable, b: TermTable) -> TermTable:
    """Union of two tables (disjoint key sets) -> one lex-sorted table."""
    hi = jnp.concatenate([a.fp_hi, b.fp_hi])
    lo = jnp.concatenate([a.fp_lo, b.fp_lo])
    ids = jnp.concatenate([a.ids, b.ids])
    hi_s, lo_s, perm = pair64.sort_pairs(hi, lo)
    ids_s = ids[perm]
    rev_ids = jnp.concatenate([a.rev_ids, b.rev_ids])
    rev_hi = jnp.concatenate([a.rev_hi, b.rev_hi])
    rev_lo = jnp.concatenate([a.rev_lo, b.rev_lo])
    rperm = jnp.argsort(rev_ids)
    return TermTable(
        hi_s, lo_s, ids_s,
        rev_ids[rperm], rev_hi[rperm], rev_lo[rperm],
        a.count + b.count,
    )


# ---------------------------------------------------------------------------
# Sharded build (shard_map body) — the paper's parallel algorithm proper
# ---------------------------------------------------------------------------


def _bin_by_owner(hi, lo, valid, n_shards: int, cap: int):
    """Scatter local terms into per-owner bins of static capacity ``cap``.

    Owner shard = fp mod n_shards (well-mixed fingerprints -> balanced).
    Returns (bins_hi, bins_lo) of shape (n_shards, cap) + overflow count.
    """
    owner = jnp.where(valid, (lo % n_shards).astype(jnp.int32), n_shards)
    # slot of each element within its owner bin = running count per owner
    one_hot = (owner[:, None] == jnp.arange(n_shards, dtype=jnp.int32)[None, :]).astype(jnp.int32)
    slot = jnp.cumsum(one_hot, axis=0) - one_hot  # exclusive per-owner rank
    slot = (slot * one_hot).sum(axis=1)
    overflow = jnp.maximum(slot - (cap - 1), 0).sum()
    flat = jnp.clip(owner, 0, n_shards - 1) * cap + jnp.clip(slot, 0, cap - 1)
    keep = valid & (slot < cap)
    bins_hi = jnp.full((n_shards * cap,), SENTINEL, dtype=jnp.int32).at[
        jnp.where(keep, flat, n_shards * cap - 1)
    ].set(jnp.where(keep, hi, SENTINEL), mode="drop")
    bins_lo = jnp.full((n_shards * cap,), SENTINEL, dtype=jnp.int32).at[
        jnp.where(keep, flat, n_shards * cap - 1)
    ].set(jnp.where(keep, lo, SENTINEL), mode="drop")
    return bins_hi.reshape(n_shards, cap), bins_lo.reshape(n_shards, cap), overflow


def sharded_dictionary_fn(axis_name: str, n_shards: int, bin_cap: int, base: int):
    """Returns a shard_map-able body: local term columns -> (ids, table).

    Implements the paper's algorithm with one all_to_all each way:
      occurrences --(hash partition)--> owner shards --(unique+scan)-->
      id assignment --(reverse all_to_all)--> resolved occurrence ids.
    """

    def body(hi, lo, valid):
        # 1. route occurrences to owner shards (dedup happens at the owner)
        bins_hi, bins_lo, overflow = _bin_by_owner(hi, lo, valid, n_shards, bin_cap)
        recv_hi = lax.all_to_all(bins_hi, axis_name, 0, 0, tiled=False)
        recv_lo = lax.all_to_all(bins_lo, axis_name, 0, 0, tiled=False)
        rhi = recv_hi.reshape(-1)
        rlo = recv_lo.reshape(-1)

        # 2. local unique + global exclusive scan of counts (paper step 2)
        rhi_s, rlo_s, _ = pair64.sort_pairs(rhi, rlo)
        valid_s = rhi_s != SENTINEL
        uniq = pair64.unique_mask_sorted(rhi_s, rlo_s) & valid_s
        local_count = uniq.astype(jnp.int32).sum()
        counts = lax.all_gather(local_count, axis_name)
        my = lax.axis_index(axis_name)
        offset = jnp.where(jnp.arange(counts.shape[0]) < my, counts, 0).sum()

        # 3. assign ids in my disjoint range (paper step 3)
        ranks = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        ids_s = jnp.where(valid_s, base + offset + ranks, -1).astype(jnp.int32)

        # 4. answer the original shards: lookup each routed bin in my table,
        #    then reverse the all_to_all to deliver ids to the askers.
        ans, _ = pair64.lookup_pair(rhi_s, rlo_s, ids_s, recv_hi.reshape(n_shards, -1), recv_lo.reshape(n_shards, -1))
        back = lax.all_to_all(ans, axis_name, 0, 0, tiled=False)  # (n_shards, cap)

        # 5. scatter bin answers back onto local occurrence order
        owner = jnp.where(valid, (lo % n_shards).astype(jnp.int32), n_shards)
        one_hot = (owner[:, None] == jnp.arange(n_shards, dtype=jnp.int32)[None, :]).astype(jnp.int32)
        slot = jnp.cumsum(one_hot, axis=0) - one_hot
        slot = (slot * one_hot).sum(axis=1)
        flat = jnp.clip(owner, 0, n_shards - 1) * bin_cap + jnp.clip(slot, 0, bin_cap - 1)
        occ_ids = jnp.where(valid & (slot < bin_cap), back.reshape(-1)[flat], -1)

        table = (
            rhi_s, rlo_s, ids_s,
            *_reverse_view(rhi_s, rlo_s, ids_s, uniq, local_count, base + offset),
        )
        # scalars leave shard_map as (1,)-vectors (one entry per shard)
        return occ_ids, table, overflow[None], local_count[None]

    return body


def sharded_out_specs():
    """out_specs matching sharded_dictionary_fn's outputs."""
    from jax.sharding import PartitionSpec as P

    d = P("d")
    return (d, (d,) * 6, d, d)


def _reverse_view(hi_s, lo_s, ids_s, uniq, count, base):
    T = hi_s.shape[0]
    ranks = jnp.cumsum(uniq.astype(jnp.int32)) - 1
    dest = jnp.where(uniq, ranks, T - 1)
    rev_hi = jnp.full((T,), SENTINEL, dtype=jnp.int32).at[dest].set(hi_s, mode="drop")
    rev_lo = jnp.full((T,), SENTINEL, dtype=jnp.int32).at[dest].set(lo_s, mode="drop")
    rev_ids = jnp.where(
        jnp.arange(T) < count, base + jnp.arange(T, dtype=jnp.int32), np.iinfo(np.int32).max
    )
    last_real = count > (T - 1)
    rev_hi = rev_hi.at[T - 1].set(jnp.where(last_real, rev_hi[T - 1], SENTINEL))
    rev_lo = rev_lo.at[T - 1].set(jnp.where(last_real, rev_lo[T - 1], SENTINEL))
    return rev_ids, rev_hi, rev_lo
