"""KnowledgeBase facade: raw triples -> encoded -> materialized -> queryable.

One object wires the whole LiteMat pipeline and exposes the three execution
modes of the paper's evaluation (lite / full / no materialization), plus the
paper's appendix queries Q1–Q4 as canned pattern lists.

Beyond the paper's batch pipeline, the KnowledgeBase is *live*: LiteMat's
interval encoding reserves unused id headroom exactly so the dictionary and
stores can grow without re-encoding, and ``insert`` / ``delete`` exploit
that:

  * ``insert(raw)``  — new instance terms extend the parallel dictionary in
    place (ids past ``n_instance_terms``; no existing id moves), and the
    encoded rows land in an append-only delta overlay (core/delta.py) that
    queries union with the base via sorted delta indexes.  Lite/full
    materialization of the delta is LAZY per mode: each store derives its
    backlog the first time it is served, so single-mode deployments run
    one materializer per insert, not two.
  * ``delete(raw)``  — tombstones the raw rows, then repairs the
    materialized stores exactly by re-deriving the affected instances from
    their remaining live triples (core/update.py).
  * ``compact()``    — folds the overlay into the base stores with one
    sorted-merge pass per index permutation; triggered automatically once
    the delta-to-base ratio passes ``compact_threshold``.

Every mutation bumps the monotonic ``version`` counter; query engines and
the serving layer (serving/engine.py) re-sync their views off it, so there
is no manual invalidation step.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.abox import EncodedKB, encode_obe, encode_sae
from repro.core.closure import full_materialize
from repro.core.delta import (
    MODES, DeltaKB, DeviceStoreCache, StoreView, compact_view,
)
from repro.core.index import StoreIndex
from repro.core.materialize import DeviceTBox, compact_rows, lite_materialize
from repro.core.query import Pattern, QueryEngine
from repro.core.tbox import TBox, build_tbox
from repro.core.update import (
    DynamicDictionary, RowLocator, absorb_new_terms, affected_instances,
    encode_delta, materialize_delta_mode, mention_rows, mentions_mask,
)
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY
from repro.rdf.generator import RawDataset
from repro.testing import faults

# The paper's appendix queries (over the LUBM vocabulary).
PAPER_QUERIES = {
    "Q1": [Pattern("?x", "rdf:type", "Professor")],
    "Q2": [Pattern("?x", "memberOf", "?y")],
    "Q3": [Pattern("?x", "rdf:type", "Professor"), Pattern("?x", "memberOf", "?y")],
    "Q4": [
        Pattern("?x", "rdf:type", "Chair"),
        Pattern("?y", "rdf:type", "Department"),
        Pattern("?x", "worksFor", "?y"),
    ],
}


def _raw_columns(raw):
    """RawDataset | (s, p, o) arrays -> (s_fp, p_fp, o_fp, term_strings)."""
    if isinstance(raw, RawDataset) or hasattr(raw, "s"):
        return (np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o),
                getattr(raw, "term_strings", None))
    s, p, o = raw
    return np.asarray(s), np.asarray(p), np.asarray(o), None


@dataclass
class KnowledgeBase:
    kb: EncodedKB
    dtb: DeviceTBox
    lite_spo: jnp.ndarray  # compacted lite-materialized base store
    full_spo: jnp.ndarray  # compacted fully-materialized base store
    lite_stats: dict
    full_stats: dict
    compact_threshold: float = 0.25  # auto-compact past this delta ratio
    version: int = 0  # bumps on every insert/delete/compact
    lazy_materialize: bool = True  # derive lite/full deltas per served mode
    mat_counts: dict = field(
        default_factory=lambda: {"litemat": 0, "full": 0})  # batches derived
    _engines: dict = field(default_factory=dict, repr=False)
    _delta: DeltaKB | None = field(default=None, repr=False)
    _dyn: DynamicDictionary | None = field(default=None, repr=False)
    _base_indexes: dict = field(default_factory=dict, repr=False)
    _views: dict = field(default_factory=dict, repr=False)
    _raw_loc: RowLocator | None = field(default=None, repr=False)
    _dev_caches: dict = field(default_factory=dict, repr=False)
    _pending_raw: list = field(default_factory=list, repr=False)
    _mat_cursor: dict = field(
        default_factory=lambda: {"litemat": 0, "full": 0}, repr=False)
    # writers (insert/delete/compact) serialize here; snapshot captures
    # (core/snapshot.py) take it briefly to see a quiescent version
    write_lock: threading.RLock = field(
        default_factory=threading.RLock, repr=False, compare=False)

    @classmethod
    def build(cls, raw: RawDataset, tbox: TBox | None = None,
              parallel_tbox: bool = False) -> "KnowledgeBase":
        tbox = tbox or build_tbox(raw.onto, parallel=parallel_tbox)
        kb = encode_obe(raw, tbox)
        dtb = DeviceTBox.build(tbox)
        lite, lvalid, lstats = lite_materialize(kb, dtb)
        full, fvalid, fstats = full_materialize(kb, dtb)
        return cls(
            kb=kb,
            dtb=dtb,
            lite_spo=compact_rows(lite, lvalid),
            full_spo=compact_rows(full, fvalid),
            lite_stats=lstats,
            full_stats=fstats,
        )

    # -- store plumbing ------------------------------------------------------
    def _base_store(self, mode: str) -> jnp.ndarray:
        return {
            "litemat": self.lite_spo,
            "full": self.full_spo,
            "rewrite": self.kb.spo,
        }[mode]

    def _base_index(self, mode: str) -> StoreIndex:
        if mode not in self._base_indexes:
            self._base_indexes[mode] = StoreIndex.build(self._base_store(mode))
        return self._base_indexes[mode]

    @property
    def delta(self) -> DeltaKB:
        if self._delta is None:
            self._delta = DeltaKB()
        return self._delta

    def dev_cache(self, mode: str) -> DeviceStoreCache:
        """The store's persistent device buffers (survive version bumps)."""
        if mode not in self._dev_caches:
            self._dev_caches[mode] = DeviceStoreCache()
        return self._dev_caches[mode]

    def _flush_mat(self, *modes: str) -> None:
        """Materialize pending insert batches for the given derived modes.

        Inserts only queue their encoded raw rows (``lazy_materialize``);
        the first time a mode is actually *served* — a view build, a
        delete's repair, a compaction — its share of the queue is derived
        here.  A lite-only deployment therefore never runs the full
        closure of its inserts (and vice versa).

        Crash-atomic per mode: every pending batch is derived BEFORE any
        of them is appended, so a failure mid-derivation (fault site
        ``engine.flush_mat``) leaves the log and cursor untouched — the
        published store stays consistent and a later flush simply retries
        the whole backlog.
        """
        n = len(self._pending_raw)
        for mode in modes:
            cur = self._mat_cursor[mode]
            if cur >= n:
                continue
            with obs_trace.span("flush_mat", mode=mode, n_batches=n - cur):
                t0 = time.perf_counter()
                derived = []
                for spo in self._pending_raw[cur:]:
                    faults.fire("engine.flush_mat", mode=mode,
                                batch=cur + len(derived))
                    derived.append(
                        materialize_delta_mode(spo, self.dtb, mode))
                for rows in derived:
                    self.delta.log(mode).append(rows)
                    self.mat_counts[mode] += 1
                self._mat_cursor[mode] = n
                REGISTRY.histogram("engine/flush_s", mode=mode).observe(
                    time.perf_counter() - t0)
                REGISTRY.counter("engine/derived_rows", mode=mode).inc(
                    sum(int(r.shape[0]) for r in derived))
        if self._pending_raw and all(
                c >= n for c in self._mat_cursor.values()):
            self._pending_raw.clear()
            self._mat_cursor = {m: 0 for m in self._mat_cursor}

    def _pending_rows(self, mode: str) -> int:
        """Raw rows queued for ``mode`` whose derivation hasn't run yet."""
        if mode not in self._mat_cursor:
            return 0
        return sum(int(b.shape[0])
                   for b in self._pending_raw[self._mat_cursor[mode]:])

    def view(self, mode: str) -> StoreView:
        """The live base+delta StoreView of one store, cached per version."""
        key = (mode, self.version)
        if key not in self._views:
            if mode in ("litemat", "full"):
                self._flush_mat(mode)
            idx = self._base_index(mode)
            if self._delta is None or self._delta.empty:
                v = StoreView(base_rows=self._base_store(mode), base_h=idx._h,
                              base_index=idx, cache=self.dev_cache(mode))
            else:
                v = StoreView.overlay(self._base_store(mode), idx,
                                      self._delta.log(mode),
                                      self._delta.base_alive[mode],
                                      cache=self.dev_cache(mode),
                                      kills=tuple(self._delta.kills[mode]))
            self._views[key] = v
        return self._views[key]

    def store_rows(self, mode: str = "litemat") -> jnp.ndarray:
        """Effective (live) rows of one store — what serving snapshots."""
        if self._delta is None or self._delta.empty:
            return self._base_store(mode)
        return jnp.asarray(self.view(mode).live_rows())

    def engine(self, mode: str = "litemat", use_index: bool = True) -> QueryEngine:
        """Cached QueryEngine per (mode, use_index), re-synced to ``version``.

        ``use_index=False`` forces the scan-only path — the oracle the
        indexed executables are validated against (tests/benchmarks).
        """
        key = (mode, use_index)
        v = self.view(mode)
        eng = self._engines.get(key)
        if eng is None:
            eng = QueryEngine(kb=self.kb, spo=self._base_store(mode),
                              mode=mode, dtb=self.dtb, use_index=use_index,
                              view=v)
            self._engines[key] = eng
        elif eng.view is not v:
            eng.set_view(v)
        return eng

    def query(self, patterns, select=None, mode: str = "litemat",
              use_index: bool = True):
        rows, sel = self.engine(mode, use_index).run(patterns, select=select)
        return rows, sel

    def answers(self, patterns, select=None, mode: str = "litemat",
                use_index: bool = True) -> set:
        rows, _ = self.query(patterns, select=select, mode=mode,
                             use_index=use_index)
        return {tuple(r) for r in rows.tolist()}

    def prewarm(self, queries=None, modes=("litemat",), buckets=(),
                use_index: bool = True) -> int:
        """Pre-trace executables for ``queries`` (default: Q1–Q4)."""
        queries = (list(queries) if queries is not None
                   else list(PAPER_QUERIES.values()))
        return sum(
            self.engine(m, use_index).prewarm(queries, buckets=buckets)
            for m in modes
        )

    def warm_device(self, mode: str = "litemat", keys=("scan", "pos")):
        """Bring ``mode``'s device buffers up to the current version.

        The post-mutation warmup unit: with plans prewarmed, this is ALL
        the work a first query pays after an insert/delete beyond the query
        itself — O(delta) bucket refresh + O(#killed) tombstone scatters,
        independent of the base size (``dev_cache(mode).stats`` has the
        transfer accounting).
        """
        return self.view(mode).warm_device(keys)

    # -- device resource accounting (obs/ledger.py feed) ---------------------
    def device_buffers(self) -> list:
        """Every device buffer this store references, as ledger records.

        ``(component, buffer id, nbytes)`` per buffer: base store arrays
        and materialized permutations under ``base``, pow2 delta buckets
        under ``delta``, liveness masks under ``alive``, the replicated
        TBox planes under ``tbox``.  Ids let the ledger dedupe arrays
        shared between owners (a compacted POS permutation IS the store
        array; a pinned snapshot references the same base).  Walks only
        existing state — never materializes a view or flushes a delta.
        """
        out = []
        for spo in (self.kb.spo, self.lite_spo, self.full_spo):
            out.append(("base", id(spo), spo.nbytes))
        for idx in self._base_indexes.values():
            for p in idx._perms.values():
                out.append(("base", id(p.rows), p.rows.nbytes))
        for cache in self._dev_caches.values():
            out.extend(cache.device_buffers())
        for v in self._views.values():
            out.extend(v.device_buffers())
        for a in vars(self.dtb).values():
            if hasattr(a, "nbytes") and hasattr(a, "shape"):
                out.append(("tbox", id(a), a.nbytes))
        return out

    def n_live_triples(self) -> int:
        """Live triples in the served (litemat) store, side-effect-free.

        Counts base rows minus tombstones plus live delta rows plus
        pending (not-yet-materialized) insert batches — deliberately NOT
        through ``view()``, which would flush materialization from inside
        a telemetry sampler.
        """
        d = self._delta
        if d is None:
            n = int(self.lite_spo.shape[0])
        else:
            alive = d.base_alive["litemat"]
            n = (int(self.lite_spo.shape[0]) if alive is None
                 else int(alive.sum()))
            n += d.logs["litemat"].n_live
        return n + self._pending_rows("litemat")

    def track_ledger(self, shard="0") -> None:
        """Register with the process ledger (idempotent, weakly held)."""
        if getattr(self, "_ledger_handle", None) is None:
            from repro.obs.ledger import LEDGER

            self._ledger_handle = LEDGER.track(shard, self)

    def sizes(self) -> dict:
        out = dict(
            original=self.kb.n,
            lite=int(self.lite_spo.shape[0]),
            full=int(self.full_spo.shape[0]),
        )
        if self._delta is not None and not self._delta.empty:
            out["delta_rows"] = sum(
                self._delta.n_rows(m) for m in MODES)
            pending = sum(self._pending_rows(m) for m in ("litemat", "full"))
            if pending:
                out["delta_rows_pending_mat"] = pending
        return out

    # -- incremental updates -------------------------------------------------
    def _dynamic(self) -> DynamicDictionary:
        if self._dyn is None:
            self._dyn = DynamicDictionary.from_kb(self.kb)
        return self._dyn

    def _raw_locator(self) -> RowLocator:
        if self._raw_loc is None:
            self._raw_loc = RowLocator.build(self._base_index("rewrite")._h)
        return self._raw_loc

    def _bump(self) -> None:
        self.version += 1
        self._views.clear()

    @property
    def delta_ratio(self) -> float:
        if self._delta is None and not self._pending_raw:
            return 0.0
        # pending (not yet derived) insert batches count once per lazy mode:
        # the raw row count is the cheap proxy for the rows their derivation
        # will add, so auto-compaction triggers on the same schedule whether
        # or not the modes have been served yet.
        extra = sum(self._pending_rows(m) for m in ("litemat", "full"))
        return self.delta.ratio({
            "rewrite": self.kb.n,
            "litemat": int(self.lite_spo.shape[0]),
            "full": int(self.full_spo.shape[0]),
        }, extra_rows=extra)

    def insert(self, raw, auto_compact: bool = True) -> dict:
        """Append raw triples without rebuilding: encode + queue derivation.

        New instance/literal terms extend the dictionary in place (ids past
        ``n_instance_terms``); predicates must be TBox properties (the TBox
        is fixed between full re-encodes).  The encoded rows land in the raw
        delta log immediately; their lite/full materialization is *lazy* —
        derived the first time each mode is actually served (``view``,
        ``delete``, ``compact``) — so single-mode deployments only ever run
        one materializer per insert.
        """
        s_fp, p_fp, o_fp, strings = _raw_columns(raw)
        if s_fp.shape[0] == 0:
            return dict(n_inserted=0, n_new_terms=0)
        with self.write_lock:
            dyn = self._dynamic()
            spo, n_new = encode_delta(dyn, s_fp, p_fp, o_fp)
            absorb_new_terms(self.kb, dyn, strings)
            d = self.delta
            d.log("rewrite").append(spo)
            self._pending_raw.append(spo)
            if not self.lazy_materialize:
                self._flush_mat("litemat", "full")
            d.n_new_terms += n_new
            self._bump()
            REGISTRY.counter("engine/inserted_rows").inc(int(spo.shape[0]))
            stats = dict(
                n_inserted=int(spo.shape[0]),
                n_new_terms=n_new,
                n_pending_mat=sum(
                    self._pending_rows(m) for m in ("litemat", "full")),
                delta_ratio=round(self.delta_ratio, 4),
                version=self.version,
            )
            if auto_compact and self.delta_ratio > self.compact_threshold:
                stats["compacted"] = self.compact()
            return stats

    # -- sharded-reusable delete primitives (core/shard.py orchestrates the
    # same three steps across shards; KnowledgeBase.delete below composes
    # them into the single-store delete) --------------------------------------
    def append_raw(self, rows: np.ndarray) -> None:
        """Append pre-encoded raw rows to the rewrite delta log (no bump)."""
        self.delta.log("rewrite").append(rows)

    def append_derived(self, mode: str, rows: np.ndarray) -> None:
        """Append pre-derived rows to one materialized store's delta log."""
        if rows.shape[0]:
            self.delta.log(mode).append(rows)

    def kill_raw_rows(self, q: np.ndarray) -> np.ndarray:
        """Tombstone exact encoded triples in the raw store (base + delta).

        Returns the rows actually killed (live copies only); does NOT
        repair the derived stores — callers follow up with
        ``kill_derived_mentions`` + re-derivation of the affected
        instances' ``live_raw_mentions``.
        """
        d = self.delta
        deleted = []
        base_h = self._base_index("rewrite")._h
        hits = self._raw_locator().find(q)
        if hits.size:
            alive = d.base_alive["rewrite"]
            if alive is not None:
                hits = hits[alive[hits]]
            if hits.size:
                deleted.append(base_h[hits])
                d.kill_base("rewrite", base_h.shape[0], hits)
        rlog = d.log("rewrite")
        if rlog.n:
            dhits = RowLocator.build(rlog.rows).find(q)
            if dhits.size:
                dhits = dhits[rlog.alive[dhits]]
                if dhits.size:
                    deleted.append(rlog.rows[dhits])
                    rlog.tombstone(dhits)
        if not deleted:
            return np.zeros((0, 3), dtype=np.int32)
        return np.concatenate(deleted)

    def kill_derived_mentions(self, inst: np.ndarray) -> None:
        """Tombstone every derived row mentioning an affected instance.

        The instance-keyed SPO/OSP lookup touches only the hit runs, so
        this is O(k log N + hits) in the base size, not an O(N) scan.
        """
        d = self.delta
        for mode in ("litemat", "full"):
            idx = self._base_index(mode)
            d.kill_base(mode, idx.n, mention_rows(idx, inst))
            log = d.log(mode)
            if log.n:
                log.tombstone(mentions_mask(log.rows, inst))

    def live_raw_mentions(self, inst: np.ndarray) -> np.ndarray:
        """Live raw triples mentioning any affected instance (s or o).

        The re-derivation frontier of a delete: materializing these rows
        and keeping the derived rows that mention an affected instance is
        an exact repair of the derived stores.
        """
        d = self.delta
        base_h = self._base_index("rewrite")._h
        raw_alive = d.base_alive["rewrite"]
        raw_rows = mention_rows(self._base_index("rewrite"), inst)
        if raw_alive is not None:
            raw_rows = raw_rows[raw_alive[raw_rows]]
        parts = [base_h[raw_rows]]
        rlog = d.log("rewrite")
        if rlog.n:
            parts.append(rlog.rows[mentions_mask(rlog.rows, inst) & rlog.alive])
        return np.concatenate(parts)

    def delete(self, raw, auto_compact: bool = True) -> dict:
        """Remove raw triples (all copies) and repair the derived stores.

        Tombstones the raw rows, then re-derives every *affected instance*
        (endpoints of the deleted triples) from its remaining live triples:
        derived rows only ever mention their source triple's instances, so
        tombstoning rows that mention an affected instance and re-deriving
        from the live triples that mention one is an exact repair — no
        support counting, no full re-materialization.
        """
        s_fp, p_fp, o_fp, _ = _raw_columns(raw)
        if s_fp.shape[0] == 0:
            return dict(n_deleted=0)
        with self.write_lock:
            # the repair below tombstones + re-appends derived delta rows,
            # so any lazily queued materialization must land first
            self._flush_mat("litemat", "full")
            dyn = self._dynamic()
            ids = np.stack([dyn.lookup(s_fp), dyn.lookup(p_fp),
                            dyn.lookup(o_fp)], axis=1)
            q = ids[(ids >= 0).all(axis=1)]  # unknown-term triples: absent

            deleted = self.kill_raw_rows(q)
            if deleted.shape[0] == 0:
                return dict(n_deleted=0)
            inst = affected_instances(deleted, self.kb.tbox.instance_base)
            self.kill_derived_mentions(inst)

            # re-derive the affected instances from their live raw triples
            frontier = self.live_raw_mentions(inst)
            for mode in ("litemat", "full"):
                derived = materialize_delta_mode(frontier, self.dtb, mode)
                self.append_derived(
                    mode, derived[mentions_mask(derived, inst)])
            self._bump()
            REGISTRY.counter("engine/deleted_rows").inc(
                int(deleted.shape[0]))
            stats = dict(
                n_deleted=int(deleted.shape[0]),
                n_affected_instances=int(inst.shape[0]),
                delta_ratio=round(self.delta_ratio, 4),
                version=self.version,
            )
            if auto_compact and self.delta_ratio > self.compact_threshold:
                stats["compacted"] = self.compact()
            return stats

    def compact(self, device: bool | None = None) -> dict:
        """Fold the delta overlay into fresh base stores (sorted merges).

        Each store's base POS run interleaves with its delta POS run in one
        merge pass (tombstones dropped on the way); the merged run doubles
        as the new base array, so the rebuilt StoreIndex starts with its POS
        permutation already materialized (the other permutations re-sort
        lazily on first use).  Dictionary growth needs no work: new terms
        were absorbed into ``kb.tables`` at insert time.

        ``device`` selects the merge implementation: the merge-path Pallas
        kernel over the resident device buffers (bit-identical to the host
        merge; default on TPU backends) or the host searchsorted interleave
        (default elsewhere, where 'device' arrays live in host RAM anyway).
        """
        with self.write_lock:
            if ((self._delta is None or self._delta.empty)
                    and not self._pending_raw):
                return dict(compacted=False)
            with obs_trace.span("compact"):
                t0 = time.perf_counter()
                self._flush_mat("litemat", "full")
                if device is None:
                    device = jax.default_backend() == "tpu"
                sizes = {}
                for mode in MODES:
                    dev, idx = compact_view(self.view(mode), device=device)
                    if mode == "rewrite":
                        self.kb.spo = dev
                    elif mode == "litemat":
                        self.lite_spo = dev
                    else:
                        self.full_spo = dev
                    self._base_indexes[mode] = idx
                    sizes[mode] = int(dev.shape[0])
                self._delta = DeltaKB()
                self._raw_loc = None
                self._bump()
                REGISTRY.counter("engine/compactions").inc()
                REGISTRY.histogram("engine/compact_s").observe(
                    time.perf_counter() - t0)
            return dict(compacted=True, version=self.version, **sizes)
