"""KnowledgeBase facade: raw triples -> encoded -> materialized -> queryable.

One object wires the whole LiteMat pipeline and exposes the three execution
modes of the paper's evaluation (lite / full / no materialization), plus the
paper's appendix queries Q1–Q4 as canned pattern lists.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.abox import EncodedKB, encode_obe, encode_sae
from repro.core.closure import full_materialize
from repro.core.materialize import DeviceTBox, compact_rows, lite_materialize
from repro.core.query import Pattern, QueryEngine
from repro.core.tbox import TBox, build_tbox
from repro.rdf.generator import RawDataset

# The paper's appendix queries (over the LUBM vocabulary).
PAPER_QUERIES = {
    "Q1": [Pattern("?x", "rdf:type", "Professor")],
    "Q2": [Pattern("?x", "memberOf", "?y")],
    "Q3": [Pattern("?x", "rdf:type", "Professor"), Pattern("?x", "memberOf", "?y")],
    "Q4": [
        Pattern("?x", "rdf:type", "Chair"),
        Pattern("?y", "rdf:type", "Department"),
        Pattern("?x", "worksFor", "?y"),
    ],
}


@dataclass
class KnowledgeBase:
    kb: EncodedKB
    dtb: DeviceTBox
    lite_spo: jnp.ndarray  # compacted lite-materialized store
    full_spo: jnp.ndarray  # compacted fully-materialized store
    lite_stats: dict
    full_stats: dict
    _engines: dict = field(default_factory=dict)

    @classmethod
    def build(cls, raw: RawDataset, tbox: TBox | None = None,
              parallel_tbox: bool = False) -> "KnowledgeBase":
        tbox = tbox or build_tbox(raw.onto, parallel=parallel_tbox)
        kb = encode_obe(raw, tbox)
        dtb = DeviceTBox.build(tbox)
        lite, lvalid, lstats = lite_materialize(kb, dtb)
        full, fvalid, fstats = full_materialize(kb, dtb)
        return cls(
            kb=kb,
            dtb=dtb,
            lite_spo=compact_rows(lite, lvalid),
            full_spo=compact_rows(full, fvalid),
            lite_stats=lstats,
            full_stats=fstats,
        )

    def engine(self, mode: str = "litemat", use_index: bool = True) -> QueryEngine:
        """Cached QueryEngine per (mode, use_index).

        ``use_index=False`` forces the scan-only path — the oracle the
        indexed executables are validated against (tests/benchmarks).
        """
        key = (mode, use_index)
        if key not in self._engines:
            store = {
                "litemat": self.lite_spo,
                "full": self.full_spo,
                "rewrite": self.kb.spo,
            }[mode]
            self._engines[key] = QueryEngine(kb=self.kb, spo=store, mode=mode,
                                             dtb=self.dtb, use_index=use_index)
        return self._engines[key]

    def query(self, patterns, select=None, mode: str = "litemat",
              use_index: bool = True):
        rows, sel = self.engine(mode, use_index).run(patterns, select=select)
        return rows, sel

    def answers(self, patterns, select=None, mode: str = "litemat",
                use_index: bool = True) -> set:
        rows, _ = self.query(patterns, select=select, mode=mode,
                             use_index=use_index)
        return {tuple(r) for r in rows.tolist()}

    def sizes(self) -> dict:
        return dict(
            original=self.kb.n,
            lite=int(self.lite_spo.shape[0]),
            full=int(self.full_spo.shape[0]),
        )
