"""Lite materialization — the paper's §IV, vectorized.

Per instance, gather *candidate concepts* (explicit rdf:type objects plus
concepts implied by rdfs:domain / rdfs:range of the properties the instance
occurs with), then keep only the Most Specific Concepts: thanks to the
interval encoding, after sorting candidates a concept is redundant iff its
immediate successor (same instance) falls inside its subsumption interval —
the paper's one-pass MSC scan, here as one sort + one vectorized adjacent
compare over the whole dataset.

RDFS subtlety the paper glosses over: ``domain`` axioms of *super*-properties
also apply (rdfs7 ∘ rdfs2/3).  We fold that in by precomputing *effective*
domain/range tables per property (union over its property-DAG ancestors) on
the host — properties are few — so the device pass stays one lookup per
triple.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tbox import TBox
from repro.utils import pair64

INVALID = jnp.int32(np.iinfo(np.int32).max)  # sorts to the end


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "concept_sorted_ids", "concept_sorted_bounds", "concept_spill_lo",
        "concept_spill_hi", "concept_ancestors", "prop_sorted_ids",
        "prop_ancestors", "dr_prop_ids", "domain_table", "range_table",
    ],
    meta_fields=["rdf_type_id"],
)
@dataclass(frozen=True)
class DeviceTBox:
    """The TBox tables the device passes need, as jnp arrays."""

    rdf_type_id: int
    concept_sorted_ids: jnp.ndarray  # int32[C]
    concept_sorted_bounds: jnp.ndarray  # int32[C]
    concept_spill_lo: jnp.ndarray  # int32[C, S]
    concept_spill_hi: jnp.ndarray
    concept_ancestors: jnp.ndarray  # int32[C, D], -1 padded (DAG ancestors)
    prop_sorted_ids: jnp.ndarray  # int32[P]
    prop_ancestors: jnp.ndarray  # int32[P, DP], -1 padded
    dr_prop_ids: jnp.ndarray  # int32[Pdr] sorted (effective tables)
    domain_table: jnp.ndarray  # int32[Pdr, Kd], -1 padded
    range_table: jnp.ndarray  # int32[Pdr, Kr], -1 padded

    @staticmethod
    def build(tbox: TBox) -> "DeviceTBox":
        c = tbox.concepts
        p = tbox.properties
        if c.total_bits > 30 or p.total_bits > 30:
            raise ValueError(
                "device path needs narrow (<=30 bit) ids; use the wide-id host path"
            )
        # effective domain/range: union over property-DAG ancestors ---------
        pid_of_node = {i: int(p.ids[i]) for i in range(p.n)}
        direct_dom = {int(k): [int(v) for v in row if v >= 0]
                      for k, row in zip(tbox.dr_prop_ids, tbox.domain_table)}
        direct_rng = {int(k): [int(v) for v in row if v >= 0]
                      for k, row in zip(tbox.dr_prop_ids, tbox.range_table)}
        eff_dom, eff_rng = {}, {}
        for node in range(p.n):
            pid = pid_of_node[node]
            chain = [node, *sorted(p.tax.dag_ancestors(node))]
            dom = sorted({d for a in chain for d in direct_dom.get(pid_of_node[a], [])})
            rng = sorted({r for a in chain for r in direct_rng.get(pid_of_node[a], [])})
            if dom:
                eff_dom[pid] = dom
            if rng:
                eff_rng[pid] = rng
        keys = sorted(set(eff_dom) | set(eff_rng))
        Kd = max(1, max((len(v) for v in eff_dom.values()), default=0))
        Kr = max(1, max((len(v) for v in eff_rng.values()), default=0))
        P = max(1, len(keys))
        dr_ids = np.full((P,), -1, dtype=np.int32)
        dom_tbl = np.full((P, Kd), -1, dtype=np.int32)
        rng_tbl = np.full((P, Kr), -1, dtype=np.int32)
        for i, k in enumerate(keys):
            dr_ids[i] = k
            for j, v in enumerate(eff_dom.get(k, [])):
                dom_tbl[i, j] = v
            for j, v in enumerate(eff_rng.get(k, [])):
                rng_tbl[i, j] = v

        return DeviceTBox(
            rdf_type_id=int(tbox.rdf_type_id),
            concept_sorted_ids=jnp.asarray(c.sorted_ids, dtype=jnp.int32),
            concept_sorted_bounds=jnp.asarray(c.sorted_bounds, dtype=jnp.int32),
            concept_spill_lo=jnp.asarray(c.sorted_spill_lo, dtype=jnp.int32),
            concept_spill_hi=jnp.asarray(c.sorted_spill_hi, dtype=jnp.int32),
            concept_ancestors=jnp.asarray(c.sorted_ancestors, dtype=jnp.int32),
            prop_sorted_ids=jnp.asarray(p.sorted_ids, dtype=jnp.int32),
            prop_ancestors=jnp.asarray(p.sorted_ancestors, dtype=jnp.int32),
            dr_prop_ids=jnp.asarray(dr_ids),
            domain_table=jnp.asarray(dom_tbl),
            range_table=jnp.asarray(rng_tbl),
        )


def concept_bounds(dtb: DeviceTBox, concept_ids):
    """bound() for concept-id arrays via the sorted TBox table.

    Unknown ids (instances/literals) get bound = id + 1 (leaf semantics).
    """
    pos = jnp.searchsorted(dtb.concept_sorted_ids, concept_ids)
    pos = jnp.clip(pos, 0, dtb.concept_sorted_ids.shape[0] - 1)
    hit = dtb.concept_sorted_ids[pos] == concept_ids
    return jnp.where(hit, dtb.concept_sorted_bounds[pos], concept_ids + 1), pos, hit


# ---------------------------------------------------------------------------
# Candidate generation + MSC
# ---------------------------------------------------------------------------


def candidate_types(spo, dtb: DeviceTBox):
    """(instance, concept, explicit) candidate rows, INVALID-padded.

    Row layout (static): N explicit + N*Kd domain + N*Kr range candidates.
    """
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    is_type = p == dtb.rdf_type_id

    inst_e = jnp.where(is_type, s, INVALID)
    conc_e = jnp.where(is_type, o, INVALID)

    pos = jnp.searchsorted(dtb.dr_prop_ids, p)
    pos = jnp.clip(pos, 0, dtb.dr_prop_ids.shape[0] - 1)
    p_hit = (dtb.dr_prop_ids[pos] == p) & (~is_type)
    doms = dtb.domain_table[pos]  # (N, Kd)
    rngs = dtb.range_table[pos]  # (N, Kr)
    dom_ok = p_hit[:, None] & (doms >= 0)
    rng_ok = p_hit[:, None] & (rngs >= 0)
    inst_d = jnp.where(dom_ok, s[:, None], INVALID).reshape(-1)
    conc_d = jnp.where(dom_ok, doms, INVALID).reshape(-1)
    inst_r = jnp.where(rng_ok, o[:, None], INVALID).reshape(-1)
    conc_r = jnp.where(rng_ok, rngs, INVALID).reshape(-1)

    inst = jnp.concatenate([inst_e, inst_d, inst_r])
    conc = jnp.concatenate([conc_e, conc_d, conc_r])
    explicit = jnp.concatenate(
        [is_type, jnp.zeros(inst_d.shape, bool), jnp.zeros(inst_r.shape, bool)]
    )
    return inst, conc, explicit


def msc_select(inst, conc, explicit, dtb: DeviceTBox):
    """One-pass MSC over (instance, concept) candidates.

    Returns (inst_s, conc_s, keep, uniq_explicit, dropped_explicit,
    added_implicit) — all aligned to the sorted candidate order.
    """
    # sort by (instance, concept, explicit-first) so duplicate heads carry
    # explicitness; INVALID rows sink to the end.
    perm = jnp.lexsort(((~explicit).astype(jnp.int32), conc, inst))
    inst_s, conc_s, expl_s = inst[perm], conc[perm], explicit[perm]
    valid = inst_s != INVALID

    first = jnp.concatenate(
        [jnp.ones((1,), bool), (inst_s[1:] != inst_s[:-1]) | (conc_s[1:] != conc_s[:-1])]
    )
    uniq = first & valid

    bounds, _, _ = concept_bounds(dtb, conc_s)
    bounds = jnp.where(valid, bounds, conc_s)  # freeze padding rows
    # a unique candidate c is dropped iff some candidate of the same instance
    # lies strictly inside (c, bound(c)) — i.e. a strict descendant is
    # present.  The sorted candidate array itself serves as the index: rows
    # in [R_right(inst, c), R_left(inst, bound)) are exactly those
    # descendants, so two binary searches decide the paper's interval test
    # exactly (duplicate runs included).
    L = pair64.searchsorted_pair(inst_s, conc_s, inst_s, conc_s, side="right")
    R = pair64.searchsorted_pair(inst_s, conc_s, inst_s, bounds, side="left")
    dropped_by_desc = R > L

    # spill intervals (multiple inheritance): candidate c is also dropped if
    # some candidate of the same instance lies in one of c's spill ranges.
    S = dtb.concept_spill_lo.shape[1]
    _, cpos, chit = concept_bounds(dtb, conc_s)
    sp_lo = jnp.where(chit[:, None], dtb.concept_spill_lo[cpos], 0)
    sp_hi = jnp.where(chit[:, None], dtb.concept_spill_hi[cpos], 0)
    any_spill_hit = jnp.zeros(conc_s.shape, bool)
    if S > 0:
        for k in range(S):
            lo_k, hi_k = sp_lo[:, k], sp_hi[:, k]
            has = lo_k < hi_k
            L = pair64.searchsorted_pair(inst_s, conc_s, inst_s, lo_k, side="left")
            R = pair64.searchsorted_pair(inst_s, conc_s, inst_s, hi_k, side="left")
            any_spill_hit = any_spill_hit | (has & (R > L))

    keep = uniq & ~dropped_by_desc & ~any_spill_hit
    dropped_explicit = (uniq & expl_s & ~keep).astype(jnp.int32).sum()
    added_implicit = (keep & ~expl_s).astype(jnp.int32).sum()
    n_explicit_uniq = (uniq & expl_s).astype(jnp.int32).sum()
    return inst_s, conc_s, keep, n_explicit_uniq, dropped_explicit, added_implicit


@jax.jit
def _lite_materialize_device(spo, dtb: DeviceTBox):
    inst, conc, explicit = candidate_types(spo, dtb)
    inst_s, conc_s, keep, n_expl, n_drop, n_add = msc_select(inst, conc, explicit, dtb)

    # output: non-type triples unchanged + MSC type triples (both padded)
    is_type = spo[:, 1] == dtb.rdf_type_id
    nt = jnp.where(is_type[:, None], INVALID, spo)
    ty = jnp.stack(
        [
            jnp.where(keep, inst_s, INVALID),
            jnp.where(keep, jnp.int32(dtb.rdf_type_id), INVALID),
            jnp.where(keep, conc_s, INVALID),
        ],
        axis=1,
    )
    out = jnp.concatenate([nt, ty], axis=0)
    valid = out[:, 0] != INVALID
    stats = dict(
        n_explicit_unique=n_expl,
        n_deleted_explicit=n_drop,
        n_added_implicit=n_add,
        n_type_out=keep.astype(jnp.int32).sum(),
        n_nontype=(~is_type).astype(jnp.int32).sum(),
    )
    return out, valid, stats


def lite_materialize(kb, dtb: DeviceTBox | None = None):
    """kb.spo -> (materialized spo (padded), valid mask, stats dict)."""
    dtb = dtb or DeviceTBox.build(kb.tbox)
    out, valid, stats = _lite_materialize_device(kb.spo, dtb)
    return out, valid, {k: int(v) for k, v in stats.items()}


def compact_rows(rows, valid):
    """Drop padding rows (host sync for the final count)."""
    order = jnp.argsort(~valid, stable=True)
    n = int(valid.sum())
    return rows[order][:n]
