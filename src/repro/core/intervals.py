"""Interval arithmetic on LiteMat ids.

The heart of the paper: for two TBox entities A, B encoded over
``total_bits`` bits with prefix encoding,

    B is subsumed by A   <=>   idA <= idB < bound(idA)
    bound(idA)            =    idA + 2 ** (total_bits - used_bits(A))

where ``used_bits(A)`` (= the paper's ``start + localLength``) is the number
of significant prefix bits of A.  Everything here is shape-polymorphic jnp
code usable inside jit / shard_map / vmap as well as plain numpy.

Two id widths are supported:

* **narrow ids** — a single int32/int64 word.  Covers LUBM (14 bits) and
  DBPedia (27 bits) comfortably.  This is the fast path used on device.
* **wide ids** — fixed-size little-endian-by-significance vectors of 30-bit
  words (most significant word first), for hierarchies like Wikidata whose
  encoding needs >31 bits (the paper measured 102).  Comparison is
  lexicographic; ``bound`` is precomputed host-side with Python bigints.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

WIDE_WORD_BITS = 30
_WORD_MASK = (1 << WIDE_WORD_BITS) - 1

# ---------------------------------------------------------------------------
# Narrow ids
# ---------------------------------------------------------------------------


def bound_of(ids, used_bits, total_bits: int):
    """Upper (exclusive) bound of the subsumption interval of each id.

    Works for numpy and jnp inputs.  ``used_bits`` broadcasts against
    ``ids``.  ``total_bits`` is a static Python int.
    """
    xp = jnp if isinstance(ids, jnp.ndarray) else np
    ids = xp.asarray(ids)
    shift = total_bits - xp.asarray(used_bits, dtype=ids.dtype)
    return ids + (xp.asarray(1, dtype=ids.dtype) << shift)


def is_subsumed_by(x, lo, hi):
    """x in [lo, hi) — vectorized; the paper's single-comparison matcher."""
    return (x >= lo) & (x < hi)


def ancestor_at(ids, ancestor_used_bits, total_bits: int):
    """Mask ``ids`` down to an ancestor's prefix (keep top ``used`` bits).

    For a concept id this reconstructs the id of its ancestor at the tree
    level that consumed ``ancestor_used_bits`` prefix bits — pure bit math,
    no table lookup.  Used by the full-materialization closure expander.
    """
    xp = jnp if isinstance(ids, jnp.ndarray) else np
    ids = xp.asarray(ids)
    one = xp.asarray(1, dtype=ids.dtype)
    low_mask = (one << (total_bits - xp.asarray(ancestor_used_bits, dtype=ids.dtype))) - one
    return ids & ~low_mask


def lookup_index(sorted_ids, query_ids):
    """Index of each query id in a sorted id table; -1 if absent.

    jnp.searchsorted based so it stays O(log C) per lookup on device.
    """
    xp = jnp if isinstance(query_ids, jnp.ndarray) or isinstance(sorted_ids, jnp.ndarray) else np
    sorted_ids = xp.asarray(sorted_ids)
    query_ids = xp.asarray(query_ids)
    pos = xp.searchsorted(sorted_ids, query_ids)
    pos = xp.clip(pos, 0, sorted_ids.shape[0] - 1)
    found = sorted_ids[pos] == query_ids
    return xp.where(found, pos, -1)


# ---------------------------------------------------------------------------
# Wide ids (W words of 30 bits, most-significant word first)
# ---------------------------------------------------------------------------


def words_needed(total_bits: int) -> int:
    return max(1, -(-total_bits // WIDE_WORD_BITS))


def pack_wide(value: int, n_words: int) -> np.ndarray:
    """Python bigint -> int32[n_words] (MSW first)."""
    out = np.zeros((n_words,), dtype=np.int32)
    for i in range(n_words - 1, -1, -1):
        out[i] = value & _WORD_MASK
        value >>= WIDE_WORD_BITS
    if value:
        raise ValueError("value does not fit in the requested wide-id width")
    return out


def unpack_wide(words: np.ndarray) -> int:
    value = 0
    for w in np.asarray(words).tolist():
        value = (value << WIDE_WORD_BITS) | int(w)
    return value


def wide_bound_host(value: int, used_bits: int, total_bits: int) -> int:
    """bound() on host bigints (precomputed into device tables)."""
    return value + (1 << (total_bits - used_bits))


def lex_lt(a, b):
    """Lexicographic a < b over trailing word axis. Shapes (..., W)."""
    xp = jnp if isinstance(a, jnp.ndarray) or isinstance(b, jnp.ndarray) else np
    a = xp.asarray(a)
    b = xp.asarray(b)
    lt = a < b
    gt = a > b
    # first index where they differ decides; implement with cumulative "all
    # equal so far" mask (associative, vectorizes cleanly on the VPU).
    eq_prefix = xp.cumprod(
        xp.concatenate(
            [xp.ones_like(lt[..., :1], dtype=xp.int32), (~(lt | gt)).astype(xp.int32)[..., :-1]],
            axis=-1,
        ),
        axis=-1,
    ).astype(bool)
    return xp.any(lt & eq_prefix, axis=-1)


def lex_le(a, b):
    return ~lex_lt(b, a)


def wide_is_subsumed_by(x, lo, hi):
    """lo <= x < hi with (..., W) wide ids."""
    return lex_le(lo, x) & lex_lt(x, hi)
