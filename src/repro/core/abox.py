"""ABox encoding: OBE (ontology-based) vs SAE (standard) — paper §III.B/VI.C1.

``encode_obe``: TBox terms (concepts, properties, rdf:type) are already
encoded; only genuine instance/literal terms go through the parallel
dictionary.  ``encode_sae`` is the paper's baseline: every term — including
the very frequent rdf:type and property IRIs — is dictionary-encoded with no
semantic structure.  The measured gap between the two reproduces Table III.

Both paths are jit-compiled end-to-end; the sharded variant wraps the same
logic in shard_map with the hash-partition dictionary of dictionary.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import dictionary as dct
from repro.core.tbox import RDF_TYPE, TBox
from repro.utils import pair64
from repro.utils.hashing import fingerprint_string


@dataclass
class EncodedKB:
    """Device-encoded knowledge base."""

    spo: jnp.ndarray  # int32[N, 3] encoded triples
    tables: tuple  # dictionary parts (TBox map, instance table)
    tbox: TBox | None
    n_instance_terms: int
    term_strings: dict | None = None  # host fp -> string (optional)
    _merged: dct.TermTable | None = None

    @property
    def n(self) -> int:
        return int(self.spo.shape[0])

    @property
    def table(self) -> dct.TermTable:
        """Full dictionary (lazily merged — only locate/extract need it)."""
        if self._merged is None:
            t = self.tables[0]
            for other in self.tables[1:]:
                t = dct.merge_tables(t, other)
            self._merged = t
        return self._merged

    # host conveniences ------------------------------------------------------
    def locate(self, terms):
        """strings -> ids (-1 if unknown)."""
        fps = np.array([fingerprint_string(t) for t in terms], dtype=np.int64)
        hi, lo = pair64.split_np(fps)
        ids, _ = self.table.locate(jnp.asarray(hi), jnp.asarray(lo))
        return np.asarray(ids)

    def extract(self, ids):
        """ids -> strings (via host term_strings; fp hex if unknown)."""
        hi, lo, hit = self.table.extract_fp(jnp.asarray(np.asarray(ids, dtype=np.int32)))
        fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
        out = []
        for f, h in zip(fps.tolist(), np.asarray(hit).tolist()):
            if not h:
                out.append(None)
            elif self.term_strings and f in self.term_strings:
                out.append(self.term_strings[f])
            else:
                out.append(f"fp:{f:x}")
        return out


def tbox_term_map(tbox: TBox):
    """(fps, ids) of every TBox-encoded term (concept + property names)."""
    fps, ids = [], []
    for enc in (tbox.concepts, tbox.properties):
        for name in enc.tax.names:
            if name.startswith("__"):  # synthetic roots have no IRI
                continue
            fps.append(fingerprint_string(name))
            ids.append(enc.id_of(name))
    fps = np.array(fps, dtype=np.int64)
    ids = np.array(ids, dtype=np.int32)
    if len(np.unique(fps)) != len(fps):
        raise ValueError("fingerprint collision among TBox terms")
    return fps, ids


@partial(jax.jit, static_argnames=("base", "dict_cols"))
def _encode_columns(shi, slo, phi, plo, ohi, olo, thi, tlo, tids, base: int, dict_cols):
    """Device core shared by OBE/SAE: resolve columns, dict-encode the rest.

    ``dict_cols`` selects which columns feed the instance dictionary: OBE
    passes (0, 2) — predicates and rdf:type objects are already TBox-encoded,
    so the dictionary sort runs on 2N occurrences instead of SAE's 3N.  This
    is exactly where the paper's OBE-vs-SAE throughput gap comes from.
    """
    qhi = jnp.stack([shi, phi, ohi])  # (3, N)
    qlo = jnp.stack([slo, plo, olo])
    tb_ids, tb_hit = pair64.lookup_pair(thi, tlo, tids, qhi, qlo)

    # dictionary over unresolved occurrences of the selected columns
    un_hi = jnp.where(tb_hit[dict_cols, :], dct.SENTINEL, qhi[dict_cols, :]).reshape(-1)
    un_lo = jnp.where(tb_hit[dict_cols, :], dct.SENTINEL, qlo[dict_cols, :]).reshape(-1)
    table = dct.build_local_dictionary(un_hi, un_lo, un_hi != dct.SENTINEL, base)
    inst_ids, _ = table.locate(qhi, qlo)
    ids = jnp.where(tb_hit, tb_ids, inst_ids)
    return ids[0], ids[1], ids[2], table


def _to_pairs(col: np.ndarray):
    hi, lo = pair64.split_np(col)
    return jnp.asarray(hi), jnp.asarray(lo)


def encode_obe(raw, tbox: TBox) -> EncodedKB:
    """Ontology-based encoding: TBox map + parallel instance dictionary."""
    fps, ids = tbox_term_map(tbox)
    ttable = dct.table_from_host(fps, ids)
    shi, slo = _to_pairs(raw.s)
    phi, plo = _to_pairs(raw.p)
    ohi, olo = _to_pairs(raw.o)
    s_id, p_id, o_id, itable = _encode_columns(
        shi, slo, phi, plo, ohi, olo,
        ttable.fp_hi, ttable.fp_lo, ttable.ids, base=tbox.instance_base, dict_cols=(0, 2),
    )
    if int(jnp.min(p_id)) < 0:
        raise ValueError(
            "OBE found predicates outside the TBox property map — classify "
            "the ontology over the full predicate set first (the N-Triples "
            "parser does this automatically)"
        )
    spo = jnp.stack([s_id, p_id, o_id], axis=1)
    return EncodedKB(
        spo=spo, tables=(ttable, itable), tbox=tbox,
        n_instance_terms=int(itable.count),
        term_strings=getattr(raw, "term_strings", None),
    )


def encode_sae(raw) -> EncodedKB:
    """Standard ABox-only encoding (paper's baseline): no TBox knowledge."""
    shi, slo = _to_pairs(raw.s)
    phi, plo = _to_pairs(raw.p)
    ohi, olo = _to_pairs(raw.o)
    empty_hi = jnp.full((1,), dct.SENTINEL, dtype=jnp.int32)
    empty_ids = jnp.full((1,), -1, dtype=jnp.int32)
    s_id, p_id, o_id, itable = _encode_columns(
        shi, slo, phi, plo, ohi, olo, empty_hi, empty_hi, empty_ids, base=0, dict_cols=(0, 1, 2),
    )
    spo = jnp.stack([s_id, p_id, o_id], axis=1)
    return EncodedKB(
        spo=spo, tables=(itable,), tbox=None,
        n_instance_terms=int(itable.count),
        term_strings=getattr(raw, "term_strings", None),
    )
