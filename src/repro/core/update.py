"""Incremental update machinery: dictionary growth + delta (re)derivation.

The LiteMat encoding makes the ABox *appendable*: concept/property ids are
fixed by the TBox, and instance ids live in their own namespace above
``tbox.instance_base``, assigned densely in dictionary-rank order.  A new
instance term therefore just takes the next free id — no existing id moves,
no store is re-encoded.  This module supplies the host-side pieces that
``KnowledgeBase.insert`` / ``.delete`` (core/engine.py) orchestrate:

  * :class:`DynamicDictionary` — a growable host mirror of the device
    dictionary.  Lookups are numpy binary searches; new terms are allocated
    ids past ``n_instance_terms`` and handed back as TermTable chunks so the
    device dictionary (``EncodedKB.tables``) absorbs them without a rebuild.
  * :func:`materialize_delta_mode` — materialization of *only* the delta
    rows against the existing DeviceTBox, one store mode at a time (the
    unit of the KnowledgeBase's lazy per-mode derivation), padded to
    power-of-two buckets so repeated insert batches reuse the compiled
    materializers (:func:`materialize_delta` bundles both modes).
  * :class:`RowLocator` — exact (s, p, o) row lookup over a store (all
    duplicate copies), for tombstoning deletes.
  * :func:`affected_instances` / :func:`mention_rows` — the delete
    re-derivation frontier: affected instances resolve to base rows through
    the SPO/OSP permutations (contiguous runs per instance), so a delete's
    base-store work is O(k log N + hits), sublinear in the store size
    (``mentions_mask`` remains the O(N) scan for the small delta arrays).

Correctness model (why delta-only materialization is enough):

  * *full* closure here is per-triple local — every derived triple is a
    gather from precomputed ancestor/domain/range tables of one source
    triple — so closure(base ∪ delta) = closure(base) ∪ closure(delta),
    exactly.
  * *lite* (MSC) output is per-instance, and a union of per-batch MSC sets
    may retain a concept alongside one of its descendants; that is
    answer-equivalent under interval evaluation (the ancestor is entailed,
    and every query interval containing the descendant contains it), which
    is the invariant the update tests pin against full rebuilds.
  * *deletes* re-derive exactly: every derived row mentions only instances
    of its source triple, so tombstoning all rows that mention an affected
    instance and re-materializing all live raw triples that mention one is
    a closed repair (Hu et al.'s delta-Datalog boundary, specialized to
    LiteMat's one-pass rules).

Assumed data model (the paper's): properties connect instances/literals;
concept ids appear only as rdf:type objects.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import dictionary as dct
from repro.core.abox import EncodedKB
from repro.core.closure import _full_materialize_device
from repro.core.index import pad_rows as _pad_rows, pow2_bucket
from repro.core.materialize import DeviceTBox, _lite_materialize_device
from repro.utils import pair64


# ---------------------------------------------------------------------------
# Growable dictionary
# ---------------------------------------------------------------------------


@dataclass
class DynamicDictionary:
    """Host mirror of an EncodedKB's dictionary that can allocate new ids.

    ``fps``/``ids`` are the sorted fingerprint -> id map of every known term
    (TBox + instances).  New terms get ``next_id``, ``next_id + 1``, ... —
    strictly past every existing instance id, so the base store's encoding
    is untouched (the unused id headroom the paper's encoding reserves).
    """

    fps: np.ndarray  # int64, sorted
    ids: np.ndarray  # int32, aligned with fps
    next_id: int
    instance_base: int
    n_new_terms: int = 0
    _pending_fps: list = field(default_factory=list)
    _pending_ids: list = field(default_factory=list)

    @classmethod
    def from_kb(cls, kb: EncodedKB) -> "DynamicDictionary":
        t = kb.table  # merged TermTable (device); one host pull at build
        hi = np.asarray(t.fp_hi)
        lo = np.asarray(t.fp_lo)
        ids = np.asarray(t.ids)
        real = ids >= 0  # padding rows carry -1
        fps = pair64.combine_np(hi[real], lo[real])
        order = np.argsort(fps)
        base = kb.tbox.instance_base if kb.tbox is not None else 0
        return cls(
            fps=fps[order],
            ids=ids[real][order].astype(np.int32),
            next_id=base + kb.n_instance_terms,
            instance_base=base,
        )

    def lookup(self, fps: np.ndarray) -> np.ndarray:
        """fps -> ids; -1 where unknown."""
        fps = np.asarray(fps, dtype=np.int64)
        if self.fps.shape[0] == 0:
            return np.full(fps.shape[0], -1, dtype=np.int32)
        pos = np.searchsorted(self.fps, fps)
        pos_c = np.clip(pos, 0, self.fps.shape[0] - 1)
        hit = self.fps[pos_c] == fps
        return np.where(hit, self.ids[pos_c], np.int32(-1)).astype(np.int32)

    def encode(self, fps: np.ndarray) -> tuple[np.ndarray, int]:
        """fps -> ids, allocating fresh ids for unknown terms.

        Returns (ids, n_new).  Duplicate unknown fps within one batch share
        one new id (same dedup the batch dictionary build performs).
        """
        out = self.lookup(fps)
        missing = out < 0
        if not missing.any():
            return out, 0
        new_fps = np.unique(np.asarray(fps, dtype=np.int64)[missing])
        new_ids = (self.next_id
                   + np.arange(new_fps.shape[0], dtype=np.int64)).astype(np.int32)
        self.next_id += int(new_fps.shape[0])
        self.n_new_terms += int(new_fps.shape[0])
        self._pending_fps.append(new_fps)
        self._pending_ids.append(new_ids)
        # splice into the sorted map
        ins = np.searchsorted(self.fps, new_fps)
        self.fps = np.insert(self.fps, ins, new_fps)
        self.ids = np.insert(self.ids, ins, new_ids)
        out = self.lookup(fps)
        return out, int(new_fps.shape[0])

    def register(self, fps: np.ndarray, ids: np.ndarray) -> int:
        """Adopt externally assigned (fps, ids) — the sharded encode's terms.

        The device-side sharded dictionary build (``dictionary.py::
        sharded_dictionary_fn``) assigns ids to a batch's unknown terms in
        its own hash-partitioned order; this splices them into the host
        mirror and queues them as a pending TermTable chunk, exactly like
        ``encode`` does for its own allocations.  ``fps`` must be distinct
        unknown terms and ``ids`` must sit at/above ``next_id``.
        """
        fps = np.asarray(fps, dtype=np.int64)
        ids = np.asarray(ids, dtype=np.int32)
        if fps.shape[0] == 0:
            return 0
        order = np.argsort(fps)
        fps, ids = fps[order], ids[order]
        self.next_id = max(self.next_id, int(ids.max()) + 1)
        self.n_new_terms += int(fps.shape[0])
        self._pending_fps.append(fps)
        self._pending_ids.append(ids)
        ins = np.searchsorted(self.fps, fps)
        self.fps = np.insert(self.fps, ins, fps)
        self.ids = np.insert(self.ids, ins, ids)
        return int(fps.shape[0])

    def take_new_terms(self):
        """Drain terms allocated since the last call -> (fps, ids) or None.

        The caller folds them into the device dictionary as one TermTable
        chunk (``EncodedKB.tables``), keeping locate/extract complete.
        """
        if not self._pending_fps:
            return None
        fps = np.concatenate(self._pending_fps)
        ids = np.concatenate(self._pending_ids)
        self._pending_fps.clear()
        self._pending_ids.clear()
        return fps, ids


def encode_delta(dyn: DynamicDictionary,
                 s_fp: np.ndarray, p_fp: np.ndarray, o_fp: np.ndarray):
    """Encode raw delta triples, growing the instance dictionary in place.

    Predicates must already be TBox properties (same OBE invariant as
    ``encode_obe``: the TBox is fixed between re-encodes; only the ABox
    grows).  Returns (spo int32[M, 3], n_new_terms).
    """
    p_ids = dyn.lookup(p_fp)
    bad = (p_ids < 0) | (p_ids >= dyn.instance_base)
    if bad.any():
        raise ValueError(
            "delta contains predicates outside the TBox property map — "
            "schema growth needs a re-encode (KnowledgeBase.build), the "
            "incremental path only grows the ABox"
        )
    # one encode over s+o: a single sorted-splice of the dictionary arrays
    # per batch instead of one per column
    so_ids, n_new = dyn.encode(np.concatenate([s_fp, o_fp]))
    s_ids, o_ids = np.split(so_ids, 2)
    spo = np.stack([s_ids, p_ids, o_ids], axis=1).astype(np.int32)
    return spo, n_new


def absorb_new_terms(kb: EncodedKB, dyn: DynamicDictionary,
                     term_strings: dict | None = None) -> int:
    """Fold freshly allocated terms into the device dictionary + string map."""
    chunk = dyn.take_new_terms()
    if chunk is None:
        return 0
    fps, ids = chunk
    kb.tables = (*kb.tables, dct.table_from_host(fps, ids))
    kb._merged = None  # next locate/extract re-merges lazily
    kb.n_instance_terms += int(ids.shape[0])
    if term_strings:
        if kb.term_strings is None:
            kb.term_strings = {}
        kb.term_strings.update(term_strings)
    return int(ids.shape[0])


# ---------------------------------------------------------------------------
# Delta materialization
# ---------------------------------------------------------------------------


_MATERIALIZERS = {
    "litemat": _lite_materialize_device,
    "full": _full_materialize_device,
}


def materialize_delta_mode(spo: np.ndarray, dtb: DeviceTBox,
                           mode: str) -> np.ndarray:
    """Materialize delta rows for ONE store mode ('litemat' | 'full').

    The unit of lazy per-mode derivation: a deployment that only serves the
    lite store never pays for the full closure of its inserts (and vice
    versa).  Rows are padded to a power-of-two bucket so the jitted device
    materializers compile once per bucket, not once per batch size.
    """
    import jax.numpy as jnp

    spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
    if spo.shape[0] == 0:
        return np.zeros((0, 3), dtype=np.int32)
    padded = jnp.asarray(_pad_rows(spo, pow2_bucket(spo.shape[0], floor=64)))
    rows, valid, _ = _MATERIALIZERS[mode](padded, dtb)
    return np.asarray(rows)[np.asarray(valid)]


def materialize_delta(spo: np.ndarray, dtb: DeviceTBox):
    """lite + full materialization of delta rows -> (lite, full) np arrays."""
    return (materialize_delta_mode(spo, dtb, "litemat"),
            materialize_delta_mode(spo, dtb, "full"))


# ---------------------------------------------------------------------------
# Delete support: exact row location + re-derivation frontier
# ---------------------------------------------------------------------------


@dataclass
class RowLocator:
    """Exact (s, p, o) -> row-index lookup over one store (all copies).

    One lexsort at build; each probe is two binary searches over an int64
    (s << 32 | p) composite plus a search of the o column inside the run.
    """

    perm: np.ndarray
    key_sp: np.ndarray  # int64 (s << 32 | p), sorted
    o_sorted: np.ndarray

    @classmethod
    def build(cls, rows: np.ndarray) -> "RowLocator":
        rows = np.asarray(rows)
        perm = np.lexsort((rows[:, 2], rows[:, 1], rows[:, 0]))
        sp = ((rows[perm, 0].astype(np.int64) << np.int64(32))
              | rows[perm, 1].astype(np.int64))
        return cls(perm=perm, key_sp=sp,
                   o_sorted=np.ascontiguousarray(rows[perm, 2]))

    def find(self, spo: np.ndarray) -> np.ndarray:
        """Row indices (original coordinates) matching ANY query triple."""
        spo = np.asarray(spo).reshape(-1, 3)
        qsp = ((spo[:, 0].astype(np.int64) << np.int64(32))
               | spo[:, 1].astype(np.int64))
        l = np.searchsorted(self.key_sp, qsp, side="left")
        r = np.searchsorted(self.key_sp, qsp, side="right")
        hits = []
        for i in range(spo.shape[0]):
            lo, hi = int(l[i]), int(r[i])
            if hi <= lo:
                continue
            seg = self.o_sorted[lo:hi]
            a = lo + int(np.searchsorted(seg, spo[i, 2], side="left"))
            b = lo + int(np.searchsorted(seg, spo[i, 2], side="right"))
            if b > a:
                hits.append(self.perm[a:b])
        if not hits:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate(hits))


def affected_instances(deleted_rows: np.ndarray, instance_base: int) -> np.ndarray:
    """Sorted instance/literal ids mentioned by the deleted raw triples.

    TBox ids (concepts as rdf:type objects, properties) are excluded: their
    derived rows are keyed by the *instance* side, which is what gets
    re-derived.
    """
    ends = np.concatenate([deleted_rows[:, 0], deleted_rows[:, 2]])
    return np.unique(ends[ends >= instance_base])


def mentions_mask(rows: np.ndarray, instances: np.ndarray) -> np.ndarray:
    """bool[N]: row mentions (as s or o) any of the sorted instance ids.

    O(N) scan — appropriate for the SMALL arrays of the delete path (delta
    logs, re-derived frontiers).  Base stores go through ``mention_rows``,
    which is sublinear in the store size.
    """
    if rows.shape[0] == 0 or instances.shape[0] == 0:
        return np.zeros(rows.shape[0], dtype=bool)
    return (np.isin(rows[:, 0], instances, assume_unique=False)
            | np.isin(rows[:, 2], instances, assume_unique=False))


def mention_rows(index, instances: np.ndarray) -> np.ndarray:
    """Row indices (original coords) mentioning any instance as s or o.

    The instance-keyed replacement for scanning a base store with
    ``mentions_mask``: each instance id is a *contiguous run* of the SPO
    permutation (as subject) and of the OSP permutation (as object), so the
    lookup is two vectorized binary searches per permutation plus the hit
    segments — O(k log N + hits) against an O(N) scan per delete.  The two
    permutations are exactly the ones variable-predicate patterns already
    materialize; first use pays their one-time lazy sort.
    """
    instances = np.asarray(instances).reshape(-1)
    if instances.shape[0] == 0 or index.n == 0:
        return np.zeros(0, dtype=np.int64)
    hits = []
    for name in ("spo", "osp"):
        p = index.perm(name)
        l = np.searchsorted(p.primary, instances, side="left")
        r = np.searchsorted(p.primary, instances, side="right")
        for a, b in zip(l.tolist(), r.tolist()):
            if b > a:
                hits.append(p.perm[a:b])
    if not hits:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(hits))
