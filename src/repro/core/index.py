"""Device-resident sorted indexes over an encoded triple store.

LiteMat's encoding turns RDFS inference into interval containment, so a
triple pattern with a constant predicate (and, for rdf:type patterns, a
constant concept interval) selects a *contiguous run* of a suitably sorted
store — the observation behind self-indexed RDF stores (WaterFowl,
k²-Triples).  This module materializes four permutations of the (N, 3)
store, each lazily on first use:

  * POS — rows ordered by (predicate, object, subject): resolves
    ``(?x p ?y)`` and ``(?x rdf:type C)`` patterns,
  * PSO — rows ordered by (predicate, subject, object): resolves
    ``(s p ?y)`` patterns with a constant subject,
  * SPO — rows ordered by (subject, predicate, object): resolves
    ``(s ?p ?y)`` patterns — constant subject, *variable* predicate,
  * OSP — rows ordered by (object, subject, predicate): resolves
    ``(?x ?p o)`` patterns — constant object, *variable* predicate.

Range endpoints are found with host-side binary searches over int64
composite keys — O(log N) on a few cached numpy arrays, negligible next to
device work — while the row gathers happen on device from the permuted
stores.  A pattern then costs two binary searches plus one contiguous gather
instead of a full scan + stable sort, and the range *length* gives the
planner an exact cardinality for free.

Each permutation keeps its source-row permutation vector so that overlay
machinery (core/delta.py) can align per-row liveness masks with the sorted
order without re-sorting.

``merge_sorted`` is the compaction primitive: two already-sorted runs of the
same permutation (the base index and a small delta index) interleave into
one sorted array by composite-key binary search — no re-sort of the base.

``TypeIndex`` is the serving-path specialization: the rdf:type subset of
the store ordered by (object, subject), so a batched "members of class C"
request is two binary searches + a slice rather than a full-view sort.
"""
from __future__ import annotations

import itertools

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

_SHIFT = np.int64(32)

# StoreIndex identity tokens: device caches (core/delta.py) key their state
# on the *base* they were built from, and Python object ids can be recycled.
_TOKENS = itertools.count()

PERMUTATIONS = ("pos", "pso", "spo", "osp")


INVALID = np.int32(np.iinfo(np.int32).max)


def pow2_bucket(n: int, floor: int = 8) -> int:
    """Smallest power of two >= n (>= floor) — THE capacity-bucket helper.

    Shared by query capacities, delta padding, and member-set padding so
    every layer lands on the same buckets and compiled executables are
    reused across them.
    """
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), int(np.log2(floor)))


def pad_rows(rows: np.ndarray, cap: int) -> np.ndarray:
    """Pad an (N, 3) triple array to ``cap`` rows of INVALID — THE padding
    helper (delta buckets, materializer batches) so the fill contract
    lives in one place."""
    pad = cap - rows.shape[0]
    if pad <= 0:
        return rows
    return np.concatenate(
        [rows, np.full((pad, 3), INVALID, dtype=np.int32)])


def _composite(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Lexicographic (a, b) order as one sortable int64 key (ids are < 2^31)."""
    return (a.astype(np.int64) << _SHIFT) | b.astype(np.int64)


@dataclass
class _Perm:
    """One sorted permutation: device rows + host search keys + source perm."""

    rows: jnp.ndarray  # device copy of the permuted store
    primary: np.ndarray  # host primary-sort column
    key: np.ndarray  # host (primary << 32 | secondary) composite keys
    perm: np.ndarray  # source-row index of each sorted row
    inv: np.ndarray | None = None  # lazy original-row -> sorted-position map


# (primary, secondary, tertiary) column indices per permutation name; the
# tertiary column breaks ties so exact duplicate rows sort adjacently.
_ORDERS = {"pos": (1, 2, 0), "pso": (1, 0, 2), "spo": (0, 1, 2), "osp": (2, 0, 1)}


def key_cols(name: str):
    """(primary, secondary) column indices of permutation ``name``.

    The device-side key planes of a sorted store are just these two columns
    of its permuted rows — the index-nested-loop join (core/query.py) probes
    them with the pair-search kernel, so no separate key upload ever exists.
    """
    a, b, _ = _ORDERS[name]
    return a, b


@dataclass
class StoreIndex:
    """Sorted permutations of one triple store + host search keys.

    Each permutation is an O(N log N) host lexsort plus a device-resident
    copy of the store, so they materialize lazily on first use: a workload
    of predicate/type patterns (all of LUBM Q1-Q4) never pays for PSO, SPO,
    or OSP.
    """

    _h: np.ndarray = field(repr=False)  # host copy of the store
    _perms: dict = field(default_factory=dict, repr=False)
    token: int = field(default_factory=lambda: next(_TOKENS), repr=False)

    @classmethod
    def build(cls, spo) -> "StoreIndex":
        return cls(_h=np.asarray(spo))

    @classmethod
    def from_sorted(cls, rows: np.ndarray, name: str,
                    dev_rows: jnp.ndarray | None = None) -> "StoreIndex":
        """Wrap an array already sorted in permutation ``name`` order.

        Used by compaction: the merged POS run doubles as the new store, so
        the POS permutation is the identity and costs nothing to register.
        ``dev_rows`` hands over an existing device copy (the device-side
        merge result) so the index never re-uploads it.
        """
        idx = cls(_h=np.asarray(rows))
        a, b, _ = _ORDERS[name]
        h = idx._h
        idx._perms[name] = _Perm(
            rows=jnp.asarray(h) if dev_rows is None else dev_rows,
            primary=np.ascontiguousarray(h[:, a]),
            key=_composite(h[:, a], h[:, b]),
            perm=np.arange(h.shape[0], dtype=np.int64),
        )
        return idx

    def perm(self, name: str) -> _Perm:
        if name not in self._perms:
            a, b, c = _ORDERS[name]
            h = self._h
            p = np.lexsort((h[:, c], h[:, b], h[:, a]))
            hp = h[p]
            self._perms[name] = _Perm(
                rows=jnp.asarray(hp),
                primary=np.ascontiguousarray(hp[:, a]),
                key=_composite(hp[:, a], hp[:, b]),
                perm=p,
            )
        return self._perms[name]

    def inv_perm(self, name: str) -> np.ndarray:
        """original-row -> sorted-position map of permutation ``name``.

        The device overlay caches (core/delta.py) need it to scatter
        tombstone bits — recorded in original store coordinates — into the
        permuted liveness buffers.  O(N) once per permutation, cached.
        """
        p = self.perm(name)
        if p.inv is None:
            inv = np.empty(p.perm.shape[0], dtype=np.int64)
            inv[p.perm] = np.arange(p.perm.shape[0], dtype=np.int64)
            p.inv = inv
        return p.inv

    # -- legacy aliases (PR 1 API) -------------------------------------------
    @property
    def pos_rows(self) -> jnp.ndarray:
        return self.perm("pos").rows

    @property
    def pso_rows(self) -> jnp.ndarray:
        return self.perm("pso").rows

    @property
    def n(self) -> int:
        return int(self._h.shape[0])

    # -- host-side O(log N) range lookups ------------------------------------
    def primary_range(self, name: str, lo: int, hi: int):
        """Row range of primary-column interval [lo, hi) in permutation ``name``."""
        col = self.perm(name).primary
        r0 = int(np.searchsorted(col, lo, side="left"))
        r1 = int(np.searchsorted(col, hi, side="left"))
        return r0, r1

    def composite_range(self, name: str, a_id: int, blo: int, bhi: int):
        """Row range of (primary == a_id, secondary in [blo, bhi))."""
        key = self.perm(name).key
        r0 = int(np.searchsorted(key, _composite_scalar(a_id, blo)))
        r1 = int(np.searchsorted(key, _composite_scalar(a_id, bhi)))
        return r0, r1

    def p_range(self, plo: int, phi: int):
        """Row range of predicate interval [plo, phi).

        Predicate is the primary sort key of BOTH the POS and PSO
        permutations, so the same (r0, r1) positions are valid in either.
        """
        return self.primary_range("pos", plo, phi)

    def single_p_run(self, r0: int, r1: int):
        """The unique predicate id of POS rows [r0, r1), or None if mixed/empty.

        A LiteMat predicate interval is often wide (free suffix bits) while
        the *store* only contains one predicate id inside it — e.g. rdf:type
        patterns.  Detecting that (O(1) after the range search) upgrades the
        pattern from run-slice + re-check to an exact composite-key range.
        """
        pos_p = self.perm("pos").primary
        if r1 <= r0:
            return None
        if pos_p[r0] == pos_p[r1 - 1]:
            return int(pos_p[r0])
        return None

    def distinct_p_ids(self, plo: int, phi: int, limit: int = 8):
        """Distinct predicate ids the store holds in [plo, phi), or None.

        Walks the sorted POS primary column run-by-run (one binary search
        per distinct id, O(k log N)); gives up past ``limit`` ids — the
        index-nested-loop join probes each id's composite range, so the
        planner only wants this when the id set is small (a LiteMat
        property interval typically covers a handful of sub-properties).
        """
        col = self.perm("pos").primary
        r0, r1 = self.p_range(plo, phi)
        out = []
        while r0 < r1:
            pid = int(col[r0])
            out.append(pid)
            if len(out) > limit:
                return None
            r0 = int(np.searchsorted(col, pid, side="right"))
        return out

    def po_range(self, p_id: int, olo: int, ohi: int):
        """Row range of (p == p_id, o in [olo, ohi)) in POS order."""
        return self.composite_range("pos", p_id, olo, ohi)

    def ps_range(self, p_id: int, slo: int, shi: int):
        """Row range of (p == p_id, s in [slo, shi)) in PSO order."""
        return self.composite_range("pso", p_id, slo, shi)

    def s_range(self, slo: int, shi: int):
        """Row range of subject interval [slo, shi) in SPO order."""
        return self.primary_range("spo", slo, shi)

    def o_range(self, olo: int, ohi: int):
        """Row range of object interval [olo, ohi) in OSP order."""
        return self.primary_range("osp", olo, ohi)


def _composite_scalar(a: int, b: int) -> np.int64:
    return (np.int64(a) << _SHIFT) | np.int64(b)


def merge_sorted(a_rows: np.ndarray, a_key: np.ndarray,
                 b_rows: np.ndarray, b_key: np.ndarray):
    """Interleave two runs sorted by the same composite key -> (rows, key).

    One binary search of the small run against the large one assigns every
    row its merged position — the base run is never re-sorted, so folding a
    delta of M rows into a base of N costs O(M log N + N) instead of the
    O((N+M) log (N+M)) full rebuild.  Rows with equal keys keep a-before-b
    order (stable); intra-key tertiary order is irrelevant to every lookup,
    which searches composite keys only.
    """
    n, m = a_key.shape[0], b_key.shape[0]
    if m == 0:
        return a_rows, a_key
    if n == 0:
        return b_rows, b_key
    pos_b = np.searchsorted(a_key, b_key, side="right") + np.arange(m)
    out_rows = np.empty((n + m, a_rows.shape[1]), dtype=a_rows.dtype)
    out_key = np.empty(n + m, dtype=np.int64)
    mask_b = np.zeros(n + m, dtype=bool)
    mask_b[pos_b] = True
    out_rows[pos_b] = b_rows
    out_key[pos_b] = b_key
    out_rows[~mask_b] = a_rows
    out_key[~mask_b] = a_key
    return out_rows, out_key


@dataclass
class TypeIndex:
    """rdf:type triples ordered by (object, subject) — the serving Q1 index.

    A class-membership request for concept interval [lo, hi) is resolved by
    two host binary searches over the object column; the subjects of the hit
    run sit in one contiguous device slice (sorted by object, then subject —
    NOT globally deduplicated: an instance carrying several types inside the
    interval appears once per type, so DISTINCT still needs a per-request
    dedup over the *slice*, which is bounded by the class size rather than
    the whole type view).
    """

    subj: jnp.ndarray  # int32[T+1] subjects, (o, s) order + INVALID sentinel
    obj: jnp.ndarray  # int32[T+1] objects, (o, s) order + INVALID sentinel
    _h_obj: np.ndarray = field(repr=False)  # true (unpadded) object column

    @classmethod
    def build(cls, spo, type_id: int) -> "TypeIndex":
        h = np.asarray(spo)
        m = h[:, 1] == np.int32(type_id)
        s, o = h[m, 0], h[m, 2]
        perm = np.lexsort((s, o))
        s, o = s[perm], o[perm]
        # one INVALID sentinel keeps device gathers well-formed when the
        # store has no type triples at all
        pad = np.full(1, np.iinfo(np.int32).max, np.int32)
        return cls(subj=jnp.asarray(np.concatenate([s, pad])),
                   obj=jnp.asarray(np.concatenate([o, pad])),
                   _h_obj=np.ascontiguousarray(o))

    @property
    def n(self) -> int:
        return int(self._h_obj.shape[0])

    def range_of(self, lo: int, hi: int):
        """(start, length) of the object interval [lo, hi)."""
        r0 = int(np.searchsorted(self._h_obj, lo, side="left"))
        r1 = int(np.searchsorted(self._h_obj, hi, side="left"))
        return r0, r1 - r0
