"""Device-resident sorted indexes over an encoded triple store.

LiteMat's encoding turns RDFS inference into interval containment, so a
triple pattern with a constant predicate (and, for rdf:type patterns, a
constant concept interval) selects a *contiguous run* of a suitably sorted
store — the observation behind self-indexed RDF stores (WaterFowl,
k²-Triples).  This module materializes two permutations of the (N, 3) store
once per KnowledgeBase:

  * POS — rows ordered by (predicate, object, subject): resolves
    ``(?x p ?y)`` and ``(?x rdf:type C)`` patterns,
  * PSO — rows ordered by (predicate, subject, object): resolves
    ``(s p ?y)`` patterns with a constant subject.

Range endpoints are found with host-side binary searches over int64
composite keys (p << 32 | o, resp. p << 32 | s) — O(log N) on a few cached
numpy arrays, negligible next to device work — while the row gathers happen
on device from the permuted stores.  A pattern then costs two binary
searches plus one contiguous gather instead of a full scan + stable sort,
and the range *length* gives the planner an exact cardinality for free.

``TypeIndex`` is the serving-path specialization: the rdf:type subset of
the store ordered by (object, subject), so a batched "members of class C"
request is two binary searches + a slice rather than a full-view sort.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

_SHIFT = np.int64(32)


def _composite(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Lexicographic (a, b) order as one sortable int64 key (ids are < 2^31)."""
    return (a.astype(np.int64) << _SHIFT) | b.astype(np.int64)


@dataclass
class StoreIndex:
    """Sorted permutations of one triple store + host search keys.

    Each permutation is an O(N log N) host lexsort plus a device-resident
    copy of the store, so they materialize lazily on first use: a workload
    of predicate/type patterns (all of LUBM Q1-Q4) never pays for PSO.
    """

    _h: np.ndarray = field(repr=False)  # host copy of the store
    _pos: tuple | None = field(default=None, repr=False)
    _pso: tuple | None = field(default=None, repr=False)

    @classmethod
    def build(cls, spo) -> "StoreIndex":
        return cls(_h=np.asarray(spo))

    def _pos_parts(self):
        """(device rows, host p column, host (p<<32|o) keys), (p, o, s) order."""
        if self._pos is None:
            h = self._h
            hp = h[np.lexsort((h[:, 0], h[:, 2], h[:, 1]))]
            self._pos = (jnp.asarray(hp), np.ascontiguousarray(hp[:, 1]),
                         _composite(hp[:, 1], hp[:, 2]))
        return self._pos

    def _pso_parts(self):
        """(device rows, host (p<<32|s) keys), (p, s, o) order."""
        if self._pso is None:
            h = self._h
            hs = h[np.lexsort((h[:, 2], h[:, 0], h[:, 1]))]
            self._pso = (jnp.asarray(hs), _composite(hs[:, 1], hs[:, 0]))
        return self._pso

    @property
    def pos_rows(self) -> jnp.ndarray:
        return self._pos_parts()[0]

    @property
    def pso_rows(self) -> jnp.ndarray:
        return self._pso_parts()[0]

    @property
    def n(self) -> int:
        return int(self._h.shape[0])

    # -- host-side O(log N) range lookups ------------------------------------
    def p_range(self, plo: int, phi: int):
        """Row range of predicate interval [plo, phi).

        Predicate is the primary sort key of BOTH permutations, so the same
        (r0, r1) positions are valid in POS and PSO order.
        """
        pos_p = self._pos_parts()[1]
        r0 = int(np.searchsorted(pos_p, plo, side="left"))
        r1 = int(np.searchsorted(pos_p, phi, side="left"))
        return r0, r1

    def single_p_run(self, r0: int, r1: int):
        """The unique predicate id of rows [r0, r1), or None if mixed/empty.

        A LiteMat predicate interval is often wide (free suffix bits) while
        the *store* only contains one predicate id inside it — e.g. rdf:type
        patterns.  Detecting that (O(1) after the range search) upgrades the
        pattern from run-slice + re-check to an exact composite-key range.
        """
        pos_p = self._pos_parts()[1]
        if r1 <= r0:
            return None
        if pos_p[r0] == pos_p[r1 - 1]:
            return int(pos_p[r0])
        return None

    def po_range(self, p_id: int, olo: int, ohi: int):
        """Row range of (p == p_id, o in [olo, ohi)) in POS order."""
        key = self._pos_parts()[2]
        r0 = int(np.searchsorted(key, _composite_scalar(p_id, olo)))
        r1 = int(np.searchsorted(key, _composite_scalar(p_id, ohi)))
        return r0, r1

    def ps_range(self, p_id: int, slo: int, shi: int):
        """Row range of (p == p_id, s in [slo, shi)) in PSO order."""
        key = self._pso_parts()[1]
        r0 = int(np.searchsorted(key, _composite_scalar(p_id, slo)))
        r1 = int(np.searchsorted(key, _composite_scalar(p_id, shi)))
        return r0, r1


def _composite_scalar(a: int, b: int) -> np.int64:
    return (np.int64(a) << _SHIFT) | np.int64(b)


@dataclass
class TypeIndex:
    """rdf:type triples ordered by (object, subject) — the serving Q1 index.

    A class-membership request for concept interval [lo, hi) is resolved by
    two host binary searches over the object column; the subjects of the hit
    run sit in one contiguous device slice (sorted by object, then subject —
    NOT globally deduplicated: an instance carrying several types inside the
    interval appears once per type, so DISTINCT still needs a per-request
    dedup over the *slice*, which is bounded by the class size rather than
    the whole type view).
    """

    subj: jnp.ndarray  # int32[T+1] subjects, (o, s) order + INVALID sentinel
    obj: jnp.ndarray  # int32[T+1] objects, (o, s) order + INVALID sentinel
    _h_obj: np.ndarray = field(repr=False)  # true (unpadded) object column

    @classmethod
    def build(cls, spo, type_id: int) -> "TypeIndex":
        h = np.asarray(spo)
        m = h[:, 1] == np.int32(type_id)
        s, o = h[m, 0], h[m, 2]
        perm = np.lexsort((s, o))
        s, o = s[perm], o[perm]
        # one INVALID sentinel keeps device gathers well-formed when the
        # store has no type triples at all
        pad = np.full(1, np.iinfo(np.int32).max, np.int32)
        return cls(subj=jnp.asarray(np.concatenate([s, pad])),
                   obj=jnp.asarray(np.concatenate([o, pad])),
                   _h_obj=np.ascontiguousarray(o))

    @property
    def n(self) -> int:
        return int(self._h_obj.shape[0])

    def range_of(self, lo: int, hi: int):
        """(start, length) of the object interval [lo, hi)."""
        r0 = int(np.searchsorted(self._h_obj, lo, side="left"))
        r1 = int(np.searchsorted(self._h_obj, hi, side="left"))
        return r0, r1 - r0
