from repro.utils.hashing import fingerprint_string, mix64, splitmix64
