"""Deterministic 62-bit term fingerprints.

The LiteMat pipeline separates the *string world* (host: IRIs, literals,
blank-node labels) from the *integer world* (device: encoded triples).  The
bridge is a stable 62-bit fingerprint per term:

  * ``fingerprint_string`` hashes an arbitrary IRI/literal (host side, used
    by the N-Triples parser and the ``locate``/``extract`` dictionary ops).
  * ``mix64`` produces *structural* fingerprints arithmetically from small
    integer tuples.  The synthetic generators use it so that building a
    100M-triple ABox never materializes 100M Python strings — exactly the
    role Spark's generator-side partitioning plays in the paper.

Fingerprints are confined to **61 bits** so that they split exactly into two
non-negative 31-bit int32 words — TPUs have no fast int64, so all device-side
dictionary work (sort/unique/binary search) runs on (hi, lo) int32 pairs with
lexicographic compare (see utils/pair64.py).  Collision probability for N
terms is ~N^2 / 2^62 (≈1e-3 for 100M terms, ≈1e-7 at our test scales).
"""
from __future__ import annotations

import hashlib

import numpy as np

_MASK62 = (1 << 61) - 1  # 61 bits: device hi-word < 2**30, leaving int32 sentinels free
_MASK64 = (1 << 64) - 1


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 in, uint64 out)."""
    x = np.asarray(x, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(_MASK64)
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(_MASK64)
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(_MASK64)
        return z ^ (z >> np.uint64(31))


def mix64(*parts) -> np.ndarray:
    """Structural fingerprint of small-int tuples -> int64 (62-bit, >= 0).

    Each part may be a scalar or a broadcastable numpy array.  The result is
    a deterministic, well-mixed 62-bit value.
    """
    acc = np.uint64(0x243F6A8885A308D3)  # pi fractional bits: arbitrary seed
    for p in parts:
        p64 = np.asarray(p, dtype=np.uint64)
        with np.errstate(over="ignore"):
            acc = splitmix64(acc ^ splitmix64(p64))
    out = acc & np.uint64(_MASK62)
    return out.astype(np.int64)


def fingerprint_string(term: str) -> int:
    """Stable 62-bit fingerprint of an arbitrary term string (host side)."""
    h = hashlib.blake2b(term.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(h, "little") & _MASK62


def fingerprint_strings(terms) -> np.ndarray:
    """Fingerprint a sequence of strings -> int64[len(terms)]."""
    return np.fromiter(
        (fingerprint_string(t) for t in terms), dtype=np.int64, count=len(terms)
    )
