"""Version-compat shims over the moving jax sharding API surface.

The codebase targets the current API (top-level ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``check_vma=``); CI containers may
pin jax 0.4.x where shard_map still lives in ``jax.experimental`` under the
``check_rep=`` spelling and meshes take no axis_types.  Route every use
through this module so version skew stays in one file.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication-check flag name bridged."""
    kw = {}
    if check_vma is not None:
        kw["check_vma"] = check_vma
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
    except TypeError:
        if check_vma is not None:
            kw = {"check_rep": check_vma}
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis_types where supported."""
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=tuple(jax.sharding.AxisType.Auto for _ in axis_names),
        )
    except (AttributeError, TypeError):
        pass
    if hasattr(jax, "make_mesh"):  # jax >= 0.4.35, no axis_types
        return jax.make_mesh(shape, axis_names)
    from jax.experimental import mesh_utils  # older still

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axis_names)
