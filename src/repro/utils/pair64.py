"""62-bit keys as (hi, lo) int32 pairs — the TPU-native fingerprint form.

TPUs have no fast int64 (and JAX x64 is off by default), so every device-side
dictionary operation works on two parallel int32 planes holding the top/bottom
31 bits of a 62-bit fingerprint.  Lexicographic (hi, lo) order equals numeric
order of the original value, so sort / unique / binary-search all transfer.

The vectorized binary search below is also implemented as a Pallas kernel
(kernels/pair_search.py); this module is the jnp oracle.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

WORD_BITS = 31
WORD_MASK = (1 << WORD_BITS) - 1


# -- host conversions --------------------------------------------------------

def split_np(fp: np.ndarray):
    """int64 62-bit values -> (hi, lo) int32 numpy planes."""
    fp = np.asarray(fp, dtype=np.int64)
    return (fp >> WORD_BITS).astype(np.int32), (fp & WORD_MASK).astype(np.int32)


def combine_np(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return (np.asarray(hi, dtype=np.int64) << WORD_BITS) | np.asarray(lo, dtype=np.int64)


# -- device ops ---------------------------------------------------------------

def pair_less(ahi, alo, bhi, blo):
    return (ahi < bhi) | ((ahi == bhi) & (alo < blo))


def pair_eq(ahi, alo, bhi, blo):
    return (ahi == bhi) & (alo == blo)


def sort_pairs(hi, lo):
    """Sort pairs lexicographically; returns (hi_s, lo_s, perm)."""
    perm = jnp.lexsort((lo, hi))
    return hi[perm], lo[perm], perm


def unique_mask_sorted(hi_s, lo_s):
    """mask[i] = True iff pair i differs from pair i-1 (first occurrence)."""
    prev_ne = ~pair_eq(hi_s[1:], lo_s[1:], hi_s[:-1], lo_s[:-1])
    return jnp.concatenate([jnp.ones((1,), dtype=bool), prev_ne])


def searchsorted_pair(table_hi, table_lo, qhi, qlo, side: str = "left"):
    """Vectorized binary search over a lex-sorted pair table.

    Returns, per query, the insertion index (side='left') — ~34 gather steps
    regardless of query count; maps 1:1 onto the Pallas kernel.
    """
    import jax.lax as lax

    n = table_hi.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
    left = side == "left"

    def step(_, carry):
        lo_b, hi_b = carry
        mid = (lo_b + hi_b) >> 1
        mh = table_hi[mid]
        ml = table_lo[mid]
        go_right = pair_less(mh, ml, qhi, qlo) if left else ~pair_less(qhi, qlo, mh, ml)
        lo_n = jnp.where(go_right & (lo_b < hi_b), mid + 1, lo_b)
        hi_n = jnp.where((~go_right) & (lo_b < hi_b), mid, hi_b)
        return lo_n, hi_n

    lo_b = jnp.zeros(qhi.shape, dtype=jnp.int32)
    hi_b = jnp.full(qhi.shape, n, dtype=jnp.int32)
    lo_b, _ = lax.fori_loop(0, steps, step, (lo_b, hi_b))
    return lo_b


def lookup_pair(table_hi, table_lo, values, qhi, qlo, default=-1):
    """Exact-match lookup: value for each query pair, ``default`` if absent."""
    pos = searchsorted_pair(table_hi, table_lo, qhi, qlo)
    pos_c = jnp.clip(pos, 0, table_hi.shape[0] - 1)
    hit = pair_eq(table_hi[pos_c], table_lo[pos_c], qhi, qlo)
    return jnp.where(hit, values[pos_c], default), hit
