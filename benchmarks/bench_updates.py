"""Incremental-update throughput: insert/delete/compact vs full rebuild.

The acceptance bar for the update subsystem: inserting a 1% delta into
LUBM-1 through the delta overlay must beat ``KnowledgeBase.build`` from
scratch by >= 10x — the difference between re-encoding 130K triples and
encoding 1.3K against a dictionary that only grows.

Emits (CSV + rows in BENCH_updates.json):
    updates/build_lubm1           full build wall time (the rebuild baseline)
    updates/insert_1pct           one 1% insert batch through the overlay
    updates/insert_1pct_speedup   rebuild / insert ratio (must be >= 10)
    updates/query_after_insert    Q1 latency on the live (base ∪ delta) store
    updates/delete_0p1pct         tombstone + re-derivation delete batch
    updates/compact               sorted-merge fold of the accumulated delta
    updates/warmup_base_{1x,4x}   post-mutation device warmup per base scale
    updates/warmup_flatness       the O(delta) pin: warmup time + transfer
                                  rows must stay flat across a 4x base-size
                                  growth at a fixed delta (device-resident
                                  delta buckets, never an O(base) re-concat)
"""
from __future__ import annotations

import json
import time


def _chunks(raw, n_chunks: int, chunk: int):
    """Disjoint slices of a delta pool as (s, p, o) column tuples."""
    out = []
    for i in range(n_chunks):
        sl = slice(i * chunk, (i + 1) * chunk)
        out.append((raw.s[sl], raw.p[sl], raw.o[sl]))
    return out


def _warmup_section(emit):
    """Post-mutation warmup across base scales — the O(delta) metric.

    Two KBs over the same ontology, one 4x the other's size, absorb the
    IDENTICAL (base-disjoint) delta sequence; after each insert,
    ``warm_device`` is timed — everything a first query pays beyond cached
    executables: lazy per-mode delta derivation of the batch plus the
    device bucket refresh.  With device-resident delta buckets both cost
    O(delta), so warmup time and transfer rows must be flat across the
    scales — pinned for ALL THREE serving modes (litemat / full /
    rewrite): the lazily derived materializations land in the same
    O(delta) buckets as the raw log.
    """
    import numpy as np

    from repro.core.engine import KnowledgeBase
    from repro.core.query import Pattern
    from repro.rdf.generator import generate_random_abox
    from repro.rdf.vocab import lubm_ontology

    onto = lubm_ontology()
    q = [Pattern("?x", "rdf:type", "Professor")]
    modes = ("litemat", "full", "rewrite")
    out = {}
    for scale in (1, 4):
        raw = generate_random_abox(
            onto, n_instances=3000 * scale, n_type_triples=9000 * scale,
            n_prop_triples=8000 * scale, seed=5)
        K = KnowledgeBase.build(raw)
        for mode in modes:
            K.prewarm([q], modes=(mode,))
        chunks = [
            generate_random_abox(
                onto, n_instances=256, n_type_triples=512,
                n_prop_triples=512, seed=100 + i,
                instance_offset=10_000_000 + 10_000 * i)
            for i in range(4)
        ]
        K.insert(chunks[0], auto_compact=False)
        for mode in modes:  # allocate every mode's bucket at the delta cap
            K.warm_device(mode, keys=("pos",))
        rows0 = {m: K.dev_cache(m).stats["upload_delta_rows"] for m in modes}
        ts = {m: [] for m in modes}
        for c in chunks[1:]:
            K.insert(c, auto_compact=False)
            for mode in modes:
                t0 = time.perf_counter()
                K.warm_device(mode, keys=("pos",))
                ts[mode].append(time.perf_counter() - t0)
        for mode in modes:
            t_warm = float(np.median(ts[mode]))
            transfer = (K.dev_cache(mode).stats["upload_delta_rows"]
                        - rows0[mode])
            emit(f"updates/warmup_base_{scale}x_{mode}", t_warm,
                 n_base_triples=raw.n_triples, transfer_rows=transfer)
            out[(scale, mode)] = (t_warm, transfer)

    # the O(delta) contract gates on the DETERMINISTIC signal (transfer
    # rows identical across base scales, per mode); the wall-clock ratio
    # is reported for trending but a 3-sample median of millisecond
    # warmups on a shared runner is too noisy to hard-fail CI on
    flat = {m: bool(out[(1, m)][1] == out[(4, m)][1]) for m in modes}
    for mode in modes:
        ratio = out[(4, mode)][0] / max(out[(1, mode)][0], 1e-9)
        emit(f"updates/warmup_flatness_{mode}", 0.0,
             warmup_ratio_4x_over_1x=round(ratio, 2),
             transfer_rows_equal=flat[mode], passed=flat[mode])
    emit("updates/warmup_flatness", 0.0,
         warmup_ratio_4x_over_1x=round(
             out[(4, "litemat")][0] / max(out[(1, "litemat")][0], 1e-9), 2),
         transfer_rows_equal=all(flat.values()),
         passed=bool(all(flat.values())))


def _sharded_warmup_section(emit):
    """Per-SHARD post-mutation warmup must be O(delta), base-size free.

    Two ShardedKBs, one 4x the other, absorb the same-shaped disjoint
    delta; after the insert, every shard's device-cache transfer rows
    (litemat) must equal EXACTLY the pow2 bucket its own delta log
    predicts — a pure function of the delta, at either base scale (an
    O(base) leak would show up as base-sized transfer terms).  The raw
    per-shard numbers are not comparable across scales: the dictionary
    ranks the delta's new ids differently over different bases, so the
    subject-hash partition of the same delta differs.
    ``REPRO_BENCH_SHARDED=0`` skips.
    """
    import os
    import time

    import numpy as np

    from repro.core.index import pow2_bucket
    from repro.core.query import Pattern
    from repro.core.shard import ShardedKB
    from repro.rdf.generator import generate_random_abox
    from repro.rdf.vocab import lubm_ontology

    if os.environ.get("REPRO_BENCH_SHARDED", "1") != "1":
        return
    n_shards = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
    onto = lubm_ontology()
    q = [Pattern("?x", "rdf:type", "Professor")]
    flat = {}
    for scale in (1, 4):
        raw = generate_random_abox(
            onto, n_instances=2000 * scale, n_type_triples=6000 * scale,
            n_prop_triples=5000 * scale, seed=5)
        S = ShardedKB.build(raw, n_shards=n_shards)
        S.prewarm([q], modes=("litemat",))
        S.warm_device("litemat", keys=("pos",))
        rows0 = [K.dev_cache("litemat").stats["upload_delta_rows"]
                 for K in S.shards]
        delta = generate_random_abox(
            onto, n_instances=256, n_type_triples=512, n_prop_triples=512,
            seed=100, instance_offset=10_000_000)
        S.insert(delta, auto_compact=False)
        t0 = time.perf_counter()
        S.warm_device("litemat", keys=("pos",))
        t_warm = time.perf_counter() - t0
        got = [K.dev_cache("litemat").stats["upload_delta_rows"] - b
               for K, b in zip(S.shards, rows0)]
        want = [pow2_bucket(K.delta.log("litemat").n)
                if K.delta.log("litemat").n else 0 for K in S.shards]
        flat[scale] = got == want
        emit(f"updates/sharded_warmup_base_{scale}x", t_warm,
             n_base_triples=raw.n_triples, transfer_rows=int(np.sum(got)))
    emit("updates/sharded_warmup_flatness", 0.0,
         transfer_rows_delta_exact=all(flat.values()), shards=n_shards,
         passed=bool(all(flat.values())))


def main(json_path: str = "BENCH_updates.json"):
    import numpy as np

    from benchmarks.common import all_records, emit, timeit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.rdf.generator import generate_lubm

    records_before = len(all_records())

    base = generate_lubm(1, seed=0)
    t_build, K = timeit(lambda: KnowledgeBase.build(base), repeats=1)
    emit("updates/build_lubm1", t_build, n_triples=base.n_triples)

    # 1% delta pool from a disjoint university (every instance term is new)
    chunk = max(base.n_triples // 100, 1)
    pool = generate_lubm(1, seed=7, univ_offset=1)
    chunks = _chunks(pool, 5, chunk)

    K.insert(chunks[0], auto_compact=False)  # warm the encode+materialize path
    ts = []
    for c in chunks[1:4]:
        t0 = time.perf_counter()
        st = K.insert(c, auto_compact=False)
        ts.append(time.perf_counter() - t0)
    t_insert = float(np.median(ts))
    speedup = t_build / max(t_insert, 1e-9)
    emit("updates/insert_1pct", t_insert, n_triples=chunk,
         triples_per_s=int(chunk / max(t_insert, 1e-9)))
    emit("updates/insert_1pct_speedup", 0.0,
         speedup_vs_rebuild=round(speedup, 1), target=10.0,
         passed=bool(speedup >= 10.0))

    # live-store query latency (base ∪ delta via the overlay view)
    K.query(PAPER_QUERIES["Q1"])  # compile at the current delta bucket
    t_q, _ = timeit(lambda: K.query(PAPER_QUERIES["Q1"]), repeats=3)
    emit("updates/query_after_insert", t_q,
         n_answers=len(K.answers(PAPER_QUERIES["Q1"])))

    # the inserts above were only served in litemat mode, so the full-mode
    # delta derivation is still queued (lazy per-mode materialization);
    # flush it as its own step so the delete below measures deletion only
    t0 = time.perf_counter()
    K.view("full")
    emit("updates/lazy_full_flush", time.perf_counter() - t0,
         n_batches=K.mat_counts["full"])

    # delete 0.1% of the base (tombstones + affected-instance re-derivation)
    n_del = max(base.n_triples // 1000, 1)
    idx = np.arange(0, base.n_triples, max(base.n_triples // n_del, 1))[:n_del]
    t0 = time.perf_counter()
    st = K.delete((base.s[idx], base.p[idx], base.o[idx]), auto_compact=False)
    t_del = time.perf_counter() - t0
    emit("updates/delete_0p1pct", t_del, n_deleted=st["n_deleted"],
         n_affected=st.get("n_affected_instances", 0))

    # compaction: sorted-merge the overlay back into the base stores
    t0 = time.perf_counter()
    st = K.compact()
    t_c = time.perf_counter() - t0
    emit("updates/compact", t_c, **{k: v for k, v in st.items()
                                    if isinstance(v, int)})

    # post-mutation warmup must be O(delta): flat across base scales
    _warmup_section(emit)
    _sharded_warmup_section(emit)

    if json_path:
        rows = all_records()[records_before:]
        artifact = {
            "n_base_triples": base.n_triples,
            "chunk_triples": chunk,
            "insert_speedup_vs_rebuild": round(speedup, 1),
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {json_path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
