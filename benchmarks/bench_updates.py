"""Incremental-update throughput: insert/delete/compact vs full rebuild.

The acceptance bar for the update subsystem: inserting a 1% delta into
LUBM-1 through the delta overlay must beat ``KnowledgeBase.build`` from
scratch by >= 10x — the difference between re-encoding 130K triples and
encoding 1.3K against a dictionary that only grows.

Emits (CSV + rows in BENCH_updates.json):
    updates/build_lubm1           full build wall time (the rebuild baseline)
    updates/insert_1pct           one 1% insert batch through the overlay
    updates/insert_1pct_speedup   rebuild / insert ratio (must be >= 10)
    updates/query_after_insert    Q1 latency on the live (base ∪ delta) store
    updates/delete_0p1pct         tombstone + re-derivation delete batch
    updates/compact               sorted-merge fold of the accumulated delta
"""
from __future__ import annotations

import json
import time


def _chunks(raw, n_chunks: int, chunk: int):
    """Disjoint slices of a delta pool as (s, p, o) column tuples."""
    out = []
    for i in range(n_chunks):
        sl = slice(i * chunk, (i + 1) * chunk)
        out.append((raw.s[sl], raw.p[sl], raw.o[sl]))
    return out


def main(json_path: str = "BENCH_updates.json"):
    import numpy as np

    from benchmarks.common import all_records, emit, timeit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.rdf.generator import generate_lubm

    records_before = len(all_records())

    base = generate_lubm(1, seed=0)
    t_build, K = timeit(lambda: KnowledgeBase.build(base), repeats=1)
    emit("updates/build_lubm1", t_build, n_triples=base.n_triples)

    # 1% delta pool from a disjoint university (every instance term is new)
    chunk = max(base.n_triples // 100, 1)
    pool = generate_lubm(1, seed=7, univ_offset=1)
    chunks = _chunks(pool, 5, chunk)

    K.insert(chunks[0], auto_compact=False)  # warm the encode+materialize path
    ts = []
    for c in chunks[1:4]:
        t0 = time.perf_counter()
        st = K.insert(c, auto_compact=False)
        ts.append(time.perf_counter() - t0)
    t_insert = float(np.median(ts))
    speedup = t_build / max(t_insert, 1e-9)
    emit("updates/insert_1pct", t_insert, n_triples=chunk,
         triples_per_s=int(chunk / max(t_insert, 1e-9)))
    emit("updates/insert_1pct_speedup", 0.0,
         speedup_vs_rebuild=round(speedup, 1), target=10.0,
         passed=bool(speedup >= 10.0))

    # live-store query latency (base ∪ delta via the overlay view)
    K.query(PAPER_QUERIES["Q1"])  # compile at the current delta bucket
    t_q, _ = timeit(lambda: K.query(PAPER_QUERIES["Q1"]), repeats=3)
    emit("updates/query_after_insert", t_q,
         n_answers=len(K.answers(PAPER_QUERIES["Q1"])))

    # delete 0.1% of the base (tombstones + affected-instance re-derivation)
    n_del = max(base.n_triples // 1000, 1)
    idx = np.arange(0, base.n_triples, max(base.n_triples // n_del, 1))[:n_del]
    t0 = time.perf_counter()
    st = K.delete((base.s[idx], base.p[idx], base.o[idx]), auto_compact=False)
    t_del = time.perf_counter() - t0
    emit("updates/delete_0p1pct", t_del, n_deleted=st["n_deleted"],
         n_affected=st.get("n_affected_instances", 0))

    # compaction: sorted-merge the overlay back into the base stores
    t0 = time.perf_counter()
    st = K.compact()
    t_c = time.perf_counter() - t0
    emit("updates/compact", t_c, **{k: v for k, v in st.items()
                                    if isinstance(v, int)})

    if json_path:
        rows = all_records()[records_before:]
        artifact = {
            "n_base_triples": base.n_triples,
            "chunk_triples": chunk,
            "insert_speedup_vs_rebuild": round(speedup, 1),
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {json_path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
