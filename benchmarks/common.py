"""Benchmark utilities: warmed, repeated wall-clock timing + CSV emit."""
from __future__ import annotations

import os
import time

import numpy as np

BENCH_UNIVERSITIES = int(os.environ.get("REPRO_BENCH_UNIV", "4"))
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "5"))


def timeit(fn, *args, repeats: int = None, warmup: int = 1):
    """Median wall seconds of fn(*args) (block_until_ready aware)."""
    repeats = repeats or REPEATS
    for _ in range(warmup):
        r = fn(*args)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def _block(r):
    import jax

    for leaf in jax.tree.leaves(r):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


_rows = []
_records = []


def emit(name: str, seconds: float, **derived):
    us = seconds * 1e6
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us:.1f},{extra}"
    _rows.append(line)
    _records.append({"name": name, "us_per_call": round(us, 1), **derived})
    print(line, flush=True)


def all_rows():
    return list(_rows)


def all_records():
    """Structured copies of every emitted row (for the JSON artifact)."""
    return list(_records)
