"""Paper Table VI: Q1-Q4 response time, lite vs full vs no materialization.

Also validates completeness per run (all three modes must agree), then
benches the vmapped serving path (beyond paper: batched query throughput).
"""
from __future__ import annotations


def main():
    from benchmarks.common import BENCH_UNIVERSITIES, emit, timeit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.rdf.generator import generate_lubm
    from repro.serving.engine import QueryServer

    raw = generate_lubm(BENCH_UNIVERSITIES, seed=0)
    K = KnowledgeBase.build(raw)
    emit("table6/kb_sizes", 0.0, **K.sizes())

    for qn, pats in PAPER_QUERIES.items():
        answers = {}
        for mode in ("litemat", "full", "rewrite"):
            t, _ = timeit(lambda m=mode: K.query(pats, mode=m), repeats=3)
            answers[mode] = K.answers(pats, mode=mode)
            emit(f"table6/{qn}/{mode}", t, n_answers=len(answers[mode]))
        assert answers["litemat"] == answers["full"] == answers["rewrite"], qn

    # batched serving (vmapped plans)
    srv = QueryServer(K)
    names = ["Professor", "Student", "Faculty", "Person", "Course",
             "Publication", "Organization", "Department"] * 32
    t, _ = timeit(lambda: srv.class_members(names), repeats=3)
    emit("serving/class_members_batch256", t, qps=int(len(names) / t))
    t, _ = timeit(lambda: srv.class_prop_join(["Professor"] * 64, ["memberOf"] * 64),
                  repeats=3)
    emit("serving/class_prop_join_batch64", t, qps=int(64 / t))


if __name__ == "__main__":
    main()
