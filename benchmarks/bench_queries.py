"""Paper Table VI: Q1-Q4 response time, lite vs full vs no materialization.

Also validates completeness per run (all three modes must agree), then
benches the parts the paper leaves to the engine:

  * indexed (sorted-store slice) vs scan execution per query/mode,
  * plan-cache effect: cold (trace + compile) vs warm (cache hit) run of
    the same query, and a parameterized variant reusing the executable,
  * the vmapped serving path (batched query throughput).
"""
from __future__ import annotations


def main():
    from benchmarks.common import BENCH_UNIVERSITIES, emit, timeit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.core.query import Pattern, QueryEngine
    from repro.rdf.generator import generate_lubm
    from repro.serving.engine import QueryServer

    raw = generate_lubm(BENCH_UNIVERSITIES, seed=0)
    K = KnowledgeBase.build(raw)
    emit("table6/kb_sizes", 0.0, **K.sizes())

    for qn, pats in PAPER_QUERIES.items():
        answers = {}
        for mode in ("litemat", "full", "rewrite"):
            t, _ = timeit(lambda m=mode: K.query(pats, mode=m), repeats=3)
            answers[mode] = K.answers(pats, mode=mode)
            emit(f"table6/{qn}/{mode}", t, n_answers=len(answers[mode]))
            t_scan, _ = timeit(
                lambda m=mode: K.query(pats, mode=m, use_index=False),
                repeats=3)
            emit(f"table6/{qn}/{mode}_scan", t_scan,
                 speedup=round(t_scan / max(t, 1e-9), 2))
        assert answers["litemat"] == answers["full"] == answers["rewrite"], qn

    # plan cache: cold run traces + compiles, warm run reuses the executable
    import time

    eng = QueryEngine(kb=K.kb, spo=K.lite_spo, mode="litemat", dtb=K.dtb)
    t0 = time.perf_counter()
    eng.run(PAPER_QUERIES["Q3"])
    cold = time.perf_counter() - t0
    warm, _ = timeit(lambda: eng.run(PAPER_QUERIES["Q3"]), repeats=5)
    emit("plan_cache/q3_cold_first_run", cold)
    emit("plan_cache/q3_warm_repeat", warm,
         retrace_speedup=round(cold / max(warm, 1e-9), 1))
    # parameterized reuse: same signature, different constant
    eng.run([Pattern("?x", "memberOf", "?y")])
    t_param, _ = timeit(lambda: eng.run([Pattern("?x", "worksFor", "?y")]),
                        repeats=5)
    emit("plan_cache/param_reuse_worksFor", t_param,
         hits=eng.cache_stats["hits"], misses=eng.cache_stats["misses"])

    # batched serving (vmapped plans over index slices)
    srv = QueryServer(K)
    names = ["Professor", "Student", "Faculty", "Person", "Course",
             "Publication", "Organization", "Department"] * 32
    t, _ = timeit(lambda: srv.class_members(names), repeats=3)
    emit("serving/class_members_batch256", t, qps=int(len(names) / t))
    t, _ = timeit(lambda: srv.class_prop_join(["Professor"] * 64, ["memberOf"] * 64),
                  repeats=3)
    emit("serving/class_prop_join_batch64", t, qps=int(64 / t))

    # rewrite-mode dual-branch pass count: (?x rdf:type Person) entails
    # through BOTH domain- and range-entailing properties, so the pattern
    # needs a subject-binding AND an object-binding compaction over the
    # same store.  The fused member-compaction kernel resolves both in
    # ONE pass with the member/domain/range id sets resident on-chip; the
    # trace-time counters pin it (per-source: 1 member pass, 0 mask-based
    # passes, where the pre-fusion plan materialized full-store masks).
    from repro.kernels import ops as _kops

    dual_q = [Pattern("?x", "rdf:type", "Person")]
    eng_rw = QueryEngine(kb=K.kb, spo=K.kb.spo, mode="rewrite", dtb=K.dtb)
    # counters bump when the inner op traces; clear their caches so the
    # cold plan below re-traces every pass it actually makes
    _kops.compact_indices.clear_cache()
    _kops.dual_compact_indices.clear_cache()
    _kops.rewrite_member_compact.clear_cache()
    _kops.reset_pass_counters()
    eng_rw.run(dual_q)
    member_passes = _kops.pass_counters["member_compact"]
    # one residual single-mask pass belongs to DISTINCT's dedup compaction,
    # not the pattern; the pattern itself must trace zero single passes
    # (it used to trace two — one per branch)
    single_passes = _kops.pass_counters["compact"]
    t_dual, _ = timeit(lambda: eng_rw.run(dual_q), repeats=3)
    emit("table6/rewrite_dual_branch", t_dual,
         member_passes=member_passes, single_passes=single_passes,
         passed=bool(member_passes >= 1 and single_passes <= 1))

    # live-overlay cost: Q1 against an uncompacted ~1% delta (two-source
    # gathers over base + device-resident delta bucket) vs post-compaction
    from repro.rdf.generator import generate_lubm as _gen

    pool = _gen(1, seed=3, univ_offset=BENCH_UNIVERSITIES + 1)
    n = max(K.kb.n // 100, 1)
    K.insert((pool.s[:n], pool.p[:n], pool.o[:n]), auto_compact=False)
    t_live, _ = timeit(lambda: K.query(PAPER_QUERIES["Q1"]), repeats=3)
    K.compact()
    t_comp, _ = timeit(lambda: K.query(PAPER_QUERIES["Q1"]), repeats=3)
    emit("table6/Q1/litemat_live_overlay", t_live,
         delta_rows=n, overhead_vs_compacted=round(t_live / max(t_comp, 1e-9), 2))

    _sharded_section(emit, timeit, raw)


def _sharded_section(emit, timeit, raw):
    """ShardedKB rows: Q1-Q4 latency, serving fan-out, bulk ingest.

    ``REPRO_BENCH_SHARDED=0`` skips the section (the single-device CI
    leg); ``REPRO_BENCH_SHARDS`` sets the logical shard count (execution
    lowers through shard_map when a device per shard exists — the
    8-forced-device CI leg); ``REPRO_BENCH_INGEST_ROWS`` scales the bulk
    ingest (default 1e7 — the ROADMAP's LUBM-100-class target; CI sets it
    lower to bound runner time, emitting ``sharded/ingest_scaled``).
    """
    import os
    import time

    if os.environ.get("REPRO_BENCH_SHARDED", "1") != "1":
        return
    import jax

    from repro.core.engine import PAPER_QUERIES
    from repro.core.shard import ShardedKB
    from repro.rdf.generator import generate_random_abox
    from repro.rdf.vocab import lubm_ontology
    from repro.serving.engine import ShardedQueryServer

    n_shards = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
    t0 = time.perf_counter()
    S = ShardedKB.build(raw, n_shards=n_shards)
    emit("sharded/build", time.perf_counter() - t0, shards=n_shards,
         devices=jax.device_count(), **S.sizes())
    for qn, pats in PAPER_QUERIES.items():
        answers = {}
        for mode in ("litemat", "rewrite"):
            t, _ = timeit(lambda m=mode: S.query(pats, mode=m), repeats=3)
            answers[mode] = S.answers(pats, mode=mode)
            emit(f"sharded/{qn}/{mode}", t, n_answers=len(answers[mode]))
        assert answers["litemat"] == answers["rewrite"], qn
    eng = S.engine("litemat")
    emit("sharded/exec_path", 0.0, **eng.cache_stats,
         shard_map=eng._shard_map_on())

    # device-side cross-group combine: Q4's object-keyed join folds through
    # the hash-repartition exchange; the host fold re-runs the same plan for
    # the speedup column.  The flag row pins the acceptance invariant: on
    # the device path the combine makes ZERO host re-uploads (the
    # `device/transfer_bytes{src=combine_upload}` meter stays flat) and the
    # repartition combine actually ran — a silent degrade to the host
    # fallback flips `passed` and fails bench_diff's flag gate.
    from repro.obs.metrics import REGISTRY

    q4 = PAPER_QUERIES["Q4"]
    device_path = eng._repartition_on()
    up = REGISTRY.counter("device/transfer_bytes", src="combine_upload")
    runs0 = eng.cache_stats["repartition_runs"]
    up0 = up.value
    t_dev, _ = timeit(lambda: eng.run(q4), repeats=3)
    zero_upload = up.value == up0
    ran = eng.cache_stats["repartition_runs"] > runs0
    eng.use_repartition_join = False
    try:
        t_host, _ = timeit(lambda: eng.run(q4), repeats=3)
    finally:
        eng.use_repartition_join = None
    emit("sharded/repartition_join", t_dev, host_fold_s=round(t_host, 6),
         speedup=round(t_host / max(t_dev, 1e-9), 2),
         device_path=device_path, zero_host_upload=zero_upload,
         passed=bool(not device_path or (zero_upload and ran)))

    srv = ShardedQueryServer(S)
    names = ["Professor", "Student", "Faculty", "Person", "Course",
             "Publication", "Organization", "Department"] * 32
    t, _ = timeit(lambda: srv.class_members(names), repeats=3)
    emit("sharded/serving_class_members", t, batch=len(names),
         per_request_us=round(t * 1e6 / len(names), 1))

    # bulk ingest: per-shard encode + partition + lazy per-shard derivation
    rows_target = int(float(os.environ.get("REPRO_BENCH_INGEST_ROWS", "1e7")))
    if rows_target <= 0:
        return
    onto = lubm_ontology()
    n_parts = 10
    per = rows_target // n_parts
    parts = (generate_random_abox(
        onto, n_instances=max(per // 4, 1), n_type_triples=int(per * 0.3),
        n_prop_triples=per - int(per * 0.3), seed=40 + i,
        instance_offset=20_000_000 * (i + 1)) for i in range(n_parts))
    t0 = time.perf_counter()
    SI = ShardedKB.ingest(parts, tbox=S.tbox, n_shards=n_shards)
    t_ingest = time.perf_counter() - t0
    total = sum((K.kb.n + (K._delta.logs["rewrite"].n if K._delta else 0))
                for K in SI.shards)
    name = ("sharded/ingest_1e7" if rows_target >= 9_000_000
            else "sharded/ingest_scaled")
    emit(name, t_ingest, n_triples=total, shards=n_shards,
         triples_per_s=int(total / max(t_ingest, 1e-9)))
    q = PAPER_QUERIES["Q1"]
    t0 = time.perf_counter()
    n_ans = len(SI.answers(q, mode="litemat"))
    t_first = time.perf_counter() - t0  # pays per-shard lazy derivation
    t_warm, _ = timeit(lambda: SI.query(q, mode="litemat"), repeats=3)
    emit(f"{name}_first_query", t_first, n_answers=n_ans)
    emit(f"{name}_warm_query", t_warm, n_answers=n_ans)
    # drop the stores before the later bench modules (benchmarks.run calls
    # bench_updates in this same process) time anything: a 1e7-row KB left
    # alive skews their allocator behavior.  srv/eng hold S, so they go too.
    del SI, S, srv, eng
    import gc

    gc.collect()


if __name__ == "__main__":
    main()
