# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

    PYTHONPATH=src python -m benchmarks.run [--only table3,table6]

Sections (paper table -> module):
    table2 -> bench_tbox          TBox encoding time vs ontology size
    table3 -> bench_abox          SAE vs OBE ABox encoding throughput
    table4/5 -> bench_materialize lite vs full materialization
    table6 -> bench_queries       Q1-Q4 across lite/full/rewrite (+serving)
    updates -> bench_updates      incremental insert/delete/compact vs
                                  rebuild (writes BENCH_updates.json)
    serving -> bench_serving      snapshot-isolated runtime latency under
                                  concurrent reads + background updates
                                  (writes BENCH_serving.json)
    kernels -> bench_kernels      Pallas kernels vs refs

Scale via env: REPRO_BENCH_UNIV (default 4 universities ~ 0.5M triples).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table3,table6")
    ap.add_argument("--json", default="BENCH_queries.json",
                    help="machine-readable artifact path ('' disables)")
    args = ap.parse_args()

    from benchmarks import (
        bench_abox, bench_kernels, bench_materialize, bench_queries,
        bench_serving, bench_tbox, bench_updates,
    )

    sections = {
        "table2": bench_tbox.main,
        "table3": bench_abox.main,
        "table45": bench_materialize.main,
        "table6": bench_queries.main,
        "updates": bench_updates.main,
        "serving": bench_serving.main,
        "kernels": bench_kernels.main,
    }
    chosen = (
        {k.strip() for k in args.only.split(",")} if args.only else set(sections)
    )
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in sections.items():
        if name not in chosen:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)
            raise
    print(f"# total bench wall: {time.time() - t0:.1f}s")

    if args.json:
        from benchmarks.common import BENCH_UNIVERSITIES, all_records

        artifact = {
            "bench_universities": BENCH_UNIVERSITIES,
            "sections": sorted(chosen & set(sections)),
            "wall_seconds": round(time.time() - t0, 1),
            "rows": all_records(),
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.json} ({len(artifact['rows'])} rows)")


if __name__ == "__main__":
    main()
