"""Serving-runtime latency under concurrent reads + background updates.

Drives the snapshot-isolated request runtime (serving/runtime.py) over a
LUBM store with CLOSED-LOOP clients — each client thread issues its next
request only after the previous outcome lands, so the reported p50/p99 is
service latency (pin + pinned-plan execution + any fresh snapshot
capture), not open-loop queue depth:

    serving/read_only        4 clients x Q1-Q4, no writer — pins are all
                             fast-path reuses of the published snapshot
    serving/mixed_workload   the same read stream racing a writer thread
                             that streams 64-row insert batches (each one
                             bumping the version and republishing), so
                             reads keep paying fresh snapshot captures;
                             also reports reader and writer throughput
    serving/mixed_slo        pass/fail row gated by scripts/bench_diff.py:
                             at this baseline load NOTHING sheds, NOTHING
                             misses its deadline, and every request is ok
                             — admission control must be invisible until
                             overload

A short unmeasured mixed warmup epoch runs first so the delta-bucket plan
compilations (pow2 capacity transitions) mostly land outside the measured
window.  Writes ``BENCH_serving.json`` for the CI bench-diff gate.
"""
from __future__ import annotations

import json
import os
import threading
import time


def _percentiles(outs):
    import numpy as np

    lat = np.asarray(sorted(o.latency_s for o in outs if o.ok))
    if lat.size == 0:
        return 0.0, 0.0
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def _closed_loop(rt, queries, n_clients: int, per_client: int):
    """n_clients threads, each serving its next request only after the
    last one resolved — latency reflects service time, not queue depth."""
    outs_by_client = [[] for _ in range(n_clients)]

    def client(c: int):
        for i in range(per_client):
            q = queries[(c + i * n_clients) % len(queries)]
            outs_by_client[c].append(rt.serve(q, deadline_s=30.0))

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return [o for outs in outs_by_client for o in outs], wall


def main(json_path: str = "BENCH_serving.json"):
    import numpy as np

    from benchmarks.common import all_records, emit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.rdf.generator import generate_lubm
    from repro.serving.runtime import ServingRuntime

    records_before = len(all_records())
    n_clients = int(os.environ.get("REPRO_BENCH_SERVE_CLIENTS", "4"))
    per_client = int(os.environ.get("REPRO_BENCH_SERVE_PER_CLIENT", "40"))
    queries = list(PAPER_QUERIES.values())

    raw = generate_lubm(1, seed=0)
    K = KnowledgeBase.build(raw)
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)

    # -- read-only baseline: pins are all fast-path, plans prewarmed --------
    rt = ServingRuntime(K, modes=("litemat",), n_workers=n_clients,
                        max_queue=256)
    with rt:
        rt.registry.prewarm(queries)
        outs, wall = _closed_loop(rt, queries, n_clients, per_client)
    p50, p99 = _percentiles(outs)
    emit("serving/read_only", p50, p99_ms=round(p99 * 1e3, 2),
         requests_per_s=int(len(outs) / max(wall, 1e-9)),
         n_ok=sum(o.ok for o in outs), n_triples=raw.n_triples)

    # -- mixed workload: the same read stream racing a background writer ----
    rt = ServingRuntime(K, modes=("litemat",), n_workers=n_clients,
                        max_queue=256, pin_lock_timeout_s=0.05)
    with rt:
        rt.registry.prewarm(queries)
        stop = threading.Event()

        def writer():
            rng = np.random.default_rng(1)
            while not stop.is_set():
                i = int(rng.integers(0, max(s.shape[0] - 64, 1)))
                rt.insert((s[i:i + 64], p[i:i + 64], o[i:i + 64]),
                          auto_compact=False)
                if stop.wait(0.02):
                    return

        w = threading.Thread(target=writer, daemon=True)
        t0 = time.perf_counter()
        w.start()
        # warmup epoch: grow the delta past its first pow2 bucket
        # transitions so their plan compiles land outside the measurement
        _closed_loop(rt, queries, n_clients, 8)
        warm_stats = dict(rt.stats)
        outs, wall = _closed_loop(rt, queries, n_clients, per_client)
        stop.set()
        w.join()
        write_wall = time.perf_counter() - t0
        stats = dict(rt.stats)
    p50, p99 = _percentiles(outs)
    n_ok = sum(o.ok for o in outs)
    n_measured_stale = (stats["stale_served"] - warm_stats["stale_served"])
    emit("serving/mixed_workload", p50, p99_ms=round(p99 * 1e3, 2),
         requests_per_s=int(len(outs) / max(wall, 1e-9)),
         update_rows_per_s=int(64 * stats["updates"]
                               / max(write_wall, 1e-9)),
         n_ok=n_ok, n_updates=stats["updates"],
         n_stale_served=n_measured_stale, n_retries=stats["retries"])
    slo_ok = (stats["shed"] == 0 and stats["deadline"] == 0
              and n_ok == len(outs))
    emit("serving/mixed_slo", 0.0, shed=stats["shed"],
         deadline_missed=stats["deadline"], errors=stats["errors"],
         passed=bool(slo_ok))

    if json_path:
        rows = all_records()[records_before:]
        artifact = {
            "n_base_triples": raw.n_triples,
            "n_requests": n_clients * per_client,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {json_path} ({len(rows)} rows)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
