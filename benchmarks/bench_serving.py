"""Serving-runtime latency under concurrent reads + background updates.

Drives the snapshot-isolated request runtime (serving/runtime.py) over a
LUBM store with CLOSED-LOOP clients — each client thread issues its next
request only after the previous outcome lands, so the reported p50/p99 is
service latency (pin + pinned-plan execution + any fresh snapshot
capture), not open-loop queue depth:

    serving/read_only        4 clients x Q1-Q4, no writer — pins are all
                             fast-path reuses of the published snapshot
    serving/mixed_workload   the same read stream racing a writer thread
                             that streams 64-row insert batches (each one
                             bumping the version and republishing), so
                             reads keep paying fresh snapshot captures;
                             also reports reader and writer throughput
    serving/batched_read     burst arrivals (16 clients submitting
                             back-to-back) through the micro-batching
                             scheduler — same-signature requests coalesce
                             into ONE vmapped executable per batch window;
                             reports p50/p99, throughput and the mean
                             batch occupancy read from the
                             ``serving/batch_size`` histogram
    serving/batched_speedup  pass/fail row: batched throughput must reach
                             ``BATCHED_SPEEDUP_GATE``x the read_only
                             baseline at batch occupancy >= 4
    serving/mixed_slo        pass/fail row gated by scripts/bench_diff.py:
                             at this baseline load NOTHING sheds, NOTHING
                             misses its deadline, and every request is ok
                             — admission control must be invisible until
                             overload
    serving/obs_overhead     TOTAL telemetry cost per request — tracing
                             plus the control plane (one rollup tick over
                             the stock SLO set and one resource-ledger
                             sample, amortized across the requests a
                             default 0.25 s tick interval admits at the
                             measured throughput) — as a fraction of the
                             untraced mean latency; must stay under
                             ``gate_max_pct`` (3%) or bench_diff fails
                             the build.  The cost is CALIBRATED, not
                             A/B'd: per-request latency on shared CPU
                             runners swings +/-10% between back-to-back
                             identical requests (measured), so a
                             wall-clock traced-vs-untraced diff cannot
                             resolve a 3% budget — instead the bench
                             times the exact span lifecycle a real
                             served trace performs (same span count as
                             the traced run's median trace, best-of-3)
                             plus the exact tick/sample the rollup
                             thread performs, and divides by the
                             measured untraced mean.  The raw A/B delta
                             is kept as an informational
                             ``ab_overhead_pct`` field

Every latency figure is read back from the runtime's
:class:`~repro.obs.metrics.MetricsRegistry` (``serving/latency_s`` /
``serving/queue_s`` / ``serving/exec_s`` histograms), not recomputed from
the outcome list — the BENCH rows exercise the same observability surface
operators would read.  ``REPRO_TRACE_EXPORT`` dumps the traced run's
spans; ``REPRO_METRICS_EXPORT`` writes an aggregated fleet-schema
snapshot (the serving registry and the process-global engine registry,
ledger gauges included, merged as two labelled members) — both files
are validated by scripts/check_traces.py and the latter renders through
scripts/fleet_report.py in the CI obs smoke leg.

A short unmeasured mixed warmup epoch runs first so the delta-bucket plan
compilations (pow2 capacity transitions) mostly land outside the measured
window (the registry's ``window_summary`` subtracts the warmup's
histogram state).  Writes ``BENCH_serving.json`` for the CI bench-diff
gate.
"""
from __future__ import annotations

import json
import os
import threading
import time

#: serving/obs_overhead must stay under this (scripts/bench_diff.py gates
#: any row that carries a ``gate_max_pct`` field).
OBS_OVERHEAD_GATE_PCT = 3.0

#: serving/batched_read must beat serving/read_only by this throughput
#: factor (at batch occupancy >= 4) or bench_diff fails the build.
BATCHED_SPEEDUP_GATE = 3.0


def _closed_loop(rt, queries, n_clients: int, per_client: int):
    """n_clients threads, each serving its next request only after the
    last one resolved — latency reflects service time, not queue depth."""
    outs_by_client = [[] for _ in range(n_clients)]

    def client(c: int):
        for i in range(per_client):
            q = queries[(c + i * n_clients) % len(queries)]
            outs_by_client[c].append(rt.serve(q, deadline_s=30.0))

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return [o for outs in outs_by_client for o in outs], wall


def _tracer_cost_s(n_spans: int, iters: int = 200) -> float:
    """Measured wall cost of one traced request's full span lifecycle:
    trace mint, root + (n_spans - 1) child spans with attrs, context
    activation, finish into the bounded ring.  Deterministic Python work
    — repeatable to a few percent where wall-clock A/B is not."""
    from repro.obs.trace import Tracer, activate, span

    cal = Tracer(max_traces=8)
    t0 = time.perf_counter()
    for _ in range(iters):
        tr = cal.new_trace("cal")
        root = cal.start_root(tr, "request", n_patterns=3, mode="default")
        with activate(root):
            for _ in range(max(n_spans - 1, 0)):
                with span("s", attempt=0) as sp:
                    sp.set_attr(version=0)
        cal.finish_trace(tr)
    return (time.perf_counter() - t0) / iters


def _rollup_cost_s(registry, ledger, iters: int = 50):
    """Measured wall cost of (one rollup tick, one ledger sample).

    The tick runs on a registry carrying the bench's real instrument
    cardinality (latency histograms, outcome counters) with the stock SLO
    set attached, so collection + rate gauges + burn-rate evaluation are
    all priced; the ledger sample walks whatever owners the bench
    registered.  Deterministic Python work, like :func:`_tracer_cost_s`.
    """
    from repro.obs.slo import (SLOMonitor, TelemetryRollup,
                               default_serving_slos)

    mon = SLOMonitor(default_serving_slos(), registry=registry)
    roll = TelemetryRollup(registry, monitor=mon)
    roll.tick()  # baseline point so measured ticks do the full rate pass
    t0 = time.perf_counter()
    for _ in range(iters):
        roll.tick()
    tick_s = (time.perf_counter() - t0) / iters
    t0 = time.perf_counter()
    for _ in range(iters):
        ledger.sample()
    return tick_s, (time.perf_counter() - t0) / iters


def _ok_latency(rt, window=None):
    """(p50, p99, mean) seconds of ok-status requests, from the registry."""
    from repro.obs.metrics import window_summary

    h = rt.metrics.histogram("serving/latency_s", status="ok")
    s = h.summary() if window is None else window_summary(h, window)
    if s.get("n", 0) == 0:
        return 0.0, 0.0, 0.0
    return s["p50"], s["p99"], s["mean"]


def main(json_path: str = "BENCH_serving.json"):
    import numpy as np

    from benchmarks.common import all_records, emit
    from repro.core.engine import PAPER_QUERIES, KnowledgeBase
    from repro.obs.export import export_traces
    from repro.obs.trace import Tracer
    from repro.rdf.generator import generate_lubm
    from repro.serving.runtime import ServingRuntime

    records_before = len(all_records())
    n_clients = int(os.environ.get("REPRO_BENCH_SERVE_CLIENTS", "4"))
    per_client = int(os.environ.get("REPRO_BENCH_SERVE_PER_CLIENT", "40"))
    queries = list(PAPER_QUERIES.values())

    raw = generate_lubm(1, seed=0)
    K = KnowledgeBase.build(raw)
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)

    # -- read-only baseline: pins are all fast-path, plans prewarmed --------
    warm = max(2, per_client // 8)
    rt = ServingRuntime(K, modes=("litemat",), n_workers=n_clients,
                        max_queue=256)
    with rt:
        rt.registry.prewarm(queries)
        _closed_loop(rt, queries, n_clients, warm)
        win = rt.metrics.histogram("serving/latency_s", status="ok").state()
        outs, wall = _closed_loop(rt, queries, n_clients, per_client)
        p50, p99, untraced_mean = _ok_latency(rt, window=win)
    read_rps = len(outs) / max(wall, 1e-9)
    emit("serving/read_only", p50, p99_ms=round(p99 * 1e3, 2),
         requests_per_s=int(read_rps),
         n_ok=len(outs), n_triples=raw.n_triples)

    # -- micro-batched burst reads: same-signature coalescing ---------------
    # 8x the closed-loop client count over the same 2-worker budget: the
    # queue holds deep same-signature bursts, every drain coalesces ~30
    # peers, and the engine answers each duplicate cluster with ONE
    # executable dispatch (identical-signature members share it, identical
    # requests dedupe outright)
    burst = int(os.environ.get("REPRO_BENCH_SERVE_BURST", "32"))
    rt_b = ServingRuntime(K, modes=("litemat",), n_workers=2,
                          max_queue=512, batch_window_s=0.003,
                          max_batch=burst)
    with rt_b:
        rt_b.registry.prewarm(queries)
        _closed_loop(rt_b, queries, burst, warm)  # compile batched plans
        win = rt_b.metrics.histogram("serving/latency_s",
                                     status="ok").state()
        outs_b, wall_b = _closed_loop(rt_b, queries, burst, per_client)
        bp50, bp99, _ = _ok_latency(rt_b, window=win)
        occ = rt_b.metrics.histogram("serving/batch_size",
                                     kind="query").summary()
        n_batched = rt_b.stats["batched"]
    batched_rps = len(outs_b) / max(wall_b, 1e-9)
    occupancy = float(occ.get("mean", 0.0))
    emit("serving/batched_read", bp50, p99_ms=round(bp99 * 1e3, 2),
         requests_per_s=int(batched_rps),
         batch_occupancy=round(occupancy, 2),
         n_batched=n_batched, n_ok=sum(o.ok for o in outs_b))
    speedup = batched_rps / max(read_rps, 1e-9)
    emit("serving/batched_speedup", 0.0,
         speedup=round(speedup, 2), occupancy=round(occupancy, 2),
         baseline_rps=int(read_rps), batched_rps=int(batched_rps),
         gate_min_speedup=BATCHED_SPEEDUP_GATE,
         passed=bool(speedup >= BATCHED_SPEEDUP_GATE
                     and occupancy >= 4.0))

    # -- traced twin: the exported trace corpus + informational A/B --------
    tracer = Tracer()
    rt_t = ServingRuntime(K, modes=("litemat",), n_workers=n_clients,
                          max_queue=256, tracer=tracer)
    with rt_t:
        rt_t.registry.prewarm(queries)
        _closed_loop(rt_t, queries, n_clients, warm)
        win = rt_t.metrics.histogram("serving/latency_s",
                                     status="ok").state()
        _closed_loop(rt_t, queries, n_clients, per_client)
        _, _, traced_mean = _ok_latency(rt_t, window=win)
        traced_metrics = rt_t.metrics
    ab_pct = ((traced_mean - untraced_mean)
              / max(untraced_mean, 1e-12) * 100.0)

    # -- calibrated overhead gate: (tracer + control plane) / mean latency --
    from repro.obs.ledger import LEDGER

    traces = tracer.finished_traces()
    span_counts = sorted(len(t.spans) for t in traces) or [7]
    n_spans = span_counts[len(span_counts) // 2]
    cost_s = min(_tracer_cost_s(n_spans) for _ in range(3))
    # control-plane share: the rollup tick + ledger sample run once per
    # interval, not per request — amortize one (tick + sample) across the
    # requests a default 0.25 s interval admits at the measured rate
    K.track_ledger()
    tick_s, ledger_s = min(
        (_rollup_cost_s(traced_metrics, LEDGER) for _ in range(3)),
        key=sum)
    control_s = (tick_s + ledger_s) / (0.25 * max(read_rps, 1e-9))
    total_s = cost_s + control_s
    overhead_pct = total_s / max(untraced_mean, 1e-12) * 100.0
    emit("serving/obs_overhead", total_s,
         untraced_us=round(untraced_mean * 1e6, 1),
         overhead_pct=round(overhead_pct, 2),
         trace_pct=round(cost_s / max(untraced_mean, 1e-12) * 100.0, 2),
         control_plane_pct=round(
             control_s / max(untraced_mean, 1e-12) * 100.0, 2),
         rollup_tick_us=round(tick_s * 1e6, 1),
         ledger_sample_us=round(ledger_s * 1e6, 1),
         ab_overhead_pct=round(ab_pct, 2),
         spans_per_trace=n_spans,
         n_traces=len(traces),
         gate_max_pct=OBS_OVERHEAD_GATE_PCT,
         passed=bool(overhead_pct <= OBS_OVERHEAD_GATE_PCT))

    trace_path = os.environ.get("REPRO_TRACE_EXPORT")
    if trace_path:
        n = export_traces(tracer, trace_path)
        print(f"# wrote {trace_path} ({n} traces)")

    # -- mixed workload: the same read stream racing a background writer ----
    rt = ServingRuntime(K, modes=("litemat",), n_workers=n_clients,
                        max_queue=256, pin_lock_timeout_s=0.05)
    with rt:
        rt.registry.prewarm(queries)
        stop = threading.Event()

        def writer():
            rng = np.random.default_rng(1)
            while not stop.is_set():
                i = int(rng.integers(0, max(s.shape[0] - 64, 1)))
                rt.insert((s[i:i + 64], p[i:i + 64], o[i:i + 64]),
                          auto_compact=False)
                if stop.wait(0.02):
                    return

        w = threading.Thread(target=writer, daemon=True)
        t0 = time.perf_counter()
        w.start()
        # warmup epoch: grow the delta past its first pow2 bucket
        # transitions so their plan compiles land outside the measurement
        _closed_loop(rt, queries, n_clients, 8)
        warm_stats = dict(rt.stats)
        window = rt.metrics.histogram("serving/latency_s",
                                      status="ok").state()
        outs, wall = _closed_loop(rt, queries, n_clients, per_client)
        stop.set()
        w.join()
        write_wall = time.perf_counter() - t0
        p50, p99, _ = _ok_latency(rt, window=window)
        stats = dict(rt.stats)
        mixed_metrics = rt.metrics
    n_ok = stats["ok"] - warm_stats["ok"]
    n_measured_stale = (stats["stale_served"] - warm_stats["stale_served"])
    emit("serving/mixed_workload", p50, p99_ms=round(p99 * 1e3, 2),
         requests_per_s=int(len(outs) / max(wall, 1e-9)),
         update_rows_per_s=int(64 * stats["updates"]
                               / max(write_wall, 1e-9)),
         n_ok=n_ok, n_updates=stats["updates"],
         n_stale_served=n_measured_stale, n_retries=stats["retries"])
    slo_ok = (stats["shed"] == 0 and stats["deadline"] == 0
              and n_ok == len(outs))
    emit("serving/mixed_slo", 0.0, shed=stats["shed"],
         deadline_missed=stats["deadline"], errors=stats["errors"],
         passed=bool(slo_ok))

    metrics_path = os.environ.get("REPRO_METRICS_EXPORT")
    if metrics_path:
        # one aggregated fleet-schema snapshot: the mixed run's serving
        # registry + the process-global engine registry (plan cache,
        # capacity retries, ledger hbm gauges) as two labelled members —
        # schema-validated by scripts/check_traces.py, rendered by
        # scripts/fleet_report.py
        from repro.obs.aggregate import aggregate
        from repro.obs.metrics import REGISTRY

        LEDGER.sample()
        fleet = aggregate([
            mixed_metrics.mergeable_snapshot(process="serving"),
            REGISTRY.mergeable_snapshot(process="engine"),
        ])
        with open(metrics_path, "w") as f:
            json.dump(fleet, f, indent=1, sort_keys=True)
        print(f"# wrote {metrics_path} (fleet schema, "
              f"{len(fleet['histograms'])} histograms)")

    if json_path:
        rows = all_records()[records_before:]
        artifact = {
            "n_base_triples": raw.n_triples,
            "n_requests": n_clients * per_client,
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {json_path} ({len(rows)} rows)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
