"""Paper Table III: SAE vs OBE ABox encoding throughput (triples/sec).

OBE pre-resolves TBox terms (predicates + rdf:type objects) so its parallel
dictionary processes 2 columns instead of 3 — the source of the paper's
reported 1.5-2.8x advantage.
"""
from __future__ import annotations


def main():
    from benchmarks.common import BENCH_UNIVERSITIES, emit, timeit
    from repro.core.abox import encode_obe, encode_sae
    from repro.core.tbox import build_tbox
    from repro.rdf.generator import generate_lubm

    raw = generate_lubm(BENCH_UNIVERSITIES, seed=0)
    tbox = build_tbox(raw.onto)
    n = raw.n_triples

    t_obe, kb = timeit(lambda: encode_obe(raw, tbox), repeats=3)
    t_sae, _ = timeit(lambda: encode_sae(raw), repeats=3)
    emit("table3/obe_encode", t_obe, triples=n,
         throughput_tps=int(n / t_obe), instance_terms=kb.n_instance_terms)
    emit("table3/sae_encode", t_sae, triples=n,
         throughput_tps=int(n / t_sae), obe_speedup=round(t_sae / t_obe, 2))


if __name__ == "__main__":
    main()
