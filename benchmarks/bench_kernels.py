"""Kernel microbenches: Pallas (interpret on CPU) vs jnp reference.

NOTE: on this CPU host the Pallas kernels run in INTERPRET mode, so their
wall-times measure the validation path, not TPU performance — the numbers
that matter are the ref-path times (XLA CPU) and, on real hardware, the
Mosaic-compiled kernels.  Reported for completeness + regression tracking.

The SIZE SWEEP section (1e5 -> 4e6 rows, REPRO_BENCH_SWEEP_MAX tunable)
captures the scaling curve the chunked-cumsum compaction and the
diagonal-partitioned merge unlock: stream compaction + compaction-merge
rows at multi-million-row stores — sizes the old (block, block) one-hot
scatter and both-tables-VMEM-resident merge could not express on real
hardware (64 MB cube / >16 MB key residency).  ``kernels/sweep/scale_ok``
gates on the sweep actually reaching >= 2e6 rows.
"""
from __future__ import annotations

import os

import numpy as np

SWEEP_SIZES = (100_000, 400_000, 1_000_000, 2_000_000, 4_000_000)


def _sweep(emit, timeit):
    import jax.numpy as jnp

    from repro.kernels import ops

    max_n = int(float(os.environ.get("REPRO_BENCH_SWEEP_MAX", "4e6")))
    sizes = [n for n in SWEEP_SIZES if n <= max_n]
    rng = np.random.default_rng(7)
    ran = 0
    for n in sizes:
        mask = jnp.asarray(rng.random(n) < 0.1)
        p = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
        o = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
        alive = jnp.asarray(rng.random(n) < 0.97)
        params = jnp.asarray([100, 300, 0, 1 << 19], jnp.int32)
        cap = 1 << 15
        blk = ops.auto_block(n)
        t, _ = timeit(lambda: ops.compact_indices(mask, cap, block=blk),
                      repeats=2)
        emit(f"kernels/sweep/stream_compact_n{n}", t, n=n, block=blk,
             rows_per_s=int(n / max(t, 1e-9)))
        t, _ = timeit(lambda: ops.masked_interval_compact(
            p, o, alive, params, cap, block=blk), repeats=2)
        emit(f"kernels/sweep/masked_interval_compact_n{n}", t, n=n, block=blk,
             rows_per_s=int(n / max(t, 1e-9)))

        # compaction-merge: fold a 10% delta into a 90% base (tombstones
        # dropped through the compaction kernel) — core/delta.py's device
        # compaction at scale
        nb, nd = (n * 9) // 10, n // 10
        def run(k):
            hi = rng.integers(0, 1 << 20, k).astype(np.int32)
            lo = rng.integers(0, 1 << 20, k).astype(np.int32)
            srt = np.lexsort((lo, hi))
            return jnp.asarray(hi[srt]), jnp.asarray(lo[srt])
        bh_, bl_ = run(nb)
        dh_, dl_ = run(nd)
        keep = jnp.asarray(rng.random(n) < 0.97)

        def merge_compact():
            gidx = ops.merge_gather(bh_, bl_, dh_, dl_)
            al = keep[gidx]
            return ops.compact_indices(al, cap, block=blk)

        t, _ = timeit(merge_compact, repeats=2)
        emit(f"kernels/sweep/merge_compact_n{n}", t, n=n,
             rows_per_s=int(n / max(t, 1e-9)))
        ran = n

    # block-size effect at a fixed size: the old 512 ceiling vs 4096 tiles
    n = min(400_000, max_n)
    mask = jnp.asarray(rng.random(n) < 0.1)
    for blk in (512, ops.LARGE_BLOCK):
        t, _ = timeit(lambda: ops.compact_indices(mask, 1 << 15, block=blk),
                      repeats=2)
        emit(f"kernels/sweep/stream_compact_block{blk}", t, n=n, block=blk)

    emit("kernels/sweep/scale_ok", 0.0, max_rows=ran,
         passed=bool(ran >= 2_000_000))


def main():
    import jax.numpy as jnp

    from benchmarks.common import emit, timeit
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    N = 200_000
    p = jnp.asarray(rng.integers(0, 1000, N), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, N), jnp.int32)
    params = jnp.asarray([100, 300, 0, 1 << 19], jnp.int32)
    t, _ = timeit(ops.interval_filter, p, o, params, repeats=3)
    emit("kernels/interval_filter_pallas", t, n=N)
    import jax

    reff = jax.jit(lambda: ref.ref_interval_filter(None, p, o, 100, 300, 0, 1 << 19, 0))
    t, _ = timeit(reff, repeats=3)
    emit("kernels/interval_filter_ref", t, n=N)

    G, K = 2048, 16
    conc = jnp.asarray(rng.integers(-1, 500, (G, K)).astype(np.int32))
    bounds = conc + jnp.asarray(rng.integers(1, 64, (G, K)).astype(np.int32))
    t, _ = timeit(ops.msc_select, conc, bounds, repeats=3)
    emit("kernels/msc_select_pallas", t, groups=G)
    reff = jax.jit(lambda: ref.ref_msc_select(conc, bounds))
    t, _ = timeit(reff, repeats=3)
    emit("kernels/msc_select_ref", t, groups=G)

    N2 = 200_000
    mask = jnp.asarray(rng.random(N2) < 0.1)
    cap = 1 << 15
    t, _ = timeit(ops.compact_indices, mask, cap, repeats=3)
    emit("kernels/stream_compact_pallas", t, n=N2, cap=cap)
    t, _ = timeit(ops.interval_compact, p, o, params, cap, repeats=3)
    emit("kernels/interval_compact_fused_pallas", t, n=N, cap=cap)
    argsort_ref = jax.jit(lambda: jnp.argsort(~mask, stable=True)[:cap])
    t, _ = timeit(argsort_ref, repeats=3)
    emit("kernels/compact_argsort_ref", t, n=N2, cap=cap)

    _sweep(emit, timeit)


if __name__ == "__main__":
    main()
