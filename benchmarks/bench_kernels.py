"""Kernel microbenches: Pallas (interpret on CPU) vs jnp reference.

NOTE: on this CPU host the Pallas kernels run in INTERPRET mode, so their
wall-times measure the validation path, not TPU performance — the numbers
that matter are the ref-path times (XLA CPU) and, on real hardware, the
Mosaic-compiled kernels.  Reported for completeness + regression tracking.
"""
from __future__ import annotations

import numpy as np


def main():
    import jax.numpy as jnp

    from benchmarks.common import emit, timeit
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    N = 200_000
    p = jnp.asarray(rng.integers(0, 1000, N), jnp.int32)
    o = jnp.asarray(rng.integers(0, 1 << 20, N), jnp.int32)
    params = jnp.asarray([100, 300, 0, 1 << 19], jnp.int32)
    t, _ = timeit(ops.interval_filter, p, o, params, repeats=3)
    emit("kernels/interval_filter_pallas", t, n=N)
    import jax

    reff = jax.jit(lambda: ref.ref_interval_filter(None, p, o, 100, 300, 0, 1 << 19, 0))
    t, _ = timeit(reff, repeats=3)
    emit("kernels/interval_filter_ref", t, n=N)

    G, K = 2048, 16
    conc = jnp.asarray(rng.integers(-1, 500, (G, K)).astype(np.int32))
    bounds = conc + jnp.asarray(rng.integers(1, 64, (G, K)).astype(np.int32))
    t, _ = timeit(ops.msc_select, conc, bounds, repeats=3)
    emit("kernels/msc_select_pallas", t, groups=G)
    reff = jax.jit(lambda: ref.ref_msc_select(conc, bounds))
    t, _ = timeit(reff, repeats=3)
    emit("kernels/msc_select_ref", t, groups=G)

    N2 = 200_000
    mask = jnp.asarray(rng.random(N2) < 0.1)
    cap = 1 << 15
    t, _ = timeit(ops.compact_indices, mask, cap, repeats=3)
    emit("kernels/stream_compact_pallas", t, n=N2, cap=cap)
    t, _ = timeit(ops.interval_compact, p, o, params, cap, repeats=3)
    emit("kernels/interval_compact_fused_pallas", t, n=N, cap=cap)
    argsort_ref = jax.jit(lambda: jnp.argsort(~mask, stable=True)[:cap])
    t, _ = timeit(argsort_ref, repeats=3)
    emit("kernels/compact_argsort_ref", t, n=N2, cap=cap)

    V, E, B, L = 10_000, 64, 512, 16
    table = jnp.asarray(rng.normal(size=(V, E)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-1, V, (B, L)).astype(np.int32))
    t, _ = timeit(ops.embedding_bag, table, idx, repeats=3)
    emit("kernels/embedding_bag_pallas", t, bags=B)
    reff = jax.jit(lambda: ref.ref_embedding_bag(table, idx))
    t, _ = timeit(reff, repeats=3)
    emit("kernels/embedding_bag_ref", t, bags=B)


if __name__ == "__main__":
    main()
