"""Paper Table II: TBox (ontology) encoding time vs ontology size.

Three ontology scales stand in for LUBM / DBPedia / Wikidata; we addition-
ally benchmark the beyond-paper parallel (JAX) encoder against the host
reference — the paper's own pipeline serializes this stage through HermiT
(122 s for Wikidata's 213K concepts).
"""
from __future__ import annotations


def main():
    from benchmarks.common import emit, timeit
    from repro.core.hierarchy import build_taxonomy
    from repro.core.tbox import build_tbox, encode_hierarchy, encode_hierarchy_parallel
    from repro.rdf.generator import generate_deep_ontology
    from repro.rdf.vocab import lubm_ontology

    cases = {
        "lubm(43c)": lubm_ontology(),
        "dbpedia-like(814c)": generate_deep_ontology(
            n_concepts=814, n_properties=300, depth_bias=0.25, seed=1
        ),
        # a 5K-concept slice of a Wikidata-scale taxonomy: the host stage
        # is O(C·depth) python, the parallel JAX encoder is the beyond-paper
        # answer for the full 213K-concept case (paper: 122 s via HermiT).
        "wikidata-subset(5000c)": generate_deep_ontology(
            n_concepts=5_000, n_properties=353, depth_bias=0.02,
            max_children=64, seed=2
        ),
    }
    for name, onto in cases.items():
        t, tb = timeit(lambda o=onto: build_tbox(o), repeats=3)
        emit(f"table2/tbox_encode/{name}", t,
             concepts=tb.concepts.n, props=tb.properties.n,
             bits=tb.concepts.total_bits)
        tax = build_taxonomy(onto.concepts, onto.subclass)
        if tb.concepts.total_bits <= 31:
            th, _ = timeit(lambda: encode_hierarchy(tax), repeats=3)
            tp, _ = timeit(lambda: encode_hierarchy_parallel(tax), repeats=3)
            emit(f"table2/encoder_host/{name}", th)
            emit(f"table2/encoder_parallel/{name}", tp, speedup=round(th / tp, 2))


if __name__ == "__main__":
    main()
