"""Roofline report: aggregate reports/dryrun/*.json into the §Roofline table.

Per (arch x shape x mesh): the three terms (seconds), the dominant term,
MODEL_FLOPS, the useful-compute ratio, and the roofline fraction
(= achieved useful FLOP/s at the bound, divided by peak):

    bound      = max(t_compute, t_memory, t_collective)
    roofline%  = (model_flops / chips / bound) / PEAK_FLOPS
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12


def load(dirpath="reports/dryrun"):
    recs = []
    for f in sorted(Path(dirpath).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def table(recs, mesh="single"):
    rows = []
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        t = r["roofline"]
        bound = max(t.values())
        frac = (r["model_flops_per_chip"] / bound) / PEAK_FLOPS if bound else 0.0
        rows.append(dict(
            arch=r["arch"], shape=r["shape"],
            t_compute=t["t_compute"], t_memory=t["t_memory"],
            t_collective=t["t_collective"], dominant=r["dominant"],
            model_flops=r["model_flops"], useful_ratio=r.get("useful_ratio"),
            roofline_frac=frac, compile_s=r.get("compile_s"),
        ))
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant | "
           "MODEL_FLOPs | useful | roofline% |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"{r['dominant'].replace('t_', '')} | {r['model_flops']:.2e} | "
            f"{(r['useful_ratio'] or 0):.2f} | {100 * r['roofline_frac']:.1f}% |"
        )
    return "\n".join(lines)


def main():
    from benchmarks.common import emit

    recs = load()
    ok = [r for r in recs if r.get("status") == "ok"]
    fail = [r for r in recs if r.get("status") != "ok"]
    emit("roofline/cells_ok", 0.0, ok=len(ok), fail=len(fail))
    for mesh in ("single", "multi"):
        rows = table(recs, mesh)
        for r in rows:
            emit(
                f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                max(r["t_compute"], r["t_memory"], r["t_collective"]),
                dominant=r["dominant"].replace("t_", ""),
                roofline_pct=round(100 * r["roofline_frac"], 2),
            )
        md = render_markdown(rows)
        out = Path(f"reports/roofline_{mesh}.md")
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(md + "\n")
        print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
