"""Paper Tables IV + V: lite vs full materialization (duration + size delta).

Run on the LUBM-style KB (paper: lite ~0% delta, full +38%) and on a
deep-hierarchy KB standing in for DBPedia/Wikidata (paper: full +13..58%,
lite may *shrink* the store).
"""
from __future__ import annotations


def main():
    from benchmarks.common import BENCH_UNIVERSITIES, emit, timeit
    from repro.core.abox import encode_obe
    from repro.core.closure import full_materialize
    from repro.core.materialize import DeviceTBox, lite_materialize
    from repro.core.tbox import build_tbox
    from repro.rdf.generator import generate_deep_ontology, generate_lubm, generate_random_abox

    def run(tag, raw, tbox):
        kb = encode_obe(raw, tbox)
        dtb = DeviceTBox.build(tbox)
        n = kb.n
        t_lite, (out, valid, stats) = timeit(
            lambda: lite_materialize(kb, dtb), repeats=3
        )
        lite_n = stats["n_type_out"] + stats["n_nontype"]
        emit(f"table4/lite_mat/{tag}", t_lite, triples=n,
             throughput_tps=int(n / t_lite),
             added=stats["n_added_implicit"], deleted=stats["n_deleted_explicit"],
             delta_pct=round(100.0 * (lite_n - n) / n, 2))
        t_full, (fout, fvalid, fstats) = timeit(
            lambda: full_materialize(kb, dtb), repeats=3
        )
        emit(f"table5/full_mat/{tag}", t_full, triples=n,
             throughput_tps=int(n / t_full),
             added_pct=round(fstats["added_pct"], 2),
             lite_speedup=round(t_full / t_lite, 2))

    raw = generate_lubm(BENCH_UNIVERSITIES, seed=0)
    run("lubm", raw, build_tbox(raw.onto))

    onto = generate_deep_ontology(n_concepts=814, n_properties=120,
                                  depth_bias=0.35, n_domain=60, n_range=55,
                                  seed=3, max_children=7, max_depth=8)
    deep = generate_random_abox(onto, n_instances=60_000, n_type_triples=150_000,
                                n_prop_triples=350_000, seed=4)
    run("deep-dbpedia-like", deep, build_tbox(onto))


if __name__ == "__main__":
    main()
