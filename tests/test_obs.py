"""Observability subsystem: metrics registry, tracer, serving trace contract.

Three layers of coverage:

  * unit behavior of the instruments — counter atomicity under threads,
    histogram sketch accuracy and windowed summaries, the kernel
    pass-counter race fix, the tracer's span tree / ring bound / no-op
    off path, the hand-rolled trace schema validator;
  * the planner introspection surface (``QueryEngine.explain`` +
    observed-selectivity capture);
  * the serving contract (the tentpole's acceptance bar): EVERY submitted
    request — ok, retried, stale-degraded, deadline-missed, shed — yields
    exactly one schema-valid trace whose structure matches its Outcome
    (pin span present, attempt spans == retries + 1, queue_s + exec_s ==
    latency_s), under the tests/test_faults.py fault matrix.
"""
import json
import threading

import numpy as np
import pytest

import jax

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.obs.export import export_traces, validate, validate_trace
from repro.obs.metrics import (MetricsRegistry, REGISTRY, window_summary)
from repro.obs.trace import Tracer, activate
from repro.serving.runtime import ServingRuntime
from repro.testing import faults
from repro.testing.faults import FaultCrash, FaultError

Q1, Q4 = PAPER_QUERIES["Q1"], PAPER_QUERIES["Q4"]


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    yield
    faults.uninstall()


@pytest.fixture(scope="module")
def obs_kb():
    """Private KB for the tests that INSERT through the runtime (and crash
    its flushes mid-way): the session-scoped ``lubm_kb`` is shared with
    every later test file and must stay pristine."""
    from repro.rdf.generator import generate_lubm

    raw = generate_lubm(n_universities=1, seed=7)
    return KnowledgeBase.build(raw), raw


# -- metrics instruments ------------------------------------------------------

def test_counter_increments_are_atomic_under_threads():
    reg = MetricsRegistry()
    c = reg.counter("t/hits", kind="x")

    def worker():
        for _ in range(2000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 16000
    assert reg.counter_value("t/hits", kind="x") == 16000
    assert reg.counter_value("t/hits", kind="untouched") == 0


def test_pass_counters_thread_safe_and_mirrored():
    """Satellite fix: ops.pass_counters bumps were racy dict +=."""
    before = ops.reset_pass_counters()
    assert set(before) == set(ops.pass_counters)
    assert all(v == 0 for v in ops.pass_counters.values())
    mirror0 = REGISTRY.counter_value("kernels/passes", kind="merge_resident")

    def worker():
        for _ in range(500):
            ops._bump_pass("merge_resident")

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ops.pass_counters["merge_resident"] == 4000
    assert (REGISTRY.counter_value("kernels/passes", kind="merge_resident")
            - mirror0) == 4000
    snap = ops.reset_pass_counters()
    assert snap["merge_resident"] == 4000  # snapshot semantics preserved
    assert ops.pass_counters["merge_resident"] == 0


def test_histogram_sketch_accuracy_and_summary():
    reg = MetricsRegistry()
    h = reg.histogram("t/lat")
    rng = np.random.default_rng(0)
    xs = rng.uniform(1.0, 1000.0, size=2000)
    for x in xs:
        h.observe(float(x))
    s = h.summary()
    assert s["n"] == 2000
    assert s["min"] == float(xs.min()) and s["max"] == float(xs.max())
    assert abs(s["mean"] - xs.mean()) < 1e-6
    # log-bucket sketch: <=~4.5% value error, allow slack for rank error
    assert abs(s["p50"] - np.percentile(xs, 50)) / np.percentile(xs, 50) < 0.1
    assert abs(s["p99"] - np.percentile(xs, 99)) / np.percentile(xs, 99) < 0.1
    assert reg.histogram("t/empty").summary() == dict(n=0)


def test_window_summary_excludes_prior_observations():
    reg = MetricsRegistry()
    h = reg.histogram("t/win")
    for _ in range(100):
        h.observe(1.0)  # warmup epoch: all small
    before = h.state()
    for _ in range(50):
        h.observe(100.0)  # measured window: all large
    w = window_summary(h, before)
    assert w["n"] == 50
    assert abs(w["mean"] - 100.0) < 1e-9
    assert w["p50"] > 50.0  # warmup's 1.0s must not drag the median down
    assert h.summary()["p50"] < 50.0  # ...though they dominate the total
    assert window_summary(h, h.state()) == dict(n=0)


def test_registry_snapshot_is_json_ready():
    reg = MetricsRegistry()
    reg.counter("a/ops", kind="merge").inc(3)
    reg.gauge("a/depth").set(7)
    reg.histogram("a/lat", status="ok").observe(0.25)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["a/ops{kind=merge}"] == 3
    assert snap["gauges"]["a/depth"] == 7
    assert snap["histograms"]["a/lat{status=ok}"]["n"] == 1


# -- tracer -------------------------------------------------------------------

def test_span_tree_parenting_and_error_capture():
    tracer = Tracer()
    tr = tracer.new_trace()
    root = tracer.start_root(tr, "request", mode="litemat")
    with activate(root):
        with obs_trace.span("pin", version=3):
            with obs_trace.span("execute"):
                obs_trace.event("marker", k=1)
        with pytest.raises(ValueError):
            with obs_trace.span("boom"):
                raise ValueError("no")
    tracer.finish_trace(tr)

    d = tr.to_dict()
    assert validate_trace(d) == []
    by_name = {s["name"]: s for s in d["spans"]}
    assert by_name["pin"]["parent_id"] == root.span_id
    assert by_name["execute"]["parent_id"] == by_name["pin"]["span_id"]
    assert by_name["execute"]["events"][0]["name"] == "marker"
    assert "ValueError" in by_name["boom"]["attrs"]["error"]
    assert all(s["t1"] >= s["t0"] for s in d["spans"])


def test_span_is_noop_without_active_trace():
    # no activate() anywhere: instrumented code must run untraced for free
    with obs_trace.span("anything", x=1) as sp:
        sp.set_attr(y=2)
        sp.add_event("e")
    obs_trace.event("nothing")
    assert obs_trace.current_span() is None


def test_tracer_ring_is_bounded():
    tracer = Tracer(max_traces=4)
    for _ in range(7):
        tr = tracer.new_trace()
        tracer.start_root(tr, "r")
        tracer.finish_trace(tr)
    assert len(tracer.finished_traces()) == 4
    assert tracer.dropped == 3
    ids = [t.trace_id for t in tracer.finished_traces()]
    assert ids == sorted(ids)  # oldest dropped, order kept


def test_validator_catches_malformed_traces():
    tracer = Tracer()
    tr = tracer.new_trace()
    tracer.start_root(tr, "r")
    good = tr.to_dict()
    assert validate_trace(good) == []

    bad = json.loads(json.dumps(good))
    bad["spans"][0]["parent_id"] = 42  # no root anymore + dangling parent
    assert validate_trace(bad)

    bad = json.loads(json.dumps(good))
    bad["spans"][0]["t1"] = bad["spans"][0]["t0"] - 1.0
    assert any("t1 < t0" in e for e in validate_trace(bad))

    bad = json.loads(json.dumps(good))
    del bad["spans"][0]["name"]
    assert any("missing required key" in e for e in validate_trace(bad))

    assert validate(True, {"type": "integer"})  # bool is not an integer


# -- planner introspection ----------------------------------------------------

def test_explain_reports_plan_and_observed_rows(lubm_kb):
    K, _ = lubm_kb
    eng = K.engine("litemat")
    rows, sel = eng.run(Q4)
    info = eng.explain(Q4)
    assert info["mode"] == "litemat"
    assert info["n_result_rows"] == rows.shape[0]
    assert len(info["patterns"]) == len(Q4)
    for p in info["patterns"]:
        assert p["strategy"] in ("slice", "scan", "inl")
        assert p["estimated_rows"] >= 0
        assert 0.0 <= p["selectivity"] <= 1.0
    # observed selectivities land in the process registry as gauges
    gauges = REGISTRY.gauges_with_prefix("planner/selectivity")
    assert gauges  # at least one strategy/store combination recorded
    assert eng.observed_selectivity  # per-signature capture for the planner


# -- the serving trace contract (tentpole acceptance) -------------------------

def _traces_by_id(tracer):
    return {t.trace_id: t for t in tracer.finished_traces()}


def test_every_request_yields_one_wellformed_trace(obs_kb):
    """ok / retried / stale / deadline / shed requests under the fault
    matrix: one schema-valid trace each, structure matching the Outcome."""
    K, raw = obs_kb
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    tracer = Tracer()
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        pin_lock_timeout_s=0.05, max_queue=64,
                        tracer=tracer)
    outs = []
    with rt:
        outs.append(rt.serve(Q1))  # clean fast-path pin
        with faults.inject() as inj:
            # two transient execute failures -> retries == 2, then ok
            inj.arm("serving.execute", exc=FaultError, times=2)
            outs.append(rt.serve(Q1))
        with faults.inject() as inj:
            # crash the writer's publish AND the reader's own fresh-capture
            # attempt: the reader degrades to the stale published snapshot
            inj.arm("engine.flush_mat", exc=FaultCrash, times=2)
            rt.insert((s[:32], p[:32], o[:32]), auto_compact=False)
            outs.append(rt.serve(Q1))
        outs.append(rt.serve(Q1, deadline_s=0.0))  # preempted at dequeue
        with faults.inject() as inj:
            # delay-only fault pins the single worker down long enough for
            # the bounded queue to fill: later submits shed at admission
            inj.arm("serving.execute", exc=None, delay_s=0.3, times=0)
            slow = ServingRuntime(K, modes=("litemat",), n_workers=1,
                                  max_queue=1, tracer=tracer)
            with slow:
                futs = [slow.submit(Q1) for _ in range(6)]
                outs.extend(f.result() for f in futs)

    assert [o.status for o in outs[:4]] == ["ok", "ok", "ok", "deadline"]
    assert outs[1].retries == 2
    assert outs[2].stale is True
    assert any(o.status == "shed" for o in outs[4:])
    assert rt.stats["retries"] == 2 and rt.stats["stale_served"] == 1
    assert rt.registry.stats["stale_pins"] >= 1

    by_id = _traces_by_id(tracer)
    assert len(by_id) == len(outs)  # exactly one trace per request
    for out in outs:
        tr = by_id[out.trace_id]
        d = tr.to_dict()
        assert validate_trace(d) == [], d["trace_id"]
        root = d["spans"][0]
        assert root["name"] == "request"
        assert root["attrs"]["status"] == out.status
        assert root["attrs"]["retries"] == out.retries
        names = [s["name"] for s in d["spans"]]
        assert "queue" in names
        if out.status == "shed":
            # rejected at admission: no execution spans ever open
            assert "pin" not in names and "execute" not in names
        elif out.status == "ok":
            assert "pin" in names and "execute" in names
            assert len(tr.find("attempt")) == out.retries + 1
            pin_attrs = tr.find("pin")[-1].attrs
            assert pin_attrs["version"] == out.version
            assert pin_attrs["stale"] == out.stale
        # (deadline_s=0.0 preempts before the first attempt: no pin span,
        # just the deadline_preempt event on the root)
        # timing split: exact by construction
        assert abs(out.queue_s + out.exec_s - out.latency_s) < 1e-9


def test_stale_degradation_event_recorded(obs_kb):
    K, raw = obs_kb
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    tracer = Tracer()
    rt = ServingRuntime(K, modes=("litemat",), n_workers=1,
                        pin_lock_timeout_s=0.05, tracer=tracer)
    with rt:
        with faults.inject() as inj:
            inj.arm("engine.flush_mat", exc=FaultCrash, times=2)
            rt.insert((s[:16], p[:16], o[:16]), auto_compact=False)
            out = rt.serve(Q1)
    assert out.stale
    tr = _traces_by_id(tracer)[out.trace_id]
    events = [e["name"] for sp in tr.spans for e in sp.events]
    assert "stale_degraded" in events


def test_trace_export_roundtrip(tmp_path, lubm_kb):
    K, _ = lubm_kb
    tracer = Tracer()
    rt = ServingRuntime(K, modes=("litemat",), n_workers=2, tracer=tracer)
    with rt:
        for _ in range(5):
            assert rt.serve(Q1).ok
    path = tmp_path / "traces.json"
    n = export_traces(tracer, str(path))
    assert n == 5
    doc = json.loads(path.read_text())
    assert doc["dropped"] == 0
    for trace in doc["traces"]:
        assert validate_trace(trace) == []


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="shard_map path needs >1 XLA device")
def test_shard_map_fallback_recorded_in_trace(lubm_kb):
    from repro.core.shard import ShardedKB

    _, raw = lubm_kb
    skb = ShardedKB.build(raw, n_shards=2)
    eng = skb.engine("litemat")
    expected = skb.answers(Q1)  # also warms plans/stacks

    tracer = Tracer()
    tr = tracer.new_trace()
    root = tracer.start_root(tr, "test")
    faults0 = eng.cache_stats["shard_map_faults"]
    with faults.inject() as inj:
        inj.arm("shard.shard_map", exc=FaultError, times=1)
        with activate(root):
            rows, sel = eng.run(Q1)
    tracer.finish_trace(tr)
    assert {tuple(r) for r in rows.tolist()} == expected
    assert eng.cache_stats["shard_map_faults"] == faults0 + 1

    d = tr.to_dict()
    assert validate_trace(d) == []
    dispatches = [s for s in d["spans"] if s["name"] == "shard_dispatch"]
    paths = [s["attrs"].get("path") for s in dispatches]
    assert "shard_map" in paths and "loop" in paths  # degraded mid-request
    sm = next(s for s in dispatches if s["attrs"]["path"] == "shard_map")
    assert "error" in sm["attrs"]  # the injected fault is on the span
    events = [e["name"] for s in d["spans"] for e in s["events"]]
    assert "shard_map_fallback" in events


def test_snapshot_registry_stats_view(lubm_kb):
    K, _ = lubm_kb
    from repro.core.snapshot import SnapshotRegistry

    reg = SnapshotRegistry(K, modes=("litemat",))
    reg.publish()
    pin = reg.pin()
    try:
        st = reg.stats
        assert st["publishes"] >= 1 and st["pins"] == 1
        assert reg.metrics.gauge_value("snapshot/pinned_refs") == 1
    finally:
        pin.release()
    assert reg.metrics.gauge_value("snapshot/pinned_refs") == 0
