"""Unit + property tests for the id interval arithmetic."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import intervals as iv


def test_bound_basic():
    # the paper's example: id 20 = 00010100, 8 bits total, used = 6 bits
    assert int(iv.bound_of(np.int64(20), 6, 8)) == 24


def test_ancestor_masking():
    # stripping back to 4 used bits recovers the 0001 prefix
    assert int(iv.ancestor_at(np.int64(0b00010110), 4, 8)) == 0b00010000


@given(st.integers(1, 60), st.data())
@settings(max_examples=50, deadline=None)
def test_interval_consistency(total_bits, data):
    used = data.draw(st.integers(0, total_bits))
    prefix = data.draw(st.integers(0, (1 << used) - 1 if used else 0))
    ident = prefix << (total_bits - used)
    bound = int(iv.bound_of(np.int64(ident), used, total_bits))
    # every value with this prefix lies in [id, bound)
    suffix = data.draw(st.integers(0, (1 << (total_bits - used)) - 1))
    v = ident | suffix
    assert iv.is_subsumed_by(v, ident, bound)
    # and the first value outside does not
    assert not iv.is_subsumed_by(bound, ident, bound)


def test_lookup_index():
    tbl = np.array([3, 7, 9, 200], dtype=np.int64)
    q = np.array([7, 8, 3, 200, -1], dtype=np.int64)
    out = iv.lookup_index(tbl, q)
    assert out.tolist() == [1, -1, 0, 3, -1]


@given(st.lists(st.integers(0, 2**120), min_size=2, max_size=8, unique=True))
@settings(max_examples=50, deadline=None)
def test_wide_lex_order_matches_int_order(values):
    W = iv.words_needed(121)
    packed = np.stack([iv.pack_wide(v, W) for v in values])
    a = jnp.asarray(packed[:-1])
    b = jnp.asarray(packed[1:])
    want = np.array([x < y for x, y in zip(values[:-1], values[1:])])
    got = np.asarray(iv.lex_lt(a, b))
    assert (got == want).all()


def test_wide_pack_roundtrip():
    v = (1 << 101) | 12345
    W = iv.words_needed(102)
    assert iv.unpack_wide(iv.pack_wide(v, W)) == v
