"""Indexed execution vs the scan-based oracle + plan-cache behaviour.

The sorted-index path (core/index.py + 'slice' strategy in core/query.py)
must be answer-identical to the scan path on arbitrary stores, across all
three execution modes, including under capacity overflow/retry.
"""
import numpy as np
import pytest

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.index import StoreIndex
from repro.core.query import Pattern, QueryEngine
from repro.core.tbox import Ontology
from repro.rdf.generator import generate_random_abox

MODES = ("litemat", "full", "rewrite")


def _random_kb(seed: int) -> tuple:
    rng = np.random.default_rng(seed)
    nc, npr = int(rng.integers(4, 10)), int(rng.integers(2, 5))
    concepts = [f"C{i}" for i in range(nc)]
    props = [f"p{i}" for i in range(npr)]
    subclass = [(concepts[i], concepts[int(rng.integers(0, i))])
                for i in range(1, nc)]
    subprop = [(props[i], props[int(rng.integers(0, i))])
               for i in range(1, npr)]
    domain = {props[0]: [concepts[0]]} if rng.random() < 0.5 else {}
    onto = Ontology(concepts=concepts, properties=props, subclass=subclass,
                    subprop=subprop, domain=domain, range_={})
    raw = generate_random_abox(onto, n_instances=50, n_type_triples=80,
                               n_prop_triples=60, seed=seed)
    return onto, KnowledgeBase.build(raw)


def _queries(onto):
    qs = [
        [Pattern("?x", "rdf:type", onto.concepts[0])],
        [Pattern("?x", onto.properties[0], "?y")],
        [Pattern("?x", "rdf:type", onto.concepts[0]),
         Pattern("?x", onto.properties[0], "?y")],
    ]
    if len(onto.concepts) > 2:
        qs.append([Pattern("?x", "rdf:type", onto.concepts[2])])
    return qs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_indexed_equals_scan_on_random_stores(seed):
    onto, K = _random_kb(seed)
    for pats in _queries(onto):
        for mode in MODES:
            idx = K.answers(pats, mode=mode, use_index=True)
            scan = K.answers(pats, mode=mode, use_index=False)
            assert idx == scan, (seed, mode, pats)


def test_indexed_equals_scan_on_lubm(lubm_kb):
    K, _ = lubm_kb
    for qn, pats in PAPER_QUERIES.items():
        for mode in MODES:
            assert (K.answers(pats, mode=mode, use_index=True)
                    == K.answers(pats, mode=mode, use_index=False)), (qn, mode)


def test_indexed_constant_subject_and_object(lubm_kb):
    """PSO path (constant subject) + residual path (wide p, constant o)."""
    K, _ = lubm_kb
    rows, _ = K.query([Pattern("?x", "memberOf", "?y")])
    s_id, o_id = int(rows[0][0]), int(rows[0][1])
    for pats in (
        [Pattern(s_id, "memberOf", "?y")],  # PSO slice
        [Pattern("?x", "memberOf", o_id)],  # POS p-run + residual o check
        [Pattern(s_id, "memberOf", "?y"), Pattern("?x", "memberOf", "?y")],
    ):
        for mode in ("litemat", "full"):
            assert (K.answers(pats, mode=mode, use_index=True)
                    == K.answers(pats, mode=mode, use_index=False)), pats


def test_store_index_ranges(lubm_kb):
    """Range lookups agree with brute-force boolean selection."""
    K, _ = lubm_kb
    idx = StoreIndex.build(K.lite_spo)
    h = np.asarray(K.lite_spo)
    enc = K.kb.tbox.properties
    (plo, phi), _ = enc.interval_of("memberOf")
    r0, r1 = idx.p_range(plo, phi)
    assert r1 - r0 == int(((h[:, 1] >= plo) & (h[:, 1] < phi)).sum())
    got = np.asarray(idx.pos_rows)[r0:r1]
    want = h[(h[:, 1] >= plo) & (h[:, 1] < phi)]
    assert {tuple(r) for r in got.tolist()} == {tuple(r) for r in want.tolist()}

    tid = int(K.dtb.rdf_type_id)
    (clo, chi), _ = K.kb.tbox.concepts.interval_of("Professor")
    r0, r1 = idx.po_range(tid, clo, chi)
    want_n = int(((h[:, 1] == tid) & (h[:, 2] >= clo) & (h[:, 2] < chi)).sum())
    assert r1 - r0 == want_n


def test_variable_predicate_uses_spo_osp(lubm_kb):
    """(s ?p ?y) / (?x ?p o) patterns slice the SPO/OSP permutations instead
    of falling back to full scans — and agree with the scan oracle."""
    K, _ = lubm_kb
    rows, _ = K.query([Pattern("?x", "memberOf", "?y")])
    s_id, o_id = int(rows[0][0]), int(rows[0][1])
    for pats, store in (
        ([Pattern(s_id, "?p", "?y")], "spo"),
        ([Pattern("?x", "?p", o_id)], "osp"),
        ([Pattern(s_id, "?p", o_id)], "spo"),  # both const: SPO + residual o
    ):
        eng = K.engine("litemat")
        sigs, *_ = eng._plan(pats, None)
        assert sigs[0].strategy == "slice" and sigs[0].store == store, pats
        for mode in ("litemat", "full"):
            assert (K.answers(pats, mode=mode, use_index=True)
                    == K.answers(pats, mode=mode, use_index=False)), pats
        assert len(K.answers(pats)) > 0, pats


def test_spo_osp_range_lookups(lubm_kb):
    """SPO/OSP primary ranges agree with brute-force selection."""
    K, _ = lubm_kb
    idx = StoreIndex.build(K.lite_spo)
    h = np.asarray(K.lite_spo)
    s_id = int(h[0, 0])
    r0, r1 = idx.s_range(s_id, s_id + 1)
    assert r1 - r0 == int((h[:, 0] == s_id).sum())
    got = np.asarray(idx.perm("spo").rows)[r0:r1]
    want = h[h[:, 0] == s_id]
    assert {tuple(r) for r in got.tolist()} == {tuple(r) for r in want.tolist()}
    o_id = int(h[0, 2])
    r0, r1 = idx.o_range(o_id, o_id + 1)
    assert r1 - r0 == int((h[:, 2] == o_id).sum())


def test_prewarm_removes_cold_start(lubm_kb):
    """After prewarm, the first run of each query compiles nothing new."""
    K, _ = lubm_kb
    eng = QueryEngine(kb=K.kb, spo=K.lite_spo, mode="litemat", dtb=K.dtb)
    queries = list(PAPER_QUERIES.values())
    n = eng.prewarm(queries, buckets=(4096,))
    assert n >= len(queries)  # at least one executable per query
    misses = eng.cache_stats["misses"]
    for pats in queries:
        eng.run(pats)
    assert eng.cache_stats["misses"] == misses  # all warm: zero retraces


def test_capacity_overflow_retry(lubm_kb, monkeypatch):
    """Tiny initial buckets force the overflow/double/retry path; answers
    must be unchanged and at least one extra executable must be compiled."""
    K, _ = lubm_kb
    want = K.answers(PAPER_QUERIES["Q1"])
    eng = QueryEngine(kb=K.kb, spo=K.lite_spo, mode="litemat", dtb=K.dtb)
    monkeypatch.setattr(QueryEngine, "_bucket", staticmethod(lambda n: 32))
    rows, sel = eng.run(PAPER_QUERIES["Q1"], max_retries=10)
    got = {tuple(r) for r in rows.tolist()}
    assert got == want
    n_exec = sum(1 for k in eng._exec_cache if k[0] == "exec")
    assert n_exec >= 2  # first bucket overflowed, retry compiled a bigger one


def test_plan_cache_reuse(lubm_kb):
    """Same query twice -> cache hit; same signature with a different
    constant (parameterized query) -> cache hit, no retrace."""
    K, _ = lubm_kb
    eng = QueryEngine(kb=K.kb, spo=K.lite_spo, mode="litemat", dtb=K.dtb)
    eng.run([Pattern("?x", "memberOf", "?y")])
    misses_after_first = eng.cache_stats["misses"]
    eng.run([Pattern("?x", "memberOf", "?y")])
    assert eng.cache_stats["misses"] == misses_after_first
    assert eng.cache_stats["hits"] >= 1
    # different property, same signature: hits as long as buckets coincide
    eng.run([Pattern("?x", "worksFor", "?y")])
    eng.run([Pattern("?x", "worksFor", "?y")])
    assert eng.cache_stats["hits"] >= 2
