"""Incremental updates: insert/delete/compact must equal full rebuilds
AND an independent naive oracle.

Two contracts under test:

  * rebuild equivalence — any sequence of ``insert`` / ``delete`` /
    ``compact`` operations yields query answers identical, in fingerprint
    space (instance ids are rank-assigned, so only fingerprints survive a
    re-encode), to ``KnowledgeBase.build`` on the final triple set, across
    all three execution modes and both execution strategies;
  * differential oracle — after EVERY step, answers match
    :class:`tests.oracle.NaiveKB`, a set-semantics brute-force RDFS
    reference sharing no code with the engine.  The rebuild comparison
    cannot see a bug both pipelines share (same encoders, materializers,
    query engine); the oracle can.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from oracle import NaiveKB, query_vars

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.query import Pattern
from repro.core.tbox import Ontology
from repro.rdf.generator import RawDataset, generate_lubm, generate_random_abox
from repro.utils import pair64

MODES = ("litemat", "full", "rewrite")


def answers_fp(K: KnowledgeBase, patterns, mode="litemat", use_index=True,
               select=None):
    """Query answers with ids mapped back to term fingerprints.

    TBox ids (hit=False only for padding; concepts/properties resolve too)
    are stable across rebuilds, but instance ids are rank-assigned — the
    fingerprint is the identity that survives a re-encode.
    """
    rows, _ = K.query(patterns, mode=mode, use_index=use_index, select=select)
    if rows.size == 0:
        return set()
    ids = jnp.asarray(rows.reshape(-1).astype(np.int32))
    hi, lo, hit = K.kb.table.extract_fp(ids)
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    fps = np.where(np.asarray(hit), fps, rows.reshape(-1))
    return {tuple(r) for r in fps.reshape(rows.shape).tolist()}


def _remove_triples(s, p, o, deleted: set):
    keep = np.array(
        [(a, b, c) not in deleted
         for a, b, c in zip(s.tolist(), p.tolist(), o.tolist())], dtype=bool)
    return s[keep], p[keep], o[keep]


def _dag_onto(seed: int) -> Ontology:
    rng = np.random.default_rng(seed)
    nc, npr = int(rng.integers(5, 10)), int(rng.integers(2, 5))
    concepts = [f"C{i}" for i in range(nc)]
    props = [f"p{i}" for i in range(npr)]
    subclass = [(concepts[i], concepts[int(rng.integers(0, i))])
                for i in range(1, nc)]
    # occasionally a second parent: exercises spill intervals under updates
    if nc > 4:
        subclass.append((concepts[nc - 1], concepts[1]))
    subprop = [(props[i], props[int(rng.integers(0, i))])
               for i in range(1, npr)]
    domain = {props[0]: [concepts[1]]} if rng.random() < 0.7 else {}
    range_ = {props[-1]: [concepts[2]]} if rng.random() < 0.7 else {}
    return Ontology(concepts=concepts, properties=props, subclass=subclass,
                    subprop=subprop, domain=domain, range_=range_)


def _queries(onto):
    return [
        [Pattern("?x", "rdf:type", onto.concepts[0])],
        [Pattern("?x", "rdf:type", onto.concepts[1])],
        [Pattern("?x", onto.properties[0], "?y")],
        [Pattern("?x", "rdf:type", onto.concepts[0]),
         Pattern("?x", onto.properties[0], "?y")],
    ]


def _check_against_oracle(K, naive, queries, seed, step, modes=MODES):
    """Engine answers (fp space) == NaiveKB answers, every query and mode."""
    for q in queries:
        sel = query_vars(q)
        want = naive.answers(q, sel)
        for mode in modes:
            got = answers_fp(K, q, mode=mode, select=sel)
            assert got == want, (seed, step, mode, q, len(got ^ want))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_randomized_update_sequence_equals_rebuild(seed):
    """Random insert/delete/compact sequences == rebuild on the final set,
    and == the naive differential oracle after EVERY step.

    Runs at 10x the original triple counts: the oracle maintains its
    closure incrementally (refcounted per-triple derivations), so the
    differential check after every step stays O(delta) instead of
    re-deriving the whole closure per step.
    """
    rng = np.random.default_rng(seed)
    onto = _dag_onto(seed)
    raw = generate_random_abox(onto, n_instances=400, n_type_triples=600,
                               n_prop_triples=500, seed=seed)
    K = KnowledgeBase.build(raw)
    naive = NaiveKB(onto)
    naive.insert(raw)
    cur_s, cur_p, cur_o = raw.s.copy(), raw.p.copy(), raw.o.copy()

    for step in range(4):
        op = rng.choice(["insert", "delete", "compact"], p=[0.5, 0.35, 0.15])
        if op == "insert":
            extra = generate_random_abox(
                onto, n_instances=int(rng.integers(100, 600)),
                n_type_triples=int(rng.integers(50, 400)),
                n_prop_triples=int(rng.integers(50, 400)),
                seed=1000 * seed + step)
            K.insert(extra, auto_compact=False)
            naive.insert(extra)
            cur_s = np.concatenate([cur_s, extra.s])
            cur_p = np.concatenate([cur_p, extra.p])
            cur_o = np.concatenate([cur_o, extra.o])
        elif op == "delete":
            n = cur_s.shape[0]
            idx = rng.choice(n, size=max(n // 10, 1), replace=False)
            K.delete((cur_s[idx], cur_p[idx], cur_o[idx]), auto_compact=False)
            naive.delete((cur_s[idx], cur_p[idx], cur_o[idx]))
            deleted = set(zip(cur_s[idx].tolist(), cur_p[idx].tolist(),
                              cur_o[idx].tolist()))
            cur_s, cur_p, cur_o = _remove_triples(cur_s, cur_p, cur_o, deleted)
        else:
            K.compact()
            naive.compact()
        # the differential check runs after EVERY step — rebuild-only
        # comparison happens once at the end and shares the engine code
        _check_against_oracle(K, naive, _queries(onto)[:2], seed, step)

    _check_against_oracle(K, naive, _queries(onto), seed, "final")
    rebuilt = KnowledgeBase.build(
        RawDataset(s=cur_s, p=cur_p, o=cur_o, onto=onto))
    for q in _queries(onto):
        for mode in MODES:
            got = answers_fp(K, q, mode=mode)
            want = answers_fp(rebuilt, q, mode=mode)
            assert got == want, (seed, mode, q, len(got ^ want))
    # the scan path over the live store must agree with the sliced path
    q = _queries(onto)[0]
    assert answers_fp(K, q, use_index=False) == answers_fp(K, q)


@pytest.fixture(scope="module")
def lubm_pair():
    """A small LUBM KB grown incrementally + its final-state rebuild oracle."""
    base = generate_lubm(1, seed=11, literals=False)
    delta = generate_lubm(1, seed=13, literals=False, univ_offset=1)
    K = KnowledgeBase.build(base)
    K.insert(delta, auto_compact=False)
    # delete a slice of the base (every 9th triple) post-insert
    idx = np.arange(0, base.n_triples, 9)
    K.delete((base.s[idx], base.p[idx], base.o[idx]), auto_compact=False)

    deleted = set(zip(base.s[idx].tolist(), base.p[idx].tolist(),
                      base.o[idx].tolist()))
    s1, p1, o1 = _remove_triples(base.s, base.p, base.o, deleted)
    s2, p2, o2 = _remove_triples(delta.s, delta.p, delta.o, deleted)
    oracle = KnowledgeBase.build(RawDataset(
        s=np.concatenate([s1, s2]), p=np.concatenate([p1, p2]),
        o=np.concatenate([o1, o2]), onto=base.onto))
    return K, oracle


def test_lubm_paper_queries_after_updates(lubm_pair):
    """Q1-Q4 in all modes: incrementally updated KB == final-state rebuild."""
    K, oracle = lubm_pair
    for qn, pats in PAPER_QUERIES.items():
        for mode in MODES:
            got = answers_fp(K, pats, mode=mode)
            want = answers_fp(oracle, pats, mode=mode)
            assert got == want, (qn, mode, len(got), len(want))
            assert len(got) > 0, (qn, mode)


def test_lubm_compact_preserves_answers(lubm_pair):
    """Compaction (sorted-merge fold) must not change any Q1-Q4 answer."""
    K, _ = lubm_pair
    before = {qn: answers_fp(K, pats) for qn, pats in PAPER_QUERIES.items()}
    st = K.compact()
    assert st["compacted"]
    assert K.delta.empty if K._delta is not None else True
    for qn, pats in PAPER_QUERIES.items():
        assert answers_fp(K, pats) == before[qn], qn


def test_dictionary_growth_in_place():
    """New terms get ids past n_instance_terms; existing ids never move."""
    onto = _dag_onto(5)
    raw = generate_random_abox(onto, n_instances=30, n_type_triples=40,
                               n_prop_triples=30, seed=5)
    K = KnowledgeBase.build(raw)
    base = K.kb.tbox.instance_base
    n_before = K.kb.n_instance_terms
    old_spo = np.asarray(K.kb.spo).copy()

    extra = generate_random_abox(onto, n_instances=90, n_type_triples=50,
                                 n_prop_triples=20, seed=99)
    st = K.insert(extra, auto_compact=False)
    assert st["n_new_terms"] > 0
    assert K.kb.n_instance_terms == n_before + st["n_new_terms"]
    # base store untouched, new rows only reference ids below the new ceiling
    np.testing.assert_array_equal(np.asarray(K.kb.spo), old_spo)
    delta_rows = K.delta.log("rewrite").rows
    assert delta_rows[:, 0].max() < base + K.kb.n_instance_terms
    assert (delta_rows >= 0).all()
    # locate/extract round-trips through the grown dictionary
    new_ids = np.unique(delta_rows[:, 0])
    new_ids = new_ids[new_ids >= base + n_before]
    assert new_ids.size > 0
    hi, lo, hit = K.kb.table.extract_fp(jnp.asarray(new_ids.astype(np.int32)))
    assert np.asarray(hit).all()
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    ids2, _ = K.kb.table.locate(
        *map(jnp.asarray, pair64.split_np(fps)))
    np.testing.assert_array_equal(np.asarray(ids2), new_ids)


def test_insert_rejects_unknown_predicates():
    onto = _dag_onto(6)
    raw = generate_random_abox(onto, n_instances=20, n_type_triples=30,
                               n_prop_triples=20, seed=6)
    K = KnowledgeBase.build(raw)
    from repro.utils.hashing import fingerprint_string

    s = np.array([fingerprint_string("inst:new")], dtype=np.int64)
    p = np.array([fingerprint_string("notAProperty")], dtype=np.int64)
    with pytest.raises(ValueError, match="TBox property map"):
        K.insert((s, p, s.copy()))


def test_auto_compaction_threshold():
    """Past the delta-ratio threshold an insert folds the overlay itself."""
    onto = _dag_onto(7)
    raw = generate_random_abox(onto, n_instances=40, n_type_triples=50,
                               n_prop_triples=40, seed=7)
    K = KnowledgeBase.build(raw)
    K.compact_threshold = 0.05  # tiny: first real insert must trigger
    extra = generate_random_abox(onto, n_instances=30, n_type_triples=25,
                                 n_prop_triples=20, seed=70)
    before = answers_fp(K, _queries(onto)[0])
    st = K.insert(extra)
    assert st.get("compacted", {}).get("compacted") is True
    assert K._delta is None or K.delta.empty
    after = answers_fp(K, _queries(onto)[0])
    assert after >= before  # inserts only grow the answer set


def test_version_counter_monotonic():
    onto = _dag_onto(8)
    raw = generate_random_abox(onto, n_instances=20, n_type_triples=30,
                               n_prop_triples=20, seed=8)
    K = KnowledgeBase.build(raw)
    assert K.version == 0
    extra = generate_random_abox(onto, n_instances=10, n_type_triples=10,
                                 n_prop_triples=5, seed=80)
    K.insert(extra, auto_compact=False)
    v1 = K.version
    assert v1 == 1
    K.delete((extra.s[:3], extra.p[:3], extra.o[:3]), auto_compact=False)
    v2 = K.version
    assert v2 > v1
    K.compact()
    assert K.version > v2
    # deleting absent triples is a no-op and must NOT bump the version
    missing = np.array([123456789], dtype=np.int64)
    st = K.delete((missing, missing, missing))
    assert st["n_deleted"] == 0 and K.version == v2 + 1


def test_serving_resyncs_on_update():
    """QueryServer picks up inserts/deletes with no invalidate() call."""
    from repro.serving.engine import QueryServer

    onto = _dag_onto(9)
    raw = generate_random_abox(onto, n_instances=40, n_type_triples=60,
                               n_prop_triples=30, seed=9)
    K = KnowledgeBase.build(raw)
    srv = QueryServer(K, topk=8)
    c0, _ = srv.class_members([onto.concepts[0]])
    extra = generate_random_abox(onto, n_instances=120, n_type_triples=60,
                                 n_prop_triples=10, seed=90)
    K.insert(extra, auto_compact=False)
    c1, _ = srv.class_members([onto.concepts[0]])
    oracle = len(K.answers([Pattern("?x", "rdf:type", onto.concepts[0])]))
    assert int(c1[0]) == oracle
    assert int(c1[0]) > int(c0[0])
    # deletes propagate too (tombstones must be dropped from the snapshot)
    K.delete((extra.s, extra.p, extra.o), auto_compact=False)
    c2, _ = srv.class_members([onto.concepts[0]])
    oracle2 = len(K.answers([Pattern("?x", "rdf:type", onto.concepts[0])]))
    assert int(c2[0]) == oracle2 == int(c0[0])


@given(st.integers(0, 10_000), st.integers(2, 5), st.booleans())
@settings(max_examples=8, deadline=None)
def test_update_sequence_property(seed, n_steps, compact_mid):
    """Hypothesis-randomized sequences vs the naive differential oracle.

    10x the original triple counts (the memoized oracle keeps the
    per-step differential check O(delta))."""
    rng = np.random.default_rng(seed)
    onto = _dag_onto(seed % 97)
    raw = generate_random_abox(onto, n_instances=250, n_type_triples=350,
                               n_prop_triples=250, seed=seed % 89)
    K = KnowledgeBase.build(raw)
    naive = NaiveKB(onto)
    naive.insert(raw)
    cur_s, cur_p, cur_o = raw.s.copy(), raw.p.copy(), raw.o.copy()
    for step in range(n_steps):
        if rng.random() < 0.6:
            extra = generate_random_abox(
                onto, n_instances=int(rng.integers(50, 300)),
                n_type_triples=int(rng.integers(30, 200)),
                n_prop_triples=int(rng.integers(30, 200)),
                seed=int(rng.integers(0, 1 << 30)))
            K.insert(extra, auto_compact=False)
            naive.insert(extra)
            cur_s = np.concatenate([cur_s, extra.s])
            cur_p = np.concatenate([cur_p, extra.p])
            cur_o = np.concatenate([cur_o, extra.o])
        else:
            n = cur_s.shape[0]
            idx = rng.choice(n, size=max(n // 8, 1), replace=False)
            K.delete((cur_s[idx], cur_p[idx], cur_o[idx]), auto_compact=False)
            naive.delete((cur_s[idx], cur_p[idx], cur_o[idx]))
            deleted = set(zip(cur_s[idx].tolist(), cur_p[idx].tolist(),
                              cur_o[idx].tolist()))
            cur_s, cur_p, cur_o = _remove_triples(cur_s, cur_p, cur_o, deleted)
        if compact_mid and step == n_steps // 2:
            K.compact()
            naive.compact()
    _check_against_oracle(K, naive, _queries(onto)[:2], seed, "property")


def test_lazy_materialization_per_mode():
    """Single-mode service skips the other mode's delta derivation.

    Inserts queue raw rows only; serving 'litemat' derives lite rows and
    must NOT run the full closure (and vice versa) — the regression pin for
    lazy per-mode delta materialization.
    """
    onto = _dag_onto(11)
    raw = generate_random_abox(onto, n_instances=30, n_type_triples=40,
                               n_prop_triples=30, seed=11)
    K = KnowledgeBase.build(raw)
    naive = NaiveKB(onto)
    naive.insert(raw)
    extra = generate_random_abox(onto, n_instances=40, n_type_triples=25,
                                 n_prop_triples=20, seed=12)
    K.insert(extra, auto_compact=False)
    naive.insert(extra)
    assert K.mat_counts == {"litemat": 0, "full": 0}

    _check_against_oracle(K, naive, _queries(onto)[:2], 11, "lite-only",
                          modes=("litemat",))
    assert K.mat_counts["litemat"] == 1
    assert K.mat_counts["full"] == 0  # full closure never ran

    # a second single-mode insert + query still leaves 'full' underived
    K.insert(generate_random_abox(onto, n_instances=10, n_type_triples=8,
                                  n_prop_triples=6, seed=13),
             auto_compact=False)
    answers_fp(K, _queries(onto)[0], mode="litemat")
    assert K.mat_counts["full"] == 0

    # first 'full' service derives the whole backlog, answers correct
    got = answers_fp(K, _queries(onto)[0], mode="full",
                     select=query_vars(_queries(onto)[0]))
    assert K.mat_counts["full"] > 0
    naive.insert(generate_random_abox(onto, n_instances=10, n_type_triples=8,
                                      n_prop_triples=6, seed=13))
    assert got == naive.answers(_queries(onto)[0],
                                query_vars(_queries(onto)[0]))
