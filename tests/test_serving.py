"""QueryServer: index-sliced batched plans + view invalidation."""
import numpy as np

import jax.numpy as jnp

from repro.core.query import Pattern
from repro.serving.engine import QueryServer


def test_class_members_matches_oracle(lubm_kb):
    K, _ = lubm_kb
    srv = QueryServer(K, topk=8)
    classes = ["Professor", "Student", "Department", "Chair"]
    counts, members = srv.class_members(classes)
    for name, cnt, mem in zip(classes, counts, members):
        oracle = {r[0] for r in K.answers([Pattern("?x", "rdf:type", name)])}
        assert int(cnt) == len(oracle), name
        got = {int(v) for v in mem if v >= 0}
        assert got <= oracle
        assert len(got) == min(8, len(oracle))


def test_views_invalidate_on_store_change(lubm_kb):
    """_views snapshot the store; invalidate() must rebuild them."""
    K, _ = lubm_kb
    srv = QueryServer(K, topk=8)
    before, _ = srv.class_members(["Professor"])
    assert int(before[0]) > 0

    old_store = K.lite_spo
    try:
        keep = np.asarray(old_store[:, 1] != K.dtb.rdf_type_id)
        K.lite_spo = jnp.asarray(np.asarray(old_store)[keep])
        stale, _ = srv.class_members(["Professor"])
        assert int(stale[0]) == int(before[0])  # snapshot: still the old view
        srv.invalidate()
        fresh, _ = srv.class_members(["Professor"])
        assert int(fresh[0]) == 0  # no type triples left
    finally:
        K.lite_spo = old_store
        srv.invalidate()


def test_spill_intervals_in_serving():
    """Multi-parent concepts get spill intervals; the server must include
    them (the QueryEngine oracle does)."""
    from repro.core.engine import KnowledgeBase
    from repro.core.tbox import Ontology
    from repro.rdf.generator import generate_random_abox

    onto = Ontology(
        concepts=["A", "B", "C", "D"], properties=["p0"],
        subclass=[("C", "A"), ("C", "B"), ("D", "B")],  # C has two parents
        subprop=[], domain={}, range_={},
    )
    raw = generate_random_abox(onto, n_instances=30, n_type_triples=60,
                               n_prop_triples=20, seed=3)
    K = KnowledgeBase.build(raw)
    srv = QueryServer(K, topk=32)
    names = ["A", "B", "C", "D"]
    counts, members = srv.class_members(names)
    for name, cnt, mem in zip(names, counts, members):
        oracle = {r[0] for r in K.answers([Pattern("?x", "rdf:type", name)])}
        assert int(cnt) == len(oracle), (name, int(cnt), len(oracle))
        assert {int(v) for v in mem if v >= 0} <= oracle
    cj, _ = srv.class_prop_join(["B"], ["p0"])
    oracle = K.answers([Pattern("?x", "rdf:type", "B"),
                        Pattern("?x", "p0", "?y")], select=("?x",))
    assert int(cj[0]) == len(oracle)


def test_empty_class_batch(lubm_kb):
    """Classes with no (or few) instances keep the slice machinery sane."""
    K, _ = lubm_kb
    srv = QueryServer(K, topk=4)
    counts, members = srv.class_members(["Department", "Department"])
    assert int(counts[0]) == int(counts[1])
    assert (np.asarray(members) >= -1).all()
