"""Query completeness: lite == full == rewrite (the paper's own check)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.query import Pattern
from repro.core.tbox import Ontology
from repro.rdf.generator import generate_random_abox


def test_paper_queries_complete(lubm_kb):
    K, _ = lubm_kb
    for qn, pats in PAPER_QUERIES.items():
        res = {m: K.answers(pats, mode=m) for m in ("litemat", "full", "rewrite")}
        assert res["litemat"] == res["full"] == res["rewrite"], qn
        assert len(res["litemat"]) > 0, f"{qn} should not be empty"


def test_q1_professor_counts(lubm_kb):
    """Q1 must include all Professor subsumees but exclude e.g. Lecturers."""
    K, _ = lubm_kb
    profs = K.answers(PAPER_QUERIES["Q1"])
    full_prof = K.answers([Pattern("?x", "rdf:type", "FullProfessor")])
    lect = K.answers([Pattern("?x", "rdf:type", "Lecturer")])
    assert full_prof <= profs
    assert not (lect & profs)


def test_q4_chair_is_derived_only(lubm_kb):
    """No explicit Chair triples exist; Chair answers come from domain(headOf)
    (lite/full) or the domain-aware rewriting (the paper's Q4' observation)."""
    K, _ = lubm_kb
    raw_engine = K.engine("rewrite")
    chairs = K.answers([Pattern("?x", "rdf:type", "Chair")], mode="litemat")
    assert len(chairs) > 0
    # the raw store has no explicit triple with the Chair id as object
    cid = K.kb.tbox.concept_id("Chair")
    spo = np.asarray(K.kb.spo)
    tmask = spo[:, 1] == K.kb.tbox.rdf_type_id
    assert not (spo[tmask, 2] == cid).any()
    # and the rewrite engine still finds them (via ?x headOf ?y)
    assert K.answers([Pattern("?x", "rdf:type", "Chair")], mode="rewrite") == chairs


def test_property_hierarchy_query(lubm_kb):
    """?x worksFor ?y must be included in ?x memberOf ?y (subproperty)."""
    K, _ = lubm_kb
    member = K.answers([Pattern("?x", "memberOf", "?y")])
    works = K.answers([Pattern("?x", "worksFor", "?y")])
    head = K.answers([Pattern("?x", "headOf", "?y")])
    assert works <= member
    assert head <= works


def test_join_on_object_position(lubm_kb):
    """Object-object / subject-object joins: advisor's department."""
    K, _ = lubm_kb
    pats = [
        Pattern("?s", "advisor", "?prof"),
        Pattern("?prof", "worksFor", "?dept"),
    ]
    res = {m: K.answers(pats, select=("?s", "?dept"), mode=m)
           for m in ("litemat", "full", "rewrite")}
    assert res["litemat"] == res["full"] == res["rewrite"]
    assert len(res["litemat"]) > 100


def test_inl_join_fallback_matches_merge_join(lubm_kb):
    """Q4-style tiny-side joins: INL probe plan == merge-join plan.

    The planner must actually convert Q4's dominant pattern (worksFor,
    ~40x the Chair count) to an index-nested-loop probe of the PSO
    permutation, and the answers must be identical to the merge-join plan
    with INL disabled.
    """
    K, _ = lubm_kb
    for mode in ("litemat", "full"):
        eng = K.engine(mode)
        sigs, *_ = eng._plan(PAPER_QUERIES["Q4"], None)
        assert any(s.strategy == "inl" for s in sigs), mode
        got = K.answers(PAPER_QUERIES["Q4"], mode=mode)
        eng.use_inl = False
        try:
            rows, _ = eng.run(PAPER_QUERIES["Q4"])
        finally:
            eng.use_inl = True
        assert got == {tuple(r) for r in rows.tolist()}
        assert len(got) > 0


def test_inl_join_object_probe(lubm_kb):
    """Constant-object probes take the POS permutation (o is the bound var)."""
    K, _ = lubm_kb
    pats = [Pattern("?x", "rdf:type", "Chair"),
            Pattern("?s", "advisor", "?x")]
    eng = K.engine("litemat")
    sigs, *_ = eng._plan(pats, None)
    inl = [s for s in sigs if s.strategy == "inl"]
    assert inl and inl[0].store == "pos" and inl[0].probe_pos == 2
    got = K.answers(pats, mode="litemat")
    eng.use_inl = False
    try:
        rows, _ = eng.run(pats)
    finally:
        eng.use_inl = True
    assert got == {tuple(r) for r in rows.tolist()}


def test_rewrite_dual_branch_is_one_pass(lubm_kb):
    """(?x rdf:type Person) has dom AND rng branches: ONE fused member pass.

    Person entails through domain properties (memberOf, advisor, ...) and
    range properties (member, publicationAuthor) — the dual-branch shape
    the fused member-compaction kernel resolves in one grid pass per
    source, with the member/domain/range id sets resident on-chip instead
    of materialized as full-store masks.  The trace-time pass counters pin
    it: >= 1 member pass, zero mask-based dual passes, and at most the
    single pass DISTINCT's dedup owns; answers stay equal to litemat.
    """
    from repro.core.query import QueryEngine
    from repro.kernels import ops

    K, _ = lubm_kb
    q = [Pattern("?x", "rdf:type", "Person")]
    want = K.answers(q, mode="litemat")
    eng = QueryEngine(kb=K.kb, spo=K.kb.spo, mode="rewrite", dtb=K.dtb)
    ops.compact_indices.clear_cache()
    ops.dual_compact_indices.clear_cache()
    ops.rewrite_member_compact.clear_cache()
    ops.reset_pass_counters()
    rows, _ = eng.run(q)
    assert ops.pass_counters["member_compact"] >= 1
    assert ops.pass_counters["dual_compact"] == 0, ops.pass_counters
    assert ops.pass_counters["compact"] <= 1, ops.pass_counters
    assert {tuple(r) for r in rows.tolist()} == want
    assert len(want) > 0


@st.composite
def dag_onto(draw):
    nc = draw(st.integers(4, 10))
    concepts = [f"C{i}" for i in range(nc)]
    edges = []
    for i in range(1, nc):
        for p in draw(st.lists(st.integers(0, i - 1), min_size=1, max_size=2,
                               unique=True)):
            edges.append((concepts[i], concepts[p]))
    return Ontology(concepts=concepts, properties=["p0", "p1"], subclass=edges,
                    subprop=[("p1", "p0")], domain={}, range_={}), draw(st.integers(0, 999))


@given(dag_onto())
@settings(max_examples=10, deadline=None)
def test_completeness_on_random_dags(spec):
    """Multiple-inheritance ontologies: spill intervals keep queries complete."""
    onto, seed = spec
    raw = generate_random_abox(onto, n_instances=40, n_type_triples=60,
                               n_prop_triples=30, seed=seed)
    K = KnowledgeBase.build(raw)
    for cname in onto.concepts[: min(len(onto.concepts), 6)]:
        pats = [Pattern("?x", "rdf:type", cname)]
        res = {m: K.answers(pats, mode=m) for m in ("litemat", "full", "rewrite")}
        assert res["litemat"] == res["full"] == res["rewrite"], cname
