"""Device-side hash-repartition joins: parity, skew, faults, cursor pins.

The cross-group combine used to all-gather per-shard relations to the host
and fold them there (`combine_groups` + `_host_relation` re-upload).  The
repartition path hashes the join key, exchanges capacity-padded partitions
(all-to-all under shard_map; an axis swap on the emulated dispatch path),
and joins shard-locally — intermediate relations never leave devices.  The
contract pinned here: rows bit-identical to the host fold AND the
single-device engine, zero host re-uploads on the device combine, graceful
degradation to the host fold on exchange faults, and survival of the
`pin_version` cursor path across concurrent retirement.
"""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.query import Pattern
from repro.core.shard import ShardedKB, assert_partitioned
from repro.core.snapshot import SnapshotRegistry
from repro.core.tbox import RDF_TYPE, Ontology
from repro.kernels import ops
from repro.obs.metrics import REGISTRY
from repro.rdf.generator import generate_random_abox
from repro.testing import faults
from repro.testing.faults import FaultCrash, FaultError
from repro.utils.hashing import fingerprint_string

MODES = ("litemat", "full", "rewrite")


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    yield
    faults.uninstall()


@pytest.fixture(scope="module", autouse=True)
def _free_compiled_state():
    """Drop this module's compiled executables when it finishes.

    The parity matrix (4 queries x 3 modes x device/host combine, plus the
    skew and update sweeps) JITs a few hundred executables; leaving them
    resident pushes the process's accumulated XLA state high enough that a
    compile much later in the full tier-1 run can crash the CPU backend.
    Later modules just recompile what they need.
    """
    yield
    import jax

    jax.clear_caches()


def _sel(patterns):
    return tuple(dict.fromkeys(
        v for p in patterns for v in (p.s, p.p, p.o)
        if isinstance(v, str) and v.startswith("?")))


def _repartition_engine(S, mode):
    """Force the device combine on the dispatch-loop path (1-device CI)."""
    eng = S.engine(mode)
    eng.use_shard_map = False
    eng.use_repartition_join = True
    return eng


@pytest.fixture(scope="module")
def sharded_pair(lubm_kb):
    K, raw = lubm_kb
    return K, ShardedKB.build(raw, n_shards=4), raw


# ---------------------------------------------------------------------------
# bit-identical parity: repartition == host fold == single-device engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_repartition_matches_host_fold_and_single(sharded_pair, mode):
    K, S, _ = sharded_pair
    eng = _repartition_engine(S, mode)
    runs0 = eng.cache_stats["repartition_runs"]
    for qn, pats in PAPER_QUERIES.items():
        want, wsel = K.query(pats, mode=mode)
        got, gsel = eng.run(pats)
        assert gsel == wsel and np.array_equal(np.asarray(got), want), (
            mode, qn)
        eng.use_repartition_join = False
        try:
            host, hsel = eng.run(pats)
        finally:
            eng.use_repartition_join = True
        assert hsel == gsel and np.array_equal(np.asarray(host),
                                               np.asarray(got)), (mode, qn)
    # at least one paper query per mode is multi-group (Q4's ?y join), so
    # the device combine must actually have run — not silently degraded
    assert eng.cache_stats["repartition_runs"] > runs0
    assert eng.cache_stats["exchange_faults"] == 0


def test_single_group_queries_skip_repartition(sharded_pair):
    _, S, _ = sharded_pair
    eng = _repartition_engine(S, "litemat")
    runs0 = eng.cache_stats["repartition_runs"]
    host0 = REGISTRY.counter("shard/combine_runs", path="host").value
    eng.run(PAPER_QUERIES["Q1"])  # one subject-keyed group: host path
    assert eng.cache_stats["repartition_runs"] == runs0
    assert REGISTRY.counter("shard/combine_runs", path="host").value > host0


def test_device_combine_makes_zero_host_uploads(sharded_pair):
    """The acceptance pin: Q4's cross-group join runs with NO host gather.

    `_host_relation` (the host fold's re-upload of the folded relation)
    meters every upload through `device/transfer_bytes{src=combine_upload}`;
    the repartition combine must leave that counter untouched while the
    host fold provably moves it — same query, same engine, same store.
    """
    _, S, _ = sharded_pair
    eng = _repartition_engine(S, "litemat")
    c = REGISTRY.counter("device/transfer_bytes", src="combine_upload")
    before = c.value
    rows, _ = eng.run(PAPER_QUERIES["Q4"])
    assert rows.shape[0] > 0
    assert c.value == before, "device combine leaked a host re-upload"
    eng.use_repartition_join = False
    try:
        eng.run(PAPER_QUERIES["Q4"])
    finally:
        eng.use_repartition_join = True
    assert c.value > before, "host fold should meter its uploads"


# ---------------------------------------------------------------------------
# skewed join keys: one shard owns ~90% of the exchanged rows
# ---------------------------------------------------------------------------


def _skew_onto():
    # no range axiom on p0: range-entailment would type EVERY hot-object
    # row C2, and the hot key's rewrite-mode self-product (hot x hot) blows
    # past the retry budget on the single-device oracle engine too — the
    # skew belongs in the exchange, not in a quadratic join
    return Ontology(
        concepts=["C0", "C1", "C2"], properties=["p0", "p1"],
        subclass=[("C1", "C0"), ("C2", "C0")], subprop=[("p1", "p0")],
        domain={"p0": ["C1"]}, range_={})


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_skewed_join_key_distribution_parity(seed):
    """90% of join keys hash to ONE bin: padding/overflow retries must
    absorb the hot partition without dropping or duplicating rows."""
    onto = _skew_onto()
    raw = generate_random_abox(onto, n_instances=240, n_type_triples=400,
                               n_prop_triples=500, seed=seed)
    rng = np.random.default_rng(seed)
    p0 = fingerprint_string("p0")
    idx = np.where(raw.p == p0)[0]
    assert idx.size > 50
    hot = raw.o[idx[0]]
    n_hot = int(idx.size * 0.9)
    raw.o[rng.permutation(idx)[:n_hot]] = hot
    # the hot instance needs a C2 type so the skewed keys actually join
    raw.s[idx[1]] = hot
    raw.p[idx[1]] = fingerprint_string(RDF_TYPE)
    raw.o[idx[1]] = fingerprint_string("C2")

    K = KnowledgeBase.build(raw)
    S = ShardedKB.build(raw, n_shards=4)
    q = [Pattern("?x", "p0", "?y"), Pattern("?y", "rdf:type", "C2")]
    sel = _sel(q)
    for mode in MODES:
        eng = _repartition_engine(S, mode)
        runs0 = eng.cache_stats["repartition_runs"]
        want, _ = K.query(q, select=sel, mode=mode)
        got, _ = eng.run(q, select=sel)
        assert want.shape[0] > 50, "skewed join should be dense"
        assert np.array_equal(np.asarray(got), want), (seed, mode)
        assert eng.cache_stats["repartition_runs"] > runs0
        assert eng.cache_stats["exchange_faults"] == 0
    assert_partitioned(S)


# ---------------------------------------------------------------------------
# randomized update oracle: mutations keep the device combine bit-identical
# ---------------------------------------------------------------------------


def test_randomized_updates_keep_repartition_parity():
    onto = _skew_onto()
    raw = generate_random_abox(onto, n_instances=200, n_type_triples=300,
                               n_prop_triples=300, seed=5)
    rng = np.random.default_rng(5)
    K = KnowledgeBase.build(raw)
    S = ShardedKB.build(raw, n_shards=4)
    q = [Pattern("?x", "p0", "?y"), Pattern("?y", "rdf:type", "C2")]
    sel = _sel(q)
    for step in range(3):
        op = rng.choice(["insert", "delete", "compact"], p=[0.5, 0.35, 0.15])
        if op == "insert":
            extra = generate_random_abox(
                onto, n_instances=60, n_type_triples=80, n_prop_triples=80,
                seed=100 + step, instance_offset=50_000 * (step + 1))
            K.insert(extra, auto_compact=False)
            S.insert(extra, auto_compact=False)
        elif op == "delete":
            pick = rng.choice(raw.s.shape[0], 30, replace=False)
            batch = (raw.s[pick], raw.p[pick], raw.o[pick])
            K.delete(batch, auto_compact=False)
            S.delete(batch, auto_compact=False)
        else:
            K.compact()
            S.compact()
        mode = MODES[step % 3]
        eng = _repartition_engine(S, mode)
        want, _ = K.query(q, select=sel, mode=mode)
        got, _ = eng.run(q, select=sel)
        assert np.array_equal(np.asarray(got), want), (step, op, mode)
    assert_partitioned(S)


# ---------------------------------------------------------------------------
# exchange faults: degrade to the host fold, never to wrong answers
# ---------------------------------------------------------------------------


def test_exchange_fault_degrades_to_host_fold(sharded_pair):
    K, S, _ = sharded_pair
    eng = _repartition_engine(S, "litemat")
    want = K.answers(PAPER_QUERIES["Q4"], mode="litemat")
    fb0 = REGISTRY.counter("shard/combine_runs", path="host_fallback").value
    with faults.inject() as inj:
        inj.arm("shard.exchange", exc=FaultError, times=1)
        rows, _ = eng.run(PAPER_QUERIES["Q4"])
        assert inj.fired("shard.exchange") == 1
    assert {tuple(r) for r in np.asarray(rows).tolist()} == want
    assert eng.cache_stats["exchange_faults"] == 1
    assert REGISTRY.counter(
        "shard/combine_runs", path="host_fallback").value == fb0 + 1
    # fault exhausted: the next run goes back through the device combine
    runs0 = eng.cache_stats["repartition_runs"]
    rows2, _ = eng.run(PAPER_QUERIES["Q4"])
    assert {tuple(r) for r in np.asarray(rows2).tolist()} == want
    assert eng.cache_stats["repartition_runs"] == runs0 + 1


def test_exchange_hard_crash_propagates(sharded_pair):
    _, S, _ = sharded_pair
    eng = _repartition_engine(S, "litemat")
    with faults.inject() as inj:
        inj.arm("shard.exchange", exc=FaultCrash, times=1)
        with pytest.raises(FaultCrash):
            eng.run(PAPER_QUERIES["Q4"])


# ---------------------------------------------------------------------------
# pin_version after retire: the cursor-continuation regression
# ---------------------------------------------------------------------------


def _tiny_kb():
    onto = _skew_onto()
    raw = generate_random_abox(onto, n_instances=80, n_type_triples=120,
                               n_prop_triples=120, seed=9)
    return KnowledgeBase.build(raw), onto


def test_pin_version_after_retire_degrades_not_errors():
    K, onto = _tiny_kb()
    reg = SnapshotRegistry(K, modes=("litemat",))
    with reg.pin() as pin:
        v0 = pin.version
    extra = generate_random_abox(onto, n_instances=20, n_type_triples=30,
                                 n_prop_triples=30, seed=77,
                                 instance_offset=900_000)
    K.insert(extra, auto_compact=False)
    reg.publish()      # store moved on: v0 is unreferenced and unpublished
    reg.retire()
    assert v0 not in reg.live_versions()
    assert reg.pin_version(v0) is None  # cursor miss -> caller re-pins fresh
    with reg.pin() as fresh:
        assert fresh.version == K.version != v0
        # the degraded cursor is exact at ITS version, just not at v0's
        assert fresh.query([Pattern("?x", "rdf:type", "C0")])[0].shape[0] > 0


def test_pin_version_racing_retire_never_reads_a_dropped_snapshot():
    """A cursor re-pin landing inside retire's victim window must either
    keep the snapshot alive (refs bumped before deletion re-check) or miss
    cleanly — never hand back a Pin onto a deleted snapshot."""
    K, onto = _tiny_kb()
    reg = SnapshotRegistry(K, modes=("litemat",))
    with reg.pin() as pin:
        v0 = pin.version
    extra = generate_random_abox(onto, n_instances=20, n_type_triples=30,
                                 n_prop_triples=30, seed=78,
                                 instance_offset=800_000)
    K.insert(extra, auto_compact=False)
    reg.publish()
    got = {}

    def cursor():
        got["pin"] = reg.pin_version(v0)

    with faults.inject() as inj:
        inj.arm("snapshot.retire", exc=None, delay_s=0.05, times=-1)
        t = threading.Thread(target=cursor)
        # retire picks v0 as a victim, then stalls in the fault window
        # while the cursor races in
        r = threading.Thread(target=reg.retire)
        r.start()
        t.start()
        r.join()
        t.join()
    pin = got["pin"]
    if pin is None:  # the race lost: clean miss, store state intact
        assert v0 not in reg.live_versions()
    else:  # the race won: the snapshot MUST have survived retirement
        assert v0 in reg.live_versions()
        assert pin.version == v0 and pin.stale
        rows, _ = pin.query([Pattern("?x", "rdf:type", "C0")])
        assert rows.shape[0] > 0
        pin.release()
        reg.retire()
        assert v0 not in reg.live_versions()


# ---------------------------------------------------------------------------
# empty-table probes: the lazily-derived ingest store regression
# ---------------------------------------------------------------------------


def test_pair_search_empty_table_returns_zeros():
    """INL probes against a 0-row source (an ingested store keeps ALL rows
    in the delta log, base n=0) must yield empty ranges, not a 0-width
    kernel launch."""
    empty = jnp.zeros((0,), jnp.int32)
    q = jnp.asarray(np.array([3, 7, 11], np.int32))
    got = np.asarray(ops.pair_search(empty, empty, q, q))
    assert np.array_equal(got, np.zeros(3, np.int32))
    got_w = np.asarray(ops.pair_search_windowed(empty, empty, q, q))
    assert np.array_equal(got_w, np.zeros(3, np.int32))


def test_ingested_store_survives_inl_plans():
    """Q4 on an ingested LUBM store (empty base, everything in the rewrite
    delta) used to crash in the resident pair-search kernel."""
    from repro.rdf.generator import generate_lubm
    from repro.utils import pair64

    raw = generate_lubm(1, seed=11)
    n = raw.s.shape[0]
    half = n // 2
    parts = [(raw.s[:half], raw.p[:half], raw.o[:half]),
             (raw.s[half:], raw.p[half:], raw.o[half:])]
    S = ShardedKB.ingest(iter(parts), onto=raw.onto, n_shards=2)
    assert S.shards[0].kb.n == 0  # the shape that broke: all rows in delta
    K = KnowledgeBase.build(raw)

    def answers_fp(kb, pats, mode):
        rows, _ = kb.query(pats, mode=mode)
        if rows.size == 0:
            return set()
        ids = jnp.asarray(np.asarray(rows).reshape(-1).astype(np.int32))
        hi, lo, hit = kb.kb.table.extract_fp(ids)
        fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
        fps = np.where(np.asarray(hit), fps, np.asarray(rows).reshape(-1))
        return {tuple(r) for r in fps.reshape(rows.shape).tolist()}

    for mode in ("litemat", "rewrite"):
        a = answers_fp(S, PAPER_QUERIES["Q4"], mode)
        b = answers_fp(K, PAPER_QUERIES["Q4"], mode)
        assert a == b and len(a) > 0, mode
