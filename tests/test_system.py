"""End-to-end behaviour: pipeline -> engine -> serving, against oracles."""
import numpy as np

from repro.core.engine import PAPER_QUERIES, KnowledgeBase
from repro.core.query import Pattern
from repro.serving.engine import QueryServer


def test_end_to_end_sizes_and_stats(lubm_kb):
    K, raw = lubm_kb
    sizes = K.sizes()
    # lite stays ~= original (paper Table IV), full blows up ~38% (Table V)
    assert abs(sizes["lite"] - sizes["original"]) / sizes["original"] < 0.02
    assert 1.30 < sizes["full"] / sizes["original"] < 1.50
    assert K.lite_stats["n_deleted_explicit"] == 0


def test_server_matches_engine_oracle(lubm_kb):
    K, _ = lubm_kb
    srv = QueryServer(K, topk=16)
    classes = ["Professor", "Student", "Course", "Organization", "Chair"]
    counts, members = srv.class_members(classes)
    for name, cnt, mem in zip(classes, counts, members):
        oracle = K.answers([Pattern("?x", "rdf:type", name)])
        assert cnt == len(oracle), name
        got = {int(v) for v in mem if v >= 0}
        assert got <= {x[0] for x in oracle}

    c2, _ = srv.class_prop_join(["Professor"], ["memberOf"])
    oracle = K.answers(
        [Pattern("?x", "rdf:type", "Professor"), Pattern("?x", "memberOf", "?y")],
        select=("?x",),
    )
    assert c2[0] == len(oracle)


def test_interval_query_equals_union_of_subclass_queries(lubm_kb):
    """The paper's core claim: ONE interval compare == the UNION rewriting."""
    K, _ = lubm_kb
    union = set()
    for sub in ("Professor", "AssistantProfessor", "AssociateProfessor",
                "Chair", "Dean", "FullProfessor", "VisitingProfessor"):
        union |= K.answers([Pattern("?x", "rdf:type", sub)], mode="full")
    interval = K.answers([Pattern("?x", "rdf:type", "Professor")], mode="litemat")
    assert interval == union


def test_semantic_edge_selection(lubm_kb):
    """LiteMat ids as a *graph* feature: selecting edges by property
    subsumption with one interval compare (the GNN-family tie-in)."""
    K, _ = lubm_kb
    spo = np.asarray(K.kb.spo)
    enc = K.kb.tbox.properties
    (lo, hi), _ = enc.interval_of("memberOf")
    sel = spo[(spo[:, 1] >= lo) & (spo[:, 1] < hi)]
    # equals the union over explicit subproperty scans
    ids = {enc.id_of(p) for p in ("memberOf", "worksFor", "headOf")}
    want = spo[np.isin(spo[:, 1], list(ids))]
    assert {tuple(r) for r in sel.tolist()} == {tuple(r) for r in want.tolist()}
    assert len(sel) > 0
