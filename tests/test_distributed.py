"""Multi-device semantics via subprocesses (this process keeps 1 device;
XLA locks the device count at first jax init, so each test spawns a child
with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import subprocess
import sys

import pytest

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _sm  # noqa: F401
    _NEW_JAX = True
except ImportError:
    _NEW_JAX = False

# On jax 0.4.x the repro.utils.jaxcompat shim makes these programs *run*,
# but the check_rep-era shard_map on forced-multi-device CPU is orders of
# magnitude slower — minutes per subprocess — so they are excluded from
# tier-1 there rather than blowing the suite budget.
pytestmark = pytest.mark.skipif(
    not _NEW_JAX,
    reason="multi-device subprocess tests need jax>=0.6 (0.4.x compat path "
           "is functional but too slow for tier-1)")

REPO = "src"


def _run(code: str, devices: int = 8):
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560,
        env={
            "PYTHONPATH": REPO,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
        cwd="/root/repo",
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_dictionary_matches_local():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.utils.jaxcompat import make_mesh, shard_map
from repro.core import dictionary as dct
from repro.utils import pair64

rng = np.random.default_rng(0)
n_shards, per = 8, 64
fps = rng.choice(1 << 50, n_shards * per // 2, replace=False)
occ = rng.choice(fps, n_shards * per)  # duplicated occurrences
hi, lo = pair64.split_np(occ)
mesh = make_mesh((n_shards,), ('d',))
body = dct.sharded_dictionary_fn('d', n_shards, bin_cap=per, base=1000)
f = shard_map(body, mesh=mesh, in_specs=(P('d'), P('d'), P('d')),
              out_specs=dct.sharded_out_specs(), check_vma=False)
ids, table, overflow, counts = f(jnp.asarray(hi), jnp.asarray(lo),
                                 jnp.ones(occ.shape, bool))
ids = np.asarray(ids)
assert int(np.asarray(overflow).sum()) == 0
# bijectivity: same fp -> same id; distinct fps -> distinct ids
m = {}
for f_, i_ in zip(occ.tolist(), ids.tolist()):
    assert i_ >= 1000
    assert m.setdefault(f_, i_) == i_
assert len(set(m.values())) == len(m)
# density: ids cover [1000, 1000 + n_distinct)
vals = sorted(m.values())
assert vals[0] == 1000 and vals[-1] == 1000 + len(m) - 1
print('sharded dictionary OK', len(m))
"""
    )
    assert "sharded dictionary OK" in out


def test_compressed_psum_close_to_mean():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.utils.jaxcompat import make_mesh, shard_map
from repro.distributed.compression import compressed_psum, init_error_state

mesh = make_mesh((8,), ('d',))
g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)).astype(np.float32))
err = jnp.zeros((8, 128), jnp.float32)
f = shard_map(compressed_psum('d'), mesh=mesh, in_specs=(P('d'), P('d')),
              out_specs=(P('d'), P('d')), check_vma=False)
mean, new_err = f(g, err)
want = np.asarray(g).mean(axis=0)
got = np.asarray(mean)[0]
scale = np.abs(np.asarray(g)).max() / 127
assert np.abs(got - want).max() < scale * 1.5, (np.abs(got-want).max(), scale)
print('compressed psum OK')
"""
    )
    assert "compressed psum OK" in out


def test_gpipe_pipeline_matches_sequential():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.distributed.pipeline import make_pipelined_step
from repro.utils.jaxcompat import make_mesh

mesh = make_mesh((4, 2), ('pod', 'data'))
D, M, mb = 16, 6, 4
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(4, D, D)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(M, mb, D)).astype(np.float32))

def apply_fn(W, h):  # one stage = one matmul + gelu
    return jax.nn.gelu(h @ W[0])

pipe = make_pipelined_step(apply_fn, mesh, n_micro=M)
got = np.asarray(jax.jit(pipe)(Ws, x))

ref = np.asarray(x)
for i in range(4):
    ref = jax.nn.gelu(jnp.asarray(ref) @ Ws[i])
    ref = np.asarray(ref)
np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
print('gpipe OK')
""",
    )
    assert "gpipe OK" in out


def test_mini_dryrun_lm_cell():
    """A 2x2x2 'multi-pod' mesh compiles an LM train cell end-to-end and the
    HLO analyzer finds loop-multiplied collectives."""
    out = _run(
        """
import jax
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import analyze_hlo
from repro.utils.jaxcompat import make_mesh
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
cell = build_cell('olmoe-1b-7b', 'train_4k', mesh)
jfn = jax.jit(cell.fn, in_shardings=cell.shardings(mesh))
compiled = jfn.lower(*cell.abstract_args).compile()
a = analyze_hlo(compiled.as_text())
assert a['flops'] > 0 and a['collectives'].get('total', 0) > 0
assert a['collectives'].get('all-to-all', 0) >= 0  # MoE dispatch present
print('mini dryrun OK flops=%.2e coll=%.2e' % (a['flops'], a['collectives']['total']))
""",
    )
    assert "mini dryrun OK" in out
