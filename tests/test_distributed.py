"""Multi-device semantics via subprocesses (this process keeps 1 device;
XLA locks the device count at first jax init, so each test spawns a child
with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import subprocess
import sys

import pytest

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _sm  # noqa: F401
    _NEW_JAX = True
except ImportError:
    _NEW_JAX = False

# On jax 0.4.x the repro.utils.jaxcompat shim makes these programs *run*,
# but the check_rep-era shard_map on forced-multi-device CPU is orders of
# magnitude slower — minutes per subprocess — so they are excluded from
# tier-1 there rather than blowing the suite budget.
pytestmark = pytest.mark.skipif(
    not _NEW_JAX,
    reason="multi-device subprocess tests need jax>=0.6 (0.4.x compat path "
           "is functional but too slow for tier-1)")

REPO = "src"


def _run(code: str, devices: int = 8):
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560,
        env={
            "PYTHONPATH": REPO,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
        cwd="/root/repo",
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_dictionary_matches_local():
    out = _run(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.utils.jaxcompat import make_mesh, shard_map
from repro.core import dictionary as dct
from repro.utils import pair64

rng = np.random.default_rng(0)
n_shards, per = 8, 64
fps = rng.choice(1 << 50, n_shards * per // 2, replace=False)
occ = rng.choice(fps, n_shards * per)  # duplicated occurrences
hi, lo = pair64.split_np(occ)
mesh = make_mesh((n_shards,), ('d',))
body = dct.sharded_dictionary_fn('d', n_shards, bin_cap=per, base=1000)
f = shard_map(body, mesh=mesh, in_specs=(P('d'), P('d'), P('d')),
              out_specs=dct.sharded_out_specs(), check_vma=False)
ids, table, overflow, counts = f(jnp.asarray(hi), jnp.asarray(lo),
                                 jnp.ones(occ.shape, bool))
ids = np.asarray(ids)
assert int(np.asarray(overflow).sum()) == 0
# bijectivity: same fp -> same id; distinct fps -> distinct ids
m = {}
for f_, i_ in zip(occ.tolist(), ids.tolist()):
    assert i_ >= 1000
    assert m.setdefault(f_, i_) == i_
assert len(set(m.values())) == len(m)
# density: ids cover [1000, 1000 + n_distinct)
vals = sorted(m.values())
assert vals[0] == 1000 and vals[-1] == 1000 + len(m) - 1
print('sharded dictionary OK', len(m))
"""
    )
    assert "sharded dictionary OK" in out


def test_sharded_kb_shard_map_path_subprocess():
    """ShardedKB's shard_map execution (one device per shard) must equal the
    per-shard dispatch loop AND the single-device KnowledgeBase bit-exactly;
    the serving fan-out merges the same counts."""
    out = _run(
        """
import numpy as np, jax
from repro.core.engine import KnowledgeBase
from repro.core.query import Pattern
from repro.core.shard import ShardedKB
from repro.rdf.generator import generate_random_abox
from repro.rdf.vocab import lubm_ontology
from repro.serving.engine import QueryServer, ShardedQueryServer

assert jax.device_count() == 8
onto = lubm_ontology()
raw = generate_random_abox(onto, n_instances=800, n_type_triples=1500,
                           n_prop_triples=1500, seed=3)
K = KnowledgeBase.build(raw)
S = ShardedKB.build(raw, n_shards=8)
eng = S.engine('litemat')
assert eng._shard_map_on()
q1 = [Pattern('?x', 'rdf:type', 'Professor')]
want1, _ = K.query(q1, select=('?x',), mode='litemat')
got1, _ = eng.run(q1, select=('?x',))
assert np.array_equal(want1, got1)
# single-pattern plans have uniform per-shard signatures: must lower
# through the shard_mapped executable, never the dispatch loop
assert eng.cache_stats['shard_map_runs'] > 0, eng.cache_stats
q = [Pattern('?x', 'rdf:type', 'Professor'), Pattern('?x', 'worksFor', '?y')]
sel = ('?x', '?y')
want, _ = K.query(q, select=sel, mode='litemat')
got, _ = eng.run(q, select=sel)
assert np.array_equal(want, got)
eng.use_shard_map = False
loop, _ = eng.run(q, select=sel)
assert np.array_equal(want, loop)
c1, m1 = QueryServer(K, topk=8).class_members(['Professor', 'Student'])
qss = ShardedQueryServer(S, topk=8)
assert qss._sm()
c2, m2 = qss.class_members(['Professor', 'Student'])
assert np.array_equal(c1, c2) and np.array_equal(m1, m2)
print('sharded shard_map OK', c1.tolist())
"""
    )
    assert "sharded shard_map OK" in out


def test_mini_dryrun_lm_cell():
    """A 2x2x2 'multi-pod' mesh compiles an LM train cell end-to-end and the
    HLO analyzer finds loop-multiplied collectives."""
    out = _run(
        """
import jax
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import analyze_hlo
from repro.utils.jaxcompat import make_mesh
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
cell = build_cell('olmoe-1b-7b', 'train_4k', mesh)
jfn = jax.jit(cell.fn, in_shardings=cell.shardings(mesh))
compiled = jfn.lower(*cell.abstract_args).compile()
a = analyze_hlo(compiled.as_text())
assert a['flops'] > 0 and a['collectives'].get('total', 0) > 0
assert a['collectives'].get('all-to-all', 0) >= 0  # MoE dispatch present
print('mini dryrun OK flops=%.2e coll=%.2e' % (a['flops'], a['collectives']['total']))
""",
    )
    assert "mini dryrun OK" in out


def test_repartition_join_shard_map_subprocess():
    """Cross-group (object-keyed) joins fold through the device-side
    hash-repartition join under shard_map — bit-identical to the host fold
    and the single-device engine, with ZERO host re-uploads (the
    `device/transfer_bytes{src=combine_upload}` meter stays flat)."""
    out = _run(
        """
import numpy as np, jax
from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.shard import ShardedKB
from repro.obs.metrics import REGISTRY
from repro.rdf.generator import generate_lubm

assert jax.device_count() == 8
raw = generate_lubm(1, seed=7)
K = KnowledgeBase.build(raw)
S = ShardedKB.build(raw, n_shards=8)
eng = S.engine('litemat')
assert eng._shard_map_on() and eng._repartition_on()
want3, _ = K.query(PAPER_QUERIES['Q3'], mode='litemat')
got3, _ = eng.run(PAPER_QUERIES['Q3'])
assert np.array_equal(np.asarray(got3), want3)
# Q4 is the multi-group (object-keyed) plan: its combine must stay on
# device — Q3's single-group run above may legitimately meter an upload
# through the host combine, so the pin brackets Q4 alone
c = REGISTRY.counter('device/transfer_bytes', src='combine_upload')
before = c.value
want, _ = K.query(PAPER_QUERIES['Q4'], mode='litemat')
got, _ = eng.run(PAPER_QUERIES['Q4'])
assert np.array_equal(np.asarray(got), want)
assert eng.cache_stats['repartition_runs'] >= 1, eng.cache_stats
assert c.value == before, 'device combine leaked a host re-upload'
eng.use_repartition_join = False
host, _ = eng.run(PAPER_QUERIES['Q4'])
want4, _ = K.query(PAPER_QUERIES['Q4'], mode='litemat')
assert np.array_equal(np.asarray(host), want4)
assert c.value > before  # the host fold pays the upload the device path skips
print('repartition shard_map OK', eng.cache_stats['repartition_runs'])
"""
    )
    assert "repartition shard_map OK" in out


def test_sharded_encode_ingest_subprocess():
    """`ShardedKB.ingest` encodes through the all-to-all sharded dictionary
    when a device per shard exists; answers match a host-encode control in
    fingerprint space (the two encodes rank instance ids differently)."""
    out = _run(
        """
import numpy as np, jax
import jax.numpy as jnp
from repro.core.engine import PAPER_QUERIES
from repro.core.shard import ShardedKB
from repro.core.tbox import build_tbox
from repro.rdf.generator import generate_lubm
from repro.utils import pair64

assert jax.device_count() == 8
raw = generate_lubm(1, seed=11)
n = raw.s.shape[0]; half = n // 2
parts = [(raw.s[:half], raw.p[:half], raw.o[:half]),
         (raw.s[half:], raw.p[half:], raw.o[half:])]
S = ShardedKB.ingest(iter(parts), onto=raw.onto, n_shards=8)
assert S.use_sharded_encode and S._sharded_encode_on()
ctrl = ShardedKB.empty(build_tbox(raw.onto), n_shards=8)
for p in parts:
    ctrl.insert(p, auto_compact=False)

def answers_fp(kb, pats, mode):
    rows, _ = kb.query(pats, mode=mode)
    if rows.size == 0:
        return set()
    ids = jnp.asarray(np.asarray(rows).reshape(-1).astype(np.int32))
    hi, lo, hit = kb.kb.table.extract_fp(ids)
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    fps = np.where(np.asarray(hit), fps, np.asarray(rows).reshape(-1))
    return {tuple(r) for r in fps.reshape(rows.shape).tolist()}

for mode in ('litemat', 'rewrite'):
    for qn in ('Q1', 'Q4'):
        a = answers_fp(S, PAPER_QUERIES[qn], mode)
        b = answers_fp(ctrl, PAPER_QUERIES[qn], mode)
        assert a == b and len(a) > 0, (mode, qn)
print('sharded encode ingest OK')
"""
    )
    assert "sharded encode ingest OK" in out
