"""The differential oracle's incremental-closure contract.

tests/oracle.py moved from per-call brute-force closure recomputation to a
reference-counted per-triple derivation index so the randomized update
suites can run at 10x triple counts.  Three pins keep that true:

  * parity — the memoized closure equals a from-scratch rebuild on the
    same final triple set after any insert/delete interleaving (refcounts
    never drift),
  * incrementality — across a long mutation sequence the oracle performs
    exactly one full rebuild and derives each mutated triple O(1) times
    (derive-call counters, deterministic on any machine),
  * sub-quadratic wall-time — per-step closure maintenance does not grow
    with the accumulated store: the last quarter of a fixed-batch insert
    sequence takes comparably long as the first quarter (brute-force
    recompute grows linearly per step, ~7x over this window).
"""
import time

import numpy as np
import pytest

from oracle import NaiveKB, query_vars

from repro.core.query import Pattern
from repro.core.tbox import Ontology
from repro.rdf.generator import generate_random_abox


def _onto(seed: int = 0) -> Ontology:
    rng = np.random.default_rng(seed)
    concepts = [f"C{i}" for i in range(8)]
    props = [f"p{i}" for i in range(4)]
    return Ontology(
        concepts=concepts, properties=props,
        subclass=[(concepts[i], concepts[int(rng.integers(0, i))])
                  for i in range(1, 8)],
        subprop=[(props[i], props[int(rng.integers(0, i))])
                 for i in range(1, 4)],
        domain={props[0]: [concepts[1]]},
        range_={props[3]: [concepts[2]]},
    )


def _batch(onto, seed: int, scale: int = 1):
    return generate_random_abox(
        onto, n_instances=100 * scale, n_type_triples=200 * scale,
        n_prop_triples=200 * scale, seed=seed)


def test_memoized_closure_matches_scratch_rebuild():
    """Refcounted closure == fresh brute-force build on the final set."""
    onto = _onto(1)
    rng = np.random.default_rng(1)
    kb = NaiveKB(onto)
    kb.insert(_batch(onto, 0))
    kb.closure()  # build the index early so every mutation is incremental
    for step in range(8):
        if rng.random() < 0.6:
            kb.insert(_batch(onto, 10 + step))
        else:
            pool = list(kb.triples)
            idx = rng.choice(len(pool), size=max(len(pool) // 6, 1),
                             replace=False)
            rows = np.array([pool[i] for i in idx])
            kb.delete((rows[:, 0], rows[:, 1], rows[:, 2]))
        fresh = NaiveKB(onto)
        fresh.triples = set(kb.triples)
        assert set(kb.closure()) == set(fresh.closure()), step
    # and query answers agree between the two closure paths
    q = [Pattern("?x", "rdf:type", onto.concepts[0]),
         Pattern("?x", onto.properties[0], "?y")]
    sel = query_vars(q)
    assert kb.answers(q, sel) == fresh.answers(q, sel)


def test_oracle_incremental_no_per_step_rebuilds():
    """One full rebuild ever; derive calls track mutations, not history.

    The deterministic wall-time proxy: brute-force recomputation would
    re-derive every live triple once per step (derive_calls ~ steps x
    store); the incremental index derives each mutated triple once, so
    total derive calls stay within a small factor of total mutated rows.
    """
    onto = _onto(2)
    kb = NaiveKB(onto)
    mutated = 0
    steps = 12
    for step in range(steps):
        raw = _batch(onto, 100 + step)
        before = len(kb.triples)
        kb.insert(raw)
        mutated += len(kb.triples) - before
        kb.closure()
        kb.compact()
    assert kb.stats["full_rebuilds"] == 1
    # each mutated triple derived once by the rebuild or its own retain;
    # a per-step recompute would be ~steps/2 x larger
    assert kb.stats["derive_calls"] <= mutated + 16, kb.stats
    # deletes are incremental too
    pool = list(kb.triples)[: len(kb.triples) // 4]
    rows = np.array(pool)
    calls0 = kb.stats["derive_calls"]
    kb.delete((rows[:, 0], rows[:, 1], rows[:, 2]))
    kb.closure()
    assert kb.stats["full_rebuilds"] == 1
    assert kb.stats["derive_calls"] - calls0 <= len(pool)


def test_oracle_walltime_subquadratic_in_steps():
    """Fixed-size insert steps stay flat-ish as the store accumulates.

    Quadratic (per-step full recompute) maintenance makes the last window
    ~7x the first at 20 steps; the incremental index keeps the ratio near
    1.  The 6x bound leaves CI-noise margin while still failing any
    O(store)-per-step regression.
    """
    onto = _onto(3)
    kb = NaiveKB(onto)
    kb.insert(_batch(onto, 200))
    kb.closure()
    window = []
    for step in range(20):
        raw = _batch(onto, 300 + step)
        t0 = time.perf_counter()
        kb.insert(raw)
        kb.closure()
        window.append(time.perf_counter() - t0)
    first, last = sum(window[:5]), sum(window[-5:])
    assert last < 6 * max(first, 1e-4), (first, last)
