"""Differential oracle: a naive set-semantics reference KnowledgeBase.

The update tests' original contract compared an incrementally mutated
KnowledgeBase against ``KnowledgeBase.build`` on the final triple set —
strong, but blind to any bug the build pipeline *shares* with the update
pipeline (both run the same encoders, materializers, and query engine).
:class:`NaiveKB` is an independent implementation with none of that code in
common: a Python set of fingerprint triples, a brute-force RDFS closure
(dict lookups and set unions — no ids, no intervals, no device), and
nested-loop conjunctive query evaluation.  Randomized
insert/delete/compact/query sequences are checked against it after every
step, in fingerprint space, which is exactly the identity the engine-side
``answers_fp`` helper reports.

Closure semantics mirror what the engine's materializers define (and the
paper's RDFS subset): rdfs5/7 sub-property closure on non-type triples,
rdfs2/3 through *effective* domain/range tables (a property inherits its
ancestors' domain/range — rdfs7 composed with rdfs2/3), and rdfs9/11
sub-class closure over every explicit or derived type.  Set-of-triples
semantics make duplicate inserts and delete-all-copies free.
"""
from __future__ import annotations

import numpy as np

from repro.core.tbox import RDF_TYPE
from repro.utils.hashing import fingerprint_string


def _is_var(t) -> bool:
    return isinstance(t, str) and t.startswith("?")


def _ancestor_sets(edges, nodes):
    """name -> reflexive-transitive superset along (sub, sup) edges."""
    up = {}
    for sub, sup in edges:
        up.setdefault(sub, set()).add(sup)
    anc = {}
    for n in nodes:
        seen, stack = set(), [n]
        while stack:
            c = stack.pop()
            for s in up.get(c, ()):
                if s not in seen:
                    seen.add(s)
                    stack.append(s)
        anc[n] = seen | {n}
    return anc


class NaiveKB:
    """Set-of-triples reference KB with brute-force RDFS entailment."""

    def __init__(self, onto):
        self.onto = onto
        self.type_fp = int(fingerprint_string(RDF_TYPE))
        self.cfp = {c: int(fingerprint_string(c)) for c in onto.concepts}
        self.pfp = {p: int(fingerprint_string(p)) for p in onto.properties}
        c_anc = _ancestor_sets(onto.subclass, onto.concepts)
        p_anc = _ancestor_sets(onto.subprop, onto.properties)
        self.c_anc = {self.cfp[c]: {self.cfp[a] for a in c_anc[c]}
                      for c in onto.concepts}
        self.p_anc = {self.pfp[p]: {self.pfp[a] for a in p_anc[p]}
                      for p in onto.properties}
        # effective domain/range: a property inherits every ancestor's
        # axioms (the engine precomputes the same union into DeviceTBox)
        self.eff_dom, self.eff_rng = {}, {}
        for p in onto.properties:
            dom = {c for a in p_anc[p] for c in onto.domain.get(a, ())}
            rng = {c for a in p_anc[p] for c in onto.range_.get(a, ())}
            self.eff_dom[self.pfp[p]] = {self.cfp[c] for c in dom}
            self.eff_rng[self.pfp[p]] = {self.cfp[c] for c in rng}
        self.triples: set = set()

    # -- mutations (set semantics) -------------------------------------------
    @staticmethod
    def _rows(raw):
        if hasattr(raw, "s"):
            s, p, o = raw.s, raw.p, raw.o
        else:
            s, p, o = raw
        return zip(np.asarray(s).tolist(), np.asarray(p).tolist(),
                   np.asarray(o).tolist())

    def insert(self, raw) -> None:
        self.triples.update(self._rows(raw))

    def delete(self, raw) -> None:
        self.triples.difference_update(self._rows(raw))

    def compact(self) -> None:
        """Compaction must be answer-invariant: nothing to do here."""

    # -- entailment ----------------------------------------------------------
    def closure(self) -> set:
        """Full RDFS closure of the current triple set (brute force)."""
        out = set(self.triples)
        candidates = set()  # (instance, concept) type candidates
        for s, p, o in self.triples:
            if p == self.type_fp:
                candidates.add((s, o))
                continue
            for q in self.p_anc.get(p, {p}):
                out.add((s, q, o))
            for c in self.eff_dom.get(p, ()):
                candidates.add((s, c))
            for c in self.eff_rng.get(p, ()):
                candidates.add((o, c))
        for x, c in candidates:
            for a in self.c_anc.get(c, {c}):
                out.add((x, self.type_fp, a))
        return out

    # -- query evaluation ----------------------------------------------------
    def _resolve(self, term, position: str):
        if isinstance(term, (int, np.integer)):
            return int(term)
        if position == "p":
            if term in (RDF_TYPE, "a"):
                return self.type_fp
            if term in self.pfp:
                return self.pfp[term]
        if term in self.cfp:
            return self.cfp[term]
        if term in self.pfp:
            return self.pfp[term]
        raise KeyError(f"unknown oracle term {term!r}")

    def _match(self, closure, pat):
        """One pattern -> list of {var: fp} bindings (nested-loop scan)."""
        spec = []
        for term, pos in ((pat.s, "s"), (pat.p, "p"), (pat.o, "o")):
            spec.append(term if _is_var(term) else self._resolve(term, pos))
        out = []
        for t in closure:
            binding = {}
            for want, got in zip(spec, t):
                if isinstance(want, str):  # variable
                    if binding.get(want, got) != got:
                        binding = None
                        break
                    binding[want] = got
                elif want != got:
                    binding = None
                    break
            if binding is not None:
                out.append(binding)
        return out

    def answers(self, patterns, select) -> set:
        """Conjunctive query -> set of ``select``-projected fp tuples."""
        closure = self.closure()
        acc = [{}]
        for pat in patterns:
            rel = self._match(closure, pat)
            acc = [
                {**b1, **b2}
                for b1 in acc
                for b2 in rel
                if all(b1.get(k, v) == v for k, v in b2.items())
            ]
        return {tuple(b[v] for v in select) for b in acc}


def query_vars(patterns):
    """Deterministic select list: variables in first-appearance order."""
    return tuple(dict.fromkeys(
        t for pat in patterns for t in (pat.s, pat.p, pat.o) if _is_var(t)))
