"""Differential oracle: a naive set-semantics reference KnowledgeBase.

The update tests' original contract compared an incrementally mutated
KnowledgeBase against ``KnowledgeBase.build`` on the final triple set —
strong, but blind to any bug the build pipeline *shares* with the update
pipeline (both run the same encoders, materializers, and query engine).
:class:`NaiveKB` is an independent implementation with none of that code in
common: a Python set of fingerprint triples, a brute-force RDFS closure
(dict lookups and set unions — no ids, no intervals, no device), and
nested-loop conjunctive query evaluation.  Randomized
insert/delete/compact/query sequences are checked against it after every
step, in fingerprint space, which is exactly the identity the engine-side
``answers_fp`` helper reports.

Closure semantics mirror what the engine's materializers define (and the
paper's RDFS subset): rdfs5/7 sub-property closure on non-type triples,
rdfs2/3 through *effective* domain/range tables (a property inherits its
ancestors' domain/range — rdfs7 composed with rdfs2/3), and rdfs9/11
sub-class closure over every explicit or derived type.  Set-of-triples
semantics make duplicate inserts and delete-all-copies free.

The closure is maintained INCREMENTALLY: this RDFS subset derives every
entailed triple from exactly one source triple (no joins between source
triples), so the closure is the union of per-triple derivations and a
reference count per derived triple makes both mutations O(|delta| *
|derivation|): an insert adds its new triples' derivations, a delete
retracts its removed triples' — no per-step brute-force recompute.  That
is what lets the randomized differential suites run at 10x triple counts
inside the same CI budget; ``stats`` counts derivations and full rebuilds
so regression tests can pin the incremental behavior (a full recompute
happens at most once, at first use).
"""
from __future__ import annotations

import numpy as np

from repro.core.tbox import RDF_TYPE
from repro.utils.hashing import fingerprint_string


def _is_var(t) -> bool:
    return isinstance(t, str) and t.startswith("?")


def _ancestor_sets(edges, nodes):
    """name -> reflexive-transitive superset along (sub, sup) edges."""
    up = {}
    for sub, sup in edges:
        up.setdefault(sub, set()).add(sup)
    anc = {}
    for n in nodes:
        seen, stack = set(), [n]
        while stack:
            c = stack.pop()
            for s in up.get(c, ()):
                if s not in seen:
                    seen.add(s)
                    stack.append(s)
        anc[n] = seen | {n}
    return anc


class NaiveKB:
    """Set-of-triples reference KB with brute-force RDFS entailment."""

    def __init__(self, onto):
        self.onto = onto
        self.type_fp = int(fingerprint_string(RDF_TYPE))
        self.cfp = {c: int(fingerprint_string(c)) for c in onto.concepts}
        self.pfp = {p: int(fingerprint_string(p)) for p in onto.properties}
        c_anc = _ancestor_sets(onto.subclass, onto.concepts)
        p_anc = _ancestor_sets(onto.subprop, onto.properties)
        self.c_anc = {self.cfp[c]: {self.cfp[a] for a in c_anc[c]}
                      for c in onto.concepts}
        self.p_anc = {self.pfp[p]: {self.pfp[a] for a in p_anc[p]}
                      for p in onto.properties}
        # effective domain/range: a property inherits every ancestor's
        # axioms (the engine precomputes the same union into DeviceTBox)
        self.eff_dom, self.eff_rng = {}, {}
        for p in onto.properties:
            dom = {c for a in p_anc[p] for c in onto.domain.get(a, ())}
            rng = {c for a in p_anc[p] for c in onto.range_.get(a, ())}
            self.eff_dom[self.pfp[p]] = {self.cfp[c] for c in dom}
            self.eff_rng[self.pfp[p]] = {self.cfp[c] for c in rng}
        self.triples: set = set()
        self._counts: dict | None = None  # derived triple -> #live sources
        self.stats = {"derive_calls": 0, "full_rebuilds": 0}

    # -- mutations (set semantics) -------------------------------------------
    @staticmethod
    def _rows(raw):
        if hasattr(raw, "s"):
            s, p, o = raw.s, raw.p, raw.o
        else:
            s, p, o = raw
        return zip(np.asarray(s).tolist(), np.asarray(p).tolist(),
                   np.asarray(o).tolist())

    def insert(self, raw) -> None:
        for t in self._rows(raw):
            if t in self.triples:
                continue  # set semantics: duplicates contribute nothing
            self.triples.add(t)
            if self._counts is not None:
                self._retain(t, +1)

    def delete(self, raw) -> None:
        for t in self._rows(raw):
            if t not in self.triples:
                continue
            self.triples.discard(t)
            if self._counts is not None:
                self._retain(t, -1)

    def compact(self) -> None:
        """Compaction must be answer-invariant: nothing to do here."""

    # -- entailment ----------------------------------------------------------
    def _derive(self, t) -> set:
        """Everything ONE source triple entails (itself included).

        This RDFS subset never joins two source triples, so the closure is
        exactly the union of these per-triple sets — the property the
        incremental reference counts rely on.
        """
        self.stats["derive_calls"] += 1
        s, p, o = t
        out = {t}
        if p == self.type_fp:
            for a in self.c_anc.get(o, {o}):
                out.add((s, self.type_fp, a))
            return out
        for q in self.p_anc.get(p, {p}):
            out.add((s, q, o))
        for c in self.eff_dom.get(p, ()):
            for a in self.c_anc.get(c, {c}):
                out.add((s, self.type_fp, a))
        for c in self.eff_rng.get(p, ()):
            for a in self.c_anc.get(c, {c}):
                out.add((o, self.type_fp, a))
        return out

    def _retain(self, t, sign: int) -> None:
        """Add/retract one source triple's derivations from the refcounts."""
        counts = self._counts
        for d in self._derive(t):
            c = counts.get(d, 0) + sign
            if c:
                counts[d] = c
            else:
                del counts[d]

    def closure(self):
        """RDFS closure of the current triple set (memoized incrementally).

        The first call builds the reference-counted derivation index from
        scratch; every later call — across any number of insert/delete
        steps — only reflects the per-step retains/retractions and is O(1).
        """
        if self._counts is None:
            self.stats["full_rebuilds"] += 1
            self._counts = {}
            for t in self.triples:
                self._retain(t, +1)
        return self._counts.keys()

    # -- query evaluation ----------------------------------------------------
    def _resolve(self, term, position: str):
        if isinstance(term, (int, np.integer)):
            return int(term)
        if position == "p":
            if term in (RDF_TYPE, "a"):
                return self.type_fp
            if term in self.pfp:
                return self.pfp[term]
        if term in self.cfp:
            return self.cfp[term]
        if term in self.pfp:
            return self.pfp[term]
        raise KeyError(f"unknown oracle term {term!r}")

    def _match(self, closure, pat):
        """One pattern -> list of {var: fp} bindings (nested-loop scan)."""
        spec = []
        for term, pos in ((pat.s, "s"), (pat.p, "p"), (pat.o, "o")):
            spec.append(term if _is_var(term) else self._resolve(term, pos))
        out = []
        for t in closure:
            binding = {}
            for want, got in zip(spec, t):
                if isinstance(want, str):  # variable
                    if binding.get(want, got) != got:
                        binding = None
                        break
                    binding[want] = got
                elif want != got:
                    binding = None
                    break
            if binding is not None:
                out.append(binding)
        return out

    def answers(self, patterns, select) -> set:
        """Conjunctive query -> set of ``select``-projected fp tuples."""
        closure = self.closure()
        acc = [{}]
        for pat in patterns:
            rel = self._match(closure, pat)
            acc = [
                {**b1, **b2}
                for b1 in acc
                for b2 in rel
                if all(b1.get(k, v) == v for k, v in b2.items())
            ]
        return {tuple(b[v] for v in select) for b in acc}


def query_vars(patterns):
    """Deterministic select list: variables in first-appearance order."""
    return tuple(dict.fromkeys(
        t for pat in patterns for t in (pat.s, pat.p, pat.o) if _is_var(t)))
