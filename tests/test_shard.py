"""Sharded stores: ShardedKB must be indistinguishable from KnowledgeBase.

The partition invariants under test:

  * results — Q1–Q4 in all three modes, and every query through randomized
    insert/delete/compact sequences, are BIT-IDENTICAL between the
    subject-hash partitioned store and the single-device store (same
    ``select`` ⇒ same global distinct order);
  * placement — every live row of every store (raw and derived) sits on
    its subject's shard after any mutation sequence (range-derived type
    rows migrate through the exchange);
  * laziness — per-mode derivation stays lazy across shards: serving only
    the lite store never runs the full closure of ingested rows;
  * O(delta)-per-shard warmup — post-mutation device transfer rows per
    shard do not depend on the base size.

The shard_map execution path (one device per shard) is pinned in
tests/test_distributed.py via an 8-forced-device subprocess; everything
here runs the per-shard dispatch loop on the suite's single device with 8
(or 4) logical shards — same code above the executor, bit-identical
results by construction of the combine.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.query import Pattern
from repro.core.shard import (
    ShardedKB, assert_partitioned, partition_rows, plan_groups, shard_of,
)
from repro.core.tbox import Ontology
from repro.rdf.generator import generate_random_abox
from repro.utils import pair64

MODES = ("litemat", "full", "rewrite")


def _sel(patterns):
    return tuple(dict.fromkeys(
        v for p in patterns for v in (p.s, p.p, p.o)
        if isinstance(v, str) and v.startswith("?")))


def _answers_fp(K, patterns, mode, select):
    """Answers mapped to fingerprint space (ids differ across encodes)."""
    rows, _ = K.query(patterns, select=select, mode=mode)
    if rows.size == 0:
        return set()
    ids = jnp.asarray(rows.reshape(-1).astype(np.int32))
    hi, lo, hit = K.kb.table.extract_fp(ids)
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    fps = np.where(np.asarray(hit), fps, rows.reshape(-1))
    return {tuple(r) for r in fps.reshape(rows.shape).tolist()}


@pytest.fixture(scope="module")
def sharded_pair(lubm_kb):
    K, raw = lubm_kb
    return K, ShardedKB.build(raw, n_shards=8), raw


# ---------------------------------------------------------------------------
# static parity + placement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_paper_queries_bit_identical(sharded_pair, mode):
    K, S, _ = sharded_pair
    for name, pats in PAPER_QUERIES.items():
        sel = _sel(pats)
        want, _ = K.query(pats, select=sel, mode=mode)
        got, _ = S.query(pats, select=sel, mode=mode)
        assert np.array_equal(want, got), (mode, name, want.shape, got.shape)


def test_scan_path_parity(sharded_pair):
    """use_index=False (pure kernel scans) through the sharded combine."""
    K, S, _ = sharded_pair
    pats = PAPER_QUERIES["Q3"]
    sel = _sel(pats)
    want, _ = K.query(pats, select=sel, mode="litemat", use_index=False)
    got, _ = S.query(pats, select=sel, mode="litemat", use_index=False)
    assert np.array_equal(want, got)


def test_partition_invariant(sharded_pair):
    _, S, _ = sharded_pair
    assert_partitioned(S)
    # shard sizes should be roughly balanced (hash, not modulo artifacts)
    sizes = np.array([K.kb.n for K in S.shards])
    assert sizes.min() > 0.5 * sizes.mean(), sizes


def test_constant_subject_routes_to_owner_shard(sharded_pair):
    K, S, _ = sharded_pair
    s_id = int(np.asarray(K.kb.spo[0, 0]))
    pats = [Pattern(s_id, "?p", "?y")]
    want, _ = K.query(pats, select=("?p", "?y"))
    got, _ = S.query(pats, select=("?p", "?y"))
    assert np.array_equal(want, got)
    eng = S.engine("litemat")
    routed = eng._route_shards(pats)
    assert routed == [int(shard_of(np.asarray([s_id]), S.n_shards)[0])]


def test_group_planner_locality_rules():
    class _T:  # stand-in tbox: only rdf_type_id is consulted
        rdf_type_id = 7

    q4 = [Pattern("?x", "rdf:type", "Chair"),
          Pattern("?y", "rdf:type", "Department"),
          Pattern("?x", "worksFor", "?y")]
    groups = {frozenset(g) for g in plan_groups(q4, "litemat", _T)}
    assert groups == {frozenset({0, 2}), frozenset({1})}
    # rewrite-mode type patterns bind ?x from BOTH endpoints: never co-hashed
    q3 = [Pattern("?x", "rdf:type", "Professor"),
          Pattern("?x", "memberOf", "?y")]
    assert {frozenset(g) for g in plan_groups(q3, "litemat", _T)} == {
        frozenset({0, 1})}
    assert {frozenset(g) for g in plan_groups(q3, "rewrite", _T)} == {
        frozenset({0}), frozenset({1})}


def test_partition_rows_covers_and_hashes():
    rows = np.stack([np.arange(1000, dtype=np.int32)] * 3, axis=1)
    parts = partition_rows(rows, 8)
    assert sum(p.shape[0] for p in parts) == 1000
    for i, p in enumerate(parts):
        assert (shard_of(p[:, 0], 8) == i).all()


# ---------------------------------------------------------------------------
# randomized update sequences
# ---------------------------------------------------------------------------


def _dag_onto(seed: int) -> Ontology:
    rng = np.random.default_rng(seed)
    nc, npr = int(rng.integers(5, 10)), int(rng.integers(3, 5))
    concepts = [f"C{i}" for i in range(nc)]
    props = [f"p{i}" for i in range(npr)]
    subclass = [(concepts[i], concepts[int(rng.integers(0, i))])
                for i in range(1, nc)]
    if nc > 4:
        subclass.append((concepts[nc - 1], concepts[1]))
    subprop = [(props[i], props[int(rng.integers(0, i))])
               for i in range(1, npr)]
    domain = {props[0]: [concepts[1]]}
    range_ = {props[-1]: [concepts[2]]}  # range axioms exercise the exchange
    return Ontology(concepts=concepts, properties=props, subclass=subclass,
                    subprop=subprop, domain=domain, range_=range_)


@pytest.mark.parametrize("seed", [0, 1])
def test_randomized_update_parity(seed):
    """insert/delete/compact sequences stay bit-identical to the
    single-device store — each step checks one rotating mode, the final
    step all three — and the subject-hash placement survives every step."""
    rng = np.random.default_rng(seed)
    onto = _dag_onto(seed)
    raw = generate_random_abox(onto, n_instances=300, n_type_triples=450,
                               n_prop_triples=400, seed=seed)
    K = KnowledgeBase.build(raw)
    S = ShardedKB.build(raw, n_shards=4)
    queries = [
        [Pattern("?x", "rdf:type", onto.concepts[0])],
        [Pattern("?x", onto.properties[0], "?y")],
        [Pattern("?x", "rdf:type", onto.concepts[1]),
         Pattern("?x", onto.properties[0], "?y")],
        [Pattern("?x", "rdf:type", onto.concepts[0]),
         Pattern("?y", "rdf:type", onto.concepts[2]),
         Pattern("?x", onto.properties[-1], "?y")],
    ]
    n_steps = 3
    for step in range(n_steps):
        op = rng.choice(["insert", "delete", "compact"], p=[0.5, 0.35, 0.15])
        if op == "insert":
            extra = generate_random_abox(
                onto, n_instances=int(rng.integers(50, 200)),
                n_type_triples=int(rng.integers(50, 250)),
                n_prop_triples=int(rng.integers(50, 200)),
                seed=1000 + step, instance_offset=100_000 * (step + 1))
            K.insert(extra, auto_compact=False)
            S.insert(extra, auto_compact=False)
        elif op == "delete":
            n = int(rng.integers(1, 50))
            idx = rng.choice(raw.s.shape[0], n, replace=False)
            batch = (raw.s[idx], raw.p[idx], raw.o[idx])
            K.delete(batch, auto_compact=False)
            S.delete(batch, auto_compact=False)
        else:
            K.compact()
            S.compact()
        modes = MODES if step == n_steps - 1 else (MODES[step % 3],)
        for q in queries:
            sel = _sel(q)
            for mode in modes:
                want, _ = K.query(q, select=sel, mode=mode)
                got, _ = S.query(q, select=sel, mode=mode)
                assert np.array_equal(want, got), (seed, step, op, mode, q)
    assert_partitioned(S)


# ---------------------------------------------------------------------------
# bulk ingest
# ---------------------------------------------------------------------------


def test_ingest_matches_build():
    """Part-streamed ingest == one-shot build, in fingerprint space (the
    two encodes rank instance ids differently)."""
    onto = _dag_onto(3)
    parts = [generate_random_abox(onto, n_instances=150, n_type_triples=250,
                                  n_prop_triples=200, seed=10 + i,
                                  instance_offset=50_000 * i)
             for i in range(4)]
    whole = type(parts[0])(
        s=np.concatenate([p.s for p in parts]),
        p=np.concatenate([p.p for p in parts]),
        o=np.concatenate([p.o for p in parts]),
        onto=onto)
    K = KnowledgeBase.build(whole)
    S = ShardedKB.ingest(parts, n_shards=4)
    assert_partitioned(S)
    queries = [
        [Pattern("?x", "rdf:type", onto.concepts[0])],
        [Pattern("?x", "rdf:type", onto.concepts[1]),
         Pattern("?x", onto.properties[0], "?y")],
    ]
    for q in queries:
        sel = _sel(q)
        for mode in MODES:
            assert _answers_fp(K, q, mode, sel) == _answers_fp(
                S, q, mode, sel), (mode, q)


def test_ingest_lazy_per_mode():
    """Lite-only service of an ingested store never runs the full closure."""
    onto = _dag_onto(4)
    parts = [generate_random_abox(onto, n_instances=100, n_type_triples=150,
                                  n_prop_triples=150, seed=20 + i,
                                  instance_offset=50_000 * i)
             for i in range(3)]
    S = ShardedKB.ingest(parts, n_shards=4)
    assert S.mat_counts == {"litemat": 0, "full": 0}
    S.query([Pattern("?x", "rdf:type", onto.concepts[0])], mode="litemat")
    assert S.mat_counts["litemat"] == len(parts)
    assert S.mat_counts["full"] == 0


# ---------------------------------------------------------------------------
# O(delta) per-shard warmup
# ---------------------------------------------------------------------------


def test_shard_warmup_transfers_independent_of_base_size():
    """Every shard's post-insert device refresh ships EXACTLY the rows its
    own delta log predicts (one pow2 bucket per warmed key), at 1x AND 4x
    base — the per-shard O(delta) pin.  (The raw per-shard numbers cannot
    be compared across scales directly: the dictionary ranks the delta's
    new instance ids differently over different bases, so the hash
    partition of the same delta differs — what must NOT differ is the
    transfer/delta-size relation, which an O(base) leak would break.)"""
    from repro.core.index import pow2_bucket

    onto = _dag_onto(5)
    for scale in (1, 4):
        raw = generate_random_abox(
            onto, n_instances=800 * scale, n_type_triples=1500 * scale,
            n_prop_triples=1200 * scale, seed=6)
        S = ShardedKB.build(raw, n_shards=4)
        S.prewarm([[Pattern("?x", "rdf:type", onto.concepts[0])]],
                  modes=("litemat",))
        S.warm_device("litemat", keys=("pos",))
        before = [K.dev_cache("litemat").stats["upload_delta_rows"]
                  for K in S.shards]
        delta = generate_random_abox(
            onto, n_instances=64, n_type_triples=128, n_prop_triples=128,
            seed=99, instance_offset=10_000_000)
        S.insert(delta, auto_compact=False)
        S.warm_device("litemat", keys=("pos",))
        got = [K.dev_cache("litemat").stats["upload_delta_rows"] - b
               for K, b in zip(S.shards, before)]
        want = [pow2_bucket(K.delta.log("litemat").n)
                if K.delta.log("litemat").n else 0 for K in S.shards]
        assert got == want, (scale, got, want)


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------


def test_sharded_serving_matches_single(sharded_pair):
    from repro.serving.engine import QueryServer, ShardedQueryServer

    K, S, raw = sharded_pair
    names = ["Professor", "Student", "Chair", "Course"]
    qs = QueryServer(K, topk=16)
    qss = ShardedQueryServer(S, topk=16)
    c1, m1 = qs.class_members(names)
    c2, m2 = qss.class_members(names)
    assert np.array_equal(c1, c2)
    assert np.array_equal(m1, m2)
    cp1, s1 = qs.class_prop_join(["Professor", "Chair"],
                                 ["worksFor", "memberOf"])
    cp2, s2 = qss.class_prop_join(["Professor", "Chair"],
                                  ["worksFor", "memberOf"])
    assert np.array_equal(cp1, cp2)
    assert np.array_equal(s1, s2)


def test_windowed_inl_probe_parity(sharded_pair):
    """Force the windowed pair search under the INL join: results must not
    change (the last whole-table VMEM residency, now size-dispatched)."""
    from repro.core import query as qmod

    K, _, _ = sharded_pair
    pats = PAPER_QUERIES["Q4"]
    sel = _sel(pats)
    want, _ = K.query(pats, select=sel, mode="litemat")
    old = qmod.INL_RESIDENT_MAX
    qmod.INL_RESIDENT_MAX = 1  # every table takes the windowed path
    try:
        eng = qmod.QueryEngine(kb=K.kb, spo=K.lite_spo, mode="litemat",
                               dtb=K.dtb)
        got_rel = eng.run(pats, select=sel)
        assert np.array_equal(want, got_rel[0])
    finally:
        qmod.INL_RESIDENT_MAX = old
