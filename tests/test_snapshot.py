"""Snapshot isolation: pinned readers vs the mutating store.

Contracts under test (core/snapshot.py + the lease path in core/delta.py):

  * a pinned snapshot's answers are BIT-IDENTICAL before and after any
    insert / delete / compact on the live store — including the donated
    tombstone scatter path, which must copy (not donate) a leased buffer;
  * a fresh pin after each mutation matches both the live engine and the
    NaiveKB differential oracle at that version, single-store and sharded;
  * refcounts gate retirement: a pinned version survives publishes and
    compactions, and is dropped only once released;
  * a contended write lock degrades pins to the last published version
    with ``stale=True`` instead of blocking.
"""
import threading

import numpy as np
import pytest

from oracle import NaiveKB, query_vars

from repro.core.engine import KnowledgeBase, PAPER_QUERIES
from repro.core.shard import ShardedKB
from repro.core.snapshot import SnapshotRegistry
from repro.rdf.generator import generate_lubm
from test_update import answers_fp

QUERIES = {name: PAPER_QUERIES[name] for name in ("Q1", "Q3", "Q4")}


def pin_answers_fp(kb, pin, patterns, mode="litemat", select=None):
    """Pinned-snapshot answers mapped to fingerprint space (oracle identity)."""
    import jax.numpy as jnp

    from repro.utils import pair64

    rows, _ = pin.query(patterns, select=select, mode=mode)
    if rows.size == 0:
        return set()
    ids = jnp.asarray(np.asarray(rows).reshape(-1).astype(np.int32))
    hi, lo, hit = kb.kb.table.extract_fp(ids)
    fps = pair64.combine_np(np.asarray(hi), np.asarray(lo))
    fps = np.where(np.asarray(hit), fps, np.asarray(rows).reshape(-1))
    return {tuple(r) for r in fps.reshape(np.asarray(rows).shape).tolist()}


@pytest.fixture(scope="module")
def raw():
    return generate_lubm(1, seed=7)


def _mutation_script(raw):
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    return [
        ("delete", (s[:120], p[:120], o[:120])),
        ("insert", (s[:40], p[:40], o[:40])),  # re-insert some deleted rows
        ("compact", None),
        ("delete", (s[200:260], p[200:260], o[200:260])),
    ]


def _apply(kb, oracle, op, payload):
    if op == "insert":
        kb.insert(payload, auto_compact=False)
        oracle.insert(payload)
    elif op == "delete":
        kb.delete(payload, auto_compact=False)
        oracle.delete(payload)
    else:
        kb.compact()
        oracle.compact()


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["single", "sharded"])
def test_pinned_snapshot_immutable_and_fresh_pins_track_oracle(raw, sharded):
    """The core MVCC contract, against the differential oracle per version.

    Every (query, mode) pair is verified at version 0 and at the final
    version; the per-mutation middle steps rotate through the pairs (one
    pinned-stability check + one fresh-pin oracle check each) to keep the
    executable count — the dominant cost on the CPU CI — bounded.
    """
    kb = (ShardedKB.build(raw, n_shards=2) if sharded
          else KnowledgeBase.build(raw))
    oracle = NaiveKB(raw.onto)
    oracle.insert(raw)
    reg = SnapshotRegistry(kb, modes=("litemat", "rewrite"))

    sel = {name: query_vars(q) for name, q in QUERIES.items()}
    pairs = [(name, mode) for name in QUERIES
             for mode in ("litemat", "rewrite")]
    pinned = reg.pin()
    at_v0 = {
        (name, mode): pin_answers_fp(kb, pinned, QUERIES[name], mode=mode,
                                     select=sel[name])
        for name, mode in pairs}
    for key, got in at_v0.items():
        assert got == oracle.answers(QUERIES[key[0]], sel[key[0]]), key

    for step, (op, payload) in enumerate(_mutation_script(raw)):
        _apply(kb, oracle, op, payload)
        name, mode = pairs[step % len(pairs)]
        # the old pin still answers at ITS version — bit-identical
        got = pin_answers_fp(kb, pinned, QUERIES[name], mode=mode,
                             select=sel[name])
        assert got == at_v0[(name, mode)], (op, name, mode, "pin moved")
        # a fresh pin answers at the NEW version — matching the oracle
        name2, mode2 = pairs[(step + 1) % len(pairs)]
        with reg.pin() as fresh:
            assert fresh.version == kb.version
            got = pin_answers_fp(kb, fresh, QUERIES[name2], mode=mode2,
                                 select=sel[name2])
            assert got == oracle.answers(QUERIES[name2], sel[name2]), \
                (op, name2, mode2)

    # final version: every pair against the oracle; old pin still at v0
    with reg.pin() as fresh:
        for name, mode in pairs:
            got = pin_answers_fp(kb, fresh, QUERIES[name], mode=mode,
                                 select=sel[name])
            assert got == oracle.answers(QUERIES[name], sel[name]), \
                (name, mode)
    for name, mode in pairs:
        got = pin_answers_fp(kb, pinned, QUERIES[name], mode=mode,
                             select=sel[name])
        assert got == at_v0[(name, mode)], (name, mode, "pin moved")
    pinned.release()


def test_refcounts_gate_retirement(raw):
    K = KnowledgeBase.build(raw)
    reg = SnapshotRegistry(K, modes=("litemat",))
    pin0 = reg.pin()
    v0 = pin0.version
    s, p, o = np.asarray(raw.s), np.asarray(raw.p), np.asarray(raw.o)
    K.delete((s[:30], p[:30], o[:30]), auto_compact=False)
    with reg.pin() as pin1:
        assert pin1.version == K.version != v0
        # both versions alive: v0 is pinned, v1 is pinned AND published
        assert reg.pinned_versions() == [v0, pin1.version]
    K.compact()
    reg.publish()
    # v0 still pinned -> survives the compaction and the publishes
    assert v0 in reg.live_versions()
    pin0.release()
    assert v0 not in reg.live_versions()  # refcount zero -> retired


def test_contended_write_lock_degrades_to_stale_pin(raw):
    K = KnowledgeBase.build(raw)
    reg = SnapshotRegistry(K, modes=("litemat",), lock_timeout_s=0.01)
    reg.publish()
    v0 = K.version
    in_write = threading.Event()
    release = threading.Event()

    def writer():
        with K.write_lock:
            K.version += 1  # a mutation in progress past the version bump
            in_write.set()
            release.wait(5.0)
            K.version -= 1

    t = threading.Thread(target=writer)
    t.start()
    assert in_write.wait(5.0)
    try:
        with reg.pin() as pin:  # cannot capture the moved version: degrade
            assert pin.stale
            assert pin.version == v0
        assert reg.stats["stale_pins"] == 1
    finally:
        release.set()
        t.join()
    with reg.pin() as pin:  # lock free again: fresh pin, no staleness
        assert not pin.stale


def test_snapshot_store_rows_match_live(raw):
    K = KnowledgeBase.build(raw)
    reg = SnapshotRegistry(K, modes=("litemat",))
    with reg.pin() as pin:
        live = np.asarray(K.store_rows("litemat"))
        assert np.array_equal(np.sort(pin.store_rows("litemat"), axis=0),
                              np.sort(live, axis=0))
