import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def lubm_kb():
    """One shared small LUBM KnowledgeBase for the system-level tests."""
    from repro.core.engine import KnowledgeBase
    from repro.rdf.generator import generate_lubm

    raw = generate_lubm(n_universities=1, seed=7)
    return KnowledgeBase.build(raw), raw


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
