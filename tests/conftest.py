import sys
import types

import numpy as np
import pytest

import jax


def _install_hypothesis_shim():
    """Let property-test modules import cleanly when hypothesis is absent.

    Six test files hard-import ``hypothesis`` at module scope; without this
    shim a missing dependency fails *collection* for the whole suite.  The
    stub mirrors just enough surface (given/settings/strategies) for the
    decorators to evaluate; the decorated tests themselves skip at run time.
    Install the real package (requirements.txt) to run the property tests.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        """Opaque placeholder: tolerates calls/attribute chains."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def given(*a, **k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*a, **k):
        def deco(fn):
            return fn
        return deco

    def _make_strategy(*a, **k):
        return _Strategy()

    def composite(fn):
        return _make_strategy

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.composite = composite
    st.__getattr__ = lambda name: _make_strategy  # integers, lists, data, ...
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = _Strategy()
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()


@pytest.fixture(scope="session")
def lubm_kb():
    """One shared small LUBM KnowledgeBase for the system-level tests."""
    from repro.core.engine import KnowledgeBase
    from repro.rdf.generator import generate_lubm

    raw = generate_lubm(n_universities=1, seed=7)
    return KnowledgeBase.build(raw), raw


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
