"""Dictionary encoding invariants (paper §III.B) + locate/extract."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import dictionary as dct
from repro.core.abox import encode_obe, encode_sae
from repro.core.tbox import build_tbox
from repro.rdf.generator import generate_lubm
from repro.utils import pair64
from repro.utils.hashing import mix64


@given(st.integers(0, 5000), st.integers(1, 400), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_local_dictionary_bijective_dense(seed, n_occ, dup):
    rng = np.random.default_rng(seed)
    distinct = rng.choice(1 << 50, max(1, n_occ // dup), replace=False)
    occ = rng.choice(distinct, n_occ)
    hi, lo = pair64.split_np(occ)
    table = dct.build_local_dictionary(
        jnp.asarray(hi), jnp.asarray(lo), jnp.ones(occ.shape, bool), base=100
    )
    ids, hit = table.locate(jnp.asarray(hi), jnp.asarray(lo))
    ids = np.asarray(ids)
    assert np.asarray(hit).all()
    # same fp -> same id, distinct -> distinct, dense from base
    m = {}
    for f, i in zip(occ.tolist(), ids.tolist()):
        assert m.setdefault(f, i) == i
    vals = sorted(set(m.values()))
    assert vals == list(range(100, 100 + len(m)))
    # extract inverts locate
    ehi, elo, ehit = table.extract_fp(jnp.asarray(ids))
    assert np.asarray(ehit).all()
    back = pair64.combine_np(np.asarray(ehi), np.asarray(elo))
    np.testing.assert_array_equal(back, occ)


def test_obe_vs_sae_consistency():
    """Both encodings are valid bijections; OBE embeds TBox semantics."""
    raw = generate_lubm(1, seed=3)
    tbox = build_tbox(raw.onto)
    obe = encode_obe(raw, tbox)
    sae = encode_sae(raw)
    assert obe.n == sae.n == raw.n_triples
    # every original duplicate triple stays a duplicate (encoding is a
    # per-term function) and the number of distinct triples matches
    o_rows = {tuple(r) for r in np.asarray(obe.spo).tolist()}
    s_rows = {tuple(r) for r in np.asarray(sae.spo).tolist()}
    assert len(o_rows) == len(s_rows)
    # OBE type-triple objects are concept ids (< instance base)
    spo = np.asarray(obe.spo)
    tmask = spo[:, 1] == tbox.rdf_type_id
    assert (spo[tmask, 2] < tbox.instance_base).all()
    assert (spo[~tmask, 1] < tbox.instance_base).all()


def test_locate_extract_strings():
    raw = generate_lubm(1, seed=5, keep_strings=True)
    tbox = build_tbox(raw.onto)
    kb = encode_obe(raw, tbox)
    ids = kb.locate(["Professor", "memberOf", "rdf:type"])
    assert ids[0] == tbox.concept_id("Professor")
    assert ids[1] == tbox.property_id("memberOf")
    assert ids[2] == tbox.rdf_type_id
    out = kb.extract([int(i) for i in ids])
    assert out[0] == "ub:Professor" and out[1] == "ub:memberOf"
    # unknown term
    assert kb.locate(["no-such-term"])[0] == -1


def test_dynamic_dictionary_register_splices_external_ids():
    """`register` adopts ids assigned elsewhere (the sharded encode path):
    the host mirror must resolve them, advance `next_id` past them, and
    hand them to the device as a pending absorb chunk — exactly like
    `encode` does for ids it allocates itself."""
    from repro.core.engine import KnowledgeBase
    from repro.core.update import DynamicDictionary

    raw = generate_lubm(1, seed=5)
    K = KnowledgeBase.build(raw)
    dyn = DynamicDictionary.from_kb(K.kb)
    base = dyn.next_id
    rng = np.random.default_rng(0)
    fps = rng.choice(1 << 50, 17, replace=False)
    known = dyn.lookup(fps)
    assert (known == -1).all()  # fresh fingerprints
    # sharded encode ranks ids by owner-shard order, not fp order: feed a
    # shuffled id assignment and expect lookup to still resolve each fp
    ids = base + rng.permutation(len(fps)).astype(np.int32)
    n_new = dyn.register(fps, ids)
    assert n_new == len(fps)
    np.testing.assert_array_equal(dyn.lookup(fps), ids)
    assert dyn.next_id == base + len(fps)
    assert dyn.n_new_terms == len(fps)
    # the pending chunk carries the same mapping for device absorption
    chunk = dyn.take_new_terms()
    assert chunk is not None
    got = {int(f): int(i) for f, i in zip(*chunk)}
    assert got == {int(f): int(i) for f, i in zip(fps, ids)}
    # registering nothing is a no-op
    assert dyn.register(np.empty(0, np.int64), np.empty(0, np.int32)) == 0
